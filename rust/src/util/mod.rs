//! Substrate utilities hand-rolled for the offline environment (no serde,
//! rand, clap, or criterion in the vendored registry — see DESIGN.md).

pub mod cli;
pub mod jobs;
pub mod json;
pub mod linalg;
pub mod logging;
pub mod rng;
pub mod stats;
