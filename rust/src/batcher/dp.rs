//! Serving-time-oriented batching — the paper's Algorithm 1.
//!
//! Sort requests ascending by input length; dynamic programming over
//! prefixes with state
//!
//!   T[i] = min_{0<j≤i} ( T[j−1] + T_serve(i−j+1, L_i, S) )        (10)
//!
//! where L_i is the i-th (sorted) request's input length — the batch input
//! length of any batch ending at i — and the inner loop is bounded by the
//! memory rule's maximal feasible batch at (L_i, S) (Eq. 8; feasibility is
//! monotone in batch size), making the DP O(n·N_max). By minimizing total
//! estimated serving time the DP trades padding waste against batch-size
//! gains (Fig. 11).

use crate::core::{Batch, Request};
use crate::estimator::serving_time::ServeEstimate;
use crate::estimator::MemoryEstimator;

/// Knobs for Algorithm 1.
#[derive(Debug, Clone)]
pub struct DpBatcherConfig {
    /// Slice length S (the iteration limit per schedule).
    pub slice_len: u32,
    /// Optional hard cap on batch size (the PM ablation limits this to the
    /// engine's fixed SLS batch size; full AB/SCLS leaves it None).
    pub max_batch_size: Option<u32>,
}

/// Partition `requests` into batches minimizing total estimated serving
/// time. Returns batches with `est_serve_time` filled in.
///
/// Requests are consumed. Batches preserve the sorted order (each batch is
/// a contiguous run of the sorted request list).
pub fn dp_batch(
    mut requests: Vec<Request>,
    est: &dyn ServeEstimate,
    mem: &MemoryEstimator,
    cfg: &DpBatcherConfig,
) -> Vec<Batch> {
    if requests.is_empty() {
        return Vec::new();
    }
    let s = cfg.slice_len;
    // Line 1: sort ascending by current input length (stable: equal-length
    // requests keep arrival order — FCFS among ties).
    requests.sort_by_key(|r| r.input_len);
    let n = requests.len();

    // T[i]: minimal total serving time of the first i requests; P[i]: split.
    let mut t = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1];

    for i in 1..=n {
        let l_i = requests[i - 1].input_len;
        // Feasibility is monotone in batch size (Eq. 8), so the inner-loop
        // bound is known up front: the memory rule's max batch at (L_i, S)
        // intersected with the PM cap — one rule query per i instead of one
        // per (i, j) step.
        let mut n_max = mem.max_batch(l_i, s).max(1);
        if let Some(cap) = cfg.max_batch_size {
            n_max = n_max.min(cap.max(1));
        }
        // At fixed (L_i, S) both fitted estimators are affine in N, so the
        // candidate cost is one fma per step instead of a full surface
        // evaluation (falls back to serve_est if the clamp could fire).
        let affine = est.serve_affine(l_i, s);

        // Lines 6–8: request i alone as a batch.
        p[i] = i - 1;
        t[i] = t[i - 1] + est.serve_est(1, l_i, s);
        // Lines 9–15: grow the batch backwards while memory allows.
        let mut j = i - 1;
        while j > 0 {
            let size = (i - j + 1) as u32;
            if size > n_max {
                break;
            }
            let serve = match affine {
                Some((a, b)) => a * size as f64 + b,
                None => est.serve_est(size, l_i, s),
            };
            let cand = t[j - 1] + serve;
            if cand < t[i] {
                t[i] = cand;
                p[i] = j - 1;
            }
            j -= 1;
        }
    }

    // Lines 16–20: walk the split positions backwards.
    let mut cuts = Vec::new();
    let mut i = n;
    while i > 0 {
        let start = p[i];
        cuts.push((start, i));
        i = start;
    }
    cuts.reverse();

    // Materialize batches (preserve sorted order).
    let mut batches = Vec::with_capacity(cuts.len());
    let mut rest = requests;
    for &(start, end) in cuts.iter().rev() {
        let tail = rest.split_off(start);
        debug_assert_eq!(tail.len(), end - start);
        let mut b = Batch::new(tail);
        b.est_serve_time = est.serve_est(b.size() as u32, b.input_len(), s);
        batches.push(b);
    }
    batches.reverse();
    batches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::serving_time::{LinearLatency, ServingTimeEstimator};

    fn est() -> ServingTimeEstimator {
        // HF-like magnitudes so padding costs are visible.
        ServingTimeEstimator {
            prefill: LinearLatency {
                c1: 3.8e-4,
                c2: 1.7e-3,
                c3: 3.5e-4,
                c4: 0.029,
            },
            decode: LinearLatency {
                c1: 1.3e-6,
                c2: 1.8e-3,
                c3: 6.5e-6,
                c4: 0.05,
            },
        }
    }

    fn mem_loose() -> MemoryEstimator {
        MemoryEstimator::analytic(800 * 1024, 48 << 30, 0.9)
    }

    fn reqs(lens: &[u32]) -> Vec<Request> {
        lens.iter()
            .enumerate()
            .map(|(i, &l)| Request::new(i as u64, 0.0, l, 100))
            .collect()
    }

    fn cfg(s: u32) -> DpBatcherConfig {
        DpBatcherConfig {
            slice_len: s,
            max_batch_size: None,
        }
    }

    #[test]
    fn partition_is_complete_and_disjoint() {
        let batches = dp_batch(reqs(&[10, 1024, 30, 500, 10, 80]), &est(), &mem_loose(), &cfg(128));
        let mut ids: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id))
            .collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn paper_fig11_separates_long_straggler() {
        // 15 requests of length 10 + 1 of length 1024 (paper Fig. 11):
        // separate batching beats together-batching, so the DP must split.
        let mut lens = vec![10u32; 15];
        lens.push(1024);
        let batches = dp_batch(reqs(&lens), &est(), &mem_loose(), &cfg(128));
        assert_eq!(batches.len(), 2, "straggler must be isolated");
        let sizes: Vec<usize> = batches.iter().map(|b| b.size()).collect();
        assert!(sizes.contains(&15) && sizes.contains(&1));

        // and the DP total beats the single-batch alternative:
        let dp_total: f64 = batches.iter().map(|b| b.est_serve_time).sum();
        let together = est().serve(16, 1024, 128);
        assert!(dp_total < together, "{dp_total} !< {together}");
    }

    #[test]
    fn homogeneous_requests_batch_together() {
        let batches = dp_batch(reqs(&[64; 20]), &est(), &mem_loose(), &cfg(128));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].size(), 20);
    }

    #[test]
    fn respects_memory_limit() {
        // Tight memory: max 4 requests of (64 + 128) tokens.
        let delta = 1u64 << 20;
        let budget = (4 * (64 + 128)) as u64 * delta;
        let mem = MemoryEstimator::analytic(delta, budget, 1.0);
        let batches = dp_batch(reqs(&[64; 20]), &est(), &mem, &cfg(128));
        assert!(batches.iter().all(|b| b.size() <= 4));
        assert_eq!(batches.iter().map(|b| b.size()).sum::<usize>(), 20);
    }

    #[test]
    fn respects_batch_cap() {
        let batches = dp_batch(
            reqs(&[64; 20]),
            &est(),
            &mem_loose(),
            &DpBatcherConfig {
                slice_len: 128,
                max_batch_size: Some(6),
            },
        );
        assert!(batches.iter().all(|b| b.size() <= 6));
    }

    #[test]
    fn single_request() {
        let batches = dp_batch(reqs(&[100]), &est(), &mem_loose(), &cfg(128));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].size(), 1);
        assert!(batches[0].est_serve_time > 0.0);
    }

    #[test]
    fn empty_input() {
        assert!(dp_batch(vec![], &est(), &mem_loose(), &cfg(128)).is_empty());
    }

    #[test]
    fn est_serve_time_consistent() {
        let e = est();
        let batches = dp_batch(reqs(&[10, 20, 900]), &e, &mem_loose(), &cfg(64));
        for b in &batches {
            let expect = e.serve(b.size() as u32, b.input_len(), 64);
            assert!((b.est_serve_time - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn dp_never_worse_than_naive_splits() {
        // DP total must be <= both all-singletons and one-big-batch
        // (when feasible) — it optimizes over all contiguous partitions.
        let e = est();
        let mem = mem_loose();
        let lens = [5u32, 17, 40, 64, 64, 128, 300, 700];
        let batches = dp_batch(reqs(&lens), &e, &mem, &cfg(128));
        let dp_total: f64 = batches.iter().map(|b| b.est_serve_time).sum();

        let singles: f64 = lens.iter().map(|&l| e.serve(1, l, 128)).sum();
        assert!(dp_total <= singles + 1e-9);

        let max_len = *lens.iter().max().unwrap();
        if !mem.would_oom(lens.len() as u32, max_len, 128) {
            let together = e.serve(lens.len() as u32, max_len, 128);
            assert!(dp_total <= together + 1e-9);
        }
    }
}
