//! In-repo static analysis: the determinism & invariant lint pass.
//!
//! `scls-repro lint` runs this over the crate tree and exits non-zero on
//! any finding, so CI (and a contributor's shell) catches the failure
//! modes that the differential suites can only catch *after* they bite:
//! hash-order nondeterminism, wall-clock reads in measured paths, ad-hoc
//! float comparison, deterministic modules linking real-time surfaces,
//! silent edits to frozen reference implementations, and trait/docs
//! surfaces drifting apart. See the module docs of
//! [`rules`], [`manifest`] and [`surface`] for the rule catalog, and
//! [`lexer`] for the suppression grammar
//! (`// scls-lint: allow(<rule>): <justification>`).
//!
//! Everything here is std-only and works on source *text* — no rustc
//! internals, no build, no network — so the pass runs in under a second
//! and the same logic is trivially mirrored by scripts.

pub mod classify;
pub mod lexer;
pub mod manifest;
pub mod rules;
pub mod sha256;
pub mod surface;

use std::fs;
use std::path::Path;

use crate::util::json::Json;

pub use rules::{
    scan_source, ALL_RULES, RULE_FLOAT_CMP, RULE_FROZEN_MANIFEST, RULE_HASH_ORDER,
    RULE_IMPORT_GRAPH, RULE_SINK_SURFACE, RULE_WALL_CLOCK,
};

/// One diagnostic: `file:line: rule: message`. `line` 0 means the finding
/// concerns the file (or an artifact) as a whole.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Crate-relative path with `/` separators.
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// Run the full lint pass over the crate tree at `root` (the directory
/// holding `src/`). Token rules scan `src/**/*.rs` in sorted path order;
/// then the frozen manifest and the coverage surfaces are checked. The
/// result is deterministic: stable walk order, stable finding order.
pub fn run_lint(root: &Path) -> Result<Vec<Finding>, String> {
    let src_dir = root.join("src");
    if !src_dir.is_dir() {
        return Err(format!("{}: no src/ directory — not a crate root", root.display()));
    }
    let mut files = Vec::new();
    collect_rs_files(&src_dir, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for rel in &files {
        let text = fs::read_to_string(root.join(rel)).map_err(|e| format!("{rel}: {e}"))?;
        findings.extend(scan_source(rel, &text));
    }
    findings.extend(manifest::check(root));
    findings.extend(surface::check(root));
    Ok(findings)
}

/// Collect `.rs` files under `dir` as crate-relative `/`-separated paths
/// (`src/...`). Recurses in sorted order for reproducible output.
fn collect_rs_files(dir: &Path, out: &mut Vec<String>) -> Result<(), String> {
    let mut entries: Vec<_> = fs::read_dir(dir)
        .map_err(|e| format!("{}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            let mut rel = Vec::new();
            for comp in path.components().rev() {
                let s = comp.as_os_str().to_string_lossy().into_owned();
                let is_src = s == "src";
                rel.push(s);
                if is_src {
                    break;
                }
            }
            rel.reverse();
            out.push(rel.join("/"));
        }
    }
    Ok(())
}

/// Render findings as the `--json` payload: rule catalog, counts, and the
/// diagnostics themselves.
pub fn findings_to_json(findings: &[Finding]) -> Json {
    let mut by_rule = Json::obj();
    for rule in ALL_RULES {
        let n = findings.iter().filter(|f| f.rule == rule).count();
        by_rule.set(rule, n);
    }
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            let mut o = Json::obj();
            o.set("file", f.file.as_str())
                .set("line", f.line)
                .set("rule", f.rule)
                .set("message", f.message.as_str());
            o
        })
        .collect();
    let mut out = Json::obj();
    out.set("total", findings.len())
        .set("by_rule", by_rule)
        .set("findings", Json::Arr(items));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_format_is_file_line_rule_message() {
        let f = Finding {
            file: "src/sim/x.rs".to_string(),
            line: 7,
            rule: RULE_HASH_ORDER,
            message: "m".to_string(),
        };
        assert_eq!(f.to_string(), "src/sim/x.rs:7: hash-order: m");
    }

    #[test]
    fn json_payload_shape() {
        let f = vec![Finding {
            file: "src/sim/x.rs".to_string(),
            line: 7,
            rule: RULE_HASH_ORDER,
            message: "m".to_string(),
        }];
        let j = findings_to_json(&f);
        assert_eq!(j.at(&["total"]).and_then(Json::as_i64), Some(1));
        assert_eq!(j.at(&["by_rule", "hash-order"]).and_then(Json::as_i64), Some(1));
        assert_eq!(j.at(&["by_rule", "wall-clock"]).and_then(Json::as_i64), Some(0));
        match j.at(&["findings"]) {
            Some(Json::Arr(items)) => {
                assert_eq!(items.len(), 1);
                assert_eq!(
                    items[0].at(&["file"]),
                    Some(&Json::Str("src/sim/x.rs".to_string()))
                );
            }
            other => panic!("findings not an array: {other:?}"),
        }
    }

    #[test]
    fn run_lint_flags_a_seeded_violation_tree() {
        let dir = std::env::temp_dir().join(format!("scls_lint_run_{}", std::process::id()));
        let src = dir.join("src/sim");
        std::fs::create_dir_all(&src).unwrap();
        std::fs::write(src.join("bad.rs"), "use std::collections::HashMap;\n").unwrap();
        let findings = run_lint(&dir).unwrap();
        assert!(
            findings
                .iter()
                .any(|f| f.rule == RULE_HASH_ORDER && f.file == "src/sim/bad.rs" && f.line == 1),
            "{findings:?}"
        );
        // The bare tree also lacks manifest + surfaces; those flag too.
        assert!(findings.iter().any(|f| f.rule == RULE_FROZEN_MANIFEST));
        assert!(findings.iter().any(|f| f.rule == RULE_SINK_SURFACE));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_lint_errors_without_src() {
        let dir = std::env::temp_dir().join(format!("scls_lint_nosrc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(run_lint(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
