//! Minimal `log` facade backend (env_logger is not in the offline registry).
//!
//! `SCLS_LOG=trace|debug|info|warn|error|off` controls the level (default
//! `info`). Any other non-empty value falls back to `info` and a one-line
//! warning is printed so typos (`SCLS_LOG=dbug`) don't silently change the
//! level. Messages go to stderr with elapsed wall-time prefixes.

use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, m: &log::Metadata) -> bool {
        m.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent). Call once at binary startup.
pub fn init() {
    let var = std::env::var("SCLS_LOG");
    let level = match var.as_deref() {
        Ok("trace") => log::LevelFilter::Trace,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("info") => log::LevelFilter::Info,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        Ok("off") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger {
        start: Instant::now(),
        level,
    });
    let _ = log::set_logger(logger);
    log::set_max_level(level);
    if let Ok(v) = var.as_deref() {
        if !v.is_empty() && !matches!(v, "trace" | "debug" | "info" | "warn" | "error" | "off") {
            log::warn!("unrecognized SCLS_LOG value {v:?}; defaulting to info");
        }
    }
}
