//! Run metrics: everything the paper's figures report (§5.1 Metrics plus
//! the dive-in counters of Figs. 13/14/16/19/20).

use crate::util::json::Json;
use crate::util::stats;

/// Per-request record at completion.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    pub id: u64,
    pub arrival: f64,
    pub finished: f64,
    pub generated: u32,
    /// Schedule count == slice count (Fig. 14a / 20a).
    pub slices: u32,
    pub pad_tokens: u64,
    pub invalid_tokens: u64,
}

/// Per-batch-serving record.
#[derive(Debug, Clone)]
pub struct BatchRecord {
    pub start: f64,
    pub worker: usize,
    pub size: u32,
    pub input_len: u32,
    pub pad_tokens: u64,
    pub est_serve_time: f64,
    pub actual_serve_time: f64,
    pub early_return: bool,
}

/// Raw event log of one experiment run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub completed: Vec<CompletedRequest>,
    pub batches: Vec<BatchRecord>,
    /// Per-worker completion time: when each instance finished its last
    /// batch (CT in Figs. 5e/17/21).
    pub worker_completion: Vec<f64>,
    /// Wall/virtual time when the last request completed.
    pub makespan: f64,
    /// Total requests injected (completed + any stragglers).
    pub total_requests: usize,
}

/// Headline summary of a run.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Requests per second (completed / makespan).
    pub throughput: f64,
    pub avg_response_time: f64,
    pub p95_response_time: f64,
    /// Standard deviation of worker completion times (load-balance metric).
    pub ct_std: f64,
    pub avg_batch_size: f64,
    /// Mean invalid tokens per completed request (Fig. 13a).
    pub avg_invalid_tokens: f64,
    /// Mean pad tokens per completed request, summed over reschedules
    /// (Fig. 13c).
    pub avg_pad_tokens: f64,
    /// Fraction of batch servings that early-returned (Fig. 14b).
    pub early_return_ratio: f64,
    /// Distribution of per-request slice counts: counts for 1, 2, 3, ≥4
    /// (Fig. 14a).
    pub slice_histogram: [u64; 4],
    pub completed: usize,
}

impl RunMetrics {
    pub fn record_completion(&mut self, req: &crate::core::Request, now: f64) {
        self.completed.push(CompletedRequest {
            id: req.id,
            arrival: req.arrival,
            finished: now,
            generated: req.generated,
            slices: req.slices,
            pad_tokens: req.pad_tokens,
            invalid_tokens: req.invalid_tokens,
        });
        self.makespan = self.makespan.max(now);
    }

    pub fn summarize(&self) -> Summary {
        let rts: Vec<f64> = self
            .completed
            .iter()
            .map(|c| c.finished - c.arrival)
            .collect();
        let mut slice_histogram = [0u64; 4];
        for c in &self.completed {
            let idx = (c.slices.max(1) as usize - 1).min(3);
            slice_histogram[idx] += 1;
        }
        let early = self.batches.iter().filter(|b| b.early_return).count();
        let n_batches = self.batches.len().max(1);
        Summary {
            throughput: if self.makespan > 0.0 {
                self.completed.len() as f64 / self.makespan
            } else {
                0.0
            },
            avg_response_time: stats::mean(&rts),
            p95_response_time: stats::percentile(&rts, 95.0),
            ct_std: stats::std_dev(&self.worker_completion),
            avg_batch_size: stats::mean(
                &self.batches.iter().map(|b| b.size as f64).collect::<Vec<_>>(),
            ),
            avg_invalid_tokens: stats::mean(
                &self
                    .completed
                    .iter()
                    .map(|c| c.invalid_tokens as f64)
                    .collect::<Vec<_>>(),
            ),
            avg_pad_tokens: stats::mean(
                &self
                    .completed
                    .iter()
                    .map(|c| c.pad_tokens as f64)
                    .collect::<Vec<_>>(),
            ),
            early_return_ratio: early as f64 / n_batches as f64,
            slice_histogram,
            completed: self.completed.len(),
        }
    }
}

impl Summary {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("throughput", self.throughput)
            .set("avg_response_time", self.avg_response_time)
            .set("p95_response_time", self.p95_response_time)
            .set("ct_std", self.ct_std)
            .set("avg_batch_size", self.avg_batch_size)
            .set("avg_invalid_tokens", self.avg_invalid_tokens)
            .set("avg_pad_tokens", self.avg_pad_tokens)
            .set("early_return_ratio", self.early_return_ratio)
            .set(
                "slice_histogram",
                Json::Arr(self.slice_histogram.iter().map(|&x| Json::from(x)).collect()),
            )
            .set("completed", self.completed);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Request;

    #[test]
    fn summary_basic() {
        let mut m = RunMetrics::default();
        let mut r1 = Request::new(1, 0.0, 10, 5);
        r1.slices = 1;
        r1.invalid_tokens = 3;
        r1.pad_tokens = 7;
        m.record_completion(&r1, 2.0);
        let mut r2 = Request::new(2, 1.0, 10, 5);
        r2.slices = 4;
        m.record_completion(&r2, 5.0);
        m.worker_completion = vec![4.0, 6.0];
        m.batches.push(BatchRecord {
            start: 0.0,
            worker: 0,
            size: 2,
            input_len: 10,
            pad_tokens: 0,
            est_serve_time: 1.0,
            actual_serve_time: 1.1,
            early_return: true,
        });
        m.batches.push(BatchRecord {
            start: 1.0,
            worker: 1,
            size: 4,
            input_len: 12,
            pad_tokens: 5,
            est_serve_time: 2.0,
            actual_serve_time: 2.2,
            early_return: false,
        });

        let s = m.summarize();
        assert_eq!(s.completed, 2);
        assert!((s.throughput - 2.0 / 5.0).abs() < 1e-12);
        assert!((s.avg_response_time - 3.0).abs() < 1e-12); // (2 + 4) / 2
        assert!((s.ct_std - 1.0).abs() < 1e-12);
        assert!((s.avg_batch_size - 3.0).abs() < 1e-12);
        assert!((s.early_return_ratio - 0.5).abs() < 1e-12);
        assert_eq!(s.slice_histogram, [1, 0, 0, 1]);
        assert!((s.avg_invalid_tokens - 1.5).abs() < 1e-12);
        assert!((s.avg_pad_tokens - 3.5).abs() < 1e-12);
    }

    #[test]
    fn empty_run_summary_is_zeroes() {
        let s = RunMetrics::default().summarize();
        assert_eq!(s.completed, 0);
        assert_eq!(s.throughput, 0.0);
        assert_eq!(s.avg_response_time, 0.0);
    }

    #[test]
    fn summary_json_roundtrips() {
        let mut m = RunMetrics::default();
        m.record_completion(&Request::new(1, 0.0, 10, 5), 1.0);
        let j = m.summarize().to_json();
        let s = j.to_string_pretty();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("completed").unwrap().as_i64(), Some(1));
    }
}
