//! Real engine: serve static batches by executing the AOT tiny-GPT
//! artifacts through PJRT (the full three-layer path: Rust → HLO → Pallas).
//!
//! Semantics mirror `SimEngine` exactly — padding, slice iteration limit,
//! EOS, invalid tokens, early return — except that EOS is *discovered* from
//! the model's actual output stream instead of the trace oracle, and the
//! duration is measured wall clock.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::core::{Batch, BatchOutcome, RequestOutcome};
use crate::runtime::{Bucket, ModelRuntime}; // scls-lint: allow(import-graph): the real-engine seam is wall-clock by design

/// Per-request result of a real slice, with the concrete tokens.
#[derive(Debug, Clone)]
pub struct RealSliceResult {
    pub outcome: BatchOutcome,
    /// Valid new tokens per request (up to and including EOS when present).
    pub new_tokens: Vec<Vec<i32>>,
}

pub struct RealEngine {
    pub runtime: ModelRuntime,
    pub slice_len: u32,
    pub max_gen_len: u32,
}

impl RealEngine {
    pub fn new(artifacts_dir: &Path, slice_len: u32, max_gen_len: u32) -> Result<RealEngine> {
        let runtime = ModelRuntime::new(artifacts_dir)?;
        if !runtime.manifest.slice_lens().contains(&slice_len) {
            return Err(anyhow!(
                "no artifacts for slice length {slice_len}; available: {:?} \
                 (re-run aot.py with --slice-lens)",
                runtime.manifest.slice_lens()
            ));
        }
        Ok(RealEngine {
            runtime,
            slice_len,
            max_gen_len,
        })
    }

    /// Compile all buckets for this slice length up front.
    pub fn warmup(&mut self) -> Result<()> {
        self.runtime.warmup()
    }

    /// Serve one slice for a batch of requests carrying concrete tokens.
    pub fn serve_slice(&mut self, batch: &Batch) -> Result<RealSliceResult> {
        let n = batch.size() as u32;
        anyhow::ensure!(n > 0, "empty batch");
        let l_i = batch.input_len();
        let s = self.slice_len;
        let bucket: Bucket = self
            .runtime
            .manifest
            .pick(n, l_i, s)
            .ok_or_else(|| anyhow!("no bucket for n={n} l={l_i} s={s}"))?
            .clone();

        // Build the left-padded (bucket.n × bucket.l) input.
        let (bn, bl) = (bucket.n as usize, bucket.l as usize);
        let pad = self.runtime.manifest.model.pad_id;
        let bos = self.runtime.manifest.model.bos_id;
        let eos = self.runtime.manifest.model.eos_id;
        let mut tokens = vec![pad; bn * bl];
        let mut lengths = vec![1i32; bn];
        let mut active = vec![0i32; bn];
        let mut gen_offset = vec![0i32; bn];
        for (i, r) in batch.requests.iter().enumerate() {
            let toks = &r.tokens;
            anyhow::ensure!(
                !toks.is_empty() && toks.len() <= bl,
                "request {} tokens ({}) exceed bucket l={bl}",
                r.id,
                toks.len()
            );
            let start = bl - toks.len();
            tokens[i * bl + start..(i + 1) * bl].copy_from_slice(toks);
            lengths[i] = toks.len() as i32;
            active[i] = 1;
            gen_offset[i] = r.generated as i32;
        }
        // Filler rows: single BOS token, inactive.
        for i in batch.size()..bn {
            tokens[(i + 1) * bl - 1] = bos;
        }

        let res = self
            .runtime
            .execute_slice(&bucket, &tokens, &lengths, &active, &gen_offset)?;
        let iters = res.iters;

        let mut per_request = Vec::with_capacity(batch.size());
        let mut new_tokens = Vec::with_capacity(batch.size());
        for (i, r) in batch.requests.iter().enumerate() {
            let row = &res.gen[i][..iters as usize];
            // Valid tokens end at (and include) the first EOS.
            let eos_pos = row.iter().position(|&t| t == eos);
            let mut valid = eos_pos.map(|p| p as u32 + 1).unwrap_or(iters);
            // Maximal-generation-length cap (paper §5.1 Settings).
            let cap_left = self.max_gen_len.saturating_sub(r.generated);
            let capped = valid >= cap_left;
            valid = valid.min(cap_left).max(0);
            let finished = eos_pos.map(|p| (p as u32) < valid.max(1)).unwrap_or(false)
                && !row.is_empty()
                || capped;
            per_request.push(RequestOutcome {
                id: r.id,
                new_tokens: valid,
                invalid_tokens: iters - valid,
                finished,
            });
            new_tokens.push(row[..valid as usize].to_vec());
        }

        Ok(RealSliceResult {
            outcome: BatchOutcome {
                duration: res.wall,
                iters,
                early_return: iters < s,
                per_request,
            },
            new_tokens,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Request;
    use std::path::PathBuf;

    fn art_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        art_dir().join("manifest.json").exists()
    }

    fn engine() -> RealEngine {
        RealEngine::new(&art_dir(), 16, 64).unwrap()
    }

    fn req(id: u64, toks: Vec<i32>) -> Request {
        Request::with_tokens(id, 0.0, toks)
    }

    #[test]
    fn serves_single_request() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut e = engine();
        let b = Batch::new(vec![req(1, vec![7, 8, 9, 10, 11])]);
        let r = e.serve_slice(&b).unwrap();
        assert_eq!(r.outcome.per_request.len(), 1);
        let o = &r.outcome.per_request[0];
        assert!(o.new_tokens >= 1);
        assert_eq!(o.new_tokens + o.invalid_tokens, r.outcome.iters);
        assert_eq!(r.new_tokens[0].len(), o.new_tokens as usize);
        assert!(r.outcome.duration > 0.0);
    }

    #[test]
    fn mixed_lengths_batch() {
        if !have_artifacts() {
            return;
        }
        let mut e = engine();
        let b = Batch::new(vec![
            req(1, vec![5; 3]),
            req(2, (3..40).collect()),
            req(3, vec![100, 101]),
        ]);
        let r = e.serve_slice(&b).unwrap();
        assert_eq!(r.outcome.per_request.len(), 3);
        for (o, toks) in r.outcome.per_request.iter().zip(&r.new_tokens) {
            assert_eq!(o.new_tokens as usize, toks.len());
        }
    }

    #[test]
    fn finished_requests_end_with_eos_or_cap() {
        if !have_artifacts() {
            return;
        }
        let mut e = engine();
        // Serve the same request repeatedly (the reschedule path) until done.
        let mut r = req(1, vec![42, 43, 44, 45]);
        let eos = e.runtime.manifest.model.eos_id;
        for _ in 0..8 {
            let b = Batch::new(vec![r.clone()]);
            let out = e.serve_slice(&b).unwrap();
            let o = &out.outcome.per_request[0];
            r.generated += o.new_tokens;
            r.tokens.extend_from_slice(&out.new_tokens[0]);
            r.input_len = r.tokens.len() as u32;
            if o.finished {
                let last = *r.tokens.last().unwrap();
                assert!(
                    last == eos || r.generated >= 64,
                    "finished without EOS or cap: last={last} gen={}",
                    r.generated
                );
                return;
            }
        }
        panic!("request never finished in 8 slices (cap is 64 = 4 slices)");
    }

    #[test]
    fn rejects_oversized_input() {
        if !have_artifacts() {
            return;
        }
        let mut e = engine();
        let b = Batch::new(vec![req(1, vec![5; 1000])]);
        assert!(e.serve_slice(&b).is_err());
    }
}
