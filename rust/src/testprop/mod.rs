//! `testprop` — a small property-based testing framework (proptest is not in
//! the offline registry; see DESIGN.md).
//!
//! Provides seeded random case generation, a configurable case count, and
//! greedy input shrinking on failure. Used by the coordinator-invariant
//! property tests (batcher OOM-safety and partition completeness, offloader
//! max-min optimality, DES determinism, estimator monotonicity).
//!
//! ```ignore
//! use scls::testprop::*;
//! check("sum is commutative", 256, |g| {
//!     let a = g.u32(0, 1000);
//!     let b = g.u32(0, 1000);
//!     prop_assert!(a + b == b + a, "a={a} b={b}");
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Per-case random value source. Records draws so failures can be replayed.
pub struct Gen {
    rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            case_seed: seed,
        }
    }

    pub fn u32(&mut self, lo: u32, hi: u32) -> u32 {
        self.rng.range_u32(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_u32(lo as u32, hi as u32) as usize
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector with random length in [min_len, max_len].
    pub fn vec<T>(&mut self, min_len: usize, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize(min_len, max_len);
        (0..n).map(|_| f(self)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len() - 1)]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// A failed property with a counterexample description.
#[derive(Debug)]
pub struct PropFail {
    pub msg: String,
}

pub type PropResult = Result<(), PropFail>;

/// Assert inside a property; formats into a `PropFail` on violation.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err($crate::testprop::PropFail { msg: format!($($fmt)*) });
        }
    };
}

/// Assert equality with debug formatting.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)*) => {{
        let (va, vb) = (&$a, &$b);
        if va != vb {
            return Err($crate::testprop::PropFail {
                msg: format!("{:?} != {:?}: {}", va, vb, format!($($fmt)*)),
            });
        }
    }};
}

/// Run `cases` random cases of `prop`. Panics with the first failing seed and
/// message. Base seed is stable per property name so CI is deterministic, but
/// `SCLS_PROP_SEED` can override for exploration, and `SCLS_PROP_CASES`
/// scales the case count.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    let base = match std::env::var("SCLS_PROP_SEED") {
        Ok(s) => s.parse::<u64>().unwrap_or(0),
        Err(_) => fnv1a(name.as_bytes()),
    };
    let cases = match std::env::var("SCLS_PROP_CASES") {
        Ok(s) => s.parse::<u64>().unwrap_or(cases),
        Err(_) => cases,
    };
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed);
        if let Err(fail) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {i} (seed {seed}):\n  {}\n\
                 replay with SCLS_PROP_SEED={seed} SCLS_PROP_CASES=1",
                fail.msg
            );
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 64, |g| {
            let a = g.u32(0, 1 << 20) as u64;
            let b = g.u32(0, 1 << 20) as u64;
            prop_assert!(a + b == b + a, "a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 8, |g| {
            let x = g.u32(0, 10);
            prop_assert!(x > 100, "x={x} not > 100");
            Ok(())
        });
    }

    #[test]
    fn gen_vec_bounds() {
        check("vec-bounds", 64, |g| {
            let v = g.vec(2, 7, |g| g.u32(0, 9));
            prop_assert!((2..=7).contains(&v.len()), "len={}", v.len());
            prop_assert!(v.iter().all(|&x| x <= 9), "out of range");
            Ok(())
        });
    }

    #[test]
    fn deterministic_per_name() {
        // Two runs of the same property observe identical draw sequences.
        use std::sync::Mutex;
        let log1 = Mutex::new(Vec::new());
        check("det", 16, |g| {
            log1.lock().unwrap().push(g.u64());
            Ok(())
        });
        let log2 = Mutex::new(Vec::new());
        check("det", 16, |g| {
            log2.lock().unwrap().push(g.u64());
            Ok(())
        });
        assert_eq!(*log1.lock().unwrap(), *log2.lock().unwrap());
    }
}
