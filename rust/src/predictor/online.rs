//! Online quantile-bucket predictor: the continuous-refit direction of
//! proxy-model serving (Qiu et al., arXiv 2404.08509 keep their predictor
//! fresh against the live request mix instead of trusting a one-shot fit).
//!
//! [`OnlineBuckets`] predicts exactly like
//! [`crate::predictor::BucketClassifier`] — quantile bucket upper edges
//! with an accuracy/confusion knob — but its edges are *refit from served
//! traffic*: every completed request's true generation length enters a
//! sliding window (a ring buffer of the most recent `window` completions),
//! and on a deterministic count-based schedule the edges are recut from
//! the window. A workload whose length distribution drifts mid-run
//! (deployments change, a new tenant arrives, prompts get longer) walks
//! the edges to the new distribution within one window, where a static
//! fit would keep predicting the stale quantiles forever — the
//! `figdrift` figure plots exactly that comparison.
//!
//! Determinism: the refit schedule is "every `refit_every` observations",
//! a pure function of the completion count; completions arrive in DES
//! event order, which is itself a deterministic function of the run seed.
//! No wall clock, no sampling — identical seeds give identical refit
//! points, edges, and predictions.

use crate::core::Request;
use crate::workload::distributions::LengthDistribution;

use super::{bucket_predict, quantile_edges, BucketClassifier, LengthPredictor};

/// A quantile-bucket classifier that refits its edges online from
/// completed-request true lengths (see module docs).
#[derive(Debug, Clone)]
pub struct OnlineBuckets {
    /// Current bucket upper edges (strictly ascending). Empty until the
    /// first refit when constructed cold.
    edges: Vec<u32>,
    buckets: u32,
    accuracy: f64,
    seed: u64,
    /// Prediction before any edges exist (cold start): the conservative
    /// worst case the caller chooses, typically `max_gen_len` — identical
    /// to scheduling without a predictor.
    fallback: u32,
    /// Ring buffer of the most recent true lengths, `head` is the next
    /// write position once the buffer is full.
    window: Vec<u32>,
    cap: usize,
    head: usize,
    /// Observations since the last refit; refitting every `refit_every`
    /// keeps the schedule deterministic and the amortized cost at
    /// O(log window) comparisons per completion.
    since_refit: u64,
    refit_every: u64,
    observed: u64,
    refits: u64,
    /// Reusable sort buffer for refits.
    scratch: Vec<u32>,
}

impl OnlineBuckets {
    /// Default sliding-window size (completions retained for refitting).
    pub const DEFAULT_WINDOW: usize = 4096;

    /// Refit cadence for a window of `cap`: often enough to track drift
    /// within a fraction of the window, rarely enough that the O(w log w)
    /// recut amortizes to a few comparisons per completion.
    fn cadence(cap: usize) -> u64 {
        ((cap / 8) as u64).clamp(32, 1024)
    }

    /// Cold start: no edges yet — every prediction is `fallback` (pass the
    /// generation cap for worst-case reservations) until the first refit.
    pub fn cold(
        buckets: u32,
        accuracy: f64,
        window: usize,
        seed: u64,
        fallback: u32,
    ) -> OnlineBuckets {
        assert!(buckets >= 1, "need at least one bucket");
        assert!(
            (0.0..=1.0).contains(&accuracy),
            "accuracy must be in [0, 1]"
        );
        let cap = window.max(1);
        OnlineBuckets {
            edges: Vec::new(),
            buckets,
            accuracy,
            seed,
            fallback: fallback.max(1),
            window: Vec::with_capacity(cap),
            cap,
            head: 0,
            since_refit: 0,
            refit_every: Self::cadence(cap),
            observed: 0,
            refits: 0,
            scratch: Vec::new(),
        }
    }

    /// Start from a prior fit (what the registry builds: the deployment
    /// calibrates against its assumed traffic, then refits as the real
    /// traffic comes in). `buckets` is the count future refits cut — kept
    /// explicit rather than derived from the prior, whose own count may
    /// have collapsed under edge deduplication (a degenerate prior must
    /// not pin every future refit to one bucket after traffic widens).
    pub fn with_prior(
        prior: &BucketClassifier,
        buckets: u32,
        accuracy: f64,
        window: usize,
        seed: u64,
        fallback: u32,
    ) -> OnlineBuckets {
        let mut p = OnlineBuckets::cold(buckets, accuracy, window, seed, fallback);
        p.edges = prior.edges().to_vec();
        p
    }

    /// [`Self::with_prior`] against a workload's analytic length
    /// distribution, mirroring
    /// [`BucketClassifier::fit_distribution`].
    pub fn with_prior_distribution(
        dist: &LengthDistribution,
        buckets: u32,
        accuracy: f64,
        window: usize,
        seed: u64,
        fallback: u32,
    ) -> OnlineBuckets {
        let prior = BucketClassifier::fit_distribution(dist, buckets, accuracy, seed);
        OnlineBuckets::with_prior(&prior, buckets, accuracy, window, seed, fallback)
    }

    /// Current bucket upper edges (empty before the first refit of a cold
    /// start).
    pub fn edges(&self) -> &[u32] {
        &self.edges
    }

    /// Completions observed so far.
    pub fn observed(&self) -> u64 {
        self.observed
    }

    /// Refits performed so far.
    pub fn refits(&self) -> u64 {
        self.refits
    }

    /// Recut the edges from the current window contents.
    fn refit(&mut self) {
        self.scratch.clear();
        self.scratch.extend_from_slice(&self.window);
        self.edges = quantile_edges(&mut self.scratch, self.buckets);
        self.since_refit = 0;
        self.refits += 1;
    }
}

impl LengthPredictor for OnlineBuckets {
    fn predict(&self, req: &Request) -> u32 {
        if self.edges.is_empty() {
            return self.fallback;
        }
        bucket_predict(&self.edges, self.accuracy, self.seed, req)
    }

    fn observe(&mut self, _req: &Request, true_len: u32) -> bool {
        let t = true_len.max(1);
        if self.window.len() < self.cap {
            self.window.push(t);
        } else {
            self.window[self.head] = t;
            self.head = (self.head + 1) % self.cap;
        }
        self.observed += 1;
        self.since_refit += 1;
        if self.since_refit >= self.refit_every {
            self.refit();
            true
        } else {
            false
        }
    }

    fn name(&self) -> &'static str {
        "online"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::distributions::WorkloadKind;

    fn req(id: u64, gen: u32) -> Request {
        Request::new(id, 0.0, 64, gen)
    }

    #[test]
    fn cold_start_predicts_fallback_until_first_refit() {
        let mut p = OnlineBuckets::cold(4, 1.0, 256, 7, 1024);
        assert_eq!(p.predict(&req(1, 50)), 1024);
        let cadence = OnlineBuckets::cadence(256);
        let mut refitted = false;
        for id in 0..cadence {
            refitted |= p.observe(&req(id, 100), 100);
        }
        assert!(refitted, "cadence-many observations must trigger a refit");
        assert_eq!(p.refits(), 1);
        assert_eq!(p.edges(), &[100], "uniform window collapses to one edge");
        assert_eq!(p.predict(&req(99, 50)), 100);
    }

    #[test]
    fn prior_start_predicts_like_the_static_fit() {
        let dist = WorkloadKind::CodeFuse.gen_dist(1024);
        let prior = BucketClassifier::fit_distribution(&dist, 8, 0.85, 3);
        let online = OnlineBuckets::with_prior_distribution(&dist, 8, 0.85, 1024, 3, 1024);
        assert_eq!(online.edges(), prior.edges());
        // Same seed → same confusion draws → identical predictions until
        // the first refit diverges the edges.
        for id in 0..200u64 {
            let r = req(id, 1 + (id * 13 % 900) as u32);
            assert_eq!(online.predict(&r), prior.predict(&r));
        }
    }

    #[test]
    fn window_slides_and_tracks_drift() {
        let mut p = OnlineBuckets::cold(4, 1.0, 128, 1, 1024);
        // Phase 1: short lengths around 64.
        for id in 0..256u64 {
            p.observe(&req(id, 64), 64);
        }
        assert_eq!(*p.edges().last().unwrap(), 64);
        // Phase 2: the distribution shifts to 512; once the window has
        // turned over and a refit fires, the edges must follow.
        for id in 256..640u64 {
            p.observe(&req(id, 512), 512);
        }
        assert_eq!(p.edges(), &[512], "edges must track the drifted window");
        assert_eq!(p.predict(&req(9999, 80)), 512);
        assert!(p.refits() >= 2);
        assert_eq!(p.observed(), 640);
    }

    #[test]
    fn collapsed_prior_does_not_pin_future_refits() {
        // A degenerate prior dedupes to a single edge; the online variant
        // must still cut the *requested* bucket count once real traffic
        // spreads out.
        let prior = BucketClassifier::fit_from_lengths(vec![7, 7, 7], 4, 1.0, 0);
        assert_eq!(prior.edges(), &[7]);
        let mut p = OnlineBuckets::with_prior(&prior, 4, 1.0, 64, 0, 1024);
        assert_eq!(p.edges(), &[7]);
        for id in 0..64u64 {
            let len = 100 + (id as u32 % 4) * 100; // 100/200/300/400 evenly
            p.observe(&req(id, len), len);
        }
        assert_eq!(
            p.edges(),
            &[100, 200, 300, 400],
            "refit must honor the requested 4 buckets, not the prior's 1"
        );
    }

    #[test]
    fn refit_schedule_is_deterministic() {
        let run = || {
            let mut p = OnlineBuckets::cold(8, 0.8, 64, 5, 1024);
            let mut log = Vec::new();
            for id in 0..500u64 {
                let len = 1 + (id * 37 % 700) as u32;
                if p.observe(&req(id, len), len) {
                    log.push((id, p.edges().to_vec()));
                }
            }
            (log, p.predict(&req(777, 350)))
        };
        assert_eq!(run(), run(), "same stream must give same refits and edges");
    }
}
