//! The open scheduling-policy API.
//!
//! Every scheduler in the paper — and any user-defined one — is a
//! [`SchedulingPolicy`]: an object that reacts to the three events a
//! serving cluster produces (request arrival, schedule tick, worker
//! completion) and decides batch formation, placement, per-iteration
//! admission, and the next tick interval. The generic DES loop
//! ([`crate::sim::driver::run_policy`]) owns the virtual clock, the event
//! queue, and the metrics log; the policy owns every decision and all
//! worker-model state.
//!
//! The thirteen built-in policies (SLS, SO, PM, AB, LB, SCLS, ILS,
//! SCLS-CB, the prediction-aware P-SCLS and P-CB, plus the SLO-aware
//! D-SCLS, P-SRPT, and SW-SLO) live in [`crate::sim::policies`] and
//! [`crate::sim::slo_policies`]; [`build_policy`] constructs them by
//! name for the CLI and the figure suite. Implementing a new scheduler
//! takes ~20 lines — see `examples/custom_policy.rs`.

use crate::core::Request;
use crate::engine::presets::EnginePreset;
use crate::metrics::{BatchRecord, FleetEventKind, FleetRecord, MetricsSink, PredictionRecord, RunMetrics};
use crate::sim::events::EventQueue;

/// DES event alphabet shared by every policy: the loop pops these in time
/// order (ties break by push order) and dispatches to the policy hooks.
#[derive(Debug)]
pub(crate) enum Ev {
    /// Index into the trace's request list.
    Arrival(usize),
    /// Coordinator schedule tick (only policies that arm one receive it).
    Tick,
    /// The batch/iteration a policy started on this worker completed.
    WorkerDone(usize),
    /// Index into the fault plan's event list (elastic-fleet runs only).
    Fleet(usize),
}

/// How a worker leaves the fleet (delivered to
/// [`SchedulingPolicy::on_worker_lost`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerLoss {
    /// Graceful: stop accepting new work, finish the in-flight batch, then
    /// migrate any queued work at the slice boundary.
    Drain,
    /// Abrupt: the in-flight slice is lost; surviving requests are
    /// re-queued from the last completed slice boundary.
    Crash,
}

/// What a policy sees and can do while handling one event: the virtual
/// clock, future-event scheduling, and the streaming metrics channel.
pub struct SimCtx<'a> {
    /// Current virtual time (seconds).
    pub now: f64,
    arrivals_left: usize,
    queue: &'a mut EventQueue<Ev>,
    metrics: &'a mut RunMetrics,
    sink: &'a mut dyn MetricsSink,
}

impl<'a> SimCtx<'a> {
    pub(crate) fn new(
        now: f64,
        arrivals_left: usize,
        queue: &'a mut EventQueue<Ev>,
        metrics: &'a mut RunMetrics,
        sink: &'a mut dyn MetricsSink,
    ) -> SimCtx<'a> {
        SimCtx {
            now,
            arrivals_left,
            queue,
            metrics,
            sink,
        }
    }

    /// Trace arrivals not yet injected (policies use this to decide
    /// whether to re-arm their schedule tick).
    pub fn arrivals_left(&self) -> usize {
        self.arrivals_left
    }

    /// Read-only view of the metrics accumulated so far.
    pub fn metrics(&self) -> &RunMetrics {
        self.metrics
    }

    /// Schedule `on_worker_done(worker)` at virtual time `at` — the policy
    /// committed worker `worker` until then.
    pub fn complete_at(&mut self, at: f64, worker: usize) {
        self.queue.push(at, Ev::WorkerDone(worker));
    }

    /// Schedule the next coordinator tick at virtual time `at`.
    pub fn tick_at(&mut self, at: f64) {
        self.queue.push(at, Ev::Tick);
    }

    /// Log a batch serving start (streams to sinks, then appends to
    /// `RunMetrics::batches`).
    pub fn record_batch(&mut self, rec: BatchRecord) {
        self.sink.on_batch(self.now, &rec);
        self.metrics.batches.push(rec);
    }

    /// Log a request completion at the current virtual time. SLO-carrying
    /// requests are judged against their spec and streamed through
    /// `MetricsSink::on_slo`; SLO-free requests produce no extra event.
    pub fn record_completion(&mut self, req: &Request) {
        let outcome = self.metrics.record_completion(req, self.now);
        let c = self
            .metrics
            .completed
            .last()
            .expect("record_completion just pushed");
        self.sink.on_completion(self.now, c);
        if let Some(o) = outcome {
            self.sink.on_slo(self.now, &o);
        }
    }

    /// Log a shed: an SLO-aware policy dropped `req` before service
    /// (deadline-infeasible admission or an expired requeue). Bumps
    /// `shed_requests`, folds SLO-carrying sheds into the attainment
    /// tracker as misses, and streams to sinks.
    pub fn record_shed(&mut self, req: &Request) {
        self.metrics.record_shed(req);
        self.sink.on_shed(self.now, req);
    }

    /// Note a schedule tick drained `depth` pooled requests (tracks the
    /// pool high-water mark and streams to sinks).
    pub fn observe_pool(&mut self, depth: usize) {
        self.metrics.peak_pool = self.metrics.peak_pool.max(depth);
        self.sink.on_pool_depth(self.now, depth);
    }

    /// Log a prediction-accounting event (prediction-aware policies only):
    /// updates the `underpredicted`/`overpredicted`/`wasted_kv_token_steps`
    /// counters and streams to sinks.
    pub fn record_prediction(&mut self, rec: PredictionRecord) {
        if rec.underpredicted {
            self.metrics.underpredicted += 1;
        } else {
            self.metrics.overpredicted += 1;
        }
        self.metrics.wasted_kv_token_steps += rec.wasted_tokens;
        self.sink.on_prediction(self.now, &rec);
    }

    /// Log an online-predictor refit (a completion observation that
    /// triggered [`crate::predictor::LengthPredictor::observe`] to recut
    /// the model): bumps `predictor_refits` and streams to sinks.
    pub fn record_refit(&mut self) {
        self.metrics.predictor_refits += 1;
        self.sink.on_predictor_refit(self.now);
    }

    /// Log a batch the DP batcher costed at a predicted budget strictly
    /// below the slice cap (predicted-correction opt-in only): bumps
    /// `corrected_batches` and streams to sinks.
    pub fn record_corrected_batch(&mut self) {
        self.metrics.corrected_batches += 1;
        self.sink.on_corrected_batch(self.now);
    }

    /// Log an *applied* worker-lifecycle event (fault-aware policies call
    /// this only for events that actually changed their fleet — e.g. a
    /// crash of an already-dead worker is not re-recorded): bumps
    /// `worker_crashes` for crashes and streams to sinks.
    pub fn record_fleet(&mut self, rec: FleetRecord) {
        if rec.kind == FleetEventKind::Crash {
            self.metrics.worker_crashes += 1;
        }
        self.sink.on_fleet(self.now, &rec);
    }

    /// Log a crash-time stale-work reclaim from `worker`: `in_flight`
    /// requests lost their current slice (re-served from the last
    /// completed slice boundary), `queued` requests were re-queued intact.
    /// Bumps `reclaimed_requests` by the total, `lost_slices` by
    /// `in_flight`, and `migrations` by `queued`.
    pub fn record_reclaim(&mut self, worker: usize, in_flight: usize, queued: usize) {
        self.metrics.reclaimed_requests += (in_flight + queued) as u64;
        self.metrics.lost_slices += in_flight as u64;
        self.metrics.migrations += queued as u64;
        self.sink.on_reclaim(self.now, worker, in_flight, queued);
    }

    /// Log `count` requests migrating off `worker` at a slice boundary
    /// (the drain handoff path): bumps `migrations` and streams to sinks.
    pub fn record_migration(&mut self, worker: usize, count: usize) {
        self.metrics.migrations += count as u64;
        self.sink.on_migration(self.now, worker, count);
    }

    /// Log a coordinator crash being handled (the successor is about to
    /// rebuild from worker-side state): bumps `coordinator_crashes` and
    /// streams to sinks.
    pub fn record_coordinator_crash(&mut self) {
        self.metrics.coordinator_crashes += 1;
        self.sink.on_coordinator_crash(self.now);
    }

    /// Log the KV-transfer cost of one migrated request: `tokens` resident
    /// KV tokens shipped off `worker`, stalling the request for `stall_s`
    /// seconds before it is servable elsewhere (`stall_s` is 0 when no
    /// [`crate::estimator::TransferCost`] model is configured — the tokens
    /// are still counted). Bumps `kv_tokens_migrated`/`migration_stall_s`
    /// and streams to sinks.
    pub fn record_kv_transfer(&mut self, worker: usize, tokens: u64, stall_s: f64) {
        self.metrics.kv_tokens_migrated += tokens;
        self.metrics.migration_stall_s += stall_s;
        self.sink.on_kv_transfer(self.now, worker, tokens, stall_s);
    }

    /// Stream a per-worker telemetry sample: `worker` just finished a
    /// serving that produced `new_tokens`, holds `kv_in_use` KV-cache
    /// tokens after the boundary (0 for static-batching engines, which
    /// release the batch at every slice boundary), and has `queue_depth`
    /// requests waiting locally. Telemetry-only — never touches
    /// `RunMetrics`, so attaching or dropping a sink that consumes it
    /// cannot move a run's deterministic fingerprint.
    pub fn record_served(
        &mut self,
        worker: usize,
        new_tokens: u64,
        kv_in_use: u64,
        queue_depth: usize,
    ) {
        self.sink
            .on_worker_sample(self.now, worker, new_tokens, kv_in_use, queue_depth);
    }
}

/// A scheduling policy: the full decision surface of one cluster
/// coordinator plus the worker-model state it manages.
///
/// The generic loop guarantees: `init` runs once before any event; hooks
/// run with a monotone non-decreasing `ctx.now`; every `complete_at` is
/// answered by exactly one `on_worker_done`; `finish` runs once after the
/// queue drains.
pub trait SchedulingPolicy {
    /// Arm initial events (e.g. the first schedule tick) and pre-size
    /// internal state (`ctx.arrivals_left()` is the trace length here).
    fn init(&mut self, _ctx: &mut SimCtx) {}

    /// A request entered the cluster: pool it, or place it directly.
    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx);

    /// A coordinator tick fired (only delivered if the policy armed one):
    /// form batches, place them, and re-arm the next tick.
    fn on_tick(&mut self, _ctx: &mut SimCtx) {}

    /// The serving the policy scheduled on `worker` completed: apply
    /// outcomes, record completions, reschedule leftovers, refill the
    /// worker.
    fn on_worker_done(&mut self, worker: usize, ctx: &mut SimCtx);

    /// Elastic fleet only: a cold worker joined under the (fresh,
    /// never-reused) index `worker`. Default no-op — policies that ignore
    /// fleet events behave exactly as on a fixed fleet, and fault-free
    /// runs never deliver this hook.
    fn on_worker_join(&mut self, _worker: usize, _ctx: &mut SimCtx) {}

    /// Elastic fleet only: `worker` is leaving ([`WorkerLoss::Drain`]) or
    /// gone ([`WorkerLoss::Crash`]). Fault-aware policies stop assigning
    /// it work and reclaim/migrate what it held; the default no-op keeps
    /// fault-ignorant policies byte-identical on fault-free traces.
    fn on_worker_lost(&mut self, _worker: usize, _loss: WorkerLoss, _ctx: &mut SimCtx) {}

    /// Elastic fleet only: the coordinator process crashed and a successor
    /// is taking over. Coordinator-backed policies drop their in-memory
    /// scheduling state (pools, ledger, deficit counters) and rebuild it
    /// from authoritative worker-side reports plus the arrival log; the
    /// default no-op keeps policies without a coordinator abstraction
    /// byte-identical (their "coordinator state" is the policy struct
    /// itself, which survives by construction). Fault-free runs never
    /// deliver this hook.
    fn on_coordinator_crash(&mut self, _ctx: &mut SimCtx) {}

    /// Final accounting after the event queue drains (e.g. per-worker
    /// completion times).
    fn finish(&mut self, _metrics: &mut RunMetrics) {}
}

// ---------------------------------------------------------------------------
// Built-in policy registry (CLI / figure-suite construction by name)
// ---------------------------------------------------------------------------

/// Canonical names of the thirteen built-in policies: the paper's eight in
/// paper order, the prediction-aware pair (P-SCLS, P-CB), then the
/// SLO-aware trio (D-SCLS, P-SRPT, SW-SLO).
pub const BUILTIN_POLICIES: [&str; 13] = [
    "SLS", "SO", "PM", "AB", "LB", "SCLS", "ILS", "SCLS-CB", "P-SCLS", "P-CB", "D-SCLS", "P-SRPT",
    "SW-SLO",
];

/// Case-insensitive canonicalization of a scheduler name (accepts the
/// long-form aliases and `_`/`-` variants, e.g. `scls_cb` or `SCLSCB`).
pub fn canonical_policy_name(s: &str) -> Option<&'static str> {
    let up = s.trim().replace('_', "-").to_ascii_uppercase();
    match up.as_str() {
        "SLS" => Some("SLS"),
        "SO" | "SLICE-ONLY" => Some("SO"),
        "PM" | "PADDING-MITIGATING" => Some("PM"),
        "AB" | "ADAPTIVE-BATCHING" => Some("AB"),
        "LB" | "LOAD-BALANCING" => Some("LB"),
        "SCLS" => Some("SCLS"),
        "ILS" => Some("ILS"),
        "SCLS-CB" | "SCLSCB" => Some("SCLS-CB"),
        "P-SCLS" | "PSCLS" | "PRED-SCLS" => Some("P-SCLS"),
        "P-CB" | "PCB" | "PRED-CB" => Some("P-CB"),
        "D-SCLS" | "DSCLS" | "DEADLINE-SCLS" => Some("D-SCLS"),
        "P-SRPT" | "PSRPT" | "SRPT" => Some("P-SRPT"),
        "SW-SLO" | "SWSLO" | "SLO-WINDOW" => Some("SW-SLO"),
        _ => None,
    }
}

/// Parse a scheduler name from user input, case-insensitively. On failure
/// the error lists every valid name.
pub fn parse_policy_name(s: &str) -> Result<&'static str, String> {
    canonical_policy_name(s).ok_or_else(|| {
        format!(
            "unknown scheduler '{s}' (valid, case-insensitive: {})",
            BUILTIN_POLICIES.join(", ")
        )
    })
}

/// Construct a built-in policy by (canonical or aliased) name against a
/// cluster configuration. `slice_len` parameterizes every sliced policy;
/// SLS derives its iteration limit from `cfg.max_gen_len` as in §5.1. The
/// prediction-aware policies (P-SCLS, P-CB) build their length predictor
/// from `cfg.predictor`.
pub fn build_policy(
    name: &str,
    cfg: &crate::sim::driver::SimConfig,
    slice_len: u32,
) -> Result<Box<dyn SchedulingPolicy>, String> {
    use crate::scheduler::spec::SchedulerSpec;
    use crate::sim::policies::{
        IlsPolicy, PredictiveCbPolicy, PredictiveSlicedPolicy, SclsCbPolicy, SlicedPolicy,
    };
    use crate::sim::slo_policies::{DeadlineSclsPolicy, RankKey, RankedSlicePolicy};

    let preset: &EnginePreset = &cfg.engine;
    Ok(match parse_policy_name(name)? {
        "ILS" => Box::new(IlsPolicy::new(cfg)),
        "SCLS-CB" => Box::new(SclsCbPolicy::new(cfg, slice_len)),
        "P-SCLS" => Box::new(PredictiveSlicedPolicy::new(
            &SchedulerSpec::p_scls(preset, slice_len),
            cfg,
            cfg.predictor.build(cfg.max_gen_len, cfg.seed),
        )),
        "P-CB" => Box::new(PredictiveCbPolicy::new(
            cfg,
            cfg.predictor.build(cfg.max_gen_len, cfg.seed),
        )),
        "SLS" => Box::new(SlicedPolicy::new(
            &SchedulerSpec::sls(preset, cfg.max_gen_len),
            cfg,
        )),
        "SO" => Box::new(SlicedPolicy::new(
            &SchedulerSpec::slice_only(preset, slice_len),
            cfg,
        )),
        "PM" => Box::new(SlicedPolicy::new(
            &SchedulerSpec::padding_mitigating(preset, slice_len),
            cfg,
        )),
        "AB" => Box::new(SlicedPolicy::new(
            &SchedulerSpec::adaptive_batching(preset, slice_len),
            cfg,
        )),
        "LB" => Box::new(SlicedPolicy::new(
            &SchedulerSpec::load_balancing(preset, slice_len),
            cfg,
        )),
        "SCLS" => Box::new(SlicedPolicy::new(
            &SchedulerSpec::scls(preset, slice_len),
            cfg,
        )),
        "D-SCLS" => Box::new(DeadlineSclsPolicy::new(
            &SchedulerSpec::d_scls(preset, slice_len),
            cfg,
        )),
        "P-SRPT" => Box::new(RankedSlicePolicy::new(
            &SchedulerSpec::p_srpt(preset, slice_len),
            cfg,
            RankKey::PredictedRemaining,
            Some(cfg.predictor.build(cfg.max_gen_len, cfg.seed)),
        )),
        "SW-SLO" => Box::new(RankedSlicePolicy::new(
            &SchedulerSpec::sw_slo(preset, slice_len),
            cfg,
            RankKey::DeadlineSlack,
            None,
        )),
        other => unreachable!("canonical name {other} not constructed"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_is_case_insensitive() {
        assert_eq!(parse_policy_name("scls"), Ok("SCLS"));
        assert_eq!(parse_policy_name("Scls-Cb"), Ok("SCLS-CB"));
        assert_eq!(parse_policy_name("SCLSCB"), Ok("SCLS-CB"));
        assert_eq!(parse_policy_name("scls_cb"), Ok("SCLS-CB"));
        assert_eq!(parse_policy_name("ils"), Ok("ILS"));
        assert_eq!(parse_policy_name(" lb "), Ok("LB"));
        assert_eq!(parse_policy_name("slice-only"), Ok("SO"));
        assert_eq!(parse_policy_name("p-scls"), Ok("P-SCLS"));
        assert_eq!(parse_policy_name("p_scls"), Ok("P-SCLS"));
        assert_eq!(parse_policy_name("Pred-SCLS"), Ok("P-SCLS"));
        assert_eq!(parse_policy_name("P-CB"), Ok("P-CB"));
        assert_eq!(parse_policy_name("pcb"), Ok("P-CB"));
        assert_eq!(parse_policy_name("d-scls"), Ok("D-SCLS"));
        assert_eq!(parse_policy_name("deadline-scls"), Ok("D-SCLS"));
        assert_eq!(parse_policy_name("deadline_scls"), Ok("D-SCLS"));
        assert_eq!(parse_policy_name("srpt"), Ok("P-SRPT"));
        assert_eq!(parse_policy_name("p_srpt"), Ok("P-SRPT"));
        assert_eq!(parse_policy_name("sw-slo"), Ok("SW-SLO"));
        assert_eq!(parse_policy_name("slo-window"), Ok("SW-SLO"));
    }

    #[test]
    fn parse_error_lists_valid_names() {
        let err = parse_policy_name("vllm").unwrap_err();
        assert!(err.contains("unknown scheduler 'vllm'"), "{err}");
        for name in BUILTIN_POLICIES {
            assert!(err.contains(name), "error must list {name}: {err}");
        }
    }

    #[test]
    fn every_builtin_constructs() {
        use crate::engine::presets::{EngineKind, EnginePreset};
        use crate::sim::driver::SimConfig;
        let cfg = SimConfig::new(2, EnginePreset::paper(EngineKind::Ds), 1024, 7);
        for name in BUILTIN_POLICIES {
            assert!(build_policy(name, &cfg, 128).is_ok(), "{name}");
        }
        assert!(build_policy("nope", &cfg, 128).is_err());
    }
}
