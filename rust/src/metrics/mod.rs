//! Run metrics: everything the paper's figures report (§5.1 Metrics plus
//! the dive-in counters of Figs. 13/14/16/19/20), and the streaming
//! [`sink::MetricsSink`] observer API the drivers feed while a run is in
//! flight.

pub mod sink;

pub use sink::{Fanout, MetricsSink, NullSink, Tally};

use crate::slo::{SloOutcome, SloTracker};
use crate::telemetry::StreamingHist;
use crate::util::json::Json;
use crate::util::stats;

/// Per-request record at completion.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    pub id: u64,
    pub arrival: f64,
    pub finished: f64,
    pub generated: u32,
    /// Schedule count == slice count (Fig. 14a / 20a).
    pub slices: u32,
    pub pad_tokens: u64,
    pub invalid_tokens: u64,
}

/// One prediction-accounting event from a prediction-aware policy
/// (P-SCLS / P-CB): either a mispredict-recovery action (under-prediction:
/// a re-queue to the next rung or an eviction/re-admission) or a
/// completion whose reservation over-shot the actual generation.
#[derive(Debug, Clone)]
pub struct PredictionRecord {
    /// Request the event belongs to.
    pub id: u64,
    /// True for an under-prediction recovery event; false for an
    /// over-predicted completion.
    pub underpredicted: bool,
    /// Reserved generation capacity (KV token-slots) that went unused —
    /// non-zero only on over-predicted completions.
    pub wasted_tokens: u64,
}

/// What happened to the fleet at a lifecycle event (elastic-fleet runs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetEventKind {
    Join,
    Drain,
    Crash,
}

/// One worker-lifecycle event as applied by a policy: `worker` joined,
/// started draining, or crashed. Streamed through
/// [`sink::MetricsSink::on_fleet`]; crashes also bump
/// [`RunMetrics::worker_crashes`].
#[derive(Debug, Clone, Copy)]
pub struct FleetRecord {
    pub worker: usize,
    pub kind: FleetEventKind,
}

/// Per-batch-serving record.
#[derive(Debug, Clone)]
pub struct BatchRecord {
    pub start: f64,
    pub worker: usize,
    pub size: u32,
    pub input_len: u32,
    pub pad_tokens: u64,
    pub est_serve_time: f64,
    pub actual_serve_time: f64,
    pub early_return: bool,
}

/// Raw event log of one experiment run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub completed: Vec<CompletedRequest>,
    pub batches: Vec<BatchRecord>,
    /// Per-worker completion time: when each instance finished its last
    /// batch (CT in Figs. 5e/17/21).
    pub worker_completion: Vec<f64>,
    /// Wall/virtual time when the last request completed.
    pub makespan: f64,
    /// Total requests injected (completed + any stragglers).
    pub total_requests: usize,
    /// DES events processed (arrivals + ticks + worker completions) — the
    /// denominator of the scale benchmark's events/sec figure.
    pub events: u64,
    /// Largest pool size observed at a schedule tick (coordinator paths
    /// only) — the scale benchmark's memory high-water mark.
    pub peak_pool: usize,
    /// Prediction-aware policies only: mispredict-recovery events
    /// (re-queues to the next rung under P-SCLS, evictions/re-admissions
    /// under P-CB). Always 0 for prediction-free policies.
    pub underpredicted: u64,
    /// Prediction-aware policies only: completions whose reservation
    /// over-shot the actual generation length.
    pub overpredicted: u64,
    /// Prediction-aware policies only: total reserved generation capacity
    /// (KV token-slots) that went unused across all servings/residencies.
    pub wasted_kv_token_steps: u64,
    /// Online predictors only: model refits triggered by completion
    /// observations ([`crate::predictor::LengthPredictor::observe`]).
    /// Always 0 under offline predictors and prediction-free policies.
    pub predictor_refits: u64,
    /// Predicted-correction opt-in only: batches the DP batcher costed at
    /// a predicted budget strictly below the slice cap. Always 0 with the
    /// correction off.
    pub corrected_batches: u64,
    /// Elastic-fleet runs only: workers that crashed (abrupt failures
    /// applied by a fault-aware policy). Always 0 on `FaultPlan::none()`.
    pub worker_crashes: u64,
    /// Requests re-queued off a crashed worker (in-flight survivors plus
    /// queued work it owned). Always 0 without crashes.
    pub reclaimed_requests: u64,
    /// In-flight requests whose *current* slice was lost to a crash and
    /// must be re-served from the last completed slice boundary — the
    /// per-crash work-loss bound (≤ one slice per surviving request).
    pub lost_slices: u64,
    /// Requests moved between workers at a slice boundary (drain handoffs
    /// plus queued-work reassignment after a crash).
    pub migrations: u64,
    /// Coordinator crashes survived: the coordinator's in-memory state was
    /// dropped and a successor rebuilt it from worker reports plus the
    /// arrival log. Always 0 without `coord@T` fault events.
    pub coordinator_crashes: u64,
    /// Resident context tokens (prompt + cached KV at the boundary) shipped
    /// with migrated requests. Always 0 without migrations.
    pub kv_tokens_migrated: u64,
    /// Total modeled KV-transfer stall charged to migrated requests before
    /// they were servable on their new worker. Always 0 unless a transfer
    /// cost is configured (`SimConfig::with_kv_transfer`).
    pub migration_stall_s: f64,
    /// Requests shed before service (deadline-infeasible admissions under
    /// SLO-aware policies). Always 0 under the throughput-only policies.
    pub shed_requests: u64,
    /// SLO attainment accounting: every completion (or shed) of a request
    /// carrying a non-empty [`crate::slo::SloSpec`] is folded in here.
    /// SLO-free traces never touch it, so the serialized counters stay
    /// all-zero and the frozen differential fingerprints are unchanged.
    pub slo: SloTracker,
}

/// Headline summary of a run.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Requests per second (completed / makespan).
    pub throughput: f64,
    pub avg_response_time: f64,
    pub p95_response_time: f64,
    /// Standard deviation of worker completion times (load-balance metric).
    pub ct_std: f64,
    pub avg_batch_size: f64,
    /// Mean invalid tokens per completed request (Fig. 13a).
    pub avg_invalid_tokens: f64,
    /// Mean pad tokens per completed request, summed over reschedules
    /// (Fig. 13c).
    pub avg_pad_tokens: f64,
    /// Fraction of batch servings that early-returned (Fig. 14b).
    pub early_return_ratio: f64,
    /// Distribution of per-request slice counts: counts for 1, 2, 3, ≥4
    /// (Fig. 14a).
    pub slice_histogram: [u64; 4],
    pub completed: usize,
}

impl RunMetrics {
    /// Pre-sized log for a trace of `total_requests` requests: completion
    /// records never reallocate, and the batch log starts with a workload-
    /// shaped guess (roughly one serving per few requests at paper batch
    /// sizes; it grows if the run slices more).
    pub fn with_capacity(total_requests: usize) -> RunMetrics {
        RunMetrics {
            completed: Vec::with_capacity(total_requests),
            batches: Vec::with_capacity(total_requests / 4 + 16),
            total_requests,
            ..RunMetrics::default()
        }
    }

    /// Log one completion. When the request carries a non-empty SLO the
    /// outcome is judged and folded into the tracker, and returned so the
    /// caller can stream it (`MetricsSink::on_slo`); SLO-free requests —
    /// including everything the frozen reference drivers replay — return
    /// `None` and leave the SLO counters untouched.
    pub fn record_completion(
        &mut self,
        req: &crate::core::Request,
        now: f64,
    ) -> Option<SloOutcome> {
        self.completed.push(CompletedRequest {
            id: req.id,
            arrival: req.arrival,
            finished: now,
            generated: req.generated,
            slices: req.slices,
            pad_tokens: req.pad_tokens,
            invalid_tokens: req.invalid_tokens,
        });
        self.makespan = self.makespan.max(now);
        if req.slo.is_none() {
            return None;
        }
        let outcome = req.slo.evaluate(req, now);
        self.slo.observe(&outcome);
        Some(outcome)
    }

    /// Log one shed (a request dropped before service by an SLO-aware
    /// policy). SLO-carrying sheds count as tracked-but-missed, so
    /// shedding lowers goodput honestly instead of hiding the miss.
    pub fn record_shed(&mut self, req: &crate::core::Request) {
        self.shed_requests += 1;
        if !req.slo.is_none() {
            self.slo.observe_shed(req.tenant);
        }
    }

    /// Serialize the *entire* event log deterministically — the byte-level
    /// fingerprint the policy differential suite compares across driver
    /// implementations. Two runs are behaviorally identical iff this JSON
    /// matches byte for byte.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("total_requests", self.total_requests)
            .set("events", self.events)
            .set("peak_pool", self.peak_pool)
            .set("underpredicted", self.underpredicted)
            .set("overpredicted", self.overpredicted)
            .set("wasted_kv_token_steps", self.wasted_kv_token_steps)
            .set("predictor_refits", self.predictor_refits)
            .set("corrected_batches", self.corrected_batches)
            .set("worker_crashes", self.worker_crashes)
            .set("reclaimed_requests", self.reclaimed_requests)
            .set("lost_slices", self.lost_slices)
            .set("migrations", self.migrations)
            .set("coordinator_crashes", self.coordinator_crashes)
            .set("kv_tokens_migrated", self.kv_tokens_migrated)
            .set("migration_stall_s", self.migration_stall_s)
            .set("shed_requests", self.shed_requests)
            .set("slo_tracked", self.slo.tracked)
            .set("slo_attained", self.slo.attained)
            .set("slo_ttft_misses", self.slo.ttft_misses)
            .set("slo_tpot_misses", self.slo.tpot_misses)
            .set("deadline_misses", self.slo.deadline_misses)
            .set("ttft_p99", self.slo.ttft_p99())
            .set("makespan", self.makespan)
            .set("worker_completion", self.worker_completion.clone());
        // Distribution summaries, sketched lazily at serialization time
        // from the retained logs / SLO tracker — pure functions of the
        // deterministic event log, so they are identical across driver
        // implementations and unaffected by attached sinks.
        let mut latency = StreamingHist::new();
        for c in &self.completed {
            latency.add(c.finished - c.arrival);
        }
        let mut serve = StreamingHist::new();
        for b in &self.batches {
            serve.add(b.actual_serve_time);
        }
        o.set("latency_dist", latency.summary_json())
            .set("serve_time_dist", serve.summary_json())
            .set("ttft_dist", self.slo.ttft_hist.summary_json())
            .set("tpot_dist", self.slo.tpot_hist.summary_json());
        let tenants: Vec<Json> = self
            .slo
            .per_tenant
            .iter()
            .map(|(tenant, t)| {
                let mut j = Json::obj();
                j.set("tenant", *tenant)
                    .set("tracked", t.tracked)
                    .set("attained", t.attained)
                    .set("ttft_misses", t.ttft_misses)
                    .set("tpot_misses", t.tpot_misses)
                    .set("deadline_misses", t.deadline_misses)
                    .set("shed", t.shed);
                j
            })
            .collect();
        o.set("slo_tenants", Json::Arr(tenants));
        let completed: Vec<Json> = self
            .completed
            .iter()
            .map(|c| {
                let mut j = Json::obj();
                j.set("id", c.id)
                    .set("arrival", c.arrival)
                    .set("finished", c.finished)
                    .set("generated", c.generated)
                    .set("slices", c.slices)
                    .set("pad_tokens", c.pad_tokens)
                    .set("invalid_tokens", c.invalid_tokens);
                j
            })
            .collect();
        o.set("completed", Json::Arr(completed));
        let batches: Vec<Json> = self
            .batches
            .iter()
            .map(|b| {
                let mut j = Json::obj();
                j.set("start", b.start)
                    .set("worker", b.worker)
                    .set("size", b.size)
                    .set("input_len", b.input_len)
                    .set("pad_tokens", b.pad_tokens)
                    .set("est_serve_time", b.est_serve_time)
                    .set("actual_serve_time", b.actual_serve_time)
                    .set("early_return", b.early_return);
                j
            })
            .collect();
        o.set("batches", Json::Arr(batches));
        o
    }

    pub fn summarize(&self) -> Summary {
        // Single pass over the logs; f64 sums accumulate in record order,
        // so the averages are bit-identical to the former collect-then-mean
        // formulation (figure JSON stays byte-stable across this change).
        let n_completed = self.completed.len();
        let mut rts: Vec<f64> = Vec::with_capacity(n_completed);
        let mut slice_histogram = [0u64; 4];
        let mut invalid_sum = 0.0f64;
        let mut pad_sum = 0.0f64;
        for c in &self.completed {
            rts.push(c.finished - c.arrival);
            let idx = (c.slices.max(1) as usize - 1).min(3);
            slice_histogram[idx] += 1;
            invalid_sum += c.invalid_tokens as f64;
            pad_sum += c.pad_tokens as f64;
        }
        let avg_response_time = stats::mean(&rts);
        // percentile() sorts a copy; sort in place instead (mean above
        // already consumed the arrival-order sum).
        rts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95_response_time = if rts.is_empty() {
            0.0
        } else {
            stats::percentile_sorted(&rts, 95.0)
        };

        let mut early = 0usize;
        let mut size_sum = 0.0f64;
        for b in &self.batches {
            early += b.early_return as usize;
            size_sum += b.size as f64;
        }
        let n_batches = self.batches.len().max(1);
        Summary {
            throughput: if self.makespan > 0.0 {
                n_completed as f64 / self.makespan
            } else {
                0.0
            },
            avg_response_time,
            p95_response_time,
            ct_std: stats::std_dev(&self.worker_completion),
            avg_batch_size: if self.batches.is_empty() {
                0.0
            } else {
                size_sum / self.batches.len() as f64
            },
            avg_invalid_tokens: if n_completed == 0 {
                0.0
            } else {
                invalid_sum / n_completed as f64
            },
            avg_pad_tokens: if n_completed == 0 {
                0.0
            } else {
                pad_sum / n_completed as f64
            },
            early_return_ratio: early as f64 / n_batches as f64,
            slice_histogram,
            completed: n_completed,
        }
    }
}

impl Summary {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("throughput", self.throughput)
            .set("avg_response_time", self.avg_response_time)
            .set("p95_response_time", self.p95_response_time)
            .set("ct_std", self.ct_std)
            .set("avg_batch_size", self.avg_batch_size)
            .set("avg_invalid_tokens", self.avg_invalid_tokens)
            .set("avg_pad_tokens", self.avg_pad_tokens)
            .set("early_return_ratio", self.early_return_ratio)
            .set(
                "slice_histogram",
                Json::Arr(self.slice_histogram.iter().map(|&x| Json::from(x)).collect()),
            )
            .set("completed", self.completed);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Request;

    #[test]
    fn summary_basic() {
        let mut m = RunMetrics::default();
        let mut r1 = Request::new(1, 0.0, 10, 5);
        r1.slices = 1;
        r1.invalid_tokens = 3;
        r1.pad_tokens = 7;
        m.record_completion(&r1, 2.0);
        let mut r2 = Request::new(2, 1.0, 10, 5);
        r2.slices = 4;
        m.record_completion(&r2, 5.0);
        m.worker_completion = vec![4.0, 6.0];
        m.batches.push(BatchRecord {
            start: 0.0,
            worker: 0,
            size: 2,
            input_len: 10,
            pad_tokens: 0,
            est_serve_time: 1.0,
            actual_serve_time: 1.1,
            early_return: true,
        });
        m.batches.push(BatchRecord {
            start: 1.0,
            worker: 1,
            size: 4,
            input_len: 12,
            pad_tokens: 5,
            est_serve_time: 2.0,
            actual_serve_time: 2.2,
            early_return: false,
        });

        let s = m.summarize();
        assert_eq!(s.completed, 2);
        assert!((s.throughput - 2.0 / 5.0).abs() < 1e-12);
        assert!((s.avg_response_time - 3.0).abs() < 1e-12); // (2 + 4) / 2
        assert!((s.ct_std - 1.0).abs() < 1e-12);
        assert!((s.avg_batch_size - 3.0).abs() < 1e-12);
        assert!((s.early_return_ratio - 0.5).abs() < 1e-12);
        assert_eq!(s.slice_histogram, [1, 0, 0, 1]);
        assert!((s.avg_invalid_tokens - 1.5).abs() < 1e-12);
        assert!((s.avg_pad_tokens - 3.5).abs() < 1e-12);
    }

    #[test]
    fn empty_run_summary_is_zeroes() {
        let s = RunMetrics::default().summarize();
        assert_eq!(s.completed, 0);
        assert_eq!(s.throughput, 0.0);
        assert_eq!(s.avg_response_time, 0.0);
        assert_eq!(s.p95_response_time, 0.0);
        assert_eq!(s.avg_batch_size, 0.0);
    }

    #[test]
    fn with_capacity_presizes_and_defaults() {
        let m = RunMetrics::with_capacity(1000);
        assert!(m.completed.capacity() >= 1000);
        assert_eq!(m.total_requests, 1000);
        assert_eq!(m.events, 0);
        assert_eq!(m.peak_pool, 0);
        assert_eq!(m.summarize().completed, 0);
    }

    #[test]
    fn slo_free_completions_leave_slo_counters_zero() {
        let mut m = RunMetrics::default();
        assert!(m.record_completion(&Request::new(1, 0.0, 10, 5), 1.0).is_none());
        assert!(m.slo.is_empty());
        let j = m.to_json();
        assert_eq!(j.get("slo_tracked").unwrap().as_i64(), Some(0));
        assert_eq!(j.get("slo_attained").unwrap().as_i64(), Some(0));
        assert_eq!(j.get("shed_requests").unwrap().as_i64(), Some(0));
        assert_eq!(j.get("ttft_p99").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("slo_tenants").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn slo_completions_and_sheds_are_tracked() {
        let mut m = RunMetrics::default();
        let mut r = Request::new(1, 0.0, 10, 5);
        r.generated = 5;
        r.tenant = 2;
        r.slo.deadline = Some(3.0);
        r.first_token_at = Some(0.5);
        let o = m.record_completion(&r, 2.0).expect("SLO-carrying");
        assert!(o.attained && o.deadline_ok);
        let mut late = Request::new(2, 0.0, 10, 5);
        late.generated = 5;
        late.slo.deadline = Some(1.0);
        assert!(!m.record_completion(&late, 2.0).unwrap().attained);
        let mut shed = Request::new(3, 0.0, 10, 5);
        shed.slo.deadline = Some(0.5);
        shed.tenant = 2;
        m.record_shed(&shed);
        // An SLO-free shed still counts the shed, not the tracker.
        m.record_shed(&Request::new(4, 0.0, 10, 5));
        assert_eq!(m.shed_requests, 2);
        assert_eq!(m.slo.tracked, 3);
        assert_eq!(m.slo.attained, 1);
        assert_eq!(m.slo.deadline_misses, 2);
        assert_eq!(m.slo.shed, 1);
        let j = m.to_json();
        assert_eq!(j.get("slo_tracked").unwrap().as_i64(), Some(3));
        assert_eq!(j.get("deadline_misses").unwrap().as_i64(), Some(2));
        let tenants = j.get("slo_tenants").unwrap().as_arr().unwrap();
        assert_eq!(tenants.len(), 2, "tenants 0 and 2");
        assert_eq!(tenants[1].get("tenant").unwrap().as_i64(), Some(2));
        assert_eq!(tenants[1].get("shed").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn to_json_distribution_summaries_are_lazy_and_deterministic() {
        let mut m = RunMetrics::default();
        m.record_completion(&Request::new(1, 0.0, 10, 5), 2.0);
        m.record_completion(&Request::new(2, 1.0, 10, 5), 5.0);
        let j = m.to_json();
        let lat = j.get("latency_dist").unwrap();
        assert_eq!(lat.get("count").unwrap().as_i64(), Some(2));
        assert_eq!(lat.get("min").unwrap().as_f64(), Some(2.0), "extrema are exact");
        assert_eq!(lat.get("max").unwrap().as_f64(), Some(4.0));
        // Empty logs serialize all-zero summaries (byte-stable on runs
        // that never consult the sketches).
        let e = RunMetrics::default().to_json();
        let serve = e.get("serve_time_dist").unwrap();
        assert_eq!(serve.get("count").unwrap().as_i64(), Some(0));
        assert_eq!(e.get("ttft_dist").unwrap().get("p99").unwrap().as_f64(), Some(0.0));
        // Serialization is a pure function of the log: repeat calls match.
        assert_eq!(m.to_json().to_string_pretty(), j.to_string_pretty());
    }

    #[test]
    fn summary_json_roundtrips() {
        let mut m = RunMetrics::default();
        m.record_completion(&Request::new(1, 0.0, 10, 5), 1.0);
        let j = m.summarize().to_json();
        let s = j.to_string_pretty();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("completed").unwrap().as_i64(), Some(1));
    }
}
