//! Paper-scale experiment: the full §5.2 sweep on the calibrated DES.
//!
//! Replays the paper's workflow verbatim — 8 LLaMA2-13B workers, 10-minute
//! CodeFuse-shaped Poisson traces at rates 12–28 req/s — across the five
//! (engine, scheduler) cells of Fig. 12, printing throughput, average and
//! tail response time, and the dive-in counters of Figs. 13/14. Because
//! the cluster is a virtual-time simulation, the whole sweep takes seconds
//! instead of the paper's hours of A100 time.
//!
//! Run with: `cargo run --release --example paper_scale_sim`
//! (set SCLS_FULL=1 for the full 10-minute traces; default is 2 minutes)

use scls::bench::figures::{run_cell, FigureConfig};
use scls::engine::presets::EngineKind;

fn main() {
    let full = std::env::var("SCLS_FULL").is_ok();
    let fc = if full {
        FigureConfig::default() // the paper's full 600 s
    } else {
        FigureConfig::quick(0.2) // 120 s traces — same shapes, 5× faster
    };
    println!(
        "paper_scale_sim: {} workers, {:.0}-second traces (SCLS_FULL=1 for 600 s)\n",
        fc.workers, fc.duration
    );

    let rates = [12.0, 16.0, 20.0, 24.0, 28.0];
    let cells: [(EngineKind, &str); 5] = [
        (EngineKind::Hf, "SLS"),
        (EngineKind::Hf, "SCLS"),
        (EngineKind::Ds, "SLS"),
        (EngineKind::Ds, "ILS"),
        (EngineKind::Ds, "SCLS"),
    ];

    println!(
        "{:<10} {:>5} {:>10} {:>9} {:>9} {:>8} {:>8} {:>8} {:>7}",
        "cell", "rate", "thpt", "avgRT", "p95RT", "invalid", "batch", "pads", "CTstd"
    );
    // Track the paper's headline comparisons while sweeping.
    let mut hf: Vec<(f64, f64, f64)> = Vec::new(); // (rate, sls, scls) throughput
    let mut ds: Vec<(f64, f64, f64, f64)> = Vec::new(); // (rate, sls, ils, scls)
    for &rate in &rates {
        let mut row = std::collections::BTreeMap::new();
        for &(kind, which) in &cells {
            let s = run_cell(&fc, kind, which, rate, fc.slice_len);
            println!(
                "{:<10} {:>5.0} {:>10.2} {:>9.1} {:>9.1} {:>8.1} {:>8.1} {:>8.1} {:>7.1}",
                format!("{}-{}", kind.name(), which),
                rate,
                s.throughput,
                s.avg_response_time,
                s.p95_response_time,
                s.avg_invalid_tokens,
                s.avg_batch_size,
                s.avg_pad_tokens,
                s.ct_std
            );
            row.insert(format!("{}-{}", kind.name(), which), s.throughput);
        }
        hf.push((rate, row["HF-SLS"], row["HF-SCLS"]));
        ds.push((rate, row["DS-SLS"], row["DS-ILS"], row["DS-SCLS"]));
        println!();
    }

    // The paper's headline claims (§5.2): SCLS vs SLS on HF = +232% to
    // +316%; vs SLS on DS = +83% to +192%; vs ILS on DS = +62% to +171%.
    println!("headline throughput gains (paper ranges in brackets):");
    let span = |pairs: &[(f64, f64)]| {
        let gains: Vec<f64> = pairs.iter().map(|(b, s)| 100.0 * (s / b - 1.0)).collect();
        (
            gains.iter().cloned().fold(f64::INFINITY, f64::min),
            gains.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        )
    };
    let (lo, hi) = span(&hf.iter().map(|&(_, b, s)| (b, s)).collect::<Vec<_>>());
    println!("  HF: SCLS over SLS  {lo:+.1}% .. {hi:+.1}%   [+232.3% .. +315.8%]");
    let (lo, hi) = span(&ds.iter().map(|&(_, b, _, s)| (b, s)).collect::<Vec<_>>());
    println!("  DS: SCLS over SLS  {lo:+.1}% .. {hi:+.1}%   [+82.5% .. +191.9%]");
    let (lo, hi) = span(&ds.iter().map(|&(_, _, i, s)| (i, s)).collect::<Vec<_>>());
    println!("  DS: SCLS over ILS  {lo:+.1}% .. {hi:+.1}%   [+61.6% .. +171.0%]");
}
