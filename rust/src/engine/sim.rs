//! Virtual-time static-batching engine (the DES worker substrate).
//!
//! Implements the exact serving semantics of §2.4 against the calibrated
//! latency model: padding to the batch input length, an iteration limit
//! (the slice length under SCLS; the maximal generation length under SLS),
//! early return when every request emits EOS, and invalid-token generation
//! for requests that finish while the batch keeps running.
//!
//! The trace's `target_gen_len` is the EOS oracle — the engine knows it,
//! the scheduler never does.

use crate::core::{Batch, BatchOutcome, RequestOutcome};

use super::latency::EngineLatency;

/// One simulated LLM instance.
#[derive(Debug, Clone)]
pub struct SimEngine {
    pub latency: EngineLatency,
    /// Serving-time cap on total generated tokens per request (paper: 1024).
    pub max_gen_len: u32,
}

impl SimEngine {
    pub fn new(latency: EngineLatency, max_gen_len: u32) -> SimEngine {
        SimEngine {
            latency,
            max_gen_len,
        }
    }

    /// Serve one batch for at most `iter_limit` iterations; returns the
    /// virtual duration and per-request outcomes. Does not mutate requests
    /// (the driver applies outcomes so that it can also track metrics).
    pub fn serve_slice(&mut self, batch: &Batch, iter_limit: u32) -> BatchOutcome {
        let n = batch.size() as u32;
        assert!(n > 0, "serve_slice on empty batch");
        let l_i = batch.input_len();

        // Per-request: iterations it still *needs* (to EOS or the cap).
        let needs: Vec<u32> = batch
            .requests
            .iter()
            .map(|r| {
                let to_eos = r.remaining_to_eos();
                let to_cap = self.max_gen_len.saturating_sub(r.generated);
                to_eos.min(to_cap).max(1) // even an already-capped row burns ≥1 iter
            })
            .collect();

        // Batch generation length (§2.4): min(iteration limit, longest
        // remaining generation among batched requests).
        let longest = *needs.iter().max().unwrap();
        let iters = longest.min(iter_limit).max(1);
        let early_return = iters < iter_limit;

        let per_request: Vec<RequestOutcome> = batch
            .requests
            .iter()
            .zip(&needs)
            .map(|(r, &need)| {
                let new_tokens = need.min(iters);
                let finished = need <= iters;
                // Tokens ground out after this request's EOS while the batch
                // kept running (§2.4 "invalid tokens").
                let invalid = iters - new_tokens;
                RequestOutcome {
                    id: r.id,
                    new_tokens,
                    invalid_tokens: invalid,
                    finished,
                }
            })
            .collect();

        let duration = self.latency.serve_sample(n, l_i, iters);
        BatchOutcome {
            duration,
            iters,
            early_return,
            per_request,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Request;

    fn engine() -> SimEngine {
        let mut lat = EngineLatency::ds(1);
        lat.jitter = 0.0;
        SimEngine::new(lat, 1024)
    }

    fn batch(specs: &[(u32, u32, u32)]) -> Batch {
        // (input_len, target_gen, already_generated)
        Batch::new(
            specs
                .iter()
                .enumerate()
                .map(|(i, &(li, tg, g))| {
                    let mut r = Request::new(i as u64, 0.0, li, tg);
                    r.generated = g;
                    r
                })
                .collect(),
        )
    }

    #[test]
    fn full_slice_when_any_request_unfinished() {
        let mut e = engine();
        let b = batch(&[(10, 5, 0), (10, 500, 0)]);
        let out = e.serve_slice(&b, 128);
        assert_eq!(out.iters, 128);
        assert!(!out.early_return);
        // short request: 5 valid + 123 invalid
        assert_eq!(out.per_request[0].new_tokens, 5);
        assert_eq!(out.per_request[0].invalid_tokens, 123);
        assert!(out.per_request[0].finished);
        // long request: 128 valid, unfinished
        assert_eq!(out.per_request[1].new_tokens, 128);
        assert!(!out.per_request[1].finished);
    }

    #[test]
    fn early_return_when_all_finish() {
        let mut e = engine();
        let b = batch(&[(10, 5, 0), (10, 9, 0)]);
        let out = e.serve_slice(&b, 128);
        assert_eq!(out.iters, 9);
        assert!(out.early_return);
        assert!(out.per_request.iter().all(|o| o.finished));
        assert_eq!(out.per_request[0].invalid_tokens, 4);
    }

    #[test]
    fn max_gen_cap_finishes_request() {
        let mut e = engine();
        // already generated 1000, target 2000 -> capped at 1024: needs 24
        let b = batch(&[(10, 2000, 1000)]);
        let out = e.serve_slice(&b, 128);
        assert_eq!(out.iters, 24);
        assert!(out.per_request[0].finished);
        assert_eq!(out.per_request[0].new_tokens, 24);
    }

    #[test]
    fn sls_mode_iteration_limit_is_max_gen() {
        // SLS sets the iteration limit to the maximal generation length:
        // every request completes in one serving.
        let mut e = engine();
        let b = batch(&[(10, 5, 0), (10, 900, 0)]);
        let out = e.serve_slice(&b, 1024);
        assert_eq!(out.iters, 900);
        assert!(out.per_request.iter().all(|o| o.finished));
        assert_eq!(out.per_request[0].invalid_tokens, 895);
    }

    #[test]
    fn duration_grows_with_padding() {
        // Same work, but one long-input straggler forces padding: slower.
        // With the calibrated DS constants the per-iteration base (c4)
        // dominates at N=2, so the padding penalty at 128 iterations is
        // ~1.3×; the penalty grows with batch size (Fig. 11's point).
        let mut e = engine();
        let small = batch(&[(10, 50, 0), (10, 50, 0)]);
        let padded = batch(&[(10, 50, 0), (1024, 50, 0)]);
        let d_small = e.serve_slice(&small, 128).duration;
        let d_padded = e.serve_slice(&padded, 128).duration;
        assert!(d_padded > d_small * 1.2, "{d_padded} vs {d_small}");

        // At N=16 the N·l cross term makes padding much more expensive.
        let mut wide_small: Vec<(u32, u32, u32)> = vec![(10, 50, 0); 16];
        let wide_padded = {
            let mut v = wide_small.clone();
            v[15] = (1024, 50, 0);
            v
        };
        wide_small[15] = (10, 50, 0);
        let d_ws = e.serve_slice(&batch(&wide_small), 128).duration;
        let d_wp = e.serve_slice(&batch(&wide_padded), 128).duration;
        assert!(d_wp > d_ws * 1.8, "{d_wp} vs {d_ws}");
    }

    #[test]
    fn rescheduled_request_keeps_progress() {
        let mut e = engine();
        // target 300, already generated 256 in two prior slices
        let b = batch(&[(10 + 256, 300, 256)]);
        let out = e.serve_slice(&b, 128);
        assert_eq!(out.iters, 44);
        assert!(out.per_request[0].finished);
    }
}
