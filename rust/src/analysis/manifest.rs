//! Frozen-reference manifests: the `frozen-manifest` rule.
//!
//! The differential test suites pin today's optimised schedulers to
//! reference implementations that were reviewed once and then *frozen* —
//! their bytes are the spec. This module hashes those artifacts and
//! compares against the committed manifest at `lint/frozen.sha256`
//! (relative to the crate root), so an edit to a reference — even a
//! well-intentioned one — fails lint until the manifest is regenerated
//! deliberately via `scls-repro lint --write-manifest`.
//!
//! Two entry forms:
//!
//! * `path` — SHA-256 of the whole file's bytes.
//! * `path#fn_name` — SHA-256 of the named fn item's span: the line
//!   holding the `fn` keyword through the line of its matching close
//!   brace, each line rejoined with `\n`. Brace matching runs on the
//!   lexed token stream, so braces in comments and strings don't count.
//!
//! Manifest line format is `sha256sum`-compatible: `<hex>  <entry>` with
//! two spaces; blank lines and `#`-prefixed comment lines are skipped.

use std::fs;
use std::path::Path;

use super::lexer::{self, TokKind};
use super::rules::RULE_FROZEN_MANIFEST;
use super::{sha256, Finding};

/// Where the manifest lives, relative to the crate root.
pub const MANIFEST_PATH: &str = "lint/frozen.sha256";

/// The canonical frozen artifacts. Every entry must appear in the
/// committed manifest; a manifest that drops one is itself a finding.
pub const FROZEN: [&str; 7] = [
    "src/sim/reference.rs",
    "src/batcher/dp.rs#dp_batch_reference",
    "src/batcher/dp.rs#dp_plan_reference",
    "src/batcher/dp.rs#dp_plan_corrected_reference",
    "tests/props_dp_differential.rs",
    "tests/props_dp_corrected_differential.rs",
    "tests/props_policy_differential.rs",
];

/// 1-based inclusive line span of the first `fn <name>` item in `src`:
/// the `fn` keyword's line through the line of the brace closing its
/// body. `None` when the fn (or a complete body) isn't found.
pub fn fn_span(src: &str, fn_name: &str) -> Option<(u32, u32)> {
    let (toks, _) = lexer::lex(src);
    let mut i = 0;
    while i < toks.len() {
        let is_decl = toks[i].kind == TokKind::Ident
            && toks[i].text == "fn"
            && toks.get(i + 1).is_some_and(|t| t.kind == TokKind::Ident && t.text == fn_name);
        if !is_decl {
            i += 1;
            continue;
        }
        let start_line = toks[i].line;
        let mut j = i + 2;
        while j < toks.len() && !(toks[j].kind == TokKind::Punct && toks[j].text == "{") {
            j += 1;
        }
        let mut depth = 0i32;
        while j < toks.len() {
            if toks[j].kind == TokKind::Punct {
                if toks[j].text == "{" {
                    depth += 1;
                } else if toks[j].text == "}" {
                    depth -= 1;
                    if depth == 0 {
                        return Some((start_line, toks[j].line));
                    }
                }
            }
            j += 1;
        }
        return None;
    }
    None
}

/// Bytes of lines `lo..=hi` (1-based), each line rejoined with `\n` —
/// the normalisation both the manifest writer and checker hash.
pub fn span_bytes(src: &str, lo: u32, hi: u32) -> Vec<u8> {
    let mut out = Vec::new();
    for (idx, line) in src.split('\n').enumerate() {
        let n = (idx + 1) as u32;
        if n >= lo && n <= hi {
            out.extend_from_slice(line.as_bytes());
            out.push(b'\n');
        }
    }
    out
}

/// Digest of one manifest entry under `root`, or `None` when the file or
/// fn span can't be resolved.
pub fn digest_entry(root: &Path, entry: &str) -> Option<String> {
    if let Some((path, fn_name)) = entry.split_once('#') {
        let src = fs::read_to_string(root.join(path)).ok()?;
        let (lo, hi) = fn_span(&src, fn_name)?;
        Some(sha256::digest_hex(&span_bytes(&src, lo, hi)))
    } else {
        let data = fs::read(root.join(entry)).ok()?;
        Some(sha256::digest_hex(&data))
    }
}

/// Parse manifest text into `(digest, entry)` pairs. Malformed lines are
/// returned as findings rather than silently dropped.
pub fn parse(text: &str) -> (Vec<(String, String)>, Vec<Finding>) {
    let mut entries = Vec::new();
    let mut findings = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let ok = line
            .split_once("  ")
            .filter(|(hex, entry)| {
                hex.len() == 64
                    && hex.bytes().all(|b| b.is_ascii_hexdigit())
                    && !entry.trim().is_empty()
            })
            .map(|(hex, entry)| (hex.to_string(), entry.trim().to_string()));
        match ok {
            Some(pair) => entries.push(pair),
            None => findings.push(Finding {
                file: MANIFEST_PATH.to_string(),
                line: (idx + 1) as u32,
                rule: RULE_FROZEN_MANIFEST,
                message: format!("malformed manifest line (want `<sha256-hex>  <entry>`): {line}"),
            }),
        }
    }
    (entries, findings)
}

/// Check the committed manifest under `root`. A missing manifest file is
/// itself a finding — the frozen references must always be pinned.
pub fn check(root: &Path) -> Vec<Finding> {
    match fs::read_to_string(root.join(MANIFEST_PATH)) {
        Ok(text) => check_with(root, &text, &FROZEN),
        Err(_) => vec![Finding {
            file: MANIFEST_PATH.to_string(),
            line: 0,
            rule: RULE_FROZEN_MANIFEST,
            message: format!(
                "manifest {MANIFEST_PATH} is missing; regenerate with \
                 `scls-repro lint --write-manifest` and review the diff"
            ),
        }],
    }
}

/// Testable core of [`check`]: verify `manifest_text` against the tree at
/// `root`, requiring every entry in `required` to be covered.
pub fn check_with(root: &Path, manifest_text: &str, required: &[&str]) -> Vec<Finding> {
    let (entries, mut findings) = parse(manifest_text);
    for (want, entry) in &entries {
        match digest_entry(root, entry) {
            None => findings.push(Finding {
                file: MANIFEST_PATH.to_string(),
                line: 0,
                rule: RULE_FROZEN_MANIFEST,
                message: format!("frozen artifact `{entry}` not found (file or fn span missing)"),
            }),
            Some(got) if got != *want => findings.push(Finding {
                file: entry.split('#').next().unwrap_or(entry).to_string(),
                line: 0,
                rule: RULE_FROZEN_MANIFEST,
                message: format!(
                    "frozen artifact `{entry}` drifted: manifest {want} != tree {got}; \
                     frozen references are the spec — revert, or regenerate the manifest \
                     with `--write-manifest` and have the diff reviewed"
                ),
            }),
            Some(_) => {}
        }
    }
    for req in required {
        if !entries.iter().any(|(_, e)| e == req) {
            findings.push(Finding {
                file: MANIFEST_PATH.to_string(),
                line: 0,
                rule: RULE_FROZEN_MANIFEST,
                message: format!(
                    "canonical frozen artifact `{req}` is not covered by the manifest"
                ),
            });
        }
    }
    findings
}

/// The comment header both the committed manifest and `--write-manifest`
/// regeneration carry, so regeneration on an unchanged tree is a no-op
/// diff.
pub const HEADER: &str = "\
# Frozen-reference manifest — checked by `scls-repro lint` (rule:
# frozen-manifest). These artifacts are byte-frozen: the differential
# suites compare optimised implementations against them, so any edit
# must be deliberate. Regenerate with `scls-repro lint --write-manifest`
# and have the diff reviewed.
";

/// Render the manifest for the current tree (the `--write-manifest`
/// payload). Entries that can't be digested render as a comment so the
/// breakage is visible in the diff rather than silently dropped.
pub fn render(root: &Path) -> String {
    let mut out = String::from(HEADER);
    for entry in FROZEN {
        match digest_entry(root, entry) {
            Some(hex) => {
                out.push_str(&hex);
                out.push_str("  ");
            }
            None => out.push_str("# UNRESOLVED  "),
        }
        out.push_str(entry);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = "fn alpha() -> u32 {\n    let s = \"}\"; // }\n    1\n}\n\nfn beta() {}\n";

    #[test]
    fn fn_span_matches_braces_not_strings() {
        assert_eq!(fn_span(SRC, "alpha"), Some((1, 4)));
        assert_eq!(fn_span(SRC, "beta"), Some((6, 6)));
        assert_eq!(fn_span(SRC, "gamma"), None);
    }

    #[test]
    fn span_bytes_rejoins_with_newlines() {
        assert_eq!(span_bytes(SRC, 6, 6), b"fn beta() {}\n");
        let whole = span_bytes(SRC, 1, 4);
        assert!(whole.starts_with(b"fn alpha"));
        assert!(whole.ends_with(b"}\n"));
    }

    #[test]
    fn parse_flags_malformed_lines() {
        let text = "# comment\n\nabc  src/x.rs\n";
        let (entries, findings) = parse(text);
        assert!(entries.is_empty());
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].line, 3);
        assert_eq!(findings[0].rule, RULE_FROZEN_MANIFEST);
    }

    #[test]
    fn parse_accepts_sha256sum_format() {
        let hex = "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855";
        let (entries, findings) = parse(&format!("{hex}  src/sim/reference.rs\n"));
        assert!(findings.is_empty());
        assert_eq!(entries, vec![(hex.to_string(), "src/sim/reference.rs".to_string())]);
    }

    #[test]
    fn check_with_reports_drift_missing_and_uncovered() {
        let dir = std::env::temp_dir().join(format!("scls_lint_manifest_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("src")).unwrap();
        std::fs::write(dir.join("src/frozen.rs"), "fn keep() {}\n").unwrap();
        let good = sha256::digest_hex(b"fn keep() {}\n");

        // Clean: digest matches, required entry covered.
        let manifest = format!("{good}  src/frozen.rs\n");
        assert!(check_with(&dir, &manifest, &["src/frozen.rs"]).is_empty());

        // Drift: digest mismatch names the file and the rule.
        let bad = format!("{}  src/frozen.rs\n", "0".repeat(64));
        let f = check_with(&dir, &bad, &["src/frozen.rs"]);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].file, "src/frozen.rs");
        assert!(f[0].message.contains("drifted"));

        // Missing artifact + uncovered canonical entry.
        let gone = format!("{good}  src/not_there.rs\n");
        let f = check_with(&dir, &gone, &["src/frozen.rs"]);
        assert_eq!(f.len(), 2);
        assert!(f.iter().any(|x| x.message.contains("not found")));
        assert!(f.iter().any(|x| x.message.contains("not covered")));

        std::fs::remove_dir_all(&dir).ok();
    }
}
