//! Predictor registry: construct a [`LengthPredictor`] by name, mirroring
//! the policy registry ([`crate::scheduler::policy::parse_policy_name`]).
//!
//! Names are case-insensitive and accept `_`/`-` variants; an optional
//! `:param` suffix carries the predictor's main knob, so the CLI spellings
//! `--predictor noisy:0.25`, `--predictor bucket:8`, and
//! `--predictor percentile:90` all parse. [`PredictorSpec`] is the
//! declarative form that travels inside [`crate::sim::driver::SimConfig`];
//! `build` instantiates the predictor against the workload and seed.

use crate::workload::distributions::WorkloadKind;

use super::{
    BucketClassifier, LengthPredictor, NoisyOracle, OnlineBuckets, Oracle, PercentileConst,
};

/// Canonical names of the built-in predictors.
pub const BUILTIN_PREDICTORS: [&str; 5] = ["oracle", "noisy", "bucket", "online", "percentile"];

/// Case-insensitive canonicalization of a predictor name (no `:param`
/// suffix; see [`PredictorSpec::parse`] for the full spec syntax).
pub fn canonical_predictor_name(s: &str) -> Option<&'static str> {
    let low = s.trim().replace('_', "-").to_ascii_lowercase();
    match low.as_str() {
        "oracle" | "exact" => Some("oracle"),
        "noisy" | "noisy-oracle" => Some("noisy"),
        "bucket" | "buckets" | "classifier" => Some("bucket"),
        "online" | "online-buckets" => Some("online"),
        "percentile" | "const" => Some("percentile"),
        _ => None,
    }
}

/// Parse a predictor name from user input. On failure the error lists
/// every valid name.
pub fn parse_predictor_name(s: &str) -> Result<&'static str, String> {
    canonical_predictor_name(s).ok_or_else(|| {
        format!(
            "unknown predictor '{s}' (valid, case-insensitive: {})",
            BUILTIN_PREDICTORS.join(", ")
        )
    })
}

/// Declarative predictor configuration — what `SimConfig` carries and the
/// CLI/figure suite construct. `build` turns it into a live predictor.
#[derive(Debug, Clone, PartialEq)]
pub enum PredictorSpec {
    /// Perfect foresight.
    Oracle,
    /// Multiplicative log-normal error of the given σ.
    Noisy { sigma: f64 },
    /// Quantile-bucket classifier fit from the workload's generation-length
    /// distribution.
    Bucket {
        buckets: u32,
        accuracy: f64,
        workload: WorkloadKind,
    },
    /// Online quantile-bucket classifier: starts from a prior fit on the
    /// workload's distribution, then refits its edges from a sliding
    /// window of the most recent `window` completed-request lengths.
    Online {
        window: usize,
        buckets: u32,
        accuracy: f64,
        workload: WorkloadKind,
    },
    /// Fixed workload percentile for every request.
    Percentile { pct: f64, workload: WorkloadKind },
}

impl PredictorSpec {
    pub const DEFAULT_SIGMA: f64 = 0.25;
    pub const DEFAULT_BUCKETS: u32 = 8;
    pub const DEFAULT_ACCURACY: f64 = 0.85;
    pub const DEFAULT_PCT: f64 = 90.0;
    pub const DEFAULT_WINDOW: usize = OnlineBuckets::DEFAULT_WINDOW;
    /// Upper bound on `bucket:<count>` (quantile cuts of a 64Ki
    /// calibration sample — more buckets than samples is meaningless).
    pub const MAX_BUCKETS: u32 = 65_536;
    /// Upper bound on `online:<window>` (the window is pre-allocated).
    pub const MAX_WINDOW: usize = 1 << 24;

    /// Parse `name` or `name:param` (e.g. `noisy:0.25`, `bucket:8`,
    /// `online:4096`, `percentile:90`). `workload` supplies the length
    /// distribution the fitted predictors calibrate against.
    pub fn parse(s: &str, workload: WorkloadKind) -> Result<PredictorSpec, String> {
        let (name, param) = match s.split_once(':') {
            Some((n, p)) => (n, Some(p.trim())),
            None => (s, None),
        };
        let parse_param = |what: &str| -> Result<Option<f64>, String> {
            param
                .map(|p| {
                    p.parse::<f64>()
                        .map_err(|_| format!("predictor '{name}': bad {what} '{p}'"))
                })
                .transpose()
        };
        // Integer-valued knobs (bucket counts, window sizes) must actually
        // be integers in a sane range — an unchecked `as` cast would turn
        // `online:1e18` into a capacity-overflow abort instead of an error.
        let is_integral = |v: f64| v.fract() == 0.0; // scls-lint: allow(float-cmp): exact test
        let parse_count = |what: &str, max: u64| -> Result<Option<u64>, String> {
            match parse_param(what)? {
                None => Ok(None),
                Some(v) if is_integral(v) && v >= 1.0 && v <= max as f64 => Ok(Some(v as u64)),
                Some(v) => Err(format!(
                    "predictor '{name}': {what} must be an integer in [1, {max}] (got '{v}')"
                )),
            }
        };
        Ok(match parse_predictor_name(name)? {
            "oracle" => {
                if let Some(p) = param {
                    return Err(format!("predictor 'oracle' takes no parameter (got '{p}')"));
                }
                PredictorSpec::Oracle
            }
            "noisy" => {
                // A negative (or NaN/∞) sigma would propagate into the
                // log-normal draw as a degenerate error model; reject it
                // here so both the `noisy:-0.5` spelling and the
                // `--pred-sigma` flag (which funnels through the same
                // bounds) fail with a friendly message.
                let sigma = parse_param("sigma")?.unwrap_or(Self::DEFAULT_SIGMA);
                if !(sigma.is_finite() && sigma >= 0.0) {
                    return Err(format!(
                        "predictor 'noisy': sigma must be a finite non-negative number (got '{sigma}')"
                    ));
                }
                PredictorSpec::Noisy { sigma }
            }
            "bucket" => PredictorSpec::Bucket {
                buckets: parse_count("bucket count", Self::MAX_BUCKETS as u64)?
                    .map(|b| b as u32)
                    .unwrap_or(Self::DEFAULT_BUCKETS),
                accuracy: Self::DEFAULT_ACCURACY,
                workload,
            },
            "online" => PredictorSpec::Online {
                window: parse_count("window size", Self::MAX_WINDOW as u64)?
                    .map(|w| w as usize)
                    .unwrap_or(Self::DEFAULT_WINDOW),
                buckets: Self::DEFAULT_BUCKETS,
                accuracy: Self::DEFAULT_ACCURACY,
                workload,
            },
            "percentile" => {
                let pct = parse_param("percentile")?.unwrap_or(Self::DEFAULT_PCT);
                if !(pct.is_finite() && (0.0..=100.0).contains(&pct)) {
                    return Err(format!(
                        "predictor 'percentile': percentile must be in [0, 100] (got '{pct}')"
                    ));
                }
                PredictorSpec::Percentile { pct, workload }
            }
            other => unreachable!("canonical predictor {other} not constructed"),
        })
    }

    /// Canonical name of the predictor this spec constructs.
    pub fn name(&self) -> &'static str {
        match self {
            PredictorSpec::Oracle => "oracle",
            PredictorSpec::Noisy { .. } => "noisy",
            PredictorSpec::Bucket { .. } => "bucket",
            PredictorSpec::Online { .. } => "online",
            PredictorSpec::Percentile { .. } => "percentile",
        }
    }

    /// Human-readable `name:param` form (CLI echo, figure labels).
    pub fn describe(&self) -> String {
        match self {
            PredictorSpec::Oracle => "oracle".into(),
            PredictorSpec::Noisy { sigma } => format!("noisy:{sigma}"),
            PredictorSpec::Bucket {
                buckets, accuracy, ..
            } => format!("bucket:{buckets} (accuracy {accuracy})"),
            PredictorSpec::Online {
                window,
                buckets,
                accuracy,
                ..
            } => format!("online:{window} ({buckets} buckets, accuracy {accuracy})"),
            PredictorSpec::Percentile { pct, .. } => format!("percentile:{pct}"),
        }
    }

    /// Instantiate the predictor. `max_gen_len` bounds the calibration
    /// distributions; `seed` drives both the calibration sample and the
    /// per-request error draws.
    pub fn build(&self, max_gen_len: u32, seed: u64) -> Box<dyn LengthPredictor> {
        match self {
            PredictorSpec::Oracle => Box::new(Oracle),
            PredictorSpec::Noisy { sigma } => Box::new(NoisyOracle::new(*sigma, seed)),
            PredictorSpec::Bucket {
                buckets,
                accuracy,
                workload,
            } => Box::new(BucketClassifier::fit_distribution(
                &workload.gen_dist(max_gen_len),
                *buckets,
                *accuracy,
                seed,
            )),
            PredictorSpec::Online {
                window,
                buckets,
                accuracy,
                workload,
            } => Box::new(OnlineBuckets::with_prior_distribution(
                &workload.gen_dist(max_gen_len),
                *buckets,
                *accuracy,
                *window,
                seed,
                max_gen_len,
            )),
            PredictorSpec::Percentile { pct, workload } => Box::new(
                PercentileConst::fit_distribution(&workload.gen_dist(max_gen_len), *pct, seed),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_is_case_insensitive() {
        assert_eq!(parse_predictor_name("Oracle"), Ok("oracle"));
        assert_eq!(parse_predictor_name("NOISY"), Ok("noisy"));
        assert_eq!(parse_predictor_name("noisy_oracle"), Ok("noisy"));
        assert_eq!(parse_predictor_name(" bucket "), Ok("bucket"));
        assert_eq!(parse_predictor_name("const"), Ok("percentile"));
        assert_eq!(parse_predictor_name("Online"), Ok("online"));
        assert_eq!(parse_predictor_name("online_buckets"), Ok("online"));
    }

    #[test]
    fn parse_error_lists_valid_names() {
        let err = parse_predictor_name("lstm").unwrap_err();
        assert!(err.contains("unknown predictor 'lstm'"), "{err}");
        for name in BUILTIN_PREDICTORS {
            assert!(err.contains(name), "error must list {name}: {err}");
        }
    }

    #[test]
    fn spec_parses_params() {
        let w = WorkloadKind::CodeFuse;
        assert_eq!(PredictorSpec::parse("oracle", w), Ok(PredictorSpec::Oracle));
        assert_eq!(
            PredictorSpec::parse("noisy:0.5", w),
            Ok(PredictorSpec::Noisy { sigma: 0.5 })
        );
        assert_eq!(
            PredictorSpec::parse("Bucket:4", w),
            Ok(PredictorSpec::Bucket {
                buckets: 4,
                accuracy: PredictorSpec::DEFAULT_ACCURACY,
                workload: w
            })
        );
        assert_eq!(
            PredictorSpec::parse("percentile:99", w),
            Ok(PredictorSpec::Percentile {
                pct: 99.0,
                workload: w
            })
        );
        assert_eq!(
            PredictorSpec::parse("online:2048", w),
            Ok(PredictorSpec::Online {
                window: 2048,
                buckets: PredictorSpec::DEFAULT_BUCKETS,
                accuracy: PredictorSpec::DEFAULT_ACCURACY,
                workload: w
            })
        );
        assert_eq!(
            PredictorSpec::parse("online", w),
            Ok(PredictorSpec::Online {
                window: PredictorSpec::DEFAULT_WINDOW,
                buckets: PredictorSpec::DEFAULT_BUCKETS,
                accuracy: PredictorSpec::DEFAULT_ACCURACY,
                workload: w
            })
        );
        // Defaults when the param is omitted.
        assert_eq!(
            PredictorSpec::parse("noisy", w),
            Ok(PredictorSpec::Noisy {
                sigma: PredictorSpec::DEFAULT_SIGMA
            })
        );
        assert!(PredictorSpec::parse("noisy:abc", w).is_err());
        assert!(PredictorSpec::parse("oracle:1", w).is_err());
        assert!(PredictorSpec::parse("vllm", w).is_err());
        // Degenerate knob values fail with a friendly message instead of
        // propagating into a degenerate fit.
        let err = PredictorSpec::parse("noisy:-0.5", w).unwrap_err();
        assert!(err.contains("finite non-negative"), "{err}");
        assert!(PredictorSpec::parse("noisy:nan", w).is_err());
        assert!(PredictorSpec::parse("noisy:inf", w).is_err());
        assert_eq!(
            PredictorSpec::parse("noisy:0", w),
            Ok(PredictorSpec::Noisy { sigma: 0.0 }),
            "sigma 0 (exact oracle) stays valid"
        );
        let err = PredictorSpec::parse("percentile:150", w).unwrap_err();
        assert!(err.contains("[0, 100]"), "{err}");
        assert!(PredictorSpec::parse("percentile:-5", w).is_err());
        assert!(PredictorSpec::parse("percentile:nan", w).is_err());
        // Integer knobs reject absurd, fractional, and non-positive values
        // with an error instead of casting into an abort.
        assert!(PredictorSpec::parse("online:1e18", w).is_err());
        assert!(PredictorSpec::parse("online:0.5", w).is_err());
        assert!(PredictorSpec::parse("online:0", w).is_err());
        assert!(PredictorSpec::parse("bucket:1e18", w).is_err());
        assert!(PredictorSpec::parse("bucket:2.5", w).is_err());
    }

    #[test]
    fn every_builtin_builds() {
        let w = WorkloadKind::ShareGpt;
        for name in BUILTIN_PREDICTORS {
            let spec = PredictorSpec::parse(name, w).unwrap();
            let p = spec.build(1024, 42);
            assert_eq!(p.name(), spec.name());
            let r = crate::core::Request::new(1, 0.0, 64, 200);
            assert!(p.predict(&r) >= 1);
        }
    }
}
