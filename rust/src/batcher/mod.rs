//! Batching policies: the paper's DP adaptive batcher (Alg. 1) and the
//! FCFS fixed-size baseline used by SLS/SO/PM.

pub mod dp;
pub mod fcfs;

pub use dp::{dp_batch, DpBatcherConfig};
pub use fcfs::fcfs_batches;
