//! The request model (paper §2.1).
//!
//! A request arrives with a raw input of `input_len` tokens and an
//! *unpredictable* generation length. The scheduler never observes the
//! generation length; engines do — the sim engine consumes the trace's
//! `target_gen_len` as its EOS oracle, the real engine discovers EOS from
//! the model's actual output tokens.

use crate::slo::SloSpec;

pub type RequestId = u64;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Arrival time (seconds, virtual or wall-relative).
    pub arrival: f64,
    /// Raw input length at arrival (tokens), after truncation to the limit.
    pub orig_input_len: u32,
    /// Current input length: grows on each SCLS reschedule because the
    /// prefill is recomputed over input + previously generated tokens.
    pub input_len: u32,
    /// EOS oracle for the SIM engine: total tokens this request generates
    /// before emitting EOS (uncapped; the max-generation limit applies at
    /// serving time). The scheduler MUST NOT read this — it is the paper's
    /// central premise that generation lengths are unknown a priori.
    pub target_gen_len: u32,
    /// Tokens generated so far across all slices.
    pub generated: u32,
    /// Number of times this request has been scheduled (slice count).
    pub slices: u32,
    /// Accumulated pad tokens across all schedules (Fig. 13c accounting:
    /// the paper sums pads over every reschedule).
    pub pad_tokens: u64,
    /// Accumulated invalid tokens (generated after this request's EOS while
    /// waiting for the rest of its batch).
    pub invalid_tokens: u64,
    /// Predicted total generation length, stamped by a
    /// [`crate::predictor::LengthPredictor`] when a prediction-aware
    /// policy admits the request (`None` under prediction-free policies).
    /// Unlike `target_gen_len` this is scheduler-visible by design: it is
    /// the proxy-model estimate, not the oracle.
    pub predicted_gen: Option<u32>,
    /// Set when the response is returned to the user.
    pub finished_at: Option<f64>,
    /// Owning tenant (0 = default single-tenant world).
    pub tenant: u32,
    /// Priority class, 0 = most urgent (mirrors the tenant tier under
    /// [`crate::slo::stamp_trace`]; free-form for custom embedders).
    pub priority: u8,
    /// Service-level objective (TTFT / TPOT / deadline targets);
    /// [`SloSpec::none`] keeps the request invisible to SLO accounting.
    pub slo: SloSpec,
    /// When the first generated token was delivered (stamped by
    /// static-batching policies at the end of the first served slice;
    /// `None` means SLO evaluation falls back to `finished_at`).
    pub first_token_at: Option<f64>,
    /// Real-engine only: concrete token ids of the current input (original
    /// prompt + generated so far, in order). Empty in sim mode.
    pub tokens: Vec<i32>,
    /// Real-engine only: whether EOS has been observed in the output.
    pub eos_seen: bool,
}

impl Request {
    pub fn new(id: RequestId, arrival: f64, input_len: u32, target_gen_len: u32) -> Request {
        Request {
            id,
            arrival,
            orig_input_len: input_len,
            input_len,
            target_gen_len,
            generated: 0,
            slices: 0,
            pad_tokens: 0,
            invalid_tokens: 0,
            predicted_gen: None,
            finished_at: None,
            tenant: 0,
            priority: 0,
            slo: SloSpec::none(),
            first_token_at: None,
            tokens: Vec::new(),
            eos_seen: false,
        }
    }

    /// Real-mode constructor carrying concrete token ids.
    pub fn with_tokens(id: RequestId, arrival: f64, tokens: Vec<i32>) -> Request {
        let len = tokens.len() as u32;
        let mut r = Request::new(id, arrival, len, u32::MAX);
        r.tokens = tokens;
        r
    }

    pub fn is_finished(&self) -> bool {
        self.finished_at.is_some()
    }

    /// Response time (paper's metric: send → receive generated results).
    pub fn response_time(&self) -> Option<f64> {
        self.finished_at.map(|f| f - self.arrival)
    }

    /// Tokens remaining until the sim-mode EOS oracle fires.
    pub fn remaining_to_eos(&self) -> u32 {
        self.target_gen_len.saturating_sub(self.generated)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_request_defaults() {
        let r = Request::new(1, 2.5, 100, 40);
        assert_eq!(r.input_len, 100);
        assert_eq!(r.orig_input_len, 100);
        assert!(!r.is_finished());
        assert_eq!(r.response_time(), None);
        assert_eq!(r.remaining_to_eos(), 40);
        assert_eq!(r.tenant, 0);
        assert_eq!(r.priority, 0);
        assert!(r.slo.is_none());
        assert_eq!(r.first_token_at, None);
    }

    #[test]
    fn response_time_after_finish() {
        let mut r = Request::new(1, 2.0, 10, 5);
        r.finished_at = Some(7.5);
        assert_eq!(r.response_time(), Some(5.5));
    }

    #[test]
    fn remaining_saturates() {
        let mut r = Request::new(1, 0.0, 10, 5);
        r.generated = 9;
        assert_eq!(r.remaining_to_eos(), 0);
    }

    #[test]
    fn with_tokens_sets_len() {
        let r = Request::with_tokens(3, 0.0, vec![5, 6, 7]);
        assert_eq!(r.input_len, 3);
        assert_eq!(r.tokens, vec![5, 6, 7]);
    }
}
