// Lint fixture (never compiled): import-graph positives and suppressions.
// Scanned under "src/sim/fixture.rs" (deterministic: checked) and
// "src/telemetry/fixture.rs" (out of scope) by tests/props_lint.rs.
use crate::runtime::ModelRuntime; // line 4: finding (whole-module match)
use crate::bench::harness::FigureConfig; // line 5: finding
use crate::util::logging::log_line; // line 6: finding (submodule match)
use crate::telemetry::hist::Histogram; // telemetry alone is not allowlisted
use crate::util::stats::mean; // util alone is not allowlisted
use crate::scheduler::fleet::WorkerLedger; // deterministic peer: fine

fn positives() {
    let _t = crate::telemetry::profile::timer("tick"); // line 12: finding
}

fn suppressed() {
    let _t = crate::telemetry::profile::timer("tock"); // scls-lint: allow(import-graph): opt-in profiling tap
}

fn never_fire() {
    // crate::runtime in a comment is not a finding, nor in a string:
    let s = "crate::bench::harness";
    drop(s);
}
