//! PJRT execution of the AOT slice-serving programs.
//!
//! One `ModelRuntime` owns a PJRT CPU client plus lazily-compiled
//! executables per bucket (compile once, run many). The HLO-text →
//! HloModuleProto → XlaComputation → compile path follows
//! /opt/xla-example/load_hlo (text is the id-safe interchange format).

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::artifacts::{Bucket, Manifest};

/// Output of one slice execution.
#[derive(Debug, Clone)]
pub struct SliceResult {
    /// Generated tokens, one row per bucket row (N × S; rows past the real
    /// batch are filler). Columns ≥ `iters` are PAD.
    pub gen: Vec<Vec<i32>>,
    /// Decode iterations actually executed (< S ⇒ early return).
    pub iters: u32,
    /// Wall-clock seconds of the PJRT execution.
    pub wall: f64,
}

/// PJRT client + compiled executable cache for one worker.
pub struct ModelRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    compiled: HashMap<(u32, u32, u32), xla::PjRtLoadedExecutable>,
}

impl ModelRuntime {
    /// Create a runtime over an artifact directory (loads the manifest;
    /// compiles lazily on first use of each bucket).
    pub fn new(artifacts_dir: &Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(ModelRuntime {
            manifest,
            client,
            compiled: HashMap::new(),
        })
    }

    /// Pre-compile every bucket (startup cost instead of first-request
    /// latency — what a production deployment does).
    pub fn warmup(&mut self) -> Result<()> {
        let buckets: Vec<Bucket> = self.manifest.buckets.clone();
        for b in &buckets {
            self.ensure_compiled(b)?;
        }
        Ok(())
    }

    fn ensure_compiled(&mut self, b: &Bucket) -> Result<()> {
        let key = (b.n, b.l, b.s);
        if self.compiled.contains_key(&key) {
            return Ok(());
        }
        let path = self.manifest.bucket_path(b);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        self.compiled.insert(key, exe);
        Ok(())
    }

    /// Execute one slice on a bucket.
    ///
    /// * `tokens`: row-major (bucket.n × bucket.l) LEFT-padded token ids.
    /// * `lengths`: true length per row (filler rows: 1).
    /// * `active`: 1 for real requests, 0 for filler rows.
    /// * `gen_offset`: tokens generated in prior slices per row.
    pub fn execute_slice(
        &mut self,
        bucket: &Bucket,
        tokens: &[i32],
        lengths: &[i32],
        active: &[i32],
        gen_offset: &[i32],
    ) -> Result<SliceResult> {
        let (n, l, s) = (bucket.n as usize, bucket.l as usize, bucket.s as usize);
        anyhow::ensure!(tokens.len() == n * l, "tokens must be n*l");
        anyhow::ensure!(lengths.len() == n && active.len() == n && gen_offset.len() == n);
        self.ensure_compiled(bucket)?;
        let exe = self
            .compiled
            .get(&(bucket.n, bucket.l, bucket.s))
            .expect("just compiled");

        let tok_lit = xla::Literal::vec1(tokens)
            .reshape(&[n as i64, l as i64])
            .map_err(|e| anyhow!("reshape tokens: {e:?}"))?;
        let len_lit = xla::Literal::vec1(lengths);
        let act_lit = xla::Literal::vec1(active);
        let off_lit = xla::Literal::vec1(gen_offset);

        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&[tok_lit, len_lit, act_lit, off_lit])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let wall = t0.elapsed().as_secs_f64();

        // aot.py lowers with return_tuple=True: (gen (N,S) i32, iters i32).
        let (gen_lit, iters_lit) = result
            .to_tuple2()
            .map_err(|e| anyhow!("tuple2: {e:?}"))?;
        let flat: Vec<i32> = gen_lit
            .to_vec::<i32>()
            .map_err(|e| anyhow!("gen to_vec: {e:?}"))?;
        anyhow::ensure!(flat.len() == n * s, "gen shape mismatch");
        let iters: u32 = iters_lit
            .to_vec::<i32>()
            .map_err(|e| anyhow!("iters to_vec: {e:?}"))?
            .first()
            .copied()
            .context("empty iters literal")? as u32;

        let gen = flat.chunks(s).map(|c| c.to_vec()).collect();
        Ok(SliceResult { gen, iters, wall })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn art_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        art_dir().join("manifest.json").exists()
    }

    /// Build a left-padded row batch for the smallest bucket.
    fn padded(rows: &[&[i32]], l: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::new();
        let mut lens = Vec::new();
        for r in rows {
            let mut row = vec![0i32; l - r.len()];
            row.extend_from_slice(r);
            toks.extend(row);
            lens.push(r.len() as i32);
        }
        (toks, lens)
    }

    #[test]
    fn executes_smallest_bucket() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = ModelRuntime::new(&art_dir()).unwrap();
        let s = rt.manifest.slice_lens()[0];
        let bucket = rt.manifest.pick(1, 16, s).unwrap().clone();
        let (toks, lens) = padded(&[&[5, 6, 7, 8]], bucket.l as usize);
        let res = rt
            .execute_slice(&bucket, &toks, &lens, &[1], &[0])
            .unwrap();
        assert_eq!(res.gen.len(), 1);
        assert_eq!(res.gen[0].len(), bucket.s as usize);
        assert!(res.iters >= 1 && res.iters <= bucket.s);
        assert!(res.wall > 0.0);
        // generated tokens in-range, no PAD/BOS before the iters cut
        for &t in &res.gen[0][..res.iters as usize] {
            assert!(t >= 1 && t < rt.manifest.model.vocab as i32);
            assert_ne!(t, rt.manifest.model.bos_id);
        }
    }

    #[test]
    fn deterministic_across_calls() {
        if !have_artifacts() {
            return;
        }
        let mut rt = ModelRuntime::new(&art_dir()).unwrap();
        let s = rt.manifest.slice_lens()[0];
        let bucket = rt.manifest.pick(2, 16, s).unwrap().clone();
        let (toks, lens) = padded(&[&[10, 11, 12], &[20, 21, 22, 23, 24]], bucket.l as usize);
        let a = rt
            .execute_slice(&bucket, &toks, &lens, &[1, 1], &[0, 0])
            .unwrap();
        let b = rt
            .execute_slice(&bucket, &toks, &lens, &[1, 1], &[0, 0])
            .unwrap();
        assert_eq!(a.gen, b.gen);
        assert_eq!(a.iters, b.iters);
    }

    #[test]
    fn filler_rows_do_not_change_active_rows() {
        if !have_artifacts() {
            return;
        }
        let mut rt = ModelRuntime::new(&art_dir()).unwrap();
        let s = rt.manifest.slice_lens()[0];
        let b1 = rt.manifest.pick(1, 16, s).unwrap().clone();
        let (t1, l1) = padded(&[&[9, 8, 7, 6, 5]], b1.l as usize);
        let solo = rt.execute_slice(&b1, &t1, &l1, &[1], &[0]).unwrap();

        let b2 = rt.manifest.pick(2, 16, s).unwrap().clone();
        let (t2, l2) = padded(&[&[9, 8, 7, 6, 5], &[3]], b2.l as usize);
        let dual = rt
            .execute_slice(&b2, &t2, &l2, &[1, 0], &[0, 0])
            .unwrap();
        // Row 0's stream must be identical whether or not filler rides along.
        let k = solo.iters.min(dual.iters) as usize;
        assert_eq!(solo.gen[0][..k], dual.gen[0][..k]);
    }

    #[test]
    fn rejects_bad_shapes() {
        if !have_artifacts() {
            return;
        }
        let mut rt = ModelRuntime::new(&art_dir()).unwrap();
        let s = rt.manifest.slice_lens()[0];
        let bucket = rt.manifest.pick(1, 16, s).unwrap().clone();
        assert!(rt
            .execute_slice(&bucket, &[1, 2, 3], &[3], &[1], &[0])
            .is_err());
    }
}
