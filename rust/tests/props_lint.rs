//! Self-tests for the `scls-repro lint` static-analysis pass.
//!
//! Three layers:
//!
//! 1. **Fixture proofs** — for every token rule, a fixture under
//!    `tests/fixtures/lint/` is scanned under virtual paths proving the
//!    rule fires (positive lines), honours per-line suppressions, and
//!    stays silent in allowlisted / non-deterministic modules.
//! 2. **Frozen-manifest drift** — a throwaway tree shows that editing a
//!    frozen artifact flips lint from clean to failing, and that
//!    `--write-manifest` regeneration is byte-stable on a clean tree.
//! 3. **The repo itself** — `run_lint` over this crate returns zero
//!    findings, which is exactly what CI enforces.

use std::path::{Path, PathBuf};

use scls::analysis::{
    manifest, run_lint, scan_source, surface, RULE_FLOAT_CMP, RULE_FROZEN_MANIFEST,
    RULE_HASH_ORDER, RULE_IMPORT_GRAPH, RULE_SINK_SURFACE, RULE_WALL_CLOCK,
};

const HASH_ORDER: &str = include_str!("fixtures/lint/hash_order.rs");
const WALL_CLOCK: &str = include_str!("fixtures/lint/wall_clock.rs");
const FLOAT_CMP: &str = include_str!("fixtures/lint/float_cmp.rs");
const IMPORT_GRAPH: &str = include_str!("fixtures/lint/import_graph.rs");
const CLEAN: &str = include_str!("fixtures/lint/clean.rs");

fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn rule_lines(rel: &str, src: &str, rule: &str) -> Vec<u32> {
    scan_source(rel, src)
        .into_iter()
        .filter(|f| f.rule == rule)
        .map(|f| f.line)
        .collect()
}

// ---------------------------------------------------------------- rule fixtures

#[test]
fn hash_order_fixture_fires_suppresses_and_respects_module_set() {
    // Deterministic module: every unsuppressed mention fires (line 9
    // mentions HashMap twice), the suppressed line (15) stays silent.
    let lines = rule_lines("src/sim/fixture.rs", HASH_ORDER, RULE_HASH_ORDER);
    assert_eq!(lines, vec![5, 6, 9, 9, 10]);
    // Non-deterministic module: the same text is entirely out of scope.
    assert!(rule_lines("src/telemetry/fixture.rs", HASH_ORDER, RULE_HASH_ORDER).is_empty());
    assert!(rule_lines("tests/fixture.rs", HASH_ORDER, RULE_HASH_ORDER).is_empty());
}

#[test]
fn wall_clock_fixture_fires_suppresses_and_respects_allowlist() {
    let lines = rule_lines("src/sim/fixture.rs", WALL_CLOCK, RULE_WALL_CLOCK);
    assert_eq!(lines, vec![4, 5, 8, 9]);
    // The rule applies outside deterministic modules too…
    assert_eq!(rule_lines("src/metrics/fixture.rs", WALL_CLOCK, RULE_WALL_CLOCK).len(), 4);
    // …but never inside the real-time allowlist (module and submodule).
    assert!(rule_lines("src/bench/fixture.rs", WALL_CLOCK, RULE_WALL_CLOCK).is_empty());
    assert!(rule_lines("src/util/logging.rs", WALL_CLOCK, RULE_WALL_CLOCK).is_empty());
    assert!(rule_lines("src/main.rs", WALL_CLOCK, RULE_WALL_CLOCK).is_empty());
}

#[test]
fn float_cmp_fixture_fires_suppresses_and_respects_module_set() {
    let lines = rule_lines("src/estimator/fixture.rs", FLOAT_CMP, RULE_FLOAT_CMP);
    assert_eq!(lines, vec![6, 7, 8]);
    assert!(rule_lines("src/util/fixture.rs", FLOAT_CMP, RULE_FLOAT_CMP).is_empty());
}

#[test]
fn import_graph_fixture_fires_suppresses_and_respects_module_set() {
    // Deterministic module: whole-module and submodule allowlist paths
    // fire; non-allowlisted siblings (`telemetry::hist`, `util::stats`)
    // and deterministic peers stay silent; line 16 is suppressed.
    let lines = rule_lines("src/sim/fixture.rs", IMPORT_GRAPH, RULE_IMPORT_GRAPH);
    assert_eq!(lines, vec![4, 5, 6, 12]);
    // Outside the deterministic set the dependency is legitimate.
    assert!(rule_lines("src/telemetry/fixture.rs", IMPORT_GRAPH, RULE_IMPORT_GRAPH).is_empty());
    assert!(rule_lines("src/metrics/fixture.rs", IMPORT_GRAPH, RULE_IMPORT_GRAPH).is_empty());
}

#[test]
fn clean_fixture_is_clean_everywhere() {
    for rel in ["src/sim/fixture.rs", "src/batcher/fixture.rs", "src/telemetry/fixture.rs"] {
        assert_eq!(scan_source(rel, CLEAN), vec![], "{rel}");
    }
}

// ---------------------------------------------------------------- frozen manifest

/// Build a tiny crate tree with one frozen file + matching manifest.
fn scratch_tree(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("scls_props_lint_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(dir.join("src")).unwrap();
    std::fs::write(dir.join("src/frozen.rs"), "fn reference() -> u32 {\n    7\n}\n").unwrap();
    dir
}

#[test]
fn frozen_manifest_drift_flips_clean_to_failing() {
    let dir = scratch_tree("drift");
    let entry = "src/frozen.rs#reference";
    let good = manifest::digest_entry(&dir, entry).unwrap();
    let text = format!("{good}  {entry}\n");
    assert!(manifest::check_with(&dir, &text, &[entry]).is_empty());

    // Edit the frozen fn — same file, one token changed.
    std::fs::write(dir.join("src/frozen.rs"), "fn reference() -> u32 {\n    8\n}\n").unwrap();
    let findings = manifest::check_with(&dir, &text, &[entry]);
    assert_eq!(findings.len(), 1);
    assert_eq!(findings[0].rule, RULE_FROZEN_MANIFEST);
    assert!(findings[0].message.contains("drifted"), "{}", findings[0].message);

    // Appending *after* the fn leaves the span digest intact (the span is
    // the fn body, not the file), so span pins survive unrelated edits.
    std::fs::write(
        dir.join("src/frozen.rs"),
        "fn reference() -> u32 {\n    7\n}\n\nfn unrelated() {}\n",
    )
    .unwrap();
    assert!(manifest::check_with(&dir, &text, &[entry]).is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn committed_manifest_matches_regeneration_byte_for_byte() {
    // `lint --write-manifest` on the committed tree must be a no-op diff:
    // the Rust digests, the entry order, and the header all match what is
    // checked in at lint/frozen.sha256.
    let root = crate_root();
    let committed = std::fs::read_to_string(root.join(manifest::MANIFEST_PATH)).unwrap();
    assert_eq!(manifest::render(&root), committed);
}

#[test]
fn every_canonical_frozen_entry_resolves_on_this_tree() {
    let root = crate_root();
    for entry in manifest::FROZEN {
        assert!(
            manifest::digest_entry(&root, entry).is_some(),
            "frozen entry `{entry}` did not resolve (file moved or fn renamed?)"
        );
    }
}

// ---------------------------------------------------------------- surfaces

#[test]
fn dropping_a_trait_method_from_an_impl_is_a_finding() {
    let src = std::fs::read_to_string(crate_root().join(surface::SINK_PATH)).unwrap();
    assert!(surface::check_sink_text(&src).is_empty(), "committed sink surface must be clean");
    // Doctor the text: rename one Tally method so the impl no longer
    // covers the trait. The finding anchors at the trait's fn line.
    let doctored = src.replacen(
        "fn on_run_end(&mut self, _metrics: &RunMetrics) {\n        self.runs += 1;",
        "fn run_end_renamed(&mut self, _metrics: &RunMetrics) {\n        self.runs += 1;",
        1,
    );
    assert_ne!(doctored, src, "doctoring must hit the Tally impl");
    let findings = surface::check_sink_text(&doctored);
    assert_eq!(findings.len(), 1);
    assert_eq!(findings[0].rule, RULE_SINK_SURFACE);
    assert!(findings[0].message.contains("on_run_end"));
    assert!(findings[0].message.contains("Tally"));
}

#[test]
fn undocumented_policy_is_a_finding() {
    let root = crate_root();
    let policy = std::fs::read_to_string(root.join(surface::POLICY_PATH)).unwrap();
    let readme = std::fs::read_to_string(root.parent().unwrap().join("README.md")).unwrap();
    assert!(surface::check_readme_text(&policy, &readme).is_empty());
    // Doctor the README: strip one policy's backtick-quoted mention.
    let doctored = readme.replace("`SW-SLO`", "SW-SLO");
    let findings = surface::check_readme_text(&policy, &doctored);
    assert_eq!(findings.len(), 1);
    assert!(findings[0].message.contains("SW-SLO"));
}

// ---------------------------------------------------------------- the repo itself

#[test]
fn lint_is_clean_on_repo() {
    let findings = run_lint(&crate_root()).unwrap();
    assert!(
        findings.is_empty(),
        "committed tree must lint clean; findings:\n{}",
        findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}

#[test]
fn seeded_violation_fails_repo_style_scan() {
    // End-to-end over a scratch tree shaped like the repo: a wall-clock
    // read seeded into a scheduler file is caught with file:line.
    let dir = scratch_tree("seeded");
    std::fs::create_dir_all(dir.join("src/scheduler")).unwrap();
    std::fs::write(
        dir.join("src/scheduler/tick.rs"),
        "pub fn tick() {\n    let t = std::time::Instant::now();\n    drop(t);\n}\n",
    )
    .unwrap();
    let findings = run_lint(&dir).unwrap();
    let hit = findings
        .iter()
        .find(|f| f.rule == RULE_WALL_CLOCK)
        .expect("seeded Instant::now must be found");
    assert_eq!(hit.file, "src/scheduler/tick.rs");
    assert_eq!(hit.line, 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn all_committed_suppressions_name_real_rules() {
    // Guard against typo'd `allow(...)` names silently suppressing
    // nothing: every suppression in the tree must name a known rule.
    let root = crate_root();
    let mut stack = vec![root.join("src")];
    while let Some(dir) = stack.pop() {
        for e in std::fs::read_dir(&dir).unwrap().flatten() {
            let path = e.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "rs") {
                check_suppression_names(&path);
            }
        }
    }
}

fn check_suppression_names(path: &Path) {
    let src = std::fs::read_to_string(path).unwrap();
    let (_, supp) = scls::analysis::lexer::lex(&src);
    for (line, rules) in &supp {
        for rule in rules {
            assert!(
                scls::analysis::ALL_RULES.contains(&rule.as_str()),
                "{}:{line}: unknown rule `{rule}` in suppression",
                path.display()
            );
        }
    }
}
