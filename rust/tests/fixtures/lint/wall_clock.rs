// Lint fixture (never compiled): wall-clock positives and suppressions.
// Scanned under "src/sim/fixture.rs" (checked) and "src/bench/fixture.rs"
// (allowlisted) by tests/props_lint.rs.
use std::time::Instant; // line 4: finding
use std::time::SystemTime; // line 5: finding

fn positives() {
    let t0 = Instant::now(); // line 8: finding
    let now = SystemTime::now(); // line 9: finding
    drop((t0, now));
}

fn suppressed() {
    let t0 = Instant::now(); // scls-lint: allow(wall-clock): log timestamp only, never measured
    drop(t0);
}

fn never_fire() {
    // Instant in a comment is not a finding; InstantEvent is a distinct
    // identifier and must not match the whole-token rule.
    let e = InstantEvent { at: 1 };
    let s = "SystemTime in a string";
    drop((e, s));
}
