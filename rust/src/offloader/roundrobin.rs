//! Round-robin offloading — the policy existing SLS/ILS schedulers use
//! (§3.2), which the paper shows causes load imbalance.

/// Cyclic worker assignment.
#[derive(Debug, Clone)]
pub struct RoundRobin {
    next: usize,
    workers: usize,
}

impl RoundRobin {
    pub fn new(workers: usize) -> RoundRobin {
        assert!(workers > 0);
        RoundRobin { next: 0, workers }
    }

    pub fn next_worker(&mut self) -> usize {
        let w = self.next;
        self.next = (self.next + 1) % self.workers;
        w
    }

    /// Widen the cycle to `workers` (elastic fleet: joiners get fresh
    /// trailing indices). The cursor is untouched, so the cycle before the
    /// join is unchanged and the new indices enter rotation naturally.
    pub fn grow(&mut self, workers: usize) {
        debug_assert!(workers >= self.workers);
        self.workers = workers;
    }

    /// Number of worker indices in the cycle.
    pub fn workers(&self) -> usize {
        self.workers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles() {
        let mut rr = RoundRobin::new(3);
        let seq: Vec<usize> = (0..7).map(|_| rr.next_worker()).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn single_worker() {
        let mut rr = RoundRobin::new(1);
        assert_eq!(rr.next_worker(), 0);
        assert_eq!(rr.next_worker(), 0);
    }
}
