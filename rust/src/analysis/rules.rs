//! The token-stream rules: hash-order, wall-clock, float-cmp.
//!
//! Each rule is deliberately *stricter than the invariant it protects* —
//! a lexical pass cannot see types or data flow, so it flags every
//! mention and lets a reviewed, per-line
//! `// scls-lint: allow(<rule>): <justification>` carve out the sound
//! exceptions. The catalog:
//!
//! * `hash-order` — any `HashMap`/`HashSet` identifier in a deterministic
//!   module. Hash iteration order is seeded per-process, so a drain, sort
//!   key, or event sequence derived from one silently varies run-to-run;
//!   deterministic modules use `BTreeMap`/`BTreeSet` or sorted vectors.
//! * `wall-clock` — any `Instant`/`SystemTime` identifier outside the
//!   real-time allowlist. Virtual time is the only clock the simulator
//!   and scheduler may read; a wall-clock read anywhere else makes
//!   results machine-dependent.
//! * `float-cmp` — in deterministic modules: `==`/`!=` with a float
//!   literal operand, or any `partial_cmp` call (its `None`-on-NaN result
//!   turns into comparator panics or order flips). Ordering goes through
//!   `total_cmp`; exact sentinel comparisons carry a justified `allow`.
//! * `import-graph` — a `crate::<module>` path in a deterministic module
//!   that lands in the real-time allowlist (`bench`, `runtime`,
//!   `telemetry::profile`, ...). A measured path that *links* to a
//!   wall-clock surface is one refactor away from reading it; the few
//!   sound dependencies (opt-in profiling taps, the real-driver seam)
//!   each carry a reviewed per-line allow.

use super::classify;
use super::lexer::{self, Suppressions, Tok, TokKind};
use super::Finding;

/// Rule names (the suppression grammar's vocabulary).
pub const RULE_HASH_ORDER: &str = "hash-order";
pub const RULE_WALL_CLOCK: &str = "wall-clock";
pub const RULE_FLOAT_CMP: &str = "float-cmp";
pub const RULE_FROZEN_MANIFEST: &str = "frozen-manifest";
pub const RULE_SINK_SURFACE: &str = "sink-surface";
pub const RULE_IMPORT_GRAPH: &str = "import-graph";

/// All rule names, for docs and `--json` output.
pub const ALL_RULES: [&str; 6] = [
    RULE_HASH_ORDER,
    RULE_WALL_CLOCK,
    RULE_FLOAT_CMP,
    RULE_IMPORT_GRAPH,
    RULE_FROZEN_MANIFEST,
    RULE_SINK_SURFACE,
];

/// Run the token-stream rules over one source file. `rel` is the
/// crate-relative path (`src/sim/driver.rs`) that drives module
/// classification.
pub fn scan_source(rel: &str, src: &str) -> Vec<Finding> {
    let (toks, supp) = lexer::lex(src);
    let det = classify::is_deterministic(rel);
    let clock_checked = !classify::wall_clock_allowed(rel);
    let mut findings = Vec::new();
    for (idx, t) in toks.iter().enumerate() {
        if det {
            scan_hash_order(rel, t, &supp, &mut findings);
            scan_float_cmp(rel, &toks, idx, &supp, &mut findings);
            scan_import_graph(rel, &toks, idx, &supp, &mut findings);
        }
        if clock_checked {
            scan_wall_clock(rel, t, &supp, &mut findings);
        }
    }
    findings
}

fn push(findings: &mut Vec<Finding>, rel: &str, line: u32, rule: &'static str, msg: String) {
    findings.push(Finding {
        file: rel.to_string(),
        line,
        rule,
        message: msg,
    });
}

fn scan_hash_order(rel: &str, t: &Tok, supp: &Suppressions, findings: &mut Vec<Finding>) {
    if t.kind != TokKind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
        return;
    }
    if lexer::is_allowed(supp, t.line, RULE_HASH_ORDER) {
        return;
    }
    let module = classify::module_of(rel).unwrap_or("?");
    push(
        findings,
        rel,
        t.line,
        RULE_HASH_ORDER,
        format!(
            "{} in deterministic module `{module}` — iteration order is \
             process-seeded; use BTreeMap/BTreeSet or a sorted vector",
            t.text
        ),
    );
}

fn scan_wall_clock(rel: &str, t: &Tok, supp: &Suppressions, findings: &mut Vec<Finding>) {
    if t.kind != TokKind::Ident || (t.text != "Instant" && t.text != "SystemTime") {
        return;
    }
    if lexer::is_allowed(supp, t.line, RULE_WALL_CLOCK) {
        return;
    }
    push(
        findings,
        rel,
        t.line,
        RULE_WALL_CLOCK,
        format!(
            "{} outside the real-time allowlist — deterministic paths read \
             only virtual time",
            t.text
        ),
    );
}

fn scan_float_cmp(
    rel: &str,
    toks: &[Tok],
    idx: usize,
    supp: &Suppressions,
    findings: &mut Vec<Finding>,
) {
    let t = &toks[idx];
    if t.kind == TokKind::Punct && (t.text == "==" || t.text == "!=") {
        let float_operand =
            |i: usize| toks.get(i).is_some_and(|o| o.kind == TokKind::Num && o.is_float);
        let prev = idx > 0 && float_operand(idx - 1);
        let next = float_operand(idx + 1);
        if (prev || next) && !lexer::is_allowed(supp, t.line, RULE_FLOAT_CMP) {
            push(
                findings,
                rel,
                t.line,
                RULE_FLOAT_CMP,
                format!(
                    "bare `{}` against a float literal in a deterministic \
                     module — compare via total_cmp or a documented sentinel \
                     with an allow",
                    t.text
                ),
            );
        }
        return;
    }
    if t.kind == TokKind::Ident && t.text == "partial_cmp" {
        // `fn partial_cmp` is a PartialOrd impl, not a comparator call.
        let is_def = idx > 0 && toks[idx - 1].kind == TokKind::Ident && toks[idx - 1].text == "fn";
        if !is_def && !lexer::is_allowed(supp, t.line, RULE_FLOAT_CMP) {
            push(
                findings,
                rel,
                t.line,
                RULE_FLOAT_CMP,
                "partial_cmp in a deterministic module — NaN turns it into \
                 None (comparator panics / order flips); use total_cmp"
                    .to_string(),
            );
        }
    }
}

fn scan_import_graph(
    rel: &str,
    toks: &[Tok],
    idx: usize,
    supp: &Suppressions,
    findings: &mut Vec<Finding>,
) {
    let t = &toks[idx];
    if t.kind != TokKind::Ident || t.text != "crate" {
        return;
    }
    let sep = |i: usize| toks.get(i).is_some_and(|o| o.kind == TokKind::Punct && o.text == "::");
    let ident =
        |i: usize| toks.get(i).and_then(|o| (o.kind == TokKind::Ident).then_some(o.text.as_str()));
    if !sep(idx + 1) {
        return;
    }
    let Some(seg1) = ident(idx + 2) else {
        return;
    };
    let seg2 = if sep(idx + 3) { ident(idx + 4) } else { None };
    if !classify::wall_clock_module(seg1, seg2) {
        return;
    }
    if lexer::is_allowed(supp, t.line, RULE_IMPORT_GRAPH) {
        return;
    }
    let module = classify::module_of(rel).unwrap_or("?");
    // Name the shallowest allowlisted path: the whole module when it
    // matches, else the `module::submodule` pair.
    let target = match seg2 {
        Some(s2) if !classify::wall_clock_module(seg1, None) => format!("{seg1}::{s2}"),
        _ => seg1.to_string(),
    };
    push(
        findings,
        rel,
        t.line,
        RULE_IMPORT_GRAPH,
        format!(
            "deterministic module `{module}` depends on real-time module \
             `crate::{target}` — measured paths must not link wall-clock \
             surfaces; sound taps carry a reviewed allow"
        ),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines_of(rel: &str, src: &str, rule: &str) -> Vec<u32> {
        scan_source(rel, src)
            .into_iter()
            .filter(|f| f.rule == rule)
            .map(|f| f.line)
            .collect()
    }

    #[test]
    fn hash_order_fires_only_in_deterministic_modules() {
        let src = "use std::collections::HashMap;\nlet m: HashMap<u32, u32> = HashMap::new();\n";
        assert_eq!(lines_of("src/sim/x.rs", src, RULE_HASH_ORDER), vec![1, 2, 2]);
        assert!(lines_of("src/telemetry/x.rs", src, RULE_HASH_ORDER).is_empty());
    }

    #[test]
    fn wall_clock_respects_allowlist() {
        let src = "use std::time::Instant;\nlet t = Instant::now();\n";
        assert_eq!(lines_of("src/sim/x.rs", src, RULE_WALL_CLOCK), vec![1, 2]);
        assert_eq!(lines_of("src/metrics/x.rs", src, RULE_WALL_CLOCK), vec![1, 2]);
        assert!(lines_of("src/bench/x.rs", src, RULE_WALL_CLOCK).is_empty());
        assert!(lines_of("src/util/logging.rs", src, RULE_WALL_CLOCK).is_empty());
    }

    #[test]
    fn instant_event_is_not_instant() {
        let src = "let e = InstantEvent { at: 1 };\n";
        assert!(lines_of("src/sim/x.rs", src, RULE_WALL_CLOCK).is_empty());
    }

    #[test]
    fn float_cmp_literal_adjacency() {
        let src = "if x == 0.0 { }\nif 1.5 != y { }\nif n == 0 { }\nif x <= 1.0 { }\n";
        assert_eq!(lines_of("src/estimator/x.rs", src, RULE_FLOAT_CMP), vec![1, 2]);
        assert!(lines_of("src/util/x.rs", src, RULE_FLOAT_CMP).is_empty());
    }

    #[test]
    fn partial_cmp_call_flagged_definition_not() {
        let src = "fn partial_cmp(&self, o: &Self) -> Option<Ordering> { None }\n\
                   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
                   v.sort_by(|a, b| a.total_cmp(b));\n";
        assert_eq!(lines_of("src/scheduler/x.rs", src, RULE_FLOAT_CMP), vec![2]);
    }

    #[test]
    fn import_graph_flags_real_time_deps_in_deterministic_modules() {
        let src = "use crate::runtime::ModelRuntime;\n\
                   let _t = crate::telemetry::profile::timer(\"x\");\n\
                   use crate::telemetry::hist::Histogram;\n\
                   use crate::util::logging::log;\n\
                   use crate::util::stats::mean;\n\
                   use crate::bench::harness::run;\n";
        assert_eq!(lines_of("src/sim/x.rs", src, RULE_IMPORT_GRAPH), vec![1, 2, 4, 6]);
        // Outside deterministic modules the dependency is fine.
        assert!(lines_of("src/telemetry/x.rs", src, RULE_IMPORT_GRAPH).is_empty());
        assert!(lines_of("src/metrics/x.rs", src, RULE_IMPORT_GRAPH).is_empty());
    }

    #[test]
    fn import_graph_allow_silences_the_tap() {
        let src = "let _t = crate::telemetry::profile::timer(\"tick\"); \
                   // scls-lint: allow(import-graph): opt-in profiling tap\n\
                   let _u = crate::telemetry::profile::timer(\"tock\");\n";
        assert_eq!(lines_of("src/scheduler/x.rs", src, RULE_IMPORT_GRAPH), vec![2]);
    }

    #[test]
    fn import_graph_ignores_non_crate_paths_and_comments() {
        let src = "// crate::runtime in a comment\n\
                   let s = \"crate::bench\";\n\
                   use std::runtime_hint::x;\n\
                   use crate::scheduler::fleet::WorkerLedger;\n";
        assert!(lines_of("src/sim/x.rs", src, RULE_IMPORT_GRAPH).is_empty());
    }

    #[test]
    fn suppressions_silence_exact_line() {
        let src = "if x == 0.0 { } // scls-lint: allow(float-cmp): sentinel\n\
                   if y == 0.0 { }\n";
        assert_eq!(lines_of("src/engine/x.rs", src, RULE_FLOAT_CMP), vec![2]);
    }

    #[test]
    fn strings_and_comments_never_fire() {
        let src = "// HashMap Instant 1.0 == 2.0\nlet s = \"HashMap Instant\";\n";
        assert!(scan_source("src/sim/x.rs", src).is_empty());
    }
}
