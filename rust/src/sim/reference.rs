//! Frozen pre-trait DES drivers — the differential oracles for the
//! [`SchedulingPolicy`](crate::scheduler::SchedulingPolicy) ports.
//!
//! These are the three bespoke event loops the repo used before scheduling
//! was unified behind the policy trait: `run_sliced_reference` (SLS → SO →
//! PM → AB → LB → SCLS), `run_ils_reference`, and `run_scls_cb_reference`.
//! They are retained verbatim — the same pattern as
//! [`crate::batcher::dp_batch_reference`] — so the differential suite
//! (`tests/props_policy_differential.rs`) can assert, at test time, that
//! every ported policy run through the single generic loop produces a
//! **byte-identical** `RunMetrics` event log (`RunMetrics::to_json`).
//!
//! Do not extend these: new scheduling behavior goes through the trait.

use std::collections::VecDeque;

use crate::batcher::{dp_batch_into, fcfs_batches, DpBatcherConfig, DpScratch};
use crate::core::{Batch, Request};
use crate::engine::sim::SimEngine;
use crate::estimator::ServingTimeEstimator;
use crate::metrics::{BatchRecord, RunMetrics};
use crate::offloader::{LoadLedger, MaxMinOffloader, RoundRobin};
use crate::scheduler::spec::{BatchingSpec, IntervalSpec, OffloadSpec, SchedulerSpec};
use crate::scheduler::{IntervalController, RequestPool};
use crate::workload::Trace;

use super::driver::{fitted_estimator, SimConfig};
use super::events::EventQueue;

#[derive(Debug)]
enum Ev {
    Arrival(usize),
    Tick,
    WorkerDone(usize),
}

/// Per-worker state for the sliced-family driver.
struct WorkerState {
    /// Coordinator-formed batches waiting in the local queue.
    batch_queue: VecDeque<Batch>,
    /// Worker-locus FCFS: raw requests waiting locally (SLS/SO).
    req_queue: VecDeque<Request>,
    /// The batch currently being served (None = idle).
    serving: Option<Batch>,
    engine: SimEngine,
    last_done: f64,
}

/// Run one sliced-family experiment to drain (frozen pre-trait loop).
pub fn run_sliced_reference(trace: &Trace, spec: &SchedulerSpec, cfg: &SimConfig) -> RunMetrics {
    assert!(cfg.workers > 0);
    let est = fitted_estimator(&cfg.engine, cfg.seed);
    let mem = cfg.engine.memory_estimator();

    let mut workers: Vec<WorkerState> = (0..cfg.workers)
        .map(|w| WorkerState {
            batch_queue: VecDeque::new(),
            req_queue: VecDeque::new(),
            serving: None,
            engine: SimEngine::new(
                cfg.engine.latency(cfg.seed ^ (w as u64).wrapping_mul(0x9E37)),
                cfg.max_gen_len,
            ),
            last_done: 0.0,
        })
        .collect();

    let mut pool = RequestPool::with_capacity(trace.len().min(1 << 16));
    let mut ledger = LoadLedger::new(cfg.workers);
    let mut rr = RoundRobin::new(cfg.workers);
    let mut metrics = RunMetrics::with_capacity(trace.len());

    let mut q: EventQueue<Ev> = EventQueue::with_capacity(trace.len() + cfg.workers + 2);
    for (i, r) in trace.requests.iter().enumerate() {
        q.push(r.arrival, Ev::Arrival(i));
    }
    // Hoisted batcher config: `Some` exactly for coordinator (DP) batching.
    let dp_cfg = match spec.batching {
        BatchingSpec::Dp { max_batch_size } => Some(DpBatcherConfig {
            slice_len: spec.slice_len,
            max_batch_size,
            pred_corrected: false,
        }),
        BatchingSpec::WorkerFcfs { .. } => None,
    };
    let coordinator_batching = dp_cfg.is_some();
    let interval = match spec.interval {
        IntervalSpec::Immediate => None,
        IntervalSpec::Fixed(t) => Some(IntervalController::Fixed(t)),
        IntervalSpec::Adaptive { lambda, gamma } => {
            Some(IntervalController::Adaptive { lambda, gamma })
        }
    };
    if interval.is_some() {
        q.push(0.0, Ev::Tick);
    }
    let mut arrivals_left = trace.len();

    // ---- helpers as closures over the mutable state ---------------------

    // Start serving on worker `w` if idle and work is queued.
    fn try_start(
        w: usize,
        now: f64,
        workers: &mut [WorkerState],
        spec: &SchedulerSpec,
        est: &ServingTimeEstimator,
        metrics: &mut RunMetrics,
        q: &mut EventQueue<Ev>,
    ) {
        let ws = &mut workers[w];
        if ws.serving.is_some() {
            return;
        }
        // Worker-locus FCFS: form a batch from the local request queue.
        if let BatchingSpec::WorkerFcfs { batch_size } = spec.batching {
            if ws.batch_queue.is_empty() && !ws.req_queue.is_empty() {
                let take = (batch_size as usize).min(ws.req_queue.len());
                let reqs: Vec<Request> = ws.req_queue.drain(..take).collect();
                let mut batches = fcfs_batches(reqs, batch_size, est, spec.slice_len);
                debug_assert_eq!(batches.len(), 1);
                ws.batch_queue.push_back(batches.pop().unwrap());
            }
        }
        let Some(mut batch) = ws.batch_queue.pop_front() else {
            return;
        };
        // Serving-start accounting: each request pays its pads and a slice.
        let li = batch.input_len();
        for r in &mut batch.requests {
            r.slices += 1;
            r.pad_tokens += (li - r.input_len) as u64;
        }
        let outcome = ws.engine.serve_slice(&batch, spec.slice_len);
        metrics.batches.push(BatchRecord {
            start: now,
            worker: w,
            size: batch.size() as u32,
            input_len: li,
            pad_tokens: batch.pad_tokens(),
            est_serve_time: batch.est_serve_time,
            actual_serve_time: outcome.duration,
            early_return: outcome.early_return,
        });
        let done_at = now + outcome.duration;
        for (r, o) in batch.requests.iter_mut().zip(&outcome.per_request) {
            debug_assert_eq!(r.id, o.id);
            r.generated += o.new_tokens;
            r.invalid_tokens += o.invalid_tokens as u64;
            // SCLS reschedule: the next prefill recomputes over input +
            // everything generated so far.
            r.input_len += o.new_tokens;
            if o.finished {
                r.finished_at = Some(done_at);
            }
        }
        ws.serving = Some(batch);
        q.push(done_at, Ev::WorkerDone(w));
    }

    // Per-tick scratch, reused across the whole drain.
    let mut tick_reqs: Vec<Request> = Vec::new();
    let mut batch_buf: Vec<Batch> = Vec::new();
    let mut assign_buf: Vec<(usize, Batch)> = Vec::new();
    let mut dp_scratch = DpScratch::new();

    while let Some((now, ev)) = q.pop() {
        metrics.events += 1;
        match ev {
            Ev::Arrival(i) => {
                arrivals_left -= 1;
                let r = trace.requests[i].clone();
                if coordinator_batching {
                    pool.push(r);
                } else {
                    // SLS/SO: round-robin the request to a worker queue.
                    let w = rr.next_worker();
                    workers[w].req_queue.push_back(r);
                    try_start(w, now, &mut workers, spec, &est, &mut metrics, &mut q);
                }
            }
            Ev::Tick => {
                let Some(ctrl) = &interval else { continue };
                pool.fetch_all_into(&mut tick_reqs);
                if !tick_reqs.is_empty() {
                    metrics.peak_pool = metrics.peak_pool.max(tick_reqs.len());
                    let dp_cfg = dp_cfg
                        .as_ref()
                        .expect("ticks only exist under coordinator batching");
                    dp_batch_into(
                        &mut tick_reqs,
                        &est,
                        &mem,
                        dp_cfg,
                        &mut dp_scratch,
                        &mut batch_buf,
                    );
                    match spec.offload {
                        OffloadSpec::MaxMin => MaxMinOffloader.offload_into(
                            &mut batch_buf,
                            &mut ledger,
                            &mut assign_buf,
                        ),
                        OffloadSpec::RoundRobin => {
                            assign_buf.clear();
                            for b in batch_buf.drain(..) {
                                let w = rr.next_worker();
                                ledger.add(w, b.est_serve_time);
                                assign_buf.push((w, b));
                            }
                        }
                    }
                    for (w, b) in assign_buf.drain(..) {
                        workers[w].batch_queue.push_back(b);
                        try_start(w, now, &mut workers, spec, &est, &mut metrics, &mut q);
                    }
                }
                // Re-arm the tick while any work can still appear.
                let work_pending = arrivals_left > 0
                    || !pool.is_empty()
                    || workers
                        .iter()
                        .any(|w| w.serving.is_some() || !w.batch_queue.is_empty());
                if work_pending {
                    let t = ctrl.next_interval(&ledger);
                    q.push(now + t.max(1e-3), Ev::Tick);
                }
            }
            Ev::WorkerDone(w) => {
                let batch = workers[w].serving.take().expect("done without serving");
                ledger.complete(w, batch.est_serve_time);
                workers[w].last_done = now;
                for r in batch.requests {
                    if r.is_finished() {
                        metrics.record_completion(&r, now);
                    } else if coordinator_batching {
                        pool.push(r);
                    } else {
                        // SO: re-send unfinished requests round-robin.
                        let tw = rr.next_worker();
                        workers[tw].req_queue.push_back(r);
                        try_start(tw, now, &mut workers, spec, &est, &mut metrics, &mut q);
                    }
                }
                try_start(w, now, &mut workers, spec, &est, &mut metrics, &mut q);
            }
        }
    }

    metrics.worker_completion = workers.iter().map(|w| w.last_done).collect();
    metrics
}

/// Run the ILS baseline to drain (frozen pre-trait loop).
pub fn run_ils_reference(trace: &Trace, cfg: &SimConfig) -> RunMetrics {
    use crate::engine::continuous::ContinuousWorker;

    assert!(cfg.workers > 0);
    let kv_budget = (0.9 * cfg.engine.m_ava as f64) as u64;

    let mut workers: Vec<ContinuousWorker> = (0..cfg.workers)
        .map(|w| {
            ContinuousWorker::new(
                cfg.engine
                    .latency(cfg.seed ^ (w as u64).wrapping_mul(0xA5A5)),
                cfg.engine.ils_max_parallel,
                kv_budget,
                cfg.engine.kv_delta,
                cfg.max_gen_len,
            )
        })
        .collect();
    let mut looping = vec![false; cfg.workers];
    let mut last_done = vec![0.0f64; cfg.workers];

    let mut rr = RoundRobin::new(cfg.workers);
    let mut metrics = RunMetrics::with_capacity(trace.len());

    enum IEv {
        Arrival(usize),
        IterDone(usize),
    }

    let mut q: EventQueue<IEv> = EventQueue::with_capacity(trace.len() + cfg.workers + 2);
    for (i, r) in trace.requests.iter().enumerate() {
        q.push(r.arrival, IEv::Arrival(i));
    }

    while let Some((now, ev)) = q.pop() {
        metrics.events += 1;
        match ev {
            IEv::Arrival(i) => {
                let r = trace.requests[i].clone();
                let w = rr.next_worker();
                workers[w].waiting.push_back(r);
                if !looping[w] {
                    if let Some(d) = workers[w].begin_iteration() {
                        looping[w] = true;
                        q.push(now + d, IEv::IterDone(w));
                    }
                }
            }
            IEv::IterDone(wi) => {
                for r in workers[wi].finish_iteration(now) {
                    last_done[wi] = now;
                    metrics.record_completion(&r, now);
                }
                if let Some(d) = workers[wi].begin_iteration() {
                    q.push(now + d, IEv::IterDone(wi));
                } else {
                    looping[wi] = false;
                }
            }
        }
    }

    metrics.worker_completion = last_done;
    metrics
}

/// Run the §7 extension to drain (frozen pre-trait loop).
pub fn run_scls_cb_reference(trace: &Trace, cfg: &SimConfig, slice_len: u32) -> RunMetrics {
    use crate::engine::continuous_scls::SlicedContinuousWorker;

    assert!(cfg.workers > 0);
    let kv_budget = (0.9 * cfg.engine.m_ava as f64) as u64;

    let mut workers: Vec<SlicedContinuousWorker> = (0..cfg.workers)
        .map(|w| {
            SlicedContinuousWorker::new(
                cfg.engine
                    .latency(cfg.seed ^ (w as u64).wrapping_mul(0x5A5A)),
                slice_len,
                kv_budget,
                cfg.engine.kv_delta,
                cfg.max_gen_len,
            )
        })
        .collect();
    let mut looping = vec![false; cfg.workers];
    let mut last_done = vec![0.0f64; cfg.workers];
    let mut metrics = RunMetrics::with_capacity(trace.len());

    enum CEv {
        Arrival(usize),
        IterDone(usize),
    }

    let mut q: EventQueue<CEv> = EventQueue::with_capacity(trace.len() + cfg.workers + 2);
    for (i, r) in trace.requests.iter().enumerate() {
        q.push(r.arrival, CEv::Arrival(i));
    }

    // Offload to the instance with the most free projected memory (ties:
    // shortest local queue); kick its iteration loop if idle.
    fn assign(
        r: Request,
        now: f64,
        workers: &mut [SlicedContinuousWorker],
        looping: &mut [bool],
        q: &mut EventQueue<CEv>,
    ) {
        let w = (0..workers.len())
            .min_by(|&a, &b| {
                workers[a]
                    .kv_projected()
                    .cmp(&workers[b].kv_projected())
                    .then_with(|| workers[a].waiting.len().cmp(&workers[b].waiting.len()))
            })
            .unwrap();
        workers[w].waiting.push_back(r);
        if !looping[w] {
            if let Some(d) = workers[w].begin_iteration() {
                looping[w] = true;
                q.push(now + d, CEv::IterDone(w));
            }
        }
    }

    while let Some((now, ev)) = q.pop() {
        metrics.events += 1;
        match ev {
            CEv::Arrival(i) => {
                let r = trace.requests[i].clone();
                assign(r, now, &mut workers, &mut looping, &mut q);
            }
            CEv::IterDone(wi) => {
                let exits = workers[wi].finish_iteration(now);
                for r in exits.done {
                    last_done[wi] = now;
                    metrics.record_completion(&r, now);
                }
                // §7: slice-capped requests are rescheduled to the least
                // memory-loaded instance (their KV was just released).
                for r in exits.rescheduled {
                    assign(r, now, &mut workers, &mut looping, &mut q);
                }
                if let Some(d) = workers[wi].begin_iteration() {
                    q.push(now + d, CEv::IterDone(wi));
                } else {
                    looping[wi] = false;
                }
            }
        }
    }

    metrics.worker_completion = last_done;
    metrics
}
