"""AOT export: lower the tiny-GPT slice-serving function to HLO text.

Emits one self-contained HLO program per (N, L, S) bucket plus a
``manifest.json`` the Rust runtime uses to discover buckets. HLO **text** is
the interchange format (NOT ``.serialize()``): jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts \
        [--batch-sizes 1,2,4,8] [--input-lens 16,32,64,128,160] \
        [--slice-lens 16]

Python runs ONLY here (and in pytest); the Rust binary is self-contained
once ``artifacts/`` is built.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_bucket(cfg: M.ModelConfig, n: int, l: int, s: int, out_dir: str) -> dict:
    """Lower one (N, L, S) bucket and write its HLO text file."""
    import jax.numpy as jnp

    fn = M.generate_slice_fn(cfg, n, l, s, use_pallas=True, interpret=True)
    tok_spec = jax.ShapeDtypeStruct((n, l), jnp.int32)
    vec_spec = jax.ShapeDtypeStruct((n,), jnp.int32)
    t0 = time.time()
    # inputs: tokens (N,L), lengths (N,), active (N,), gen_offset (N,)
    lowered = jax.jit(fn).lower(tok_spec, vec_spec, vec_spec, vec_spec)
    text = to_hlo_text(lowered)
    fname = f"generate_n{n}_l{l}_s{s}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    dt = time.time() - t0
    print(f"  bucket n={n:<2} l={l:<4} s={s:<3} -> {fname} "
          f"({len(text)/1024:.0f} KiB, {dt:.1f}s)")
    return {"n": n, "l": l, "s": s, "file": fname}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch-sizes", default="1,2,4,8")
    ap.add_argument("--input-lens", default="16,32,64,128,160")
    ap.add_argument("--slice-lens", default="16")
    args = ap.parse_args()

    cfg = M.ModelConfig()
    ns = [int(x) for x in args.batch_sizes.split(",")]
    ls = [int(x) for x in args.input_lens.split(",")]
    ss = [int(x) for x in args.slice_lens.split(",")]

    os.makedirs(args.out_dir, exist_ok=True)
    buckets = []
    print(f"exporting {len(ns) * len(ls) * len(ss)} buckets to {args.out_dir}")
    for s in ss:
        for l in ls:
            if l + s > cfg.max_pos:
                print(f"  skip l={l} s={s}: exceeds max_pos={cfg.max_pos}")
                continue
            for n in ns:
                buckets.append(export_bucket(cfg, n, l, s, args.out_dir))

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "max_pos": cfg.max_pos,
            "eos_alpha": cfg.eos_alpha,
            "param_seed": cfg.param_seed,
            "kv_bytes_per_token": cfg.kv_bytes_per_token,
        },
        "tokens": {"pad": M.PAD_ID, "eos": M.EOS_ID, "bos": M.BOS_ID},
        "buckets": buckets,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(buckets)} buckets")


if __name__ == "__main__":
    main()
