//! Build stub of the PJRT/XLA binding.
//!
//! The offline image has no PJRT shared library, so this crate provides the
//! exact API surface `scls::runtime::client` compiles against, with every
//! runtime entry point returning an "unavailable" error. All real-mode
//! tests and benches gate on `artifacts/manifest.json` existing, and
//! artifact production requires the JAX toolchain anyway, so the stub is
//! never reached in CI. To serve the real model, replace this path
//! dependency with a real PJRT binding exposing the same names.

use std::fmt;
use std::path::Path;

/// Error type matching the binding's `Result<_, XlaError>` shape.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: PJRT runtime not available (offline `xla` stub; see rust/vendor/xla)"
    )))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal), XlaError> {
        unavailable("Literal::to_tuple2")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[3, 1]).is_err());
        assert!(lit.to_vec::<i32>().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("stub"));
    }
}
