//! Generation-length prediction (the proxy-model direction of Qiu et al.,
//! arXiv 2404.08509, grafted onto the SCLS reproduction).
//!
//! The paper's central premise is that generation length is unknowable a
//! priori, so SCLS buys predictability by capping every schedule at S
//! tokens. Related work takes the complementary path: *predict* the length
//! with a cheap proxy model and pack batches/memory against the prediction
//! instead of the worst case. This module is that subsystem:
//!
//! * [`LengthPredictor`] — the open trait: one pure function from a
//!   request to a predicted total generation length. Implementations may
//!   read anything on the request **except treat `target_gen_len` as
//!   exact**: the built-ins that consult it ([`Oracle`], [`NoisyOracle`],
//!   [`BucketClassifier`]) model proxy predictors of configurable fidelity
//!   whose ground truth happens to be the trace oracle, which is exactly
//!   how prediction-accuracy sweeps are run against a synthetic workload.
//! * [`Oracle`] — perfect foresight (σ = 0 upper bound).
//! * [`NoisyOracle`] — multiplicative log-normal error of configurable σ:
//!   `pred = truth · exp(σ·z)`, `z ~ N(0,1)` per request. σ sweeps are the
//!   figure suite's prediction-error axis.
//! * [`BucketClassifier`] — what a real proxy classifier gives you:
//!   quantile buckets fit from the workload's length distribution, a
//!   configurable per-request accuracy, and off-by-one confusion when the
//!   classifier misses.
//! * [`PercentileConst`] — the no-model baseline: predict one fixed
//!   workload percentile for every request.
//! * [`OnlineBuckets`] — the online variant of the bucket classifier: it
//!   starts from a prior fit (or cold) and *refits* its quantile edges
//!   from a sliding window of completed-request true lengths, fed through
//!   the [`LengthPredictor::observe`] completion hook (the continuous-refit
//!   direction of Qiu et al.).
//!
//! Predictions are **deterministic per request**: stochastic predictors
//! derive their randomness from `(predictor seed, request id)`, never from
//! hidden shared state, so every run is reproducible from its seed. An
//! *online* predictor's model does evolve — but only through `observe`,
//! whose call sequence is itself a deterministic function of the run seed,
//! so reproducibility holds end to end.
//!
//! The prediction-aware scheduling policies built on this trait — P-SCLS
//! (slice-ladder seeding) and P-CB (predicted-KV admission) — live in
//! [`crate::sim::policies`]; [`registry::PredictorSpec`] constructs
//! predictors by name for the CLI and the figure suite, mirroring
//! [`crate::scheduler::policy::parse_policy_name`].

pub mod online;
pub mod registry;

pub use online::OnlineBuckets;
pub use registry::{
    canonical_predictor_name, parse_predictor_name, PredictorSpec, BUILTIN_PREDICTORS,
};

use crate::core::Request;
use crate::util::rng::Rng;
use crate::workload::distributions::LengthDistribution;

/// A generation-length predictor: request in, predicted total generation
/// length (tokens, ≥ 1) out.
///
/// `predict` must be pure *between observations* — same request, same
/// model state, same answer — so policies may re-invoke it freely and
/// runs stay reproducible from the seed. The predicted value is a *total*
/// length (like `target_gen_len`), not a remaining length; policies
/// subtract `generated` themselves.
pub trait LengthPredictor {
    fn predict(&self, req: &Request) -> u32;

    /// Completion feedback: a prediction-aware policy calls this once per
    /// completed request with the true total generation length, giving
    /// online predictors ([`OnlineBuckets`]) the signal they refit from.
    /// Returns `true` when this observation triggered a model refit (the
    /// drivers count refits into `RunMetrics::predictor_refits`). Offline
    /// predictors keep the default no-op.
    fn observe(&mut self, _req: &Request, _true_len: u32) -> bool {
        false
    }

    /// Display name (diagnostics and figure labels).
    fn name(&self) -> &'static str;
}

/// Mixes a request id into a predictor seed: each request gets an
/// independent, reproducible draw stream.
fn per_request_rng(seed: u64, id: u64) -> Rng {
    Rng::new(seed ^ id.wrapping_mul(0x9E3779B97F4A7C15))
}

/// Cut a calibration sample into `buckets` equal-mass quantile edges
/// (ascending upper edges; the last edge is the sample maximum), then
/// deduplicate. Small or duplicate-heavy samples can collapse several
/// quantiles onto one value; keeping the collapsed copies would make
/// `true_bucket`'s `partition_point` silently skip buckets and turn the
/// accuracy knob's adjacent-bucket confusion into a no-op on identical
/// edges, so duplicates are dropped and the effective bucket count may be
/// smaller than requested. Sorts `lengths` in place (callers hand over a
/// scratch buffer).
fn quantile_edges(lengths: &mut [u32], buckets: u32) -> Vec<u32> {
    assert!(buckets >= 1, "need at least one bucket");
    assert!(!lengths.is_empty(), "empty calibration sample");
    lengths.sort_unstable();
    let n = lengths.len();
    let b = buckets as usize;
    let mut edges: Vec<u32> = (1..=b)
        .map(|i| lengths[(i * n / b).clamp(1, n) - 1].max(1))
        .collect();
    edges.dedup();
    edges
}

/// Ordinal confusion over `k ≥ 2` buckets: slip one bucket up or down. At
/// the edge buckets the slip *reflects inward* instead of saturating —
/// `saturating_sub` at bucket 0 (and `min` at the top) would leave the
/// prediction unchanged for half the error draws, making effective
/// accuracy at the edges higher than the knob says.
fn confused_bucket(b: usize, up: bool, k: usize) -> usize {
    debug_assert!(k >= 2 && b < k);
    if up {
        if b + 1 < k {
            b + 1
        } else {
            b - 1
        }
    } else if b > 0 {
        b - 1
    } else {
        1
    }
}

/// Shared predict kernel of [`BucketClassifier`] and [`OnlineBuckets`]:
/// classify the true length into its bucket, apply the accuracy knob's
/// adjacent-bucket confusion, and emit the bucket's upper edge.
fn bucket_predict(edges: &[u32], accuracy: f64, seed: u64, req: &Request) -> u32 {
    let len = req.target_gen_len.max(1);
    let mut b = edges.partition_point(|&e| e < len).min(edges.len() - 1);
    if accuracy < 1.0 && edges.len() >= 2 {
        let mut rng = per_request_rng(seed, req.id);
        if rng.f64() >= accuracy {
            let up = rng.next_u64() & 1 == 1;
            b = confused_bucket(b, up, edges.len());
        }
    }
    edges[b].max(1)
}

// ---------------------------------------------------------------------------
// Oracle
// ---------------------------------------------------------------------------

/// Perfect predictor: returns the trace's generation-length oracle. The
/// σ = 0 / accuracy = 1 upper bound every sweep is anchored against.
#[derive(Debug, Clone, Copy, Default)]
pub struct Oracle;

impl LengthPredictor for Oracle {
    fn predict(&self, req: &Request) -> u32 {
        req.target_gen_len.max(1)
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

// ---------------------------------------------------------------------------
// NoisyOracle
// ---------------------------------------------------------------------------

/// Oracle with multiplicative log-normal error: `pred = truth · exp(σ·z)`
/// with `z ~ N(0,1)` drawn per request. σ = 0 degenerates to [`Oracle`];
/// σ = 1 mispredicts by more than e× for ~32% of requests.
#[derive(Debug, Clone)]
pub struct NoisyOracle {
    sigma: f64,
    seed: u64,
}

impl NoisyOracle {
    pub fn new(sigma: f64, seed: u64) -> NoisyOracle {
        assert!(sigma >= 0.0, "sigma must be non-negative");
        NoisyOracle { sigma, seed }
    }

    pub fn sigma(&self) -> f64 {
        self.sigma
    }
}

impl LengthPredictor for NoisyOracle {
    fn predict(&self, req: &Request) -> u32 {
        let truth = req.target_gen_len.max(1) as f64;
        if self.sigma == 0.0 { // scls-lint: allow(float-cmp): exact zero is the no-noise sentinel
            return truth as u32;
        }
        let z = per_request_rng(self.seed, req.id).normal();
        let pred = (truth * (self.sigma * z).exp()).round();
        pred.clamp(1.0, u32::MAX as f64) as u32
    }

    fn name(&self) -> &'static str {
        "noisy"
    }
}

// ---------------------------------------------------------------------------
// BucketClassifier
// ---------------------------------------------------------------------------

/// A quantile-bucket length classifier, the shape a real proxy model takes
/// (Qiu et al. fine-tune a small LM to emit a length *bucket*, not a token
/// count).
///
/// Fit: draw a calibration sample from the workload's generation-length
/// distribution and cut it into `buckets` equal-mass quantile buckets; a
/// bucket predicts its upper edge (the conservative choice — an accurate
/// classification never under-predicts by more than one bucket's width).
///
/// Accuracy knob: with probability `accuracy` the classifier emits the
/// request's true bucket; otherwise it confuses it into an adjacent bucket
/// (the dominant error mode of ordinal classifiers), direction uniform,
/// reflecting inward at the first/last bucket so edge buckets keep the
/// same effective confusion rate as interior ones.
#[derive(Debug, Clone)]
pub struct BucketClassifier {
    /// Upper edge of each bucket, strictly ascending (duplicates from a
    /// degenerate fit are removed); the last edge is the sample maximum.
    edges: Vec<u32>,
    accuracy: f64,
    seed: u64,
}

impl BucketClassifier {
    /// Calibration-sample size for quantile fitting.
    const FIT_SAMPLES: usize = 65_536;

    /// Fit quantile buckets from an explicit sample of generation lengths
    /// (e.g. a recorded trace's lengths).
    pub fn fit_from_lengths(
        mut lengths: Vec<u32>,
        buckets: u32,
        accuracy: f64,
        seed: u64,
    ) -> BucketClassifier {
        assert!(
            (0.0..=1.0).contains(&accuracy),
            "accuracy must be in [0, 1]"
        );
        let edges = quantile_edges(&mut lengths, buckets);
        BucketClassifier {
            edges,
            accuracy,
            seed,
        }
    }

    /// Fit from a workload's analytic length distribution (what the CLI
    /// and figure suite do: the deployment profiles its own traffic).
    pub fn fit_distribution(
        dist: &LengthDistribution,
        buckets: u32,
        accuracy: f64,
        seed: u64,
    ) -> BucketClassifier {
        // The calibration stream is decorrelated from every serving stream.
        let mut rng = Rng::new(seed ^ 0xB0C4_E7F1);
        let lengths: Vec<u32> = (0..Self::FIT_SAMPLES).map(|_| dist.sample(&mut rng)).collect();
        BucketClassifier::fit_from_lengths(lengths, buckets, accuracy, seed)
    }

    pub fn buckets(&self) -> usize {
        self.edges.len()
    }

    /// The fitted bucket upper edges (strictly ascending).
    pub fn edges(&self) -> &[u32] {
        &self.edges
    }
}

impl LengthPredictor for BucketClassifier {
    fn predict(&self, req: &Request) -> u32 {
        bucket_predict(&self.edges, self.accuracy, self.seed, req)
    }

    fn name(&self) -> &'static str {
        "bucket"
    }
}

// ---------------------------------------------------------------------------
// PercentileConst
// ---------------------------------------------------------------------------

/// No-model baseline: predict one fixed percentile of the workload's
/// generation-length distribution for every request. p100 reproduces the
/// worst-case (`max_gen_len`-like) reservation; p50 halves it and accepts
/// under-predicting half the traffic.
#[derive(Debug, Clone)]
pub struct PercentileConst {
    value: u32,
    pct: f64,
}

impl PercentileConst {
    /// Calibration-sample size for the percentile fit.
    const FIT_SAMPLES: usize = 65_536;

    pub fn new(value: u32, pct: f64) -> PercentileConst {
        PercentileConst {
            value: value.max(1),
            pct,
        }
    }

    /// Fit the percentile from a workload's length distribution.
    pub fn fit_distribution(dist: &LengthDistribution, pct: f64, seed: u64) -> PercentileConst {
        assert!((0.0..=100.0).contains(&pct), "percentile must be in [0, 100]");
        let mut rng = Rng::new(seed ^ 0x9C7_D15E);
        let mut lengths: Vec<u32> =
            (0..Self::FIT_SAMPLES).map(|_| dist.sample(&mut rng)).collect();
        lengths.sort_unstable();
        let idx = ((pct / 100.0) * (lengths.len() - 1) as f64).round() as usize;
        PercentileConst::new(lengths[idx.min(lengths.len() - 1)], pct)
    }

    pub fn value(&self) -> u32 {
        self.value
    }

    pub fn pct(&self) -> f64 {
        self.pct
    }
}

impl LengthPredictor for PercentileConst {
    fn predict(&self, _req: &Request) -> u32 {
        self.value
    }

    fn name(&self) -> &'static str {
        "percentile"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::distributions::WorkloadKind;

    fn req(id: u64, gen: u32) -> Request {
        Request::new(id, 0.0, 64, gen)
    }

    #[test]
    fn oracle_is_exact() {
        let o = Oracle;
        assert_eq!(o.predict(&req(1, 200)), 200);
        assert_eq!(o.predict(&req(2, 0)), 1, "predictions are at least 1");
    }

    #[test]
    fn noisy_sigma_zero_is_oracle() {
        let p = NoisyOracle::new(0.0, 42);
        for (id, gen) in [(1u64, 7u32), (2, 200), (3, 1024)] {
            assert_eq!(p.predict(&req(id, gen)), gen.max(1));
        }
    }

    #[test]
    fn noisy_is_deterministic_per_request_and_varies_across_requests() {
        let p = NoisyOracle::new(0.5, 42);
        let a = p.predict(&req(1, 200));
        assert_eq!(a, p.predict(&req(1, 200)), "same request, same prediction");
        let distinct: std::collections::BTreeSet<u32> =
            (0..64).map(|id| p.predict(&req(id, 200))).collect();
        assert!(distinct.len() > 16, "error draws must vary per request");
        assert!(distinct.iter().all(|&x| x >= 1));
    }

    #[test]
    fn noisy_error_is_centered_on_truth() {
        // Median of exp(σ·z) is 1, so the median prediction is the truth.
        let p = NoisyOracle::new(0.5, 7);
        let mut preds: Vec<u32> = (0..1001).map(|id| p.predict(&req(id, 300))).collect();
        preds.sort_unstable();
        let median = preds[preds.len() / 2] as f64;
        assert!((median - 300.0).abs() < 60.0, "median {median}");
    }

    #[test]
    fn bucket_edges_are_quantiles() {
        let c = BucketClassifier::fit_from_lengths((1..=1000).collect(), 4, 1.0, 0);
        assert_eq!(c.buckets(), 4);
        assert_eq!(c.edges, vec![250, 500, 750, 1000]);
        // Perfect accuracy: predictions are the true bucket's upper edge.
        assert_eq!(c.predict(&req(1, 10)), 250);
        assert_eq!(c.predict(&req(2, 251)), 500);
        assert_eq!(c.predict(&req(3, 1000)), 1000);
        // Beyond the sample max: clamped to the top bucket.
        assert_eq!(c.predict(&req(4, 5000)), 1000);
    }

    #[test]
    fn bucket_perfect_accuracy_never_underpredicts_in_range() {
        let dist = WorkloadKind::CodeFuse.gen_dist(1024);
        let c = BucketClassifier::fit_distribution(&dist, 8, 1.0, 3);
        let mut rng = Rng::new(11);
        for id in 0..2000u64 {
            let truth = dist.sample(&mut rng);
            let r = req(id, truth);
            let pred = c.predict(&r);
            if truth <= c.edges[c.edges.len() - 1] {
                assert!(pred >= truth, "upper-edge prediction {pred} < truth {truth}");
            }
        }
    }

    #[test]
    fn bucket_accuracy_knob_controls_confusion_rate() {
        let c = BucketClassifier::fit_from_lengths((1..=1000).collect(), 10, 0.7, 5);
        let exact = BucketClassifier::fit_from_lengths((1..=1000).collect(), 10, 1.0, 5);
        let n = 4000u64;
        let rate_over = |truth_of: &dyn Fn(u64) -> u32| {
            let confused = (0..n)
                .filter(|&id| {
                    let truth = truth_of(id);
                    let r = req(id, truth);
                    c.predict(&r) != exact.predict(&r)
                })
                .count();
            confused as f64 / n as f64
        };
        // Interior buckets.
        let interior = rate_over(&|id| 100 + ((id * 37) % 800) as u32);
        // Edge buckets: the first (truths ≤ 100) and last (truths > 900)
        // must see the same effective confusion rate — the inward
        // reflection makes every error draw move the prediction, where the
        // old saturating slip silently dropped half of them.
        let first = rate_over(&|id| 1 + ((id * 37) % 100) as u32);
        let last = rate_over(&|id| 901 + ((id * 37) % 100) as u32);
        for (name, rate) in [("interior", interior), ("first", first), ("last", last)] {
            assert!(
                (rate - 0.3).abs() < 0.08,
                "{name}-bucket confusion rate {rate} not near 1 - accuracy"
            );
        }
    }

    #[test]
    fn degenerate_fit_dedupes_collapsed_edges() {
        // More buckets than samples: the quantile cut lands several edges
        // on the same value; they must collapse to distinct edges instead
        // of leaving phantom buckets that `partition_point` can never hit.
        let c = BucketClassifier::fit_from_lengths(vec![7, 7, 7], 8, 1.0, 0);
        assert_eq!(c.edges(), &[7]);
        assert_eq!(c.predict(&req(1, 3)), 7);
        assert_eq!(c.predict(&req(2, 7)), 7);
        assert_eq!(c.predict(&req(3, 999)), 7);

        // Heavy duplicates: 90% of the sample is one value.
        let mut lengths = vec![50u32; 900];
        lengths.extend(1..=100u32);
        let c = BucketClassifier::fit_from_lengths(lengths, 10, 1.0, 0);
        let e = c.edges();
        assert!(e.windows(2).all(|w| w[0] < w[1]), "edges not strictly ascending: {e:?}");
        assert!(e.contains(&50));
        assert_eq!(*e.last().unwrap(), 100, "last edge is the sample max");

        // A single-bucket classifier draws no confusion at all: with one
        // edge there is no adjacent bucket to slip into.
        let c = BucketClassifier::fit_from_lengths(vec![9, 9, 9, 9], 4, 0.0, 3);
        assert_eq!(c.edges(), &[9]);
        for id in 0..64 {
            assert_eq!(c.predict(&req(id, 5)), 9);
        }
    }

    #[test]
    fn percentile_const_predicts_one_value() {
        let dist = WorkloadKind::CodeFuse.gen_dist(1024);
        let p50 = PercentileConst::fit_distribution(&dist, 50.0, 1);
        let p95 = PercentileConst::fit_distribution(&dist, 95.0, 1);
        assert_eq!(p50.predict(&req(1, 7)), p50.predict(&req(2, 900)));
        assert!(p95.value() > p50.value());
        // CodeFuse: "vast majority < 512" — the median is far below it.
        assert!(p50.value() < 512, "p50 {}", p50.value());
    }
}
