//! The two estimators at the heart of SCLS (paper §4.2, §4.3) plus the
//! profiling/fitting machinery that calibrates them.

pub mod fit;
pub mod memory;
pub mod profiler;
pub mod serving_time;

pub use memory::{MemoryEstimator, MemoryRule};
pub use serving_time::{LinearLatency, ServingTimeEstimator, TransferCost};
