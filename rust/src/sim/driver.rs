//! The single discrete-event simulation loop.
//!
//! There is exactly ONE event loop in the DES: [`run_policy`]. It owns the
//! virtual clock, the time-ordered event queue (ties break by push order,
//! so runs are exactly reproducible from the seed), and the `RunMetrics`
//! event log; every scheduling decision is delegated to a
//! [`SchedulingPolicy`] object through three hooks (`on_arrival`,
//! `on_tick`, `on_worker_done`). The built-in policies — the
//! SLS → SO → PM → AB → LB → SCLS sliced ladder plus ILS and the §7
//! SCLS-CB extension — live in [`crate::sim::policies`], and the
//! SLO-aware trio (D-SCLS, P-SRPT, SW-SLO) in
//! [`crate::sim::slo_policies`]; user-defined policies implement the same
//! trait (see `examples/custom_policy.rs`).
//!
//! [`Simulation`] / [`ClusterBuilder`] are the facade: configure a
//! cluster, attach streaming [`MetricsSink`]s, and run policies by object,
//! by `SchedulerSpec`, or by name. The `run_sliced` / `run_ils` /
//! `run_scls_cb` functions survive as thin conveniences over the same
//! generic loop (the three bespoke drivers they used to be are frozen in
//! [`crate::sim::reference`] as differential oracles). A 10-minute 8-GPU
//! experiment completes in milliseconds either way.

use crate::engine::presets::EnginePreset;
use crate::estimator::profiler::{profile_and_fit, ProfileGrid};
use crate::estimator::{ServingTimeEstimator, TransferCost};
use crate::metrics::{MetricsSink, NullSink, RunMetrics};
use crate::predictor::PredictorSpec;
use crate::scheduler::policy::{Ev, SchedulingPolicy, SimCtx, WorkerLoss};
use crate::scheduler::spec::SchedulerSpec;
use crate::workload::Trace;

use super::events::EventQueue;
use super::faults::{FaultKind, FaultPlan};
use super::policies::{IlsPolicy, SclsCbPolicy, SlicedPolicy};

/// Cluster-level simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub workers: usize,
    pub engine: EnginePreset,
    /// Maximal generation length limit (paper: 1024).
    pub max_gen_len: u32,
    pub seed: u64,
    /// Length predictor the prediction-aware policies (P-SCLS / P-CB)
    /// build from — ignored by every other policy. Defaults to the exact
    /// oracle.
    pub predictor: PredictorSpec,
    /// Opt in to predicted early-return correction in the DP batcher
    /// (P-SCLS only; see [`crate::batcher::dp`]): batches whose members'
    /// predictions fall below the slice cap are costed at the predicted
    /// budget. Off by default — the legacy DP path stays bit-exact.
    pub pred_corrected_dp: bool,
    /// Per-tenant service weights for the coordinator's weighted-fairness
    /// path (`weights[t]` is tenant `t`'s share). `None` (the default)
    /// keeps the exact legacy FCFS drain order — byte-identical to the
    /// pre-tenancy code.
    pub tenant_weights: Option<Vec<f64>>,
    /// KV-transfer cost model for migrations under fleet churn: migrated
    /// requests stall for `stall(resident_kv_tokens)` seconds before they
    /// are servable on a new worker. `None` (the default) keeps migration
    /// free — byte-identical to the pre-transfer-cost code. Resident KV
    /// tokens are always counted in `kv_tokens_migrated` either way.
    pub kv_transfer: Option<TransferCost>,
}

impl SimConfig {
    pub fn new(workers: usize, engine: EnginePreset, max_gen_len: u32, seed: u64) -> SimConfig {
        SimConfig {
            workers,
            engine,
            max_gen_len,
            seed,
            predictor: PredictorSpec::Oracle,
            pred_corrected_dp: false,
            tenant_weights: None,
            kv_transfer: None,
        }
    }

    /// Select the length predictor P-SCLS / P-CB use.
    pub fn with_predictor(mut self, predictor: PredictorSpec) -> SimConfig {
        self.predictor = predictor;
        self
    }

    /// Toggle predicted early-return correction in the DP batcher.
    pub fn with_pred_corrected_dp(mut self, on: bool) -> SimConfig {
        self.pred_corrected_dp = on;
        self
    }

    /// Opt in to deficit-weighted per-tenant fairness in the sliced
    /// coordinator (see [`crate::scheduler::SlicedCoordinator`]).
    pub fn with_tenant_weights(mut self, weights: Option<Vec<f64>>) -> SimConfig {
        self.tenant_weights = weights;
        self
    }

    /// Opt in to KV-transfer cost on migration (see
    /// [`crate::estimator::TransferCost`]).
    pub fn with_kv_transfer(mut self, cost: Option<TransferCost>) -> SimConfig {
        self.kv_transfer = cost;
        self
    }
}

/// Profile the engine's latency model and fit Eq. (3)/(4) — what the SCLS
/// deployment does once at startup (§4.2). The profiling stream is
/// decorrelated from the serving stream.
pub fn fitted_estimator(preset: &EnginePreset, seed: u64) -> ServingTimeEstimator {
    let mut src = preset.latency(seed ^ 0xC0FFEE);
    profile_and_fit(&mut src, &ProfileGrid::default()).estimator
}

/// Drive one policy over one trace to drain: the generic DES loop.
///
/// `workers` only pre-sizes the event heap; the policy owns all worker
/// state. Every event (arrival, tick, worker-done) is counted in
/// `metrics.events`, and the policy streams batch/completion records to
/// `sink` through its [`SimCtx`].
pub fn run_policy(
    trace: &Trace,
    policy: &mut dyn SchedulingPolicy,
    workers: usize,
    sink: &mut dyn MetricsSink,
) -> RunMetrics {
    run_policy_faulted(trace, policy, workers, sink, &FaultPlan::none())
}

/// [`run_policy`] under a deterministic fault schedule: the plan's events
/// are pushed onto the heap *after* the trace arrivals (delivery order at
/// equal timestamps: arrivals, then fleet events in plan order, then any
/// runtime `WorkerDone` pushed later — the queue's FIFO tie-break). Join
/// events hand policies fresh, never-reused worker indices starting at
/// `workers`. An empty plan is literally `run_policy`: the loop body and
/// event stream are bit-identical.
pub fn run_policy_faulted(
    trace: &Trace,
    policy: &mut dyn SchedulingPolicy,
    workers: usize,
    sink: &mut dyn MetricsSink,
    plan: &FaultPlan,
) -> RunMetrics {
    let mut metrics = RunMetrics::with_capacity(trace.len());
    let mut q: EventQueue<Ev> =
        EventQueue::with_capacity(trace.len() + workers + plan.events.len() + 2);
    for (i, r) in trace.requests.iter().enumerate() {
        q.push(r.arrival, Ev::Arrival(i));
    }
    for (i, ev) in plan.events.iter().enumerate() {
        q.push(ev.at, Ev::Fleet(i));
    }
    // Joiners get fresh indices after the initial fleet; indices are never
    // reused, so `next_worker` only grows.
    let mut next_worker = workers;
    let mut arrivals_left = trace.len();
    {
        let mut ctx = SimCtx::new(0.0, arrivals_left, &mut q, &mut metrics, &mut *sink);
        policy.init(&mut ctx);
    }
    while let Some((now, ev)) = q.pop() {
        metrics.events += 1;
        match ev {
            Ev::Arrival(i) => {
                arrivals_left -= 1;
                let r = trace.requests[i].clone();
                let mut ctx = SimCtx::new(now, arrivals_left, &mut q, &mut metrics, &mut *sink);
                policy.on_arrival(r, &mut ctx);
            }
            Ev::Tick => {
                let mut ctx = SimCtx::new(now, arrivals_left, &mut q, &mut metrics, &mut *sink);
                policy.on_tick(&mut ctx);
            }
            Ev::WorkerDone(w) => {
                let mut ctx = SimCtx::new(now, arrivals_left, &mut q, &mut metrics, &mut *sink);
                policy.on_worker_done(w, &mut ctx);
            }
            Ev::Fleet(i) => {
                let mut ctx = SimCtx::new(now, arrivals_left, &mut q, &mut metrics, &mut *sink);
                match plan.events[i].kind {
                    FaultKind::Join { count } => {
                        for _ in 0..count {
                            let w = next_worker;
                            next_worker += 1;
                            policy.on_worker_join(w, &mut ctx);
                        }
                    }
                    FaultKind::Drain { worker } => {
                        policy.on_worker_lost(worker, WorkerLoss::Drain, &mut ctx);
                    }
                    FaultKind::Crash { worker } => {
                        policy.on_worker_lost(worker, WorkerLoss::Crash, &mut ctx);
                    }
                    FaultKind::CoordinatorCrash => {
                        // Recorded here, not per-policy, so the counter is
                        // uniform: worker-locus policies (CB family, SLS)
                        // keep their scheduling state worker-resident and
                        // recover with the default no-op hook.
                        ctx.record_coordinator_crash();
                        policy.on_coordinator_crash(&mut ctx);
                    }
                }
            }
        }
    }
    policy.finish(&mut metrics);
    sink.on_run_end(&metrics);
    metrics
}

// ---------------------------------------------------------------------------
// Simulation facade
// ---------------------------------------------------------------------------

/// Builder for a simulated cluster (defaults mirror the paper's §5.1
/// setup: 8 workers, DS engine, 1024-token generation cap, seed 42).
#[derive(Debug, Clone)]
pub struct ClusterBuilder {
    workers: usize,
    engine: EnginePreset,
    max_gen_len: u32,
    seed: u64,
    predictor: PredictorSpec,
    pred_corrected_dp: bool,
    tenant_weights: Option<Vec<f64>>,
    kv_transfer: Option<TransferCost>,
}

impl Default for ClusterBuilder {
    fn default() -> Self {
        use crate::engine::presets::EngineKind;
        ClusterBuilder {
            workers: 8,
            engine: EnginePreset::paper(EngineKind::Ds),
            max_gen_len: 1024,
            seed: 42,
            predictor: PredictorSpec::Oracle,
            pred_corrected_dp: false,
            tenant_weights: None,
            kv_transfer: None,
        }
    }
}

impl ClusterBuilder {
    pub fn new() -> ClusterBuilder {
        ClusterBuilder::default()
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    pub fn engine(mut self, preset: EnginePreset) -> Self {
        self.engine = preset;
        self
    }

    pub fn max_gen_len(mut self, n: u32) -> Self {
        self.max_gen_len = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Length predictor for the prediction-aware policies (P-SCLS / P-CB).
    pub fn predictor(mut self, predictor: PredictorSpec) -> Self {
        self.predictor = predictor;
        self
    }

    /// Opt in to predicted early-return correction in the DP batcher
    /// (P-SCLS only).
    pub fn pred_corrected_dp(mut self, on: bool) -> Self {
        self.pred_corrected_dp = on;
        self
    }

    /// Per-tenant service weights for the coordinator-batched policies
    /// (deficit-weighted admission; `None` keeps the legacy drain path).
    pub fn tenant_weights(mut self, weights: Option<Vec<f64>>) -> Self {
        self.tenant_weights = weights;
        self
    }

    /// KV-transfer cost model charged to migrated requests under churn.
    pub fn kv_transfer(mut self, cost: Option<TransferCost>) -> Self {
        self.kv_transfer = cost;
        self
    }

    pub fn build(self) -> Simulation {
        Simulation::new(
            SimConfig::new(self.workers, self.engine, self.max_gen_len, self.seed)
                .with_predictor(self.predictor)
                .with_pred_corrected_dp(self.pred_corrected_dp)
                .with_tenant_weights(self.tenant_weights)
                .with_kv_transfer(self.kv_transfer),
        )
    }
}

/// A configured simulated cluster: run any policy over any trace, with
/// optional streaming metrics sinks.
#[derive(Debug, Clone)]
pub struct Simulation {
    cfg: SimConfig,
}

impl Simulation {
    pub fn new(cfg: SimConfig) -> Simulation {
        Simulation { cfg }
    }

    pub fn builder() -> ClusterBuilder {
        ClusterBuilder::default()
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// Run a policy object to drain.
    pub fn run(&self, trace: &Trace, policy: &mut dyn SchedulingPolicy) -> RunMetrics {
        self.run_with_sink(trace, policy, &mut NullSink)
    }

    /// Run a policy with a streaming sink observing the event stream
    /// (attach several with [`crate::metrics::Fanout`]).
    pub fn run_with_sink(
        &self,
        trace: &Trace,
        policy: &mut dyn SchedulingPolicy,
        sink: &mut dyn MetricsSink,
    ) -> RunMetrics {
        run_policy(trace, policy, self.cfg.workers, sink)
    }

    /// Construct and run a sliced-family policy from its declarative spec.
    pub fn run_spec(&self, trace: &Trace, spec: &SchedulerSpec) -> RunMetrics {
        let mut policy = SlicedPolicy::new(spec, &self.cfg);
        self.run(trace, &mut policy)
    }

    /// Construct and run a built-in policy by (case-insensitive) name —
    /// see [`crate::scheduler::BUILTIN_POLICIES`].
    pub fn run_named(
        &self,
        trace: &Trace,
        name: &str,
        slice_len: u32,
    ) -> Result<RunMetrics, String> {
        self.run_named_with_sink(trace, name, slice_len, &mut NullSink)
    }

    /// [`Self::run_named`] with a streaming sink.
    pub fn run_named_with_sink(
        &self,
        trace: &Trace,
        name: &str,
        slice_len: u32,
        sink: &mut dyn MetricsSink,
    ) -> Result<RunMetrics, String> {
        let mut policy = crate::scheduler::policy::build_policy(name, &self.cfg, slice_len)?;
        Ok(self.run_with_sink(trace, policy.as_mut(), sink))
    }

    /// Run a policy object under a deterministic fault schedule
    /// ([`FaultPlan`]). `FaultPlan::none()` is byte-identical to
    /// [`Self::run`].
    pub fn run_faulted(
        &self,
        trace: &Trace,
        policy: &mut dyn SchedulingPolicy,
        plan: &FaultPlan,
    ) -> RunMetrics {
        run_policy_faulted(trace, policy, self.cfg.workers, &mut NullSink, plan)
    }

    /// [`Self::run_named`] under a deterministic fault schedule.
    pub fn run_named_faulted(
        &self,
        trace: &Trace,
        name: &str,
        slice_len: u32,
        plan: &FaultPlan,
    ) -> Result<RunMetrics, String> {
        self.run_named_faulted_with_sink(trace, name, slice_len, plan, &mut NullSink)
    }

    /// [`Self::run_named_faulted`] with a streaming sink.
    pub fn run_named_faulted_with_sink(
        &self,
        trace: &Trace,
        name: &str,
        slice_len: u32,
        plan: &FaultPlan,
        sink: &mut dyn MetricsSink,
    ) -> Result<RunMetrics, String> {
        let mut policy = crate::scheduler::policy::build_policy(name, &self.cfg, slice_len)?;
        Ok(run_policy_faulted(
            trace,
            policy.as_mut(),
            self.cfg.workers,
            sink,
            plan,
        ))
    }
}

// ---------------------------------------------------------------------------
// Thin conveniences (the former bespoke drivers, now trait-backed)
// ---------------------------------------------------------------------------

/// Run one sliced-family experiment to drain (SLS/SO/PM/AB/LB/SCLS).
pub fn run_sliced(trace: &Trace, spec: &SchedulerSpec, cfg: &SimConfig) -> RunMetrics {
    let mut policy = SlicedPolicy::new(spec, cfg);
    run_policy(trace, &mut policy, cfg.workers, &mut NullSink)
}

/// Run the ILS baseline (continuous batching, conservative cap) to drain.
pub fn run_ils(trace: &Trace, cfg: &SimConfig) -> RunMetrics {
    let mut policy = IlsPolicy::new(cfg);
    run_policy(trace, &mut policy, cfg.workers, &mut NullSink)
}

/// Run the §7 SCLS-on-continuous-batching extension to drain.
pub fn run_scls_cb(trace: &Trace, cfg: &SimConfig, slice_len: u32) -> RunMetrics {
    let mut policy = SclsCbPolicy::new(cfg, slice_len);
    run_policy(trace, &mut policy, cfg.workers, &mut NullSink)
}

/// Run P-CB (continuous batching with predicted-KV admission) to drain,
/// building the predictor from `cfg.predictor`.
pub fn run_p_cb(trace: &Trace, cfg: &SimConfig) -> RunMetrics {
    let mut policy = super::policies::PredictiveCbPolicy::new(
        cfg,
        cfg.predictor.build(cfg.max_gen_len, cfg.seed),
    );
    run_policy(trace, &mut policy, cfg.workers, &mut NullSink)
}

/// Run P-SCLS (prediction-seeded slice ladder) to drain, building the
/// predictor from `cfg.predictor`.
pub fn run_p_scls(trace: &Trace, cfg: &SimConfig, slice_len: u32) -> RunMetrics {
    let spec = SchedulerSpec::p_scls(&cfg.engine, slice_len);
    let mut policy = super::policies::PredictiveSlicedPolicy::new(
        &spec,
        cfg,
        cfg.predictor.build(cfg.max_gen_len, cfg.seed),
    );
    run_policy(trace, &mut policy, cfg.workers, &mut NullSink)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::presets::{EngineKind, EnginePreset};
    use crate::metrics::Tally;
    use crate::workload::distributions::WorkloadKind;
    use crate::workload::{Trace, TraceConfig};

    fn small_trace(rate: f64, duration: f64, seed: u64) -> Trace {
        Trace::generate(&TraceConfig {
            kind: WorkloadKind::CodeFuse,
            rate,
            duration,
            max_input_len: 1024,
            max_gen_len: 1024,
            seed,
        })
    }

    fn cfg(kind: EngineKind) -> SimConfig {
        SimConfig::new(4, EnginePreset::paper(kind), 1024, 7)
    }

    #[test]
    fn scls_completes_all_requests() {
        let trace = small_trace(4.0, 30.0, 1);
        let preset = EnginePreset::paper(EngineKind::Ds);
        let spec = SchedulerSpec::scls(&preset, 128);
        let m = run_sliced(&trace, &spec, &cfg(EngineKind::Ds));
        assert_eq!(m.completed.len(), trace.len());
        // every request generated at least 1 token and at most the cap
        assert!(m.completed.iter().all(|c| c.generated >= 1));
        assert!(m.completed.iter().all(|c| c.generated <= 1024));
    }

    #[test]
    fn sls_completes_all_requests() {
        let trace = small_trace(2.0, 20.0, 2);
        let preset = EnginePreset::paper(EngineKind::Ds);
        let spec = SchedulerSpec::sls(&preset, 1024);
        let m = run_sliced(&trace, &spec, &cfg(EngineKind::Ds));
        assert_eq!(m.completed.len(), trace.len());
        // SLS: exactly one schedule per request
        assert!(m.completed.iter().all(|c| c.slices == 1));
    }

    #[test]
    fn ils_completes_all_requests() {
        let trace = small_trace(4.0, 30.0, 3);
        let m = run_ils(&trace, &cfg(EngineKind::Ds));
        assert_eq!(m.completed.len(), trace.len());
        // continuous batching: no pads, no invalid tokens
        assert!(m.completed.iter().all(|c| c.pad_tokens == 0));
        assert!(m.completed.iter().all(|c| c.invalid_tokens == 0));
    }

    #[test]
    fn deterministic_given_seed() {
        let trace = small_trace(3.0, 20.0, 4);
        let preset = EnginePreset::paper(EngineKind::Ds);
        let spec = SchedulerSpec::scls(&preset, 128);
        let a = run_sliced(&trace, &spec, &cfg(EngineKind::Ds));
        let b = run_sliced(&trace, &spec, &cfg(EngineKind::Ds));
        assert_eq!(a.completed.len(), b.completed.len());
        assert_eq!(a.summarize().throughput, b.summarize().throughput);
        assert_eq!(a.batches.len(), b.batches.len());
        assert_eq!(a.events, b.events);
        assert_eq!(a.peak_pool, b.peak_pool);
        // The full event logs are byte-identical.
        assert_eq!(a.to_json().to_string_pretty(), b.to_json().to_string_pretty());
    }

    #[test]
    fn event_and_pool_counters_populated() {
        let trace = small_trace(4.0, 30.0, 31);
        let preset = EnginePreset::paper(EngineKind::Ds);
        let spec = SchedulerSpec::scls(&preset, 128);
        let m = run_sliced(&trace, &spec, &cfg(EngineKind::Ds));
        // At least one event per arrival, plus ticks and completions.
        assert!(m.events as usize > trace.len(), "events {} ", m.events);
        assert!(m.peak_pool >= 1);
        assert!(m.peak_pool <= trace.len());
        // ILS counts its events too (no pool ticks there).
        let ils = run_ils(&trace, &cfg(EngineKind::Ds));
        assert!(ils.events as usize >= trace.len());
        assert_eq!(ils.peak_pool, 0);
    }

    #[test]
    fn scls_slices_match_generation_lengths() {
        let trace = small_trace(2.0, 20.0, 5);
        let preset = EnginePreset::paper(EngineKind::Ds);
        let spec = SchedulerSpec::scls(&preset, 128);
        let m = run_sliced(&trace, &spec, &cfg(EngineKind::Ds));
        for c in &m.completed {
            let min_slices = (c.generated as f64 / 128.0).ceil() as u32;
            assert!(
                c.slices >= min_slices,
                "req {}: {} slices for {} tokens",
                c.id,
                c.slices,
                c.generated
            );
        }
    }

    #[test]
    fn scls_beats_sls_throughput_ds() {
        // The headline claim at modest scale: same trace, same cluster.
        let trace = small_trace(8.0, 60.0, 6);
        let preset = EnginePreset::paper(EngineKind::Ds);
        let c = cfg(EngineKind::Ds);
        let scls = run_sliced(&trace, &SchedulerSpec::scls(&preset, 128), &c).summarize();
        let sls = run_sliced(&trace, &SchedulerSpec::sls(&preset, 1024), &c).summarize();
        assert!(
            scls.throughput > sls.throughput,
            "SCLS {} !> SLS {}",
            scls.throughput,
            sls.throughput
        );
        assert!(scls.avg_invalid_tokens < sls.avg_invalid_tokens);
    }

    #[test]
    fn scls_balances_better_than_sls() {
        let trace = small_trace(8.0, 60.0, 8);
        let preset = EnginePreset::paper(EngineKind::Ds);
        let c = cfg(EngineKind::Ds);
        let scls = run_sliced(&trace, &SchedulerSpec::scls(&preset, 128), &c).summarize();
        let sls = run_sliced(&trace, &SchedulerSpec::sls(&preset, 1024), &c).summarize();
        assert!(
            scls.ct_std <= sls.ct_std * 1.5,
            "SCLS ct_std {} vs SLS {}",
            scls.ct_std,
            sls.ct_std
        );
    }

    #[test]
    fn scls_cb_completes_all_requests_cleanly() {
        let trace = small_trace(4.0, 30.0, 21);
        let m = run_scls_cb(&trace, &cfg(EngineKind::Ds), 128);
        assert_eq!(m.completed.len(), trace.len());
        // Continuous batching: no pads, no invalid tokens, ever.
        assert!(m.completed.iter().all(|c| c.pad_tokens == 0));
        assert!(m.completed.iter().all(|c| c.invalid_tokens == 0));
        // Slice accounting: ceil(generated / S) schedules.
        for c in &m.completed {
            let want = (c.generated as f64 / 128.0).ceil() as u32;
            assert_eq!(c.slices, want, "req {}: {} slices", c.id, c.slices);
        }
    }

    #[test]
    fn scls_cb_beats_ils_via_precise_admission() {
        // §7's claim: precise per-slice memory admission serves more
        // requests in parallel than ILS's conservative cap → throughput.
        let trace = small_trace(10.0, 60.0, 22);
        let c = cfg(EngineKind::Ds);
        let cb = run_scls_cb(&trace, &c, 128).summarize();
        let ils = run_ils(&trace, &c).summarize();
        assert!(
            cb.throughput > ils.throughput,
            "SCLS-CB {} !> ILS {}",
            cb.throughput,
            ils.throughput
        );
        assert!(cb.avg_response_time < ils.avg_response_time);
    }

    #[test]
    fn scls_cb_balances_memory_load() {
        // Memory-aware offloading should spread completion times at least
        // as well as ILS's round-robin.
        let trace = small_trace(10.0, 60.0, 23);
        let c = cfg(EngineKind::Ds);
        let cb = run_scls_cb(&trace, &c, 128).summarize();
        let ils = run_ils(&trace, &c).summarize();
        assert!(
            cb.ct_std <= ils.ct_std * 1.2,
            "SCLS-CB ct_std {} vs ILS {}",
            cb.ct_std,
            ils.ct_std
        );
    }

    #[test]
    fn batch_records_populated() {
        let trace = small_trace(3.0, 15.0, 9);
        let preset = EnginePreset::paper(EngineKind::Hf);
        let spec = SchedulerSpec::scls(&preset, 128);
        let m = run_sliced(&trace, &spec, &cfg(EngineKind::Hf));
        assert!(!m.batches.is_empty());
        for b in &m.batches {
            assert!(b.size >= 1);
            assert!(b.actual_serve_time > 0.0);
            assert!(b.est_serve_time > 0.0);
        }
    }

    #[test]
    fn builder_facade_runs_by_spec_and_name() {
        let trace = small_trace(3.0, 20.0, 11);
        let preset = EnginePreset::paper(EngineKind::Ds);
        let sim = Simulation::builder()
            .workers(4)
            .engine(preset.clone())
            .max_gen_len(1024)
            .seed(7)
            .build();
        let by_spec = sim.run_spec(&trace, &SchedulerSpec::scls(&preset, 128));
        let by_name = sim.run_named(&trace, "scls", 128).unwrap();
        assert_eq!(
            by_spec.to_json().to_string_pretty(),
            by_name.to_json().to_string_pretty(),
            "name-based construction must match spec-based construction"
        );
        assert!(sim.run_named(&trace, "not-a-policy", 128).is_err());
    }

    #[test]
    fn sink_streams_what_metrics_record() {
        let trace = small_trace(4.0, 30.0, 12);
        let preset = EnginePreset::paper(EngineKind::Ds);
        let sim = Simulation::new(cfg(EngineKind::Ds));
        let mut tally = Tally::default();
        let mut policy = SlicedPolicy::new(&SchedulerSpec::scls(&preset, 128), sim.config());
        let m = sim.run_with_sink(&trace, &mut policy, &mut tally);
        assert_eq!(tally.completions as usize, m.completed.len());
        assert_eq!(tally.batches as usize, m.batches.len());
        assert_eq!(tally.peak_pool, m.peak_pool);
        assert_eq!(tally.last_completion, m.makespan);
        let pads: u64 = m.completed.iter().map(|c| c.pad_tokens).sum();
        assert_eq!(tally.pad_tokens, pads);
    }
}
