//! Tiny CLI argument parser (the offline registry has no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands. Typed accessors with defaults; `usage()` aggregates help.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut positional = Vec::new();
        let mut flags: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => {
                        // consume the next token as the value unless it looks
                        // like another flag
                        let next_is_val =
                            it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                        if next_is_val {
                            (stripped.to_string(), Some(it.next().unwrap()))
                        } else {
                            (stripped.to_string(), None)
                        }
                    }
                };
                flags
                    .entry(key)
                    .or_default()
                    .push(val.unwrap_or_else(|| "true".to_string()));
            } else {
                positional.push(a);
            }
        }
        Args {
            positional,
            flags,
            seen: Default::default(),
        }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// First positional argument, typically the subcommand.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains_key(key)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags
            .get(key)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str_opt(key).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.str_opt(key)
            .map(|s| {
                s.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{key} expects a number, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.str_opt(key)
            .map(|s| {
                s.parse::<u64>()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn u32_or(&self, key: &str, default: u32) -> u32 {
        self.u64_or(key, default as u64) as u32
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.u64_or(key, default as u64) as usize
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.str_opt(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(s) => panic!("--{key} expects a bool, got '{s}'"),
        }
    }

    /// Shared comma-separated list parser behind the typed wrappers.
    fn list_or<T: std::str::FromStr + Clone>(&self, key: &str, default: &[T]) -> Vec<T> {
        match self.str_opt(key) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse::<T>()
                        .unwrap_or_else(|_| panic!("--{key}: bad value '{x}'"))
                })
                .collect(),
        }
    }

    /// Comma-separated list of numbers, e.g. `--rates 12,16,20`.
    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> Vec<f64> {
        self.list_or(key, default)
    }

    pub fn u64_list_or(&self, key: &str, default: &[u64]) -> Vec<u64> {
        self.list_or(key, default)
    }

    pub fn u32_list_or(&self, key: &str, default: &[u32]) -> Vec<u32> {
        self.list_or(key, default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = args("simulate --rate 20 --engine ds --verbose");
        assert_eq!(a.subcommand(), Some("simulate"));
        assert_eq!(a.f64_or("rate", 0.0), 20.0);
        assert_eq!(a.str_or("engine", "hf"), "ds");
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = args("--slice-len=128 --zeta=0.9");
        assert_eq!(a.u32_or("slice-len", 0), 128);
        assert!((a.f64_or("zeta", 0.0) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn lists() {
        let a = args("--rates 12,16,20 --workers 1,2,4,8 --seeds 42,43");
        assert_eq!(a.f64_list_or("rates", &[]), vec![12.0, 16.0, 20.0]);
        assert_eq!(a.u32_list_or("workers", &[]), vec![1, 2, 4, 8]);
        assert_eq!(a.u64_list_or("seeds", &[7]), vec![42, 43]);
        assert_eq!(a.u64_list_or("absent", &[7]), vec![7]);
    }

    #[test]
    fn defaults() {
        let a = args("run");
        assert_eq!(a.f64_or("rate", 20.0), 20.0);
        assert_eq!(a.str_or("engine", "hf"), "hf");
        assert!(!a.bool_or("flag", false));
    }

    #[test]
    fn bool_flag_without_value() {
        let a = args("--flag --next cmd");
        assert!(a.bool_or("flag", false));
        assert_eq!(a.str_or("next", ""), "cmd");
    }
}
