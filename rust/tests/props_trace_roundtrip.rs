//! Trace persistence round-trip property: `Trace::from_json(save(t)) == t`
//! field-exact — bit-exact arrival times included — across workload kinds,
//! rates, and seeds. Both the in-memory JSON path and the on-disk
//! `save`/`load` path are exercised (the float formatter emits the
//! shortest representation that parses back to the identical f64, so
//! exactness is a guarantee, not an approximation).

use scls::testprop::{check, Gen};
use scls::util::json::Json;
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};
use scls::{prop_assert, prop_assert_eq};

fn assert_traces_field_exact(a: &Trace, b: &Trace) -> Result<(), scls::testprop::PropFail> {
    prop_assert_eq!(a.len(), b.len(), "request count");
    prop_assert!(
        a.config_rate.to_bits() == b.config_rate.to_bits(),
        "rate drifted: {} vs {}",
        a.config_rate,
        b.config_rate
    );
    prop_assert!(
        a.duration.to_bits() == b.duration.to_bits(),
        "duration drifted: {} vs {}",
        a.duration,
        b.duration
    );
    for (x, y) in a.requests.iter().zip(&b.requests) {
        prop_assert_eq!(x.id, y.id, "id");
        prop_assert!(
            x.arrival.to_bits() == y.arrival.to_bits(),
            "arrival of {} drifted: {:?} vs {:?}",
            x.id,
            x.arrival,
            y.arrival
        );
        prop_assert_eq!(x.input_len, y.input_len, "input_len of {}", x.id);
        prop_assert_eq!(
            x.target_gen_len,
            y.target_gen_len,
            "target_gen_len of {}",
            x.id
        );
    }
    Ok(())
}

#[test]
fn trace_json_roundtrip_is_field_exact() {
    check("trace-json-roundtrip", 24, |g: &mut Gen| {
        let kind = if g.bool() {
            WorkloadKind::CodeFuse
        } else {
            WorkloadKind::ShareGpt
        };
        let cfg = TraceConfig {
            kind,
            rate: *g.pick(&[0.5, 4.0, 20.0, 50.0]),
            duration: *g.pick(&[5.0, 20.0, 60.0]),
            max_input_len: *g.pick(&[64u32, 512, 1024]),
            max_gen_len: *g.pick(&[64u32, 512, 1024]),
            seed: g.u64(),
        };
        let t = Trace::generate(&cfg);
        // Compact and pretty serializations must both parse back exactly.
        for text in [
            t.to_json().to_string_compact(),
            t.to_json().to_string_pretty(),
        ] {
            let back = Trace::from_json(&Json::parse(&text).map_err(|e| {
                scls::testprop::PropFail {
                    msg: format!("reparse failed: {e:?}"),
                }
            })?)
            .map_err(|e| scls::testprop::PropFail {
                msg: format!("from_json failed: {e:#}"),
            })?;
            assert_traces_field_exact(&t, &back)?;
        }
        Ok(())
    });
}

#[test]
fn trace_save_load_roundtrip_on_disk() {
    // The satellite's exact claim, through the filesystem: save() → load()
    // reproduces every field across kinds and seeds.
    let dir = std::env::temp_dir();
    for (i, (kind, rate, seed)) in [
        (WorkloadKind::CodeFuse, 20.0, 42u64),
        (WorkloadKind::CodeFuse, 3.0, 7),
        (WorkloadKind::ShareGpt, 12.0, 1234),
    ]
    .into_iter()
    .enumerate()
    {
        let t = Trace::generate(&TraceConfig {
            kind,
            rate,
            duration: 30.0,
            max_input_len: 1024,
            max_gen_len: 1024,
            seed,
        });
        let path = dir.join(format!(
            "scls_trace_roundtrip_{}_{}.json",
            std::process::id(),
            i
        ));
        t.save(&path).expect("save");
        let back = Trace::load(&path).expect("load");
        let _ = std::fs::remove_file(&path);
        assert_eq!(t.len(), back.len());
        assert_eq!(t.config_rate.to_bits(), back.config_rate.to_bits());
        assert_eq!(t.duration.to_bits(), back.duration.to_bits());
        for (x, y) in t.requests.iter().zip(&back.requests) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits(), "req {}", x.id);
            assert_eq!(x.input_len, y.input_len);
            assert_eq!(x.target_gen_len, y.target_gen_len);
        }
        // Loaded traces start with pristine scheduling state.
        assert!(back.requests.iter().all(|r| r.generated == 0
            && r.slices == 0
            && r.predicted_gen.is_none()
            && r.finished_at.is_none()));
    }
}
