//! Experiment configuration: defaults = the paper's §5.1 settings,
//! overridable from a simple `key = value` config file and/or CLI flags
//! (the offline registry has no serde/toml, so the file format is a
//! flat TOML subset: comments with `#`, bare keys, numbers/strings/bools).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::engine::presets::EngineKind;
use crate::workload::distributions::WorkloadKind;

/// Flat key-value config file.
#[derive(Debug, Clone, Default)]
pub struct ConfigFile {
    values: BTreeMap<String, String>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<ConfigFile> {
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || line.starts_with('[') {
                continue; // section headers are cosmetic
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("config line {}: expected key = value", lineno + 1))?;
            values.insert(
                k.trim().to_string(),
                v.trim().trim_matches('"').to_string(),
            );
        }
        Ok(ConfigFile { values })
    }

    pub fn load(path: &Path) -> Result<ConfigFile> {
        ConfigFile::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|s| s.parse::<f64>().map_err(|_| anyhow!("config {key}: bad number '{s}'")))
            .transpose()
    }

    pub fn u32(&self, key: &str) -> Result<Option<u32>> {
        self.get(key)
            .map(|s| s.parse::<u32>().map_err(|_| anyhow!("config {key}: bad integer '{s}'")))
            .transpose()
    }
}

/// One experiment's full configuration (paper defaults).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub engine: EngineKind,
    pub workload: WorkloadKind,
    pub workers: usize,
    pub rate: f64,
    pub duration: f64,
    pub slice_len: u32,
    pub max_input_len: u32,
    pub max_gen_len: u32,
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            engine: EngineKind::Ds,
            workload: WorkloadKind::CodeFuse,
            workers: 8,
            rate: 20.0,
            duration: 600.0,
            slice_len: 128,
            max_input_len: 1024,
            max_gen_len: 1024,
            seed: 42,
        }
    }
}

impl ExperimentConfig {
    /// Apply overrides from a config file.
    pub fn apply_file(&mut self, f: &ConfigFile) -> Result<()> {
        if let Some(s) = f.get("engine") {
            self.engine =
                EngineKind::parse(s).ok_or_else(|| anyhow!("config engine: unknown '{s}'"))?;
        }
        if let Some(s) = f.get("workload") {
            self.workload =
                WorkloadKind::parse(s).ok_or_else(|| anyhow!("config workload: unknown '{s}'"))?;
        }
        if let Some(x) = f.u32("workers")? {
            self.workers = x as usize;
        }
        if let Some(x) = f.f64("rate")? {
            self.rate = x;
        }
        if let Some(x) = f.f64("duration")? {
            self.duration = x;
        }
        if let Some(x) = f.u32("slice_len")? {
            self.slice_len = x;
        }
        if let Some(x) = f.u32("max_input_len")? {
            self.max_input_len = x;
        }
        if let Some(x) = f.u32("max_gen_len")? {
            self.max_gen_len = x;
        }
        if let Some(x) = f.u32("seed")? {
            self.seed = x as u64;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_toml_subset() {
        let f = ConfigFile::parse(
            "# paper defaults\n[experiment]\nengine = \"hf\"\nrate = 24\nslice_len = 256\n",
        )
        .unwrap();
        assert_eq!(f.get("engine"), Some("hf"));
        let mut cfg = ExperimentConfig::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.engine, EngineKind::Hf);
        assert_eq!(cfg.rate, 24.0);
        assert_eq!(cfg.slice_len, 256);
        // untouched defaults survive
        assert_eq!(cfg.workers, 8);
    }

    #[test]
    fn rejects_garbage() {
        assert!(ConfigFile::parse("not a config").is_err());
        let f = ConfigFile::parse("rate = abc").unwrap();
        let mut cfg = ExperimentConfig::default();
        assert!(cfg.apply_file(&f).is_err());
    }

    #[test]
    fn defaults_match_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.workers, 8);
        assert_eq!(c.duration, 600.0);
        assert_eq!(c.slice_len, 128);
        assert_eq!(c.max_gen_len, 1024);
    }
}
