//! Real-mode cluster: OS-thread workers executing the PJRT engine, driven
//! by the same scheduler specs as the DES (wall clock instead of virtual).

pub mod real_driver;

pub use real_driver::{run_real, RealClusterConfig};
