//! Implement your own generation-length predictor against the
//! `LengthPredictor` trait and drive the prediction-aware P-CB scheduler
//! with it.
//!
//! The predictor below is the classic cheap heuristic: guess that a reply
//! is about as long as its prompt (code-assistant traffic often correlates
//! the two), clamped to a sane band. It takes 4 lines of logic; the same
//! generic DES loop, metrics, and recovery machinery that run the
//! built-in oracle/noisy/bucket predictors run this one.
//!
//! Run: `cargo run --release --example custom_predictor`

use scls::core::Request;
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::predictor::{LengthPredictor, PredictorSpec};
use scls::sim::policies::PredictiveCbPolicy;
use scls::sim::Simulation;
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};

/// "Replies are about as long as their prompts": predict 1.5× the input
/// length, clamped to [16, 768]. No oracle access at all — this is a
/// heuristic a real deployment could ship on day one.
struct PromptLenHeuristic;

impl LengthPredictor for PromptLenHeuristic {
    fn predict(&self, req: &Request) -> u32 {
        ((req.orig_input_len as f64 * 1.5) as u32).clamp(16, 768)
    }

    fn name(&self) -> &'static str {
        "prompt-len-heuristic"
    }
}

fn main() {
    let preset = EnginePreset::paper(EngineKind::Ds);
    let trace = Trace::generate(&TraceConfig {
        kind: WorkloadKind::CodeFuse,
        rate: 12.0,
        duration: 60.0,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed: 42,
    });
    let sim = Simulation::builder()
        .workers(4)
        .engine(preset.clone())
        .seed(42)
        .build();

    // Custom predictor → P-CB policy object, run on the generic loop.
    let mut custom = PredictiveCbPolicy::new(sim.config(), Box::new(PromptLenHeuristic));
    let mc = sim.run(&trace, &mut custom);

    // Built-in predictors for comparison: exact oracle and a p90 constant.
    let oracle_cfg = sim.config().clone().with_predictor(PredictorSpec::Oracle);
    let mut oracle = PredictiveCbPolicy::new(
        &oracle_cfg,
        oracle_cfg.predictor.build(oracle_cfg.max_gen_len, oracle_cfg.seed),
    );
    let mo = sim.run(&trace, &mut oracle);

    // Prediction-free baseline.
    let mb = sim.run_named(&trace, "SCLS-CB", 128).unwrap();

    println!("policy                thpt    avg RT   underpred  overpred  wasted tok");
    for (name, m) in [
        ("P-CB (heuristic)", &mc),
        ("P-CB (oracle)", &mo),
        ("SCLS-CB", &mb),
    ] {
        let s = m.summarize();
        println!(
            "{name:<20} {:>6.2}   {:>6.2}   {:>8}  {:>8}  {:>9}",
            s.throughput, s.avg_response_time, m.underpredicted, m.overpredicted,
            m.wasted_kv_token_steps
        );
    }
    println!(
        "\nThe oracle row is the upper bound; the heuristic pays for its misses\n\
         through eviction/re-admission (underpred) and idle reservations\n\
         (wasted tok), which is exactly the trade the predictor subsystem\n\
         makes measurable."
    );
}
