"""AOT export pipeline: HLO text is parseable-shaped, manifest is complete,
and a lowered bucket matches the eager path (what Rust will execute equals
what Python verified)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

CFG = M.ModelConfig()
ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    def fn(x):
        return (x * 2.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_bucket_lowering_matches_eager():
    """The exact function aot.py lowers must agree with eager execution."""
    n, l, s = 2, 16, 4
    fn = M.generate_slice_fn(CFG, n, l, s)
    rng = np.random.default_rng(42)
    toks = np.zeros((n, l), np.int32)
    lens = np.asarray([10, 16], np.int32)
    for i, ln in enumerate(lens):
        toks[i, l - ln:] = rng.integers(3, CFG.vocab, ln)
    active = np.ones(n, np.int32)
    off = np.zeros(n, np.int32)

    eager_gen, eager_iters = fn(
        jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(active), jnp.asarray(off))
    jit_gen, jit_iters = jax.jit(fn)(
        jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(active), jnp.asarray(off))
    np.testing.assert_array_equal(np.asarray(eager_gen), np.asarray(jit_gen))
    assert int(eager_iters) == int(jit_iters)


def test_hlo_text_has_while_loop():
    """The early-return decode loop must survive lowering as an HLO while."""
    fn = M.generate_slice_fn(CFG, 1, 16, 4)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((1, 16), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    assert "while" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_complete():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["model"]["vocab"] == CFG.vocab
    assert man["model"]["kv_bytes_per_token"] == CFG.kv_bytes_per_token
    assert man["tokens"] == {"pad": M.PAD_ID, "eos": M.EOS_ID, "bos": M.BOS_ID}
    assert len(man["buckets"]) >= 1
    for b in man["buckets"]:
        path = os.path.join(ART, b["file"])
        assert os.path.exists(path), f"missing artifact {b['file']}"
        assert b["l"] + b["s"] <= CFG.max_pos
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_covers_runtime_needs():
    """Every (N, L) a scheduler can produce must round up to some bucket."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    ns = sorted({b["n"] for b in man["buckets"]})
    ls = sorted({b["l"] for b in man["buckets"]})
    assert ns[0] == 1, "must be able to serve a single request"
    # max input (96) + accumulated generation must fit the largest L bucket
    assert ls[-1] >= 160
