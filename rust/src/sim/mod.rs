//! Discrete-event simulation substrate.

pub mod driver;
pub mod events;

pub use events::EventQueue;
