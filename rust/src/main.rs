//! `scls-repro` — leader entrypoint / CLI for the SCLS reproduction.
//!
//! Subcommands:
//!
//! * `figures`    — regenerate every paper figure (DES-backed) into
//!                  `results/` and print the tables.
//! * `figure ID`  — regenerate one figure (fig5, fig6, fig8, fig10, fig11,
//!                  fig12, fig15, fig17, fig18, fig22).
//! * `simulate`   — run one (engine, scheduler, rate) experiment cell and
//!                  print the summary.
//! * `serve`      — wall-clock serving of the real tiny-GPT model through
//!                  PJRT (requires `make artifacts`).
//! * `profile`    — print the engine latency profile grid and the fitted
//!                  Eq. (3)/(4) coefficients.
//! * `trace`      — generate a synthetic CodeFuse/ShareGPT trace to JSON.
//! * `lint`       — run the in-repo determinism & invariant static
//!                  analysis; non-zero exit on any finding.
//!
//! Run `scls-repro help` for flags.

#![forbid(unsafe_code)]

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use scls::bench::figures::{self, FigureConfig, FigureResult};
use scls::config::{ConfigFile, ExperimentConfig};
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::estimator::profiler::{profile_and_fit, ProfileGrid};
use scls::estimator::TransferCost;
use scls::predictor::PredictorSpec;
use scls::scheduler::parse_policy_name;
use scls::scheduler::spec::SchedulerSpec;
use scls::metrics::{Fanout, MetricsSink};
use scls::sim::driver::{SimConfig, Simulation};
use scls::sim::FaultPlan;
use scls::slo::{stamp_trace, SloSpec, TenantMix};
use scls::telemetry::{profile, TimeSeriesSink, TimelineSink};
use scls::util::cli::Args;
use scls::util::jobs::parallel_map;
use scls::util::logging;
use scls::worker::real_driver::{run_real, RealClusterConfig};
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};

const USAGE: &str = r#"scls-repro — Slice-Level Scheduling reproduction

USAGE:
  scls-repro <subcommand> [flags]

SUBCOMMANDS:
  figures     Regenerate all paper figures (writes results/<id>.json)
      --out-dir DIR      output directory            [results]
      --quick SCALE      trace-duration scale, 1.0 = paper's 10 min [0.2]
      --only IDS         comma list, e.g. fig5,fig12
      --seeds LIST       comma list of RNG seeds: replicate the whole set
                         per seed into results/seed<k>/  [42]
      --jobs N           parallel fan-out (output is byte-identical to
                         --jobs 1). Multiple figures/seeds fan out across
                         whole figures; a single figure fans out across
                         its simulation cells.  [1]
  figure ID   Regenerate one figure (same flags as `figures`)
  simulate    Run one experiment cell on the calibrated DES
      --engine hf|ds     inference engine            [ds]
      --scheduler NAME   SLS|ILS|SO|PM|AB|LB|SCLS|SCLS-CB|P-SCLS|P-CB|
                         D-SCLS|P-SRPT|SW-SLO (case-insensitive) [SCLS]
      --rate R           arrival rate req/s          [20]
      --workers W        LLM instances               [8]
      --duration SECS    trace duration              [600]
      --slice-len S      slice length                [128]
      --workload NAME    codefuse|sharegpt           [codefuse]
      --seed N           RNG seed                    [42]
      --config FILE      key=value config file overriding defaults
      --predictor NAME   length predictor for P-SCLS/P-CB:
                         oracle|noisy[:SIGMA]|bucket[:B]|online[:W]|
                         percentile[:P]   (online:W refits its buckets
                         from a sliding window of W completions)
                         [oracle]
      --pred-sigma S     noisy-oracle sigma (implies --predictor noisy)
      --pred-buckets B   bucket count (implies --predictor bucket)
      --pred-accuracy A  bucket/online classifier accuracy in [0,1] [0.85]
      --pred-corrected-dp  cost DP batches at their predicted early-return
                         budget instead of the full slice length (P-SCLS)
      --faults SPEC      worker/coordinator-lifecycle plan, comma list of
                         crash:wIDX@TIME | drain:wIDX@TIME | join:N@TIME |
                         rolling:PERIOD | coord@TIME (coordinator crash +
                         ledger reconstruction) | mtbf:SECS (Poisson
                         crashes; mttr:SECS adds recovery joins, seed:N
                         picks the stream) | burst:K@RATE (correlated
                         K-crash bursts). Stochastic entries expand into a
                         deterministic schedule over the run duration —
                         byte-identical replays per seed. Worker indices
                         are 0-based; joiners get fresh indices. E.g.
                         crash:w3@120,join:2@300 or mtbf:30,mttr:5,seed:7
                         or coord@15,rolling:30s.    [none]
      --kv-bandwidth B   model KV-cache transfer cost on migration: a
                         migrated request stalls base + tokens/B seconds
                         before serving on its new worker (B in tokens/s).
                         Off = migrations are free.  [off]
      --tenants SPEC     multi-tenant mix: a count N (uniform) or
                         N:w1,...,wN (weighted, e.g. 4:4,2,1,1). The
                         weights also drive the coordinator's
                         deficit-weighted fair service. [1 tenant]
      --slo SPEC         per-request SLO targets stamped on the trace,
                         comma list of ttft:SECS | tpot:SECS |
                         deadline:SECS (e.g. ttft:2,deadline:120);
                         lower-numbered tenants get tighter tiers [none]
      --trace-out FILE   write the run timeline as JSONL (one span or
                         fleet/reclaim/shed instant per line)    [off]
      --chrome-trace FILE  write the timeline as Chrome trace_event
                         JSON — load in Perfetto or chrome://tracing,
                         one track per worker                    [off]
      --imbalance        collect per-worker gauges and print the load-
                         imbalance indices (Jain's, max/mean, CV) [off]
      --profile          time scheduler hot paths (dp_plan, offload,
                         drain sort, schedule tick) and print the
                         wall-clock report                       [off]
      --out FILE         write the summary JSON                  [off]
  serve       Serve a scaled trace on the real PJRT cluster
      --artifacts DIR    AOT artifact dir            [artifacts]
      --workers W        worker threads              [2]
      --slice-len S      slice length (must be an exported bucket) [16]
      --max-gen N        generation cap              [64]
      --requests N       request count               [24]
      --rate R           arrival rate req/s          [4]
      --scheduler NAME   SLS|SO|PM|AB|LB|SCLS        [SCLS]
      --seed N           RNG seed                    [42]
  profile     Profile + fit an engine latency surface
      --engine hf|ds     engine                      [ds]
  trace       Generate a synthetic trace to JSON
      --out FILE         output path                 [trace.json]
      --workload NAME    codefuse|sharegpt           [codefuse]
      --rate R --duration SECS --seed N
  lint        Static analysis: determinism & invariant rules
              (hash-order, wall-clock, float-cmp, import-graph,
              frozen-manifest, sink-surface). Exits non-zero on any
              finding. Suppress a reviewed exception with
              `// scls-lint: allow(<rule>): <why>` on the flagged line.
      --root DIR         crate root (holding src/); default: `.` if it
                         has src/lib.rs, else `rust`
      --json             machine-readable report on stdout
      --write-manifest   regenerate lint/frozen.sha256 from the current
                         tree (review the diff before committing!)
  help        Print this text
"#;

fn main() {
    logging::init();
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("figures") => cmd_figures(args, None),
        Some("figure") => {
            let id = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("figure: missing id (e.g. `figure fig12`)"))?
                .clone();
            cmd_figures(args, Some(id))
        }
        Some("simulate") => cmd_simulate(args),
        Some("serve") => cmd_serve(args),
        Some("profile") => cmd_profile(args),
        Some("trace") => cmd_trace(args),
        Some("lint") => cmd_lint(args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}' (try `help`)"),
    }
}

// ---------------------------------------------------------------------------
// figures
// ---------------------------------------------------------------------------

/// All figure ids in paper order, with their drivers.
fn figure_ids() -> Vec<&'static str> {
    vec![
        "fig5", "fig6", "fig8", "fig10", "fig11", "fig12", "fig15", "fig17", "fig18", "fig22",
        "figpred", "figdrift", "figfault", "figslo", "figobs",
    ]
}

fn run_figure(id: &str, fc: &FigureConfig) -> Result<Vec<FigureResult>> {
    let rates = [12.0, 16.0, 20.0, 24.0, 28.0];
    let slice_lens = [32u32, 64, 128, 256, 512];
    let workers = [1usize, 2, 4, 8];
    Ok(match id {
        "fig5" => vec![figures::fig05(fc)],
        "fig6" => vec![figures::fig06(fc)],
        // Fig. 8 and Fig. 9 come from the same profiling grid.
        "fig8" | "fig9" => vec![
            figures::fig08_09(fc, EngineKind::Ds),
            figures::fig08_09(fc, EngineKind::Hf),
        ],
        "fig10" => vec![figures::fig10(fc)],
        "fig11" => vec![figures::fig11(fc)],
        // Figs. 12/13/14 are one sweep; 17 shares it but we keep the paper's
        // separate id.
        "fig12" | "fig13" | "fig14" => vec![figures::fig12_13_14(fc, &rates)],
        "fig15" | "fig16" => vec![
            figures::fig15_16(fc, EngineKind::Ds),
            figures::fig15_16(fc, EngineKind::Hf),
        ],
        "fig17" => vec![figures::fig17(fc, &rates)],
        "fig18" | "fig19" | "fig20" | "fig21" => vec![
            figures::fig18_21(fc, EngineKind::Ds, &slice_lens),
            figures::fig18_21(fc, EngineKind::Hf, &slice_lens),
        ],
        "fig22" => vec![figures::fig22(fc, &workers)],
        // Extension: throughput vs length-prediction error (P-SCLS/P-CB).
        "figpred" => vec![figures::fig_pred(fc, &[0.0, 0.1, 0.25, 0.5, 1.0])],
        // Extension: online predictor refit under a mid-run length drift.
        "figdrift" => vec![figures::fig_drift(fc)],
        // Extension: throughput/P99 through rolling restarts and correlated
        // failures (elastic fault-tolerant fleet).
        "figfault" => vec![figures::fig_fault(fc)],
        // Extension: SLO attainment vs arrival rate — the sweep runs past
        // saturation so the deadline-aware policies separate from the
        // oblivious ladder.
        "figslo" => vec![figures::fig_slo(fc, &[8.0, 16.0, 24.0, 32.0, 40.0])],
        // Extension: per-worker telemetry view of the load-balance claim
        // (served/busy imbalance indices over the time-series gauges).
        "figobs" => vec![figures::figobs(fc)],
        other => bail!("unknown figure id '{other}' (known: {:?})", figure_ids()),
    })
}

fn cmd_figures(args: &Args, only_pos: Option<String>) -> Result<()> {
    let out_dir = PathBuf::from(args.str_or("out-dir", "results"));
    let scale = args.f64_or("quick", 0.2);
    let jobs = args.usize_or("jobs", 1).max(1);
    std::fs::create_dir_all(&out_dir)?;

    let ids: Vec<String> = if let Some(id) = only_pos {
        vec![id]
    } else if let Some(only) = args.str_opt("only") {
        only.split(',').map(|s| s.trim().to_string()).collect()
    } else {
        figure_ids().into_iter().map(String::from).collect()
    };
    // Multi-seed replication: `--seeds 42,43,44` reruns the whole figure
    // set per seed into results/seed<k>/; without the flag the layout is
    // the classic single-seed one.
    let multi_seed = args.has("seeds");
    let seeds: Vec<u64> = args.u64_list_or("seeds", &[FigureConfig::default().seed]);

    // One job per (seed, figure): whole figures fan out across the pool,
    // and parallelism left over when there are fewer figure jobs than
    // `--jobs` threads goes to the simulation cells *inside* each figure
    // (so `figure fig12 --jobs 8` and `figures --only fig5,fig12 --jobs 8`
    // both saturate). Every cell is a pure function of its arguments and
    // results are assembled in input order, so output is byte-identical to
    // `--jobs 1`.
    let cells: Vec<(u64, String)> = seeds
        .iter()
        .flat_map(|&seed| ids.iter().map(move |id| (seed, id.clone())))
        .collect();
    let inner_jobs = (jobs / cells.len().max(1)).max(1);
    log::info!(
        "running {} figure job(s) over {} seed(s) with --jobs {jobs} (duration scale {scale})",
        cells.len(),
        seeds.len()
    );
    let results: Vec<Result<Vec<FigureResult>>> = parallel_map(jobs, cells.clone(), |(seed, id)| {
        let mut fc = FigureConfig::quick(scale);
        fc.jobs = inner_jobs;
        fc.seed = seed;
        run_figure(&id, &fc)
    });

    // Print and write sequentially, in input order.
    for ((seed, _id), res) in cells.into_iter().zip(results) {
        let dir = if multi_seed {
            out_dir.join(format!("seed{seed}"))
        } else {
            out_dir.clone()
        };
        std::fs::create_dir_all(&dir)?;
        for (i, r) in res?.into_iter().enumerate() {
            r.print();
            let suffix = if i == 0 { String::new() } else { format!("_{i}") };
            let path = dir.join(format!("{}{suffix}.json", r.id));
            std::fs::write(&path, r.json.to_string_pretty())?;
            log::info!("wrote {}", path.display());
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// simulate
// ---------------------------------------------------------------------------

fn experiment_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    if let Some(path) = args.str_opt("config") {
        cfg.apply_file(&ConfigFile::load(Path::new(path))?)?;
    }
    if let Some(s) = args.str_opt("engine") {
        cfg.engine = EngineKind::parse(s).ok_or_else(|| anyhow!("bad --engine '{s}'"))?;
    }
    if let Some(s) = args.str_opt("workload") {
        cfg.workload = WorkloadKind::parse(s).ok_or_else(|| anyhow!("bad --workload '{s}'"))?;
    }
    cfg.workers = args.usize_or("workers", cfg.workers);
    cfg.rate = args.f64_or("rate", cfg.rate);
    cfg.duration = args.f64_or("duration", cfg.duration);
    cfg.slice_len = args.u32_or("slice-len", cfg.slice_len);
    cfg.max_input_len = args.u32_or("max-input-len", cfg.max_input_len);
    cfg.max_gen_len = args.u32_or("max-gen-len", cfg.max_gen_len);
    cfg.seed = args.u64_or("seed", cfg.seed);
    // A NaN/∞/non-positive rate or duration would silently produce an
    // empty (or never-ending) Poisson trace — fail loudly instead.
    if !(cfg.rate.is_finite() && cfg.rate > 0.0) {
        return Err(anyhow!(
            "--rate must be a finite, positive arrival rate in req/s (got {})",
            cfg.rate
        ));
    }
    if !(cfg.duration.is_finite() && cfg.duration > 0.0) {
        return Err(anyhow!(
            "--duration must be a finite, positive number of seconds (got {})",
            cfg.duration
        ));
    }
    Ok(cfg)
}

/// Parse `--tenants` / `--slo` into the trace-stamping inputs. Either flag
/// alone works: `--slo` without `--tenants` stamps a single tenant, and
/// `--tenants` without `--slo` stamps tenancy (and turns on weighted fair
/// service) with no SLO targets.
fn tenancy_spec(args: &Args) -> Result<(Option<TenantMix>, Option<SloSpec>)> {
    let mix = match args.str_opt("tenants") {
        Some(s) => Some(TenantMix::parse(s).map_err(|e| anyhow!("--tenants: {e}"))?),
        None => None,
    };
    let slo = match args.str_opt("slo") {
        Some(s) => {
            let spec = SloSpec::parse(s).map_err(|e| anyhow!("--slo: {e}"))?;
            if spec.is_none() {
                None
            } else {
                Some(spec)
            }
        }
        None => None,
    };
    Ok((mix, slo))
}

/// Assemble the predictor spec from `--predictor` plus the dedicated
/// override flags (`--pred-sigma`, `--pred-buckets`, `--pred-accuracy`).
fn predictor_spec(args: &Args, workload: WorkloadKind) -> Result<PredictorSpec> {
    let mut spec = PredictorSpec::parse(args.str_or("predictor", "oracle"), workload)
        .map_err(|e| anyhow!("{e}"))?;
    if args.has("pred-sigma") {
        // Same bounds as the `noisy:<sigma>` spelling: a negative (or
        // NaN/∞) sigma must fail loudly here rather than propagate into a
        // degenerate log-normal error model.
        let sigma = args.f64_or("pred-sigma", PredictorSpec::DEFAULT_SIGMA);
        if !(sigma.is_finite() && sigma >= 0.0) {
            return Err(anyhow!("--pred-sigma must be finite and non-negative (got {sigma})"));
        }
        spec = match spec {
            PredictorSpec::Oracle | PredictorSpec::Noisy { .. } => {
                PredictorSpec::Noisy { sigma }
            }
            other => other, // sigma is meaningless for bucket/percentile
        };
    }
    if args.has("pred-buckets") || args.has("pred-accuracy") {
        // Override only what the flags name, keeping whatever the
        // `--predictor bucket:N` / `online:W` spelling already set.
        let (base_buckets, base_accuracy) = match &spec {
            PredictorSpec::Bucket {
                buckets, accuracy, ..
            }
            | PredictorSpec::Online {
                buckets, accuracy, ..
            } => (*buckets, *accuracy),
            _ => (
                PredictorSpec::DEFAULT_BUCKETS,
                PredictorSpec::DEFAULT_ACCURACY,
            ),
        };
        // Parse wide, then validate: `u32_or` would wrap ≥ 2^32 values
        // before the range check. Same bounds as `--predictor bucket:<N>`
        // — the two spellings must not disagree on what they accept.
        let buckets = args.u64_or("pred-buckets", base_buckets as u64);
        if !(1..=PredictorSpec::MAX_BUCKETS as u64).contains(&buckets) {
            return Err(anyhow!(
                "--pred-buckets must be in [1, {}] (got {buckets})",
                PredictorSpec::MAX_BUCKETS
            ));
        }
        let buckets = buckets as u32;
        let accuracy = args.f64_or("pred-accuracy", base_accuracy);
        // clamp(NaN) is NaN — reject it before it reaches the confusion
        // draw as a never-confuse/always-confuse coin.
        if !accuracy.is_finite() {
            return Err(anyhow!(
                "--pred-accuracy must be a finite number in [0, 1] (got {accuracy})"
            ));
        }
        let accuracy = accuracy.clamp(0.0, 1.0);
        spec = match spec {
            PredictorSpec::Oracle | PredictorSpec::Bucket { .. } => PredictorSpec::Bucket {
                buckets,
                accuracy,
                workload,
            },
            PredictorSpec::Online { window, .. } => PredictorSpec::Online {
                window,
                buckets,
                accuracy,
                workload,
            },
            other => other,
        };
    }
    Ok(spec)
}

/// Parse `--faults` into a validated plan against the run's initial fleet
/// size. Absent flag → the canonical empty plan (byte-identical runs to the
/// fixed-fleet world). `horizon` bounds the stochastic (`mtbf:`/`burst:`)
/// expansion — callers pass the run duration so generated faults land
/// inside the trace.
fn fault_plan(args: &Args, workers: usize, horizon: f64) -> Result<FaultPlan> {
    match args.str_opt("faults") {
        Some(spec) => FaultPlan::parse_with_horizon(spec, workers, horizon)
            .map_err(|e| anyhow!("--faults: {e}")),
        None => Ok(FaultPlan::none()),
    }
}

/// Parse `--kv-bandwidth` into a KV-transfer cost model: tokens/s of
/// migration bandwidth. Absent flag → no model (migrations are free, the
/// pre-PR 10 behaviour and the byte-identity baseline).
fn kv_transfer_cost(args: &Args) -> Result<Option<TransferCost>> {
    match args.str_opt("kv-bandwidth") {
        Some(raw) => {
            let bw: f64 = raw.parse().map_err(|_| {
                anyhow!("--kv-bandwidth: expected tokens/s as a number, got `{raw}`")
            })?;
            if !bw.is_finite() || bw <= 0.0 {
                return Err(anyhow!(
                    "--kv-bandwidth: bandwidth must be finite and positive (got {bw})"
                ));
            }
            Ok(Some(TransferCost::from_bandwidth(bw)))
        }
        None => Ok(None),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = experiment_config(args)?;
    // Case-insensitive; unknown names error with the valid-name list.
    let which = parse_policy_name(args.str_or("scheduler", "SCLS")).map_err(|e| anyhow!("{e}"))?;
    let pspec = predictor_spec(args, cfg.workload)?;
    let plan = fault_plan(args, cfg.workers, cfg.duration)?;
    let kv_transfer = kv_transfer_cost(args)?;
    let (mix, slo) = tenancy_spec(args)?;
    let mut trace = Trace::generate(&TraceConfig {
        kind: cfg.workload,
        rate: cfg.rate,
        duration: cfg.duration,
        max_input_len: cfg.max_input_len,
        max_gen_len: cfg.max_gen_len,
        seed: cfg.seed,
    });
    if mix.is_some() || slo.is_some() {
        let m = mix.clone().unwrap_or_else(|| TenantMix::uniform(1));
        let base = slo.clone().unwrap_or_else(SloSpec::none);
        stamp_trace(&mut trace, &m, &base, cfg.seed);
    }
    // Multi-tenant runs drive the coordinator's deficit-weighted fair
    // service off the mix weights; single-tenant runs keep the legacy
    // drain path.
    let tenant_weights = mix
        .as_ref()
        .filter(|m| m.tenants() > 1)
        .map(|m| m.weights.clone());
    // bool_or handles all spellings: absent → false, bare flag → true,
    // `--pred-corrected-dp false` → false.
    let pred_corrected = args.bool_or("pred-corrected-dp", false);
    if pred_corrected && which != "P-SCLS" {
        log::warn!(
            "--pred-corrected-dp only affects the P-SCLS scheduler (got {which}); \
             this run is uncorrected"
        );
    }
    let sim = Simulation::new(
        SimConfig::new(
            cfg.workers,
            EnginePreset::paper(cfg.engine),
            cfg.max_gen_len,
            cfg.seed,
        )
        .with_predictor(pspec.clone())
        .with_pred_corrected_dp(pred_corrected)
        .with_tenant_weights(tenant_weights)
        .with_kv_transfer(kv_transfer),
    );
    log::info!(
        "simulate: {} requests, {} workers, engine {}, scheduler {}",
        trace.len(),
        cfg.workers,
        cfg.engine.name(),
        which
    );
    // Opt-in telemetry: attaching sinks cannot perturb the run (they never
    // touch `RunMetrics`), so a traced run's summary is byte-identical to
    // a bare one.
    let trace_out = args.str_opt("trace-out");
    let chrome_out = args.str_opt("chrome-trace");
    let want_timeline = trace_out.is_some() || chrome_out.is_some();
    let want_imbalance = args.bool_or("imbalance", false);
    let want_profile = args.bool_or("profile", false);
    let mut timeline = TimelineSink::new();
    let mut series = TimeSeriesSink::default();
    if want_profile {
        profile::enable();
    }
    let metrics = {
        let mut sinks: Vec<&mut dyn MetricsSink> = Vec::new();
        if want_timeline {
            sinks.push(&mut timeline);
        }
        if want_imbalance {
            sinks.push(&mut series);
        }
        if sinks.is_empty() {
            sim.run_named_faulted(&trace, which, cfg.slice_len, &plan)
        } else {
            let mut fan = Fanout(sinks);
            sim.run_named_faulted_with_sink(&trace, which, cfg.slice_len, &plan, &mut fan)
        }
    }
    .map_err(|e| anyhow!("{e}"))?;
    let s = metrics.summarize();
    println!("engine            {}", cfg.engine.name());
    println!("scheduler         {which}");
    println!("requests          {} (completed {})", trace.len(), s.completed);
    println!("throughput        {:.3} req/s", s.throughput);
    println!("avg response      {:.2} s", s.avg_response_time);
    println!("p95 response      {:.2} s", s.p95_response_time);
    println!("avg batch size    {:.2}", s.avg_batch_size);
    println!("invalid tok/req   {:.2}", s.avg_invalid_tokens);
    println!("pad tok/req       {:.2}", s.avg_pad_tokens);
    println!("CT std            {:.2} s", s.ct_std);
    println!("early-return      {:.4}", s.early_return_ratio);
    println!("slices [1,2,3,4+] {:?}", s.slice_histogram);
    if !plan.is_empty() {
        println!("fault events      {}", plan.events.len());
        println!("worker crashes    {}", metrics.worker_crashes);
        println!("coord crashes     {}", metrics.coordinator_crashes);
        println!("reclaimed reqs    {}", metrics.reclaimed_requests);
        println!("lost slices       {}", metrics.lost_slices);
        println!("migrations        {}", metrics.migrations);
        println!("kv tok migrated   {}", metrics.kv_tokens_migrated);
        println!("migration stall   {:.2} s", metrics.migration_stall_s);
    }
    if slo.is_some() {
        println!(
            "slo attained      {}/{} ({:.3})",
            metrics.slo.attained,
            metrics.slo.tracked,
            metrics.slo.attainment()
        );
        println!("ttft p99          {:.2} s", metrics.slo.ttft_p99());
        println!("ttft misses       {}", metrics.slo.ttft_misses);
        println!("tpot misses       {}", metrics.slo.tpot_misses);
        println!("deadline misses   {}", metrics.slo.deadline_misses);
        println!("shed requests     {}", metrics.shed_requests);
        for (t, ts) in &metrics.slo.per_tenant {
            println!(
                "  tenant {t:<3}     {}/{} attained, {} shed",
                ts.attained, ts.tracked, ts.shed
            );
        }
    }
    if matches!(which, "P-SCLS" | "P-CB") {
        println!("predictor         {}", pspec.describe());
        println!("underpredicted    {}", metrics.underpredicted);
        println!("overpredicted     {}", metrics.overpredicted);
        println!("wasted KV tokens  {}", metrics.wasted_kv_token_steps);
        if matches!(pspec, PredictorSpec::Online { .. }) {
            println!("predictor refits  {}", metrics.predictor_refits);
        }
        if pred_corrected {
            println!("corrected batches {}", metrics.corrected_batches);
        }
    }
    if want_imbalance {
        let served = series.served_imbalance();
        let busy = series.busy_imbalance();
        println!(
            "served imbalance  Jain {:.3}  max/mean {:.2}  CV {:.3}",
            served.jains, served.max_over_mean, served.cv
        );
        println!(
            "busy imbalance    Jain {:.3}  max/mean {:.2}  CV {:.3}",
            busy.jains, busy.max_over_mean, busy.cv
        );
    }
    if want_profile {
        profile::disable();
        print!("{}", profile::take().report());
    }
    if let Some(path) = trace_out {
        timeline.write_jsonl(Path::new(path))?;
        log::info!(
            "wrote timeline {path} ({} spans, {} instants)",
            timeline.spans().len(),
            timeline.instants().len()
        );
    }
    if let Some(path) = chrome_out {
        timeline.write_chrome_trace(Path::new(path))?;
        log::info!("wrote Chrome trace {path}");
    }
    if let Some(out) = args.str_opt("out") {
        std::fs::write(out, s.to_json().to_string_pretty())?;
        log::info!("wrote {out}");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// serve (real PJRT cluster)
// ---------------------------------------------------------------------------

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts_dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    if !artifacts_dir.join("manifest.json").exists() {
        bail!(
            "no artifacts at {} — run `make artifacts` first",
            artifacts_dir.display()
        );
    }
    let cfg = RealClusterConfig {
        artifacts_dir,
        workers: args.usize_or("workers", 2),
        slice_len: args.u32_or("slice-len", 16),
        max_gen_len: args.u32_or("max-gen", 64),
        skip_profiling: args.bool_or("skip-profiling", false),
        warmup: args.bool_or("warmup", true),
    };
    let n = args.usize_or("requests", 24);
    let rate = args.f64_or("rate", 4.0);
    let seed = args.u64_or("seed", 42);
    let which = parse_policy_name(args.str_or("scheduler", "SCLS")).map_err(|e| anyhow!("{e}"))?;

    // Synthesize token-bearing requests with Poisson arrivals; lengths from
    // the CodeFuse-shaped input distribution scaled to the bucket budget.
    let mut rng = scls::util::rng::Rng::new(seed);
    let max_in = 48u32;
    let mut reqs = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for id in 0..n as u64 {
        t += rng.exponential(rate);
        let len = 3 + (rng.next_u64() % (max_in as u64 - 3)) as usize;
        let tokens: Vec<i32> = (0..len).map(|_| 3 + (rng.next_u64() % 400) as i32).collect();
        reqs.push(scls::core::Request::with_tokens(id, t, tokens));
    }

    let preset = EnginePreset::paper(EngineKind::Hf);
    let mut spec = match which {
        "SLS" => SchedulerSpec::sls(&preset, cfg.max_gen_len),
        "SO" => SchedulerSpec::slice_only(&preset, cfg.slice_len),
        // (fixed batch sizes are clamped to the largest exported N bucket
        // below — the real cluster's OOM limit is bucket capacity)
        "PM" => SchedulerSpec::padding_mitigating(&preset, cfg.slice_len),
        "AB" => SchedulerSpec::adaptive_batching(&preset, cfg.slice_len),
        "LB" => SchedulerSpec::load_balancing(&preset, cfg.slice_len),
        "SCLS" => SchedulerSpec::scls(&preset, cfg.slice_len),
        other => bail!("scheduler {other} is not available in real mode (valid: SLS, SO, PM, AB, LB, SCLS)"),
    };
    // Real mode slices are bucket-bound; scale the tick interval Γ down to
    // the small model's speed (paper: Γ tuned per engine, §5.1).
    spec.slice_len = cfg.slice_len;
    if let scls::scheduler::spec::BatchingSpec::WorkerFcfs { batch_size } = spec.batching {
        spec.batching = scls::scheduler::spec::BatchingSpec::WorkerFcfs {
            batch_size: batch_size.min(8),
        };
    }
    let gamma = args.f64_or("gamma", 0.5);
    if let scls::scheduler::spec::IntervalSpec::Adaptive { lambda, .. } = spec.interval {
        spec.interval = scls::scheduler::spec::IntervalSpec::Adaptive { lambda, gamma };
    } else if let scls::scheduler::spec::IntervalSpec::Fixed(_) = spec.interval {
        spec.interval = scls::scheduler::spec::IntervalSpec::Fixed(gamma);
    }

    log::info!(
        "serving {n} requests on {} real workers (slice {}, scheduler {which})",
        cfg.workers,
        cfg.slice_len
    );
    let t0 = std::time::Instant::now();
    let m = run_real(reqs, &spec, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();
    let s = m.summarize();
    println!("completed         {}/{n} in {wall:.2} s wall", s.completed);
    println!("throughput        {:.3} req/s", s.throughput);
    println!("avg response      {:.3} s", s.avg_response_time);
    println!("p95 response      {:.3} s", s.p95_response_time);
    println!("avg batch size    {:.2}", s.avg_batch_size);
    println!("pad tok/req       {:.2}", s.avg_pad_tokens);
    println!("invalid tok/req   {:.2}", s.avg_invalid_tokens);
    println!("CT std            {:.3} s", s.ct_std);
    if let Some(out) = args.str_opt("out") {
        std::fs::write(out, s.to_json().to_string_pretty())?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// profile
// ---------------------------------------------------------------------------

fn cmd_profile(args: &Args) -> Result<()> {
    let kind = EngineKind::parse(args.str_or("engine", "ds"))
        .ok_or_else(|| anyhow!("bad --engine"))?;
    let preset = EnginePreset::paper(kind);
    let mut lat = preset.latency(args.u64_or("seed", 7));
    let res = profile_and_fit(&mut lat, &ProfileGrid::default());
    println!("engine {}", kind.name());
    println!(
        "prefill  T(N,L) = {:.3e}·N·L + {:.3e}·N + {:.3e}·L + {:.3e}   (RMSE {:.4} s)",
        res.estimator.prefill.c1,
        res.estimator.prefill.c2,
        res.estimator.prefill.c3,
        res.estimator.prefill.c4,
        res.prefill_rmse
    );
    println!(
        "decode   τ(l,N) = {:.3e}·N·l + {:.3e}·N + {:.3e}·l + {:.3e}   (RMSE {:.4} s)",
        res.estimator.decode.c1,
        res.estimator.decode.c2,
        res.estimator.decode.c3,
        res.estimator.decode.c4,
        res.decode_rmse
    );
    // A few example estimates mirroring the paper's anchors.
    for (n, l, s) in [(1u32, 64u32, 128u32), (8, 1024, 128), (12, 512, 128), (16, 1024, 128)] {
        println!(
            "T_serve(N={n:<2} L={l:<4} S={s}) = {:.2} s",
            res.estimator.serve(n, l, s)
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// lint
// ---------------------------------------------------------------------------

/// Crate root for the lint pass: `--root DIR`, else `.` when it looks
/// like the crate directory, else the `rust/` subdirectory (so the
/// command works from both the repo root and the crate root).
fn lint_root(args: &Args) -> PathBuf {
    if let Some(dir) = args.str_opt("root") {
        return PathBuf::from(dir);
    }
    if Path::new("src/lib.rs").exists() {
        PathBuf::from(".")
    } else {
        PathBuf::from("rust")
    }
}

fn cmd_lint(args: &Args) -> Result<()> {
    let root = lint_root(args);
    if args.bool_or("write-manifest", false) {
        let text = scls::analysis::manifest::render(&root);
        let path = root.join(scls::analysis::manifest::MANIFEST_PATH);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, &text)?;
        println!("wrote {} ({} entries)", path.display(), text.lines().count());
        return Ok(());
    }
    let findings = scls::analysis::run_lint(&root).map_err(|e| anyhow!("lint: {e}"))?;
    if args.bool_or("json", false) {
        println!("{}", scls::analysis::findings_to_json(&findings).to_string_pretty());
    } else {
        for f in &findings {
            println!("{f}");
        }
        println!(
            "lint: {} finding(s) across {} rule(s)",
            findings.len(),
            scls::analysis::ALL_RULES.len()
        );
    }
    if findings.is_empty() {
        Ok(())
    } else {
        Err(anyhow!("lint: {} finding(s)", findings.len()))
    }
}

// ---------------------------------------------------------------------------
// trace
// ---------------------------------------------------------------------------

fn cmd_trace(args: &Args) -> Result<()> {
    let kind = WorkloadKind::parse(args.str_or("workload", "codefuse"))
        .ok_or_else(|| anyhow!("bad --workload"))?;
    let cfg = TraceConfig {
        kind,
        rate: args.f64_or("rate", 20.0),
        duration: args.f64_or("duration", 600.0),
        max_input_len: args.u32_or("max-input-len", 1024),
        max_gen_len: args.u32_or("max-gen-len", 1024),
        seed: args.u64_or("seed", 42),
    };
    let trace = Trace::generate(&cfg);
    let out = PathBuf::from(args.str_or("out", "trace.json"));
    trace.save(&out)?;
    println!("wrote {} requests to {}", trace.len(), out.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    fn spec_of(s: &str) -> Result<PredictorSpec> {
        predictor_spec(&args(s), WorkloadKind::CodeFuse)
    }

    #[test]
    fn predictor_flags_assemble_specs() {
        assert_eq!(spec_of("simulate").unwrap(), PredictorSpec::Oracle);
        assert_eq!(
            spec_of("simulate --pred-sigma 0.3").unwrap(),
            PredictorSpec::Noisy { sigma: 0.3 }
        );
        match spec_of("simulate --pred-buckets 4 --pred-accuracy 0.9").unwrap() {
            PredictorSpec::Bucket { buckets, accuracy, .. } => {
                assert_eq!(buckets, 4);
                assert!((accuracy - 0.9).abs() < 1e-12);
            }
            other => panic!("expected bucket spec, got {other:?}"),
        }
    }

    #[test]
    fn pred_buckets_zero_is_a_friendly_error() {
        let err = spec_of("simulate --pred-buckets 0").unwrap_err().to_string();
        assert!(err.contains("--pred-buckets"), "{err}");
        assert!(err.contains("[1,"), "{err}");
        // Same failure through the `--predictor bucket:0` spelling.
        assert!(spec_of("simulate --predictor bucket:0").is_err());
    }

    #[test]
    fn negative_pred_sigma_is_a_friendly_error() {
        let err = spec_of("simulate --pred-sigma -0.5").unwrap_err().to_string();
        assert!(err.contains("--pred-sigma"), "{err}");
        assert!(err.contains("non-negative"), "{err}");
        assert!(spec_of("simulate --pred-sigma nan").is_err());
        assert!(spec_of("simulate --pred-sigma inf").is_err());
        // Zero sigma (an exact oracle) stays valid.
        assert_eq!(
            spec_of("simulate --pred-sigma 0").unwrap(),
            PredictorSpec::Noisy { sigma: 0.0 }
        );
        // The equivalent registry spelling fails the same way.
        assert!(spec_of("simulate --predictor noisy:-0.5").is_err());
    }

    fn plan_of(s: &str, workers: usize) -> Result<FaultPlan> {
        fault_plan(&args(s), workers, 600.0)
    }

    #[test]
    fn faults_flag_absent_is_the_empty_plan() {
        assert_eq!(plan_of("simulate", 8).unwrap(), FaultPlan::none());
    }

    #[test]
    fn faults_flag_parses_valid_specs() {
        let plan = plan_of("simulate --faults crash:w3@120,join:2@300", 8).unwrap();
        assert_eq!(plan.events.len(), 2);
        // Rolling restarts expand to drain+join per initial worker.
        let plan = plan_of("simulate --faults rolling:30s", 4).unwrap();
        assert_eq!(plan.events.len(), 8);
    }

    #[test]
    fn faults_unknown_worker_index_is_a_friendly_error() {
        let err = plan_of("simulate --faults crash:w9@10", 8).unwrap_err().to_string();
        assert!(err.contains("--faults"), "{err}");
        assert!(err.contains("unknown worker"), "{err}");
        // A join that fires first makes the index valid.
        assert!(plan_of("simulate --faults join:2@5,crash:w9@10", 8).is_ok());
    }

    #[test]
    fn faults_bad_times_are_friendly_errors() {
        let err = plan_of("simulate --faults crash:w1@-5", 8).unwrap_err().to_string();
        assert!(err.contains("negative"), "{err}");
        let err = plan_of("simulate --faults drain:w1@nan", 8).unwrap_err().to_string();
        assert!(err.contains("finite"), "{err}");
    }

    #[test]
    fn faults_zero_join_count_is_a_friendly_error() {
        let err = plan_of("simulate --faults join:0@5", 8).unwrap_err().to_string();
        assert!(err.contains("at least 1"), "{err}");
    }

    #[test]
    fn faults_junk_entries_are_friendly_errors() {
        let err = plan_of("simulate --faults explode:w1@10", 8).unwrap_err().to_string();
        assert!(err.contains("unknown fault op"), "{err}");
        let err = plan_of("simulate --faults crash:w1", 8).unwrap_err().to_string();
        assert!(err.contains("@TIME"), "{err}");
    }

    #[test]
    fn faults_coordinator_crash_parses() {
        let plan = plan_of("simulate --faults coord@15", 8).unwrap();
        assert_eq!(plan.events.len(), 1);
        // Mixed with worker events, still one plan.
        let plan = plan_of("simulate --faults coord@15,crash:w1@10", 8).unwrap();
        assert_eq!(plan.events.len(), 2);
    }

    #[test]
    fn faults_stochastic_grammar_parses_and_replays_deterministically() {
        let a = plan_of("simulate --faults mtbf:30,mttr:5,seed:7", 8).unwrap();
        assert!(!a.is_empty(), "an mtbf of 30s over 600s must generate events");
        // Same seed → byte-identical schedule; different seed → different.
        let b = plan_of("simulate --faults mtbf:30,mttr:5,seed:7", 8).unwrap();
        assert_eq!(a, b);
        let c = plan_of("simulate --faults mtbf:30,mttr:5,seed:8", 8).unwrap();
        assert_ne!(a, c);
        // Correlated bursts layer on top (expansion coverage lives in
        // sim::faults's own tests; here the grammar must just parse).
        assert!(plan_of("simulate --faults burst:3@0.05,seed:2", 8).is_ok());
    }

    #[test]
    fn faults_stochastic_junk_rates_are_friendly_errors() {
        for bad in ["mtbf:nan", "mtbf:0", "mtbf:-3", "mtbf:inf"] {
            let err = plan_of(&format!("simulate --faults {bad}"), 8)
                .unwrap_err()
                .to_string();
            assert!(err.contains("--faults"), "{bad}: {err}");
        }
        assert!(plan_of("simulate --faults mttr:5", 8).is_err(), "mttr needs mtbf");
        assert!(plan_of("simulate --faults burst:0@0.1", 8).is_err());
        assert!(plan_of("simulate --faults burst:2@nan", 8).is_err());
    }

    #[test]
    fn kv_bandwidth_flag_parses_and_rejects_junk() {
        assert_eq!(kv_transfer_cost(&args("simulate")).unwrap(), None);
        let c = kv_transfer_cost(&args("simulate --kv-bandwidth 100000"))
            .unwrap()
            .unwrap();
        assert_eq!(c, TransferCost::from_bandwidth(100_000.0));
        for bad in ["0", "-5", "nan", "inf", "fast"] {
            let err = kv_transfer_cost(&args(&format!("simulate --kv-bandwidth {bad}")))
                .unwrap_err()
                .to_string();
            assert!(err.contains("--kv-bandwidth"), "{bad}: {err}");
        }
    }

    #[test]
    fn simulate_rejects_non_finite_or_non_positive_rate_and_duration() {
        for bad in ["nan", "inf", "-inf", "-3", "0"] {
            let err = experiment_config(&args(&format!("simulate --rate {bad}")))
                .unwrap_err()
                .to_string();
            assert!(err.contains("--rate"), "rate {bad}: {err}");
            assert!(err.contains("finite, positive"), "rate {bad}: {err}");
            let err = experiment_config(&args(&format!("simulate --duration {bad}")))
                .unwrap_err()
                .to_string();
            assert!(err.contains("--duration"), "duration {bad}: {err}");
        }
        // The defaults and ordinary values stay valid.
        assert!(experiment_config(&args("simulate")).is_ok());
        assert!(experiment_config(&args("simulate --rate 2.5 --duration 30")).is_ok());
    }

    #[test]
    fn tenant_and_slo_flags_parse() {
        let (mix, slo) =
            tenancy_spec(&args("simulate --tenants 4 --slo ttft:2,deadline:120")).unwrap();
        assert_eq!(mix.unwrap().tenants(), 4);
        let slo = slo.unwrap();
        assert_eq!(slo.ttft, Some(2.0));
        assert_eq!(slo.deadline, Some(120.0));
        assert_eq!(slo.tpot, None);
        // Weighted spelling.
        let (mix, slo) = tenancy_spec(&args("simulate --tenants 2:3,1")).unwrap();
        assert_eq!(mix.unwrap().weights, vec![3.0, 1.0]);
        assert!(slo.is_none());
        // `--slo none` is the explicit SLO-free default.
        let (_, slo) = tenancy_spec(&args("simulate --slo none")).unwrap();
        assert!(slo.is_none());
        // Absent flags stamp nothing.
        let (mix, slo) = tenancy_spec(&args("simulate")).unwrap();
        assert!(mix.is_none() && slo.is_none());
    }

    #[test]
    fn tenant_and_slo_junk_is_a_friendly_error() {
        for bad in ["0", "2:1", "x", "2:1,nan", "2:1,-4"] {
            let err = tenancy_spec(&args(&format!("simulate --tenants {bad}")))
                .unwrap_err()
                .to_string();
            assert!(err.contains("--tenants"), "tenants {bad}: {err}");
        }
        for bad in ["bogus:5", "ttft:-2", "ttft:nan", "ttft", "ttft:1,ttft:2"] {
            let err = tenancy_spec(&args(&format!("simulate --slo {bad}")))
                .unwrap_err()
                .to_string();
            assert!(err.contains("--slo"), "slo {bad}: {err}");
        }
    }

    #[test]
    fn lint_root_flag_overrides_autodetect() {
        assert_eq!(lint_root(&args("lint --root /tmp/x")), PathBuf::from("/tmp/x"));
        // Unit tests run from the crate root, where src/lib.rs exists.
        assert_eq!(lint_root(&args("lint")), PathBuf::from("."));
    }

    #[test]
    fn lint_exits_nonzero_on_a_seeded_violation() {
        let dir = std::env::temp_dir().join(format!("scls_lint_cli_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("src/scheduler")).unwrap();
        std::fs::write(dir.join("src/scheduler/bad.rs"), "type M = HashMap<u8, u8>;\n").unwrap();
        let err = cmd_lint(&args(&format!("lint --root {}", dir.display())))
            .unwrap_err()
            .to_string();
        assert!(err.contains("finding"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lint_missing_root_is_a_friendly_error() {
        let err = cmd_lint(&args("lint --root /nonexistent_scls"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("no src/"), "{err}");
    }

    #[test]
    fn non_finite_pred_accuracy_is_a_friendly_error() {
        let err = spec_of("simulate --pred-accuracy nan").unwrap_err().to_string();
        assert!(err.contains("--pred-accuracy"), "{err}");
        // Out-of-range finite values still clamp (documented behaviour).
        match spec_of("simulate --pred-buckets 8 --pred-accuracy 1.5").unwrap() {
            PredictorSpec::Bucket { accuracy, .. } => assert_eq!(accuracy, 1.0),
            other => panic!("expected bucket spec, got {other:?}"),
        }
    }
}
