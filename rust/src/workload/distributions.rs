//! Request length distributions calibrated to the paper's Fig. 6.
//!
//! The paper analyzes two sources: the CodeFuse production trace (code
//! assistant: generation lengths mode ≈ 100–300, "vast majority < 512") and
//! ~400k ShareGPT conversations (chat: heavier mid-range mass). Neither
//! dataset is available offline, so we model each as a clipped lognormal
//! mixture whose PDF/CDF reproduce Fig. 6's qualitative shape; Fig. 6 is
//! regenerated from these models by `figure fig6`.
//!
//! Input lengths are likewise mixtures (short questions + long
//! code/context pastes), truncated at the configured maximum (paper: 1024).

use crate::util::rng::Rng;

/// One mixture component: lognormal(mu, sigma) with weight `w`.
#[derive(Debug, Clone, Copy)]
pub struct LogNormalComp {
    pub w: f64,
    pub mu: f64,
    pub sigma: f64,
}

/// A clipped lognormal mixture over token counts.
#[derive(Debug, Clone)]
pub struct LengthDistribution {
    pub comps: Vec<LogNormalComp>,
    /// Inclusive lower clip (lengths are at least 1 token).
    pub min: u32,
    /// Inclusive upper clip (the paper's maximal length limit, 1024).
    pub max: u32,
}

impl LengthDistribution {
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let ws: Vec<f64> = self.comps.iter().map(|c| c.w).collect();
        let c = &self.comps[rng.weighted_index(&ws)];
        let x = rng.lognormal(c.mu, c.sigma);
        (x.round() as i64).clamp(self.min as i64, self.max as i64) as u32
    }

    /// Analytic PDF of the clipped mixture (mass at the clip bounds is
    /// folded into the edge, matching how `sample` clamps).
    pub fn pdf(&self, x: f64) -> f64 {
        if x < self.min as f64 || x > self.max as f64 || x <= 0.0 {
            return 0.0;
        }
        let wsum: f64 = self.comps.iter().map(|c| c.w).sum();
        self.comps
            .iter()
            .map(|c| {
                let z = (x.ln() - c.mu) / c.sigma;
                c.w / wsum * (-0.5 * z * z).exp()
                    / (x * c.sigma * (2.0 * std::f64::consts::PI).sqrt())
            })
            .sum()
    }

    /// Empirical CDF from `n` samples (used by Fig. 6).
    pub fn empirical_cdf(&self, rng: &mut Rng, n: usize, at: &[f64]) -> Vec<f64> {
        let mut xs: Vec<u32> = (0..n).map(|_| self.sample(rng)).collect();
        xs.sort_unstable();
        at.iter()
            .map(|&t| {
                let cnt = xs.partition_point(|&x| (x as f64) <= t);
                cnt as f64 / n as f64
            })
            .collect()
    }
}

/// Sampled lengths for one request.
#[derive(Debug, Clone, Copy)]
pub struct LengthSample {
    pub input_len: u32,
    pub gen_len: u32,
}

/// CodeFuse-like generation lengths (Fig. 6a): code-assistant answers —
/// strong mode around 100–250 tokens, thin tail, almost everything < 512.
pub fn codefuse_gen(max: u32) -> LengthDistribution {
    LengthDistribution {
        comps: vec![
            // short confirmations / snippets
            LogNormalComp { w: 0.35, mu: 3.6, sigma: 0.7 },  // median ~37
            // typical code answers
            LogNormalComp { w: 0.55, mu: 5.1, sigma: 0.55 }, // median ~164
            // long generations (rare)
            LogNormalComp { w: 0.10, mu: 6.3, sigma: 0.5 },  // median ~545
        ],
        min: 1,
        max,
    }
}

/// CodeFuse-like input lengths: short prompts plus pasted code/context.
pub fn codefuse_input(max: u32) -> LengthDistribution {
    LengthDistribution {
        comps: vec![
            LogNormalComp { w: 0.5, mu: 4.0, sigma: 0.8 },  // median ~55
            LogNormalComp { w: 0.4, mu: 5.5, sigma: 0.7 },  // median ~245
            LogNormalComp { w: 0.1, mu: 6.7, sigma: 0.4 },  // median ~812
        ],
        min: 1,
        max,
    }
}

/// ShareGPT-like generation lengths (Fig. 6b): chat — heavier mid-range
/// mass than CodeFuse, still predominantly < 512.
pub fn sharegpt_gen(max: u32) -> LengthDistribution {
    LengthDistribution {
        comps: vec![
            LogNormalComp { w: 0.30, mu: 3.2, sigma: 0.9 },  // short replies
            LogNormalComp { w: 0.55, mu: 5.3, sigma: 0.6 },  // typical answers
            LogNormalComp { w: 0.15, mu: 6.2, sigma: 0.45 }, // long answers
        ],
        min: 1,
        max,
    }
}

/// ShareGPT-like input lengths.
pub fn sharegpt_input(max: u32) -> LengthDistribution {
    LengthDistribution {
        comps: vec![
            LogNormalComp { w: 0.6, mu: 3.8, sigma: 0.9 },
            LogNormalComp { w: 0.4, mu: 5.6, sigma: 0.8 },
        ],
        min: 1,
        max,
    }
}

/// Named workload presets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    CodeFuse,
    ShareGpt,
}

impl WorkloadKind {
    pub fn parse(s: &str) -> Option<WorkloadKind> {
        match s.to_ascii_lowercase().as_str() {
            "codefuse" => Some(WorkloadKind::CodeFuse),
            "sharegpt" => Some(WorkloadKind::ShareGpt),
            _ => None,
        }
    }

    pub fn gen_dist(&self, max: u32) -> LengthDistribution {
        match self {
            WorkloadKind::CodeFuse => codefuse_gen(max),
            WorkloadKind::ShareGpt => sharegpt_gen(max),
        }
    }

    pub fn input_dist(&self, max: u32) -> LengthDistribution {
        match self {
            WorkloadKind::CodeFuse => codefuse_input(max),
            WorkloadKind::ShareGpt => sharegpt_input(max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_within_clip() {
        let mut rng = Rng::new(1);
        let d = codefuse_gen(1024);
        for _ in 0..5000 {
            let x = d.sample(&mut rng);
            assert!((1..=1024).contains(&x));
        }
    }

    #[test]
    fn codefuse_majority_below_512() {
        // The paper's central observation (§3.3): "the vast majority of
        // requests have a small generation length of less than 512".
        let mut rng = Rng::new(2);
        let d = codefuse_gen(1024);
        let n = 50_000;
        let below = (0..n).filter(|_| d.sample(&mut rng) < 512).count();
        let frac = below as f64 / n as f64;
        assert!(frac > 0.85, "only {frac:.3} below 512");
    }

    #[test]
    fn sharegpt_majority_below_512() {
        let mut rng = Rng::new(3);
        let d = sharegpt_gen(1024);
        let n = 50_000;
        let below = (0..n).filter(|_| d.sample(&mut rng) < 512).count();
        let frac = below as f64 / n as f64;
        assert!(frac > 0.80, "only {frac:.3} below 512");
    }

    #[test]
    fn long_requests_rare_but_exist() {
        let mut rng = Rng::new(4);
        let d = codefuse_gen(1024);
        let n = 50_000;
        let long = (0..n).filter(|_| d.sample(&mut rng) >= 512).count();
        assert!(long > 0, "tail must exist");
        assert!((long as f64) / (n as f64) < 0.15);
    }

    #[test]
    fn pdf_integrates_to_about_one() {
        let d = sharegpt_gen(1024);
        // trapezoid over [1, 1024]
        let steps = 4096;
        let mut acc = 0.0;
        for i in 0..steps {
            let x0 = 1.0 + (1023.0 * i as f64) / steps as f64;
            let x1 = 1.0 + (1023.0 * (i + 1) as f64) / steps as f64;
            acc += 0.5 * (d.pdf(x0) + d.pdf(x1)) * (x1 - x0);
        }
        // clipping moves some mass to the bounds, so < 1 but close
        assert!(acc > 0.85 && acc <= 1.001, "integral {acc}");
    }

    #[test]
    fn empirical_cdf_monotone() {
        let mut rng = Rng::new(5);
        let d = codefuse_gen(1024);
        let at: Vec<f64> = (0..=16).map(|i| (i * 64) as f64).collect();
        let cdf = d.empirical_cdf(&mut rng, 20_000, &at);
        for w in cdf.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!((cdf[at.len() - 1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn kind_parse() {
        assert_eq!(WorkloadKind::parse("codefuse"), Some(WorkloadKind::CodeFuse));
        assert_eq!(WorkloadKind::parse("ShareGPT"), Some(WorkloadKind::ShareGpt));
        assert_eq!(WorkloadKind::parse("x"), None);
    }
}
