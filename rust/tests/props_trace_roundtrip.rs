//! Trace persistence round-trip property: `Trace::from_json(save(t)) == t`
//! field-exact — bit-exact arrival times included — across workload kinds,
//! rates, and seeds. Both the in-memory JSON path and the on-disk
//! `save`/`load` path are exercised (the float formatter emits the
//! shortest representation that parses back to the identical f64, so
//! exactness is a guarantee, not an approximation).

use scls::slo::{stamp_trace, SloSpec, TenantMix};
use scls::testprop::{check, Gen};
use scls::util::json::Json;
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};
use scls::{prop_assert, prop_assert_eq};

fn assert_traces_field_exact(a: &Trace, b: &Trace) -> Result<(), scls::testprop::PropFail> {
    prop_assert_eq!(a.len(), b.len(), "request count");
    prop_assert!(
        a.config_rate.to_bits() == b.config_rate.to_bits(),
        "rate drifted: {} vs {}",
        a.config_rate,
        b.config_rate
    );
    prop_assert!(
        a.duration.to_bits() == b.duration.to_bits(),
        "duration drifted: {} vs {}",
        a.duration,
        b.duration
    );
    for (x, y) in a.requests.iter().zip(&b.requests) {
        prop_assert_eq!(x.id, y.id, "id");
        prop_assert!(
            x.arrival.to_bits() == y.arrival.to_bits(),
            "arrival of {} drifted: {:?} vs {:?}",
            x.id,
            x.arrival,
            y.arrival
        );
        prop_assert_eq!(x.input_len, y.input_len, "input_len of {}", x.id);
        prop_assert_eq!(
            x.target_gen_len,
            y.target_gen_len,
            "target_gen_len of {}",
            x.id
        );
    }
    Ok(())
}

#[test]
fn trace_json_roundtrip_is_field_exact() {
    check("trace-json-roundtrip", 24, |g: &mut Gen| {
        let kind = if g.bool() {
            WorkloadKind::CodeFuse
        } else {
            WorkloadKind::ShareGpt
        };
        let cfg = TraceConfig {
            kind,
            rate: *g.pick(&[0.5, 4.0, 20.0, 50.0]),
            duration: *g.pick(&[5.0, 20.0, 60.0]),
            max_input_len: *g.pick(&[64u32, 512, 1024]),
            max_gen_len: *g.pick(&[64u32, 512, 1024]),
            seed: g.u64(),
        };
        let t = Trace::generate(&cfg);
        // Compact and pretty serializations must both parse back exactly.
        for text in [
            t.to_json().to_string_compact(),
            t.to_json().to_string_pretty(),
        ] {
            let back = Trace::from_json(&Json::parse(&text).map_err(|e| {
                scls::testprop::PropFail {
                    msg: format!("reparse failed: {e:?}"),
                }
            })?)
            .map_err(|e| scls::testprop::PropFail {
                msg: format!("from_json failed: {e:#}"),
            })?;
            assert_traces_field_exact(&t, &back)?;
        }
        Ok(())
    });
}

#[test]
fn trace_save_load_roundtrip_on_disk() {
    // The satellite's exact claim, through the filesystem: save() → load()
    // reproduces every field across kinds and seeds.
    let dir = std::env::temp_dir();
    for (i, (kind, rate, seed)) in [
        (WorkloadKind::CodeFuse, 20.0, 42u64),
        (WorkloadKind::CodeFuse, 3.0, 7),
        (WorkloadKind::ShareGpt, 12.0, 1234),
    ]
    .into_iter()
    .enumerate()
    {
        let t = Trace::generate(&TraceConfig {
            kind,
            rate,
            duration: 30.0,
            max_input_len: 1024,
            max_gen_len: 1024,
            seed,
        });
        let path = dir.join(format!(
            "scls_trace_roundtrip_{}_{}.json",
            std::process::id(),
            i
        ));
        t.save(&path).expect("save");
        let back = Trace::load(&path).expect("load");
        let _ = std::fs::remove_file(&path);
        assert_eq!(t.len(), back.len());
        assert_eq!(t.config_rate.to_bits(), back.config_rate.to_bits());
        assert_eq!(t.duration.to_bits(), back.duration.to_bits());
        for (x, y) in t.requests.iter().zip(&back.requests) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits(), "req {}", x.id);
            assert_eq!(x.input_len, y.input_len);
            assert_eq!(x.target_gen_len, y.target_gen_len);
        }
        // Loaded traces start with pristine scheduling state.
        assert!(back.requests.iter().all(|r| r.generated == 0
            && r.slices == 0
            && r.predicted_gen.is_none()
            && r.finished_at.is_none()));
    }
}

#[test]
fn slo_stamped_trace_roundtrip_is_field_exact() {
    // Tenancy and SLO stamps survive serialization bit-exactly: tenant,
    // priority, and every per-tier-scaled (and jittered) SLO target.
    check("slo-trace-roundtrip", 16, |g: &mut Gen| {
        let cfg = TraceConfig {
            kind: WorkloadKind::CodeFuse,
            rate: *g.pick(&[2.0, 10.0]),
            duration: *g.pick(&[10.0, 30.0]),
            max_input_len: 512,
            max_gen_len: 512,
            seed: g.u64(),
        };
        let mut t = Trace::generate(&cfg);
        let mix = TenantMix::parse(g.pick(&["1", "4", "3:5,2,1"])).expect("static mix");
        let base = SloSpec::parse(g.pick(&[
            "ttft:2",
            "ttft:1,tpot:0.25,deadline:90",
            "deadline:120",
        ]))
        .expect("static spec");
        stamp_trace(&mut t, &mix, &base, g.u64());
        let back = Trace::from_json(&Json::parse(&t.to_json().to_string_pretty()).map_err(
            |e| scls::testprop::PropFail {
                msg: format!("reparse failed: {e:?}"),
            },
        )?)
        .map_err(|e| scls::testprop::PropFail {
            msg: format!("from_json failed: {e:#}"),
        })?;
        assert_traces_field_exact(&t, &back)?;
        for (x, y) in t.requests.iter().zip(&back.requests) {
            prop_assert_eq!(x.tenant, y.tenant, "tenant of {}", x.id);
            prop_assert_eq!(x.priority, y.priority, "priority of {}", x.id);
            for (name, a, b) in [
                ("ttft", x.slo.ttft, y.slo.ttft),
                ("tpot", x.slo.tpot, y.slo.tpot),
                ("deadline", x.slo.deadline, y.slo.deadline),
            ] {
                prop_assert!(
                    a.map(f64::to_bits) == b.map(f64::to_bits),
                    "{} of {} drifted: {:?} vs {:?}",
                    name,
                    x.id,
                    a,
                    b
                );
            }
        }
        Ok(())
    });
}

#[test]
fn legacy_traces_load_with_default_tenancy() {
    // Unstamped traces keep the pre-tenancy wire format (no tenant /
    // priority / slo_* keys at all), and anything serialized by an older
    // build loads with the neutral defaults.
    let t = Trace::generate(&TraceConfig {
        kind: WorkloadKind::CodeFuse,
        rate: 8.0,
        duration: 20.0,
        max_input_len: 512,
        max_gen_len: 512,
        seed: 99,
    });
    let text = t.to_json().to_string_pretty();
    assert!(
        !text.contains("tenant") && !text.contains("priority") && !text.contains("slo_"),
        "default tenancy must stay off the wire"
    );
    let back = Trace::from_json(&Json::parse(&text).expect("parse")).expect("from_json");
    assert!(back
        .requests
        .iter()
        .all(|r| r.tenant == 0 && r.priority == 0 && r.slo.is_none()));
}
