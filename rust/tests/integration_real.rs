//! Integration tests over the real three-layer stack: PJRT execution of
//! the AOT tiny-GPT artifacts driven by the wall-clock cluster. These skip
//! (with a note) when `make artifacts` has not been run — CI without the
//! Python toolchain still passes, but `make test` exercises them.

use std::path::{Path, PathBuf};

use scls::core::{Batch, Request};
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::engine::real::RealEngine;
use scls::runtime::ModelRuntime;
use scls::scheduler::spec::{BatchingSpec, IntervalSpec, SchedulerSpec};
use scls::worker::real_driver::{profile_real, run_real, RealClusterConfig};

fn art_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = art_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping real-stack test: run `make artifacts` first");
    }
    ok
}

fn req(id: u64, arrival: f64, toks: Vec<i32>) -> Request {
    Request::with_tokens(id, arrival, toks)
}

fn mixed_requests(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let len = 2 + (i * 13) % 50;
            req(
                i as u64,
                0.05 * i as f64,
                (0..len).map(|k| 3 + ((i * 37 + k * 11) % 450) as i32).collect(),
            )
        })
        .collect()
}

#[test]
fn manifest_buckets_cover_declared_space() {
    if !have_artifacts() {
        return;
    }
    let rt = ModelRuntime::new(&art_dir()).unwrap();
    let m = &rt.manifest;
    assert!(!m.buckets.is_empty());
    // Every bucket's HLO file exists.
    for b in &m.buckets {
        assert!(
            art_dir().join(&b.file).exists(),
            "missing artifact {}",
            b.file
        );
    }
    // Picking: any (n ≤ maxN, l ≤ maxL-S) maps to a bucket that fits.
    let s = m.slice_lens()[0];
    let max_n = m.buckets.iter().filter(|b| b.s == s).map(|b| b.n).max().unwrap();
    let max_l = m.buckets.iter().filter(|b| b.s == s).map(|b| b.l).max().unwrap();
    for n in 1..=max_n {
        for l in [1u32, 7, 16, 33, 64, 100, max_l] {
            if l > max_l {
                continue;
            }
            let b = m.pick(n, l, s).unwrap_or_else(|| panic!("no bucket n={n} l={l}"));
            assert!(b.n >= n && b.l >= l && b.s == s);
        }
    }
    // Out-of-range requests must not pick.
    assert!(m.pick(max_n + 1, 16, s).is_none());
    assert!(m.pick(1, max_l + 1, s).is_none());
}

#[test]
fn pjrt_execution_is_deterministic() {
    if !have_artifacts() {
        return;
    }
    let mut e = RealEngine::new(&art_dir(), 16, 64).unwrap();
    let b = Batch::new(vec![req(1, 0.0, vec![10, 20, 30, 40])]);
    let r1 = e.serve_slice(&b).unwrap();
    let r2 = e.serve_slice(&b).unwrap();
    assert_eq!(r1.new_tokens, r2.new_tokens, "greedy decode must be deterministic");
    assert_eq!(r1.outcome.iters, r2.outcome.iters);
}

#[test]
fn batch_row_outputs_independent_of_batchmates() {
    // A request's generated tokens must not depend on what else is in the
    // batch (padding is masked — §2.4's correctness requirement).
    if !have_artifacts() {
        return;
    }
    let mut e = RealEngine::new(&art_dir(), 16, 64).unwrap();
    let target: Vec<i32> = (5..25).collect();
    let alone = e
        .serve_slice(&Batch::new(vec![req(1, 0.0, target.clone())]))
        .unwrap();
    let crowded = e
        .serve_slice(&Batch::new(vec![
            req(1, 0.0, target.clone()),
            req(2, 0.0, vec![400, 401, 402]),
            req(3, 0.0, (100..140).collect()),
        ]))
        .unwrap();
    assert_eq!(
        alone.new_tokens[0], crowded.new_tokens[0],
        "batchmates changed row output (padding leak)"
    );
}

#[test]
fn slice_chaining_equals_long_generation() {
    // Generating 32 tokens as 2 chained slices of 16 must equal one
    // 32-token generation (the SCLS reschedule property: prefill over
    // input+generated reproduces the KV state).
    if !have_artifacts() {
        return;
    }
    let mut e = RealEngine::new(&art_dir(), 16, 64).unwrap();
    let prompt: Vec<i32> = vec![50, 60, 70, 80, 90];

    // One request chained across slices until 32 tokens or EOS.
    let mut r = req(1, 0.0, prompt.clone());
    let mut chained: Vec<i32> = Vec::new();
    for _ in 0..2 {
        let out = e.serve_slice(&Batch::new(vec![r.clone()])).unwrap();
        chained.extend_from_slice(&out.new_tokens[0]);
        let o = &out.outcome.per_request[0];
        r.generated += o.new_tokens;
        r.tokens.extend_from_slice(&out.new_tokens[0]);
        r.input_len = r.tokens.len() as u32;
        if o.finished {
            break;
        }
    }

    // Reference: token-by-token greedy continuation of the same prompt via
    // chaining one-token-at-a-time slices is the same computation; instead
    // compare against a fresh run of the same two-slice chain.
    let mut r2 = req(2, 0.0, prompt);
    let mut chained2: Vec<i32> = Vec::new();
    for _ in 0..2 {
        let out = e.serve_slice(&Batch::new(vec![r2.clone()])).unwrap();
        chained2.extend_from_slice(&out.new_tokens[0]);
        let o = &out.outcome.per_request[0];
        r2.generated += o.new_tokens;
        r2.tokens.extend_from_slice(&out.new_tokens[0]);
        r2.input_len = r2.tokens.len() as u32;
        if o.finished {
            break;
        }
    }
    assert_eq!(chained, chained2, "slice chaining not reproducible");
    assert!(!chained.is_empty());
}

#[test]
fn profiled_estimator_is_monotone_and_positive() {
    if !have_artifacts() {
        return;
    }
    let mut rt = ModelRuntime::new(&art_dir()).unwrap();
    let est = profile_real(&mut rt, 16, 1).unwrap();
    use scls::estimator::serving_time::ServeEstimate;
    let t_small = est.serve_est(1, 16, 16);
    let t_big = est.serve_est(8, 128, 16);
    assert!(t_small > 0.0);
    assert!(t_big > t_small, "{t_big} !> {t_small}");
}

#[test]
fn real_cluster_serves_all_schedulers() {
    if !have_artifacts() {
        return;
    }
    let preset = EnginePreset::paper(EngineKind::Hf);
    let cfg = RealClusterConfig {
        artifacts_dir: art_dir(),
        workers: 2,
        slice_len: 16,
        max_gen_len: 32,
        skip_profiling: true,
        warmup: false,
    };
    // SCLS with a tight tick; SO (worker-locus slicing); PM (capped DP).
    let mut scls = SchedulerSpec::scls(&preset, 16);
    scls.interval = IntervalSpec::Adaptive {
        lambda: 0.5,
        gamma: 0.05,
    };
    let mut so = SchedulerSpec::slice_only(&preset, 16);
    so.batching = BatchingSpec::WorkerFcfs { batch_size: 4 };
    let mut pm = SchedulerSpec::padding_mitigating(&preset, 16);
    pm.interval = IntervalSpec::Fixed(0.05);
    pm.batching = BatchingSpec::Dp {
        max_batch_size: Some(8),
    };

    for spec in [scls, so, pm] {
        let m = run_real(mixed_requests(8), &spec, &cfg).unwrap();
        assert_eq!(m.completed.len(), 8, "{} lost requests", spec.name);
        assert!(
            m.completed.iter().all(|c| c.generated >= 1 && c.generated <= 32),
            "{} token counts",
            spec.name
        );
        // Batches' measured durations were patched in.
        assert!(m.batches.iter().all(|b| b.actual_serve_time > 0.0));
    }
}

#[test]
fn real_requests_tokens_grow_monotonically() {
    if !have_artifacts() {
        return;
    }
    let preset = EnginePreset::paper(EngineKind::Hf);
    let cfg = RealClusterConfig {
        artifacts_dir: art_dir(),
        workers: 1,
        slice_len: 16,
        max_gen_len: 48,
        skip_profiling: true,
        warmup: false,
    };
    let mut spec = SchedulerSpec::scls(&preset, 16);
    spec.interval = IntervalSpec::Adaptive {
        lambda: 0.5,
        gamma: 0.05,
    };
    let m = run_real(mixed_requests(5), &spec, &cfg).unwrap();
    for c in &m.completed {
        assert!(c.generated >= 1);
        // Slice accounting: ceil(generated / 16) ≤ slices (early EOS can
        // end a slice short, and invalid tokens don't count).
        let min_slices = (c.generated as f64 / 16.0).ceil() as u32;
        assert!(c.slices >= min_slices, "req {}: {} slices", c.id, c.slices);
    }
}
