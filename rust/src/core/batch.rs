//! Batches under static batching (paper §2.4).

use super::request::Request;

/// A group of requests served together with static batching.
#[derive(Debug, Clone)]
pub struct Batch {
    pub requests: Vec<Request>,
    /// Estimated serving time assigned by the batcher (Eq. 1). Used by the
    /// max-min offloader and the worker-load ledger.
    pub est_serve_time: f64,
}

impl Batch {
    pub fn new(requests: Vec<Request>) -> Batch {
        Batch {
            requests,
            est_serve_time: 0.0,
        }
    }

    pub fn size(&self) -> usize {
        self.requests.len()
    }

    /// Batch input length: the longest raw input in the batch — every other
    /// request is padded up to it (paper §2.4).
    pub fn input_len(&self) -> u32 {
        self.requests.iter().map(|r| r.input_len).max().unwrap_or(0)
    }

    /// Total pad tokens this batch introduces at this schedule.
    pub fn pad_tokens(&self) -> u64 {
        let li = self.input_len() as u64;
        self.requests
            .iter()
            .map(|r| li - r.input_len as u64)
            .sum()
    }
}

/// Per-request result of serving one slice.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: super::request::RequestId,
    /// Valid tokens generated this slice (up to and including EOS).
    pub new_tokens: u32,
    /// Invalid tokens generated after EOS while the batch kept running.
    pub invalid_tokens: u32,
    /// True if the request completed (EOS emitted, or the max-generation
    /// limit was reached).
    pub finished: bool,
}

/// Result of serving one batch for one slice.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// Wall/virtual duration of the slice service.
    pub duration: f64,
    /// Decode iterations actually executed (< slice_len on early return).
    pub iters: u32,
    /// True if every request finished before the iteration limit — the
    /// paper's "early return" case (§4.2), which makes the time estimate
    /// inaccurate.
    pub early_return: bool,
    pub per_request: Vec<RequestOutcome>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, input_len: u32) -> Request {
        Request::new(id, 0.0, input_len, 10)
    }

    #[test]
    fn input_len_is_max() {
        let b = Batch::new(vec![req(1, 10), req(2, 100), req(3, 55)]);
        assert_eq!(b.input_len(), 100);
        assert_eq!(b.size(), 3);
    }

    #[test]
    fn pad_tokens_sum() {
        let b = Batch::new(vec![req(1, 10), req(2, 100), req(3, 55)]);
        // pads: 90 + 0 + 45
        assert_eq!(b.pad_tokens(), 135);
    }

    #[test]
    fn empty_batch() {
        let b = Batch::new(vec![]);
        assert_eq!(b.input_len(), 0);
        assert_eq!(b.pad_tokens(), 0);
    }
}
