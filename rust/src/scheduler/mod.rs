//! Scheduling policies: SCLS (the paper's contribution, §4), the SLS and
//! ILS baselines (§5.1), and the SO/PM/AB/LB ablation ladder (§5.4).
//!
//! The policies are expressed as pure configuration over four orthogonal
//! axes (`SchedulerSpec`); the DES driver (`sim::driver`) and the real-mode
//! driver (`worker::real_driver`) interpret them. ILS is structurally
//! different (continuous batching) and has its own driver path.

pub mod interval;
pub mod pool;
pub mod spec;

pub use interval::IntervalController;
pub use pool::RequestPool;
pub use spec::{BatchingSpec, IntervalSpec, OffloadSpec, SchedulerSpec};
