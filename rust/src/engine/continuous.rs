//! Continuous-batching worker model — the ILS baseline's engine substrate
//! (DeepSpeed-FastGen-like, §5.1).
//!
//! Iteration-level semantics: at every iteration boundary the worker admits
//! waiting requests (up to the conservative parallel cap and a KV-memory
//! check), then runs one decode iteration for the whole running set. There
//! is no padding and no invalid-token generation — requests exit the moment
//! they finish — but the cap limits GPU utilization, which is exactly the
//! inefficiency the paper attributes to ILS (§3.1).

use std::collections::VecDeque;

use crate::core::Request;

use super::latency::EngineLatency;

/// A request in the running set.
#[derive(Debug)]
pub struct Running {
    pub req: Request,
    /// Cached length so far (input + generated).
    pub cached: u32,
    /// Tokens still to generate (to EOS oracle or the max-gen cap).
    pub remaining: u32,
}

/// One continuous-batching LLM instance.
pub struct ContinuousWorker {
    pub waiting: VecDeque<Request>,
    pub running: Vec<Running>,
    pub engine: EngineLatency,
    /// Conservative cap on parallel-processing requests.
    pub max_parallel: u32,
    /// KV budget in bytes and per-token KV size.
    pub kv_budget: u64,
    pub kv_delta: u64,
    pub max_gen_len: u32,
}

impl ContinuousWorker {
    pub fn new(
        engine: EngineLatency,
        max_parallel: u32,
        kv_budget: u64,
        kv_delta: u64,
        max_gen_len: u32,
    ) -> ContinuousWorker {
        ContinuousWorker {
            waiting: VecDeque::new(),
            running: Vec::new(),
            engine,
            max_parallel: max_parallel.max(1),
            kv_budget,
            kv_delta,
            max_gen_len,
        }
    }

    pub fn kv_in_use(&self) -> u64 {
        self.running
            .iter()
            .map(|r| r.cached as u64 * self.kv_delta)
            .sum()
    }

    /// Begin the next iteration: admit what fits, then return the duration
    /// of one decode iteration over the running set (including the prefill
    /// cost of the requests admitted at this boundary). `None` = idle.
    pub fn begin_iteration(&mut self) -> Option<f64> {
        let mut admit_prefill = 0.0;
        while !self.waiting.is_empty() && (self.running.len() as u32) < self.max_parallel {
            let kv_now = self.kv_in_use();
            let cand_kv = self.waiting.front().unwrap().input_len as u64 * self.kv_delta;
            if kv_now + cand_kv > self.kv_budget {
                break;
            }
            let mut req = self.waiting.pop_front().unwrap();
            // Continuous batching normally schedules once (slices == 1);
            // a crash-reclaimed re-admission counts as another schedule.
            req.slices += 1;
            admit_prefill += self.engine.prefill_mean(1, req.input_len);
            // Tokens still owed: the full target for a fresh request,
            // target minus what survived the reclaim for a re-admission.
            let total = req.target_gen_len.min(self.max_gen_len).max(1);
            let remaining = total.saturating_sub(req.generated).max(1);
            self.running.push(Running {
                cached: req.input_len,
                remaining,
                req,
            });
        }
        if self.running.is_empty() {
            return None;
        }
        // τ(l̄, N): with the bilinear form, the mean cached length scales
        // exactly as the true total-token cost d1·Σ l_i + …
        let n = self.running.len() as u32;
        let mean_l =
            (self.running.iter().map(|r| r.cached as u64).sum::<u64>() / n as u64) as u32;
        Some(admit_prefill + self.engine.decode_iter_mean(mean_l, n))
    }

    /// Complete the iteration begun by `begin_iteration`: every running
    /// request gains one token; finished requests exit and are returned.
    pub fn finish_iteration(&mut self, now: f64) -> Vec<Request> {
        for r in &mut self.running {
            r.cached += 1;
            r.remaining -= 1;
            // First-token stamp for TTFT accounting: this boundary delivers
            // the request's first generated token. (Crash-reclaimed
            // re-admissions resume with `generated > 0` and keep their
            // original stamp.)
            if r.req.generated == 0 && r.req.first_token_at.is_none() {
                r.req.first_token_at = Some(now);
            }
            r.req.generated += 1;
        }
        let mut exited = Vec::new();
        let mut k = 0;
        while k < self.running.len() {
            if self.running[k].remaining == 0 {
                let mut done = self.running.swap_remove(k);
                done.req.finished_at = Some(now);
                exited.push(done.req);
            } else {
                k += 1;
            }
        }
        exited
    }

    /// Crash path: surrender everything this worker holds. Returns
    /// `(running, waiting)` — the running set at its **last completed
    /// iteration boundary** (`finish_iteration` was never called for the
    /// in-flight iteration, so each request's `generated` is exactly its
    /// boundary state; only the interrupted iteration is lost) and the
    /// untouched waiting queue.
    pub fn abandon(&mut self) -> (Vec<Request>, Vec<Request>) {
        let running = self.running.drain(..).map(|r| r.req).collect();
        let waiting = self.waiting.drain(..).collect();
        (running, waiting)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker(max_parallel: u32) -> ContinuousWorker {
        let mut lat = EngineLatency::ds(1);
        lat.jitter = 0.0;
        ContinuousWorker::new(lat, max_parallel, 48 << 30, 800 * 1024, 1024)
    }

    fn req(id: u64, input: u32, gen: u32) -> Request {
        Request::new(id, 0.0, input, gen)
    }

    #[test]
    fn admits_up_to_cap() {
        let mut w = worker(2);
        for i in 0..5 {
            w.waiting.push_back(req(i, 100, 10));
        }
        let d = w.begin_iteration().unwrap();
        assert!(d > 0.0);
        assert_eq!(w.running.len(), 2);
        assert_eq!(w.waiting.len(), 3);
    }

    #[test]
    fn kv_budget_blocks_admission() {
        let mut w = worker(100);
        w.kv_budget = 150 * w.kv_delta; // room for one 100-token prompt
        w.waiting.push_back(req(0, 100, 10));
        w.waiting.push_back(req(1, 100, 10));
        w.begin_iteration().unwrap();
        assert_eq!(w.running.len(), 1);
    }

    #[test]
    fn requests_exit_at_eos_without_invalid_tokens() {
        let mut w = worker(8);
        w.waiting.push_back(req(0, 10, 2));
        w.waiting.push_back(req(1, 10, 5));
        w.begin_iteration().unwrap();
        assert!(w.finish_iteration(1.0).is_empty());
        w.begin_iteration().unwrap();
        let done = w.finish_iteration(2.0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 0);
        assert_eq!(done[0].generated, 2);
        assert_eq!(done[0].invalid_tokens, 0);
        // the other request keeps running and a freed slot admits nothing
        assert_eq!(w.running.len(), 1);
    }

    #[test]
    fn idle_when_empty() {
        let mut w = worker(4);
        assert!(w.begin_iteration().is_none());
    }

    #[test]
    fn ttft_stamped_at_first_decode_iteration() {
        let mut w = worker(8);
        w.waiting.push_back(req(0, 10, 4));
        let mut now = 0.0;
        let done = loop {
            let d = w.begin_iteration().unwrap();
            now += d;
            let exited = w.finish_iteration(now);
            if !exited.is_empty() {
                break exited;
            }
        };
        let r = &done[0];
        let first = r.first_token_at.expect("first token stamped");
        let finished = r.finished_at.unwrap();
        assert!(
            first < finished,
            "a multi-iteration request's TTFT ({first}) must be strictly \
             earlier than its finish ({finished})"
        );
    }

    #[test]
    fn iteration_cost_grows_with_parallelism() {
        let mut w1 = worker(1);
        w1.waiting.push_back(req(0, 100, 10));
        let d1 = w1.begin_iteration().unwrap();
        let mut w2 = worker(16);
        for i in 0..16 {
            w2.waiting.push_back(req(i, 100, 10));
        }
        let d16 = w2.begin_iteration().unwrap();
        assert!(d16 > d1);
    }

    #[test]
    fn max_gen_cap_bounds_remaining() {
        let mut w = worker(1);
        w.max_gen_len = 8;
        w.waiting.push_back(req(0, 10, 10_000));
        w.begin_iteration().unwrap();
        assert_eq!(w.running[0].remaining, 8);
    }

    #[test]
    fn abandon_surrenders_boundary_state_and_readmission_resumes() {
        let mut w = worker(8);
        w.waiting.push_back(req(0, 10, 5));
        w.waiting.push_back(req(1, 10, 7));
        w.waiting.push_back(req(2, 10, 3)); // stays waiting (cap below)
        w.max_parallel = 2;
        w.begin_iteration().unwrap();
        w.finish_iteration(1.0);
        w.begin_iteration().unwrap();
        w.finish_iteration(2.0); // both running requests at generated == 2
        w.begin_iteration().unwrap(); // in-flight iteration — lost on crash
        let (running, waiting) = w.abandon();
        assert!(w.running.is_empty() && w.waiting.is_empty());
        assert_eq!(running.len(), 2);
        assert!(running.iter().all(|r| r.generated == 2), "{running:?}");
        assert_eq!(waiting.len(), 1);
        assert_eq!(waiting[0].generated, 0);

        // Re-admission elsewhere resumes from the boundary: a reclaimed
        // request owes target - generated more tokens, and its slice count
        // keeps climbing.
        let mut w2 = worker(8);
        let mut r = running.into_iter().next().unwrap();
        r.input_len = r.orig_input_len + r.generated;
        w2.waiting.push_back(r);
        w2.begin_iteration().unwrap();
        let owed = w2.running[0].req.target_gen_len - 2;
        assert_eq!(w2.running[0].remaining, owed);
        assert_eq!(w2.running[0].req.slices, 2);
        for t in 0..owed {
            let done = w2.finish_iteration(t as f64);
            if t == owed - 1 {
                assert_eq!(done.len(), 1);
                let done = &done[0];
                assert_eq!(done.generated, done.target_gen_len);
            } else {
                w2.begin_iteration().unwrap();
            }
        }
    }
}
