//! Declarative scheduler specifications.
//!
//! Every sliced-family policy in the paper is a point in a 4-axis space:
//!
//! | policy | slice len | batching            | offload     | interval  |
//! |--------|-----------|---------------------|-------------|-----------|
//! | SLS    | max_gen   | worker FCFS (fixed) | round-robin | immediate |
//! | SO     | S         | worker FCFS (fixed) | round-robin | immediate |
//! | PM     | S         | DP, capped          | round-robin | fixed Γ   |
//! | AB     | S         | DP, uncapped        | round-robin | fixed Γ   |
//! | LB     | S         | DP, uncapped        | max-min     | fixed Γ   |
//! | SCLS   | S         | DP, uncapped        | max-min     | Eq. (12)  |
//!
//! A spec is a *constructor of policy objects*: [`SchedulerSpec::policy`]
//! builds the [`crate::sim::policies::SlicedPolicy`] that the single
//! generic DES loop ([`crate::sim::driver::run_policy`]) interprets, and
//! the real-mode driver consumes the same axes through the shared
//! [`crate::scheduler::SlicedCoordinator`]. The `name` is a free-form
//! `String`, so user-defined axis combinations are first-class — nothing
//! pattern-matches on it. ILS and SCLS-CB (continuous batching) are
//! structurally different and are policies of their own
//! ([`crate::sim::policies::IlsPolicy`] /
//! [`crate::sim::policies::SclsCbPolicy`]).

use crate::engine::presets::EnginePreset;

#[derive(Debug, Clone, PartialEq)]
pub enum BatchingSpec {
    /// Requests are offloaded individually; each *worker* forms FCFS
    /// batches of `batch_size` from its local queue (SLS/SO).
    WorkerFcfs { batch_size: u32 },
    /// The coordinator runs Algorithm 1 over the pool each tick.
    Dp { max_batch_size: Option<u32> },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadSpec {
    RoundRobin,
    MaxMin,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum IntervalSpec {
    /// Dispatch on arrival / completion (no pooling) — SLS/SO.
    Immediate,
    /// Fixed tick of Γ seconds — PM/AB/LB.
    Fixed(f64),
    /// Eq. (12) — SCLS.
    Adaptive { lambda: f64, gamma: f64 },
}

/// A fully specified sliced-family scheduler.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerSpec {
    /// Display label. Free-form: user-defined policies pick their own;
    /// no driver logic dispatches on it.
    pub name: String,
    /// Iteration limit per schedule (S; == max_gen_len for SLS).
    pub slice_len: u32,
    pub batching: BatchingSpec,
    pub offload: OffloadSpec,
    pub interval: IntervalSpec,
}

impl SchedulerSpec {
    /// Construct the policy object this spec describes, ready for
    /// [`crate::sim::driver::run_policy`] or
    /// [`crate::sim::Simulation::run`].
    pub fn policy(
        &self,
        cfg: &crate::sim::driver::SimConfig,
    ) -> crate::sim::policies::SlicedPolicy {
        crate::sim::policies::SlicedPolicy::new(self, cfg)
    }

    /// A user-defined point in the axis space.
    pub fn custom(
        name: impl Into<String>,
        slice_len: u32,
        batching: BatchingSpec,
        offload: OffloadSpec,
        interval: IntervalSpec,
    ) -> SchedulerSpec {
        SchedulerSpec {
            name: name.into(),
            slice_len,
            batching,
            offload,
            interval,
        }
    }

    /// Conventional sequence-level scheduling (§5.1 baseline).
    pub fn sls(preset: &EnginePreset, max_gen_len: u32) -> SchedulerSpec {
        SchedulerSpec {
            name: "SLS".into(),
            slice_len: max_gen_len,
            batching: BatchingSpec::WorkerFcfs {
                batch_size: preset.sls_batch_size,
            },
            offload: OffloadSpec::RoundRobin,
            interval: IntervalSpec::Immediate,
        }
    }

    /// Ablation: Slice-Only (§5.4).
    pub fn slice_only(preset: &EnginePreset, slice_len: u32) -> SchedulerSpec {
        SchedulerSpec {
            name: "SO".into(),
            slice_len,
            batching: BatchingSpec::WorkerFcfs {
                batch_size: preset.sls_batch_size,
            },
            offload: OffloadSpec::RoundRobin,
            interval: IntervalSpec::Immediate,
        }
    }

    /// Ablation: Padding-Mitigating (§5.4) — capped DP, fixed Γ, RR.
    pub fn padding_mitigating(preset: &EnginePreset, slice_len: u32) -> SchedulerSpec {
        SchedulerSpec {
            name: "PM".into(),
            slice_len,
            batching: BatchingSpec::Dp {
                max_batch_size: Some(preset.sls_batch_size),
            },
            offload: OffloadSpec::RoundRobin,
            interval: IntervalSpec::Fixed(preset.gamma),
        }
    }

    /// Ablation: Adaptive-Batching (§5.4) — uncapped DP, fixed Γ, RR.
    pub fn adaptive_batching(preset: &EnginePreset, slice_len: u32) -> SchedulerSpec {
        SchedulerSpec {
            name: "AB".into(),
            slice_len,
            batching: BatchingSpec::Dp {
                max_batch_size: None,
            },
            offload: OffloadSpec::RoundRobin,
            interval: IntervalSpec::Fixed(preset.gamma),
        }
    }

    /// Ablation: Load-Balancing (§5.4) — AB + max-min.
    pub fn load_balancing(preset: &EnginePreset, slice_len: u32) -> SchedulerSpec {
        SchedulerSpec {
            name: "LB".into(),
            slice_len,
            batching: BatchingSpec::Dp {
                max_batch_size: None,
            },
            offload: OffloadSpec::MaxMin,
            interval: IntervalSpec::Fixed(preset.gamma),
        }
    }

    /// Full SCLS (§4).
    pub fn scls(preset: &EnginePreset, slice_len: u32) -> SchedulerSpec {
        SchedulerSpec {
            name: "SCLS".into(),
            slice_len,
            batching: BatchingSpec::Dp {
                max_batch_size: None,
            },
            offload: OffloadSpec::MaxMin,
            interval: IntervalSpec::Adaptive {
                lambda: preset.lambda,
                gamma: preset.gamma,
            },
        }
    }

    /// Prediction-aware SCLS (P-SCLS): the SCLS axes — uncapped DP
    /// batching, max-min offload, Eq. (12) interval — interpreted by
    /// [`crate::sim::policies::PredictiveSlicedPolicy`], which seeds each
    /// request at the slice-ladder rung matching its predicted length
    /// bucket instead of entering at the bottom.
    pub fn p_scls(preset: &EnginePreset, slice_len: u32) -> SchedulerSpec {
        SchedulerSpec {
            name: "P-SCLS".into(),
            ..SchedulerSpec::scls(preset, slice_len)
        }
    }

    /// Deadline-aware SCLS (D-SCLS): the SCLS axes interpreted by
    /// [`crate::sim::slo_policies::DeadlineSclsPolicy`], which seeds each
    /// request's slice-ladder rung from its deadline slack (tight slack ⇒
    /// one big pass) and sheds deadline-infeasible requests early.
    pub fn d_scls(preset: &EnginePreset, slice_len: u32) -> SchedulerSpec {
        SchedulerSpec {
            name: "D-SCLS".into(),
            ..SchedulerSpec::scls(preset, slice_len)
        }
    }

    /// Predicted-SRPT (P-SRPT): the SCLS axes interpreted by
    /// [`crate::sim::slo_policies::RankedSlicePolicy`] ordering the pool
    /// by predicted remaining work (shortest first) each tick.
    pub fn p_srpt(preset: &EnginePreset, slice_len: u32) -> SchedulerSpec {
        SchedulerSpec {
            name: "P-SRPT".into(),
            ..SchedulerSpec::scls(preset, slice_len)
        }
    }

    /// Sliding-window SLO-aware batching (SW-SLO): the SCLS axes
    /// interpreted by [`crate::sim::slo_policies::RankedSlicePolicy`]
    /// admitting a bounded window of the most deadline-critical pooled
    /// requests per tick instead of the whole FCFS pool.
    pub fn sw_slo(preset: &EnginePreset, slice_len: u32) -> SchedulerSpec {
        SchedulerSpec {
            name: "SW-SLO".into(),
            ..SchedulerSpec::scls(preset, slice_len)
        }
    }

    /// The §5.4 ablation ladder in paper order.
    pub fn ablation_ladder(preset: &EnginePreset, slice_len: u32, max_gen: u32) -> Vec<SchedulerSpec> {
        vec![
            SchedulerSpec::sls(preset, max_gen),
            SchedulerSpec::slice_only(preset, slice_len),
            SchedulerSpec::padding_mitigating(preset, slice_len),
            SchedulerSpec::adaptive_batching(preset, slice_len),
            SchedulerSpec::load_balancing(preset, slice_len),
            SchedulerSpec::scls(preset, slice_len),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::presets::{EngineKind, EnginePreset};

    #[test]
    fn ladder_matches_paper_axes() {
        let p = EnginePreset::paper(EngineKind::Ds);
        let ladder = SchedulerSpec::ablation_ladder(&p, 128, 1024);
        let names: Vec<&str> = ladder.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["SLS", "SO", "PM", "AB", "LB", "SCLS"]);

        // SLS: slice == max gen, fixed batching.
        assert_eq!(ladder[0].slice_len, 1024);
        assert!(matches!(
            ladder[0].batching,
            BatchingSpec::WorkerFcfs { batch_size: 12 }
        ));
        // PM caps DP at the engine's fixed batch size.
        assert!(matches!(
            ladder[2].batching,
            BatchingSpec::Dp {
                max_batch_size: Some(12)
            }
        ));
        // LB switches offload to max-min.
        assert_eq!(ladder[4].offload, OffloadSpec::MaxMin);
        assert_eq!(ladder[3].offload, OffloadSpec::RoundRobin);
        // SCLS switches interval to adaptive.
        assert!(matches!(
            ladder[5].interval,
            IntervalSpec::Adaptive { .. }
        ));
    }

    #[test]
    fn hf_uses_batch_16_gamma_6() {
        let p = EnginePreset::paper(EngineKind::Hf);
        let sls = SchedulerSpec::sls(&p, 1024);
        assert!(matches!(
            sls.batching,
            BatchingSpec::WorkerFcfs { batch_size: 16 }
        ));
        let scls = SchedulerSpec::scls(&p, 128);
        match scls.interval {
            IntervalSpec::Adaptive { lambda, gamma } => assert_eq!((lambda, gamma), (0.5, 6.0)),
            other => panic!("expected adaptive interval, got {other:?}"),
        }
    }
}
