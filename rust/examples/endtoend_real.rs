//! End-to-end driver over the REAL model: all three layers composing.
//!
//! Loads the AOT-compiled tiny-GPT HLO artifacts (L2 JAX model calling the
//! L1 Pallas attention kernels, exported by `make artifacts`), spins up a
//! PJRT-backed worker cluster (L3), and serves a batched Poisson request
//! stream end to end under both SCLS and the SLS baseline, reporting
//! latency/throughput. This is the proof that the full Rust→HLO→Pallas
//! stack works: Python never runs here.
//!
//! Run with:
//!   make artifacts            # once
//!   cargo run --release --example endtoend_real
//!
//! Results of a reference run are recorded in EXPERIMENTS.md §E2E.

use std::path::{Path, PathBuf};
use std::time::Instant;

use scls::core::Request;
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::metrics::Summary;
use scls::scheduler::spec::{BatchingSpec, IntervalSpec, SchedulerSpec};
use scls::util::rng::Rng;
use scls::worker::real_driver::{run_real, RealClusterConfig};

/// Synthetic prompt stream: Poisson arrivals, CodeFuse-shaped (short-mode)
/// input lengths scaled to the artifact bucket budget (L ≤ 160 tokens with
/// a 64-token generation cap at slice 16).
fn requests(n: usize, rate: f64, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut reqs = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for id in 0..n as u64 {
        t += rng.exponential(rate);
        // Mixture: mostly short prompts, a few long ones (the paper's
        // motivation scenario in Fig. 11).
        let len = if rng.next_u64() % 8 == 0 {
            40 + (rng.next_u64() % 40) as usize
        } else {
            3 + (rng.next_u64() % 20) as usize
        };
        let tokens: Vec<i32> = (0..len).map(|_| 3 + (rng.next_u64() % 400) as i32).collect();
        reqs.push(Request::with_tokens(id, t, tokens));
    }
    reqs
}

fn report(name: &str, s: &Summary, wall: f64, n: usize) {
    println!("--- {name} ---");
    println!("  completed       {}/{} in {:.2} s wall", s.completed, n, wall);
    println!("  throughput      {:.3} req/s", s.throughput);
    println!("  avg response    {:.3} s", s.avg_response_time);
    println!("  p95 response    {:.3} s", s.p95_response_time);
    println!("  avg batch size  {:.2}", s.avg_batch_size);
    println!("  pad tok/req     {:.2}", s.avg_pad_tokens);
    println!("  invalid tok/req {:.2}", s.avg_invalid_tokens);
    println!("  CT std          {:.3} s", s.ct_std);
}

fn main() -> anyhow::Result<()> {
    let artifacts_dir =
        PathBuf::from(std::env::var("SCLS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    if !artifacts_dir.join("manifest.json").exists() {
        anyhow::bail!(
            "artifacts not found at {} — run `make artifacts` first",
            artifacts_dir.display()
        );
    }

    let workers = std::env::var("SCLS_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2usize);
    let n = std::env::var("SCLS_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32usize);
    let rate = 8.0;

    let cfg = RealClusterConfig {
        artifacts_dir: artifacts_dir.clone(),
        workers,
        slice_len: 16,
        max_gen_len: 64,
        skip_profiling: false,
        warmup: true,
    };

    println!(
        "endtoend_real: {n} requests @ {rate}/s on {workers} PJRT workers (tiny-GPT, slice 16)\n"
    );

    // --- SCLS: DP batching + max-min offload + adaptive interval ---------
    let preset = EnginePreset::paper(EngineKind::Hf);
    let mut scls_spec = SchedulerSpec::scls(&preset, cfg.slice_len);
    scls_spec.interval = IntervalSpec::Adaptive {
        lambda: 0.5,
        gamma: 0.8, // Γ scaled to the small model's speed (≈ its slice time)
    };
    let t0 = Instant::now();
    let m_scls = run_real(requests(n, rate, 7), &scls_spec, &cfg)?;
    let wall_scls = t0.elapsed().as_secs_f64();
    let s_scls = m_scls.summarize();
    report("SCLS (DP + max-min + adaptive T)", &s_scls, wall_scls, n);

    // --- SLS baseline: FCFS fixed-batch, round-robin ----------------------
    // The artifacts only export S=16 programs, so "serve to the limit" is
    // emulated by chaining 4 slices of 16 = the 64-token cap (worker-locus
    // FCFS, fixed batch 4, round-robin) — the scheduling semantics the
    // paper's SLS baseline has.
    let mut sls_spec = SchedulerSpec::sls(&preset, cfg.max_gen_len);
    sls_spec.slice_len = cfg.slice_len;
    sls_spec.batching = BatchingSpec::WorkerFcfs { batch_size: 4 };
    let t0 = Instant::now();
    let m_sls = run_real(requests(n, rate, 7), &sls_spec, &cfg)?;
    let wall_sls = t0.elapsed().as_secs_f64();
    let s_sls = m_sls.summarize();
    report("SLS (FCFS fixed-batch, round-robin)", &s_sls, wall_sls, n);

    println!(
        "\nSCLS vs SLS on the real model: {:+.1}% throughput, {:+.1}% avg RT",
        100.0 * (s_scls.throughput / s_sls.throughput - 1.0),
        100.0 * (s_scls.avg_response_time / s_sls.avg_response_time - 1.0),
    );

    // Sanity: the generated token streams are real model output — show one.
    if let Some(c) = m_scls.completed.first() {
        println!(
            "\nsample completion: request {} generated {} tokens over {} slice(s)",
            c.id, c.generated, c.slices
        );
    }

    // Write a machine-readable record for EXPERIMENTS.md.
    let out = Path::new("results");
    std::fs::create_dir_all(out)?;
    let mut j = scls::util::json::Json::obj();
    j.set("workers", workers)
        .set("requests", n)
        .set("scls", s_scls.to_json())
        .set("sls", s_sls.to_json())
        .set("wall_scls", wall_scls)
        .set("wall_sls", wall_sls);
    std::fs::write(out.join("endtoend_real.json"), j.to_string_pretty())?;
    println!("\nwrote results/endtoend_real.json");
    Ok(())
}
