//! Batching policies: the paper's DP adaptive batcher (Alg. 1) and the
//! FCFS fixed-size baseline used by SLS/SO/PM.

pub mod dp;
pub mod fcfs;

pub use dp::{
    dp_batch, dp_batch_into, dp_batch_reference, dp_batch_sorted_into, dp_plan,
    dp_plan_corrected_reference, dp_plan_reference, predicted_batch_iters, predicted_iters,
    DpBatcherConfig, DpScratch,
};
pub use fcfs::fcfs_batches;
