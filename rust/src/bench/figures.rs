//! Figure/table regeneration drivers — one function per figure of the
//! paper's evaluation (§3 motivation + §5). Each returns the series the
//! paper plots, as printable rows and JSON; `scls-repro figures` writes
//! them under `results/`, and the `rust/benches/fig*` targets print them
//! under `cargo bench`.
//!
//! Absolute numbers come from the calibrated DES (DESIGN.md §Calibration);
//! the claims under reproduction are the *shapes*: who wins, by what
//! factor, where the crossovers fall.

use crate::engine::presets::{EngineKind, EnginePreset};
use crate::engine::EngineLatency;
use crate::estimator::profiler::{profile_and_fit, validate_serving_time, LatencySource, ProfileGrid};
use crate::metrics::Summary;
use crate::sim::driver::{fitted_estimator, SimConfig, Simulation};
use crate::telemetry::TimeSeriesSink;
use crate::util::jobs::parallel_map;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::distributions::WorkloadKind;
use crate::workload::{Trace, TraceConfig};

/// A printable experiment output: header + rows + JSON payload.
#[derive(Debug, Clone)]
pub struct FigureResult {
    pub id: String,
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub json: Json,
}

impl FigureResult {
    pub fn print(&self) {
        println!("== {} — {}", self.id, self.title);
        println!("   {}", self.header.join(" | "));
        for r in &self.rows {
            println!("   {}", r.join(" | "));
        }
        println!();
    }
}

fn f2(x: f64) -> String {
    format!("{x:.2}")
}
fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Shared experiment defaults (paper §5.1). `duration` is shortened for
/// quick runs via `scale` (1.0 = the paper's full 10 minutes).
#[derive(Debug, Clone)]
pub struct FigureConfig {
    pub workers: usize,
    pub duration: f64,
    pub seed: u64,
    pub slice_len: u32,
    pub max_len: u32,
    pub workload: WorkloadKind,
    /// Worker threads for fanning out independent simulation cells
    /// (`--jobs`). Every cell is a pure function of its arguments, and
    /// results are reassembled in input order, so any value produces
    /// byte-identical tables and JSON to `jobs = 1`.
    pub jobs: usize,
}

impl Default for FigureConfig {
    fn default() -> Self {
        FigureConfig {
            workers: 8,
            duration: 600.0,
            seed: 42,
            slice_len: 128,
            max_len: 1024,
            workload: WorkloadKind::CodeFuse,
            jobs: 1,
        }
    }
}

impl FigureConfig {
    /// Scale the trace duration (0.1 ⇒ 1 minute instead of 10).
    pub fn quick(scale: f64) -> FigureConfig {
        FigureConfig {
            duration: (600.0 * scale).max(20.0),
            ..Default::default()
        }
    }

    fn trace(&self, rate: f64) -> Trace {
        Trace::generate(&TraceConfig {
            kind: self.workload,
            rate,
            duration: self.duration,
            max_input_len: self.max_len,
            max_gen_len: self.max_len,
            seed: self.seed,
        })
    }

    fn sim(&self, kind: EngineKind) -> SimConfig {
        SimConfig::new(
            self.workers,
            EnginePreset::paper(kind),
            self.max_len,
            self.seed,
        )
    }
}

/// Run one (engine, scheduler) cell and summarize. `which` is any name
/// the policy registry accepts ([`crate::scheduler::BUILTIN_POLICIES`]);
/// every cell goes through the single generic policy loop.
pub fn run_cell(
    fc: &FigureConfig,
    kind: EngineKind,
    which: &str,
    rate: f64,
    slice_len: u32,
) -> Summary {
    let trace = fc.trace(rate);
    let sim = Simulation::new(fc.sim(kind));
    sim.run_named(&trace, which, slice_len)
        .unwrap_or_else(|e| panic!("{e}"))
        .summarize()
}

/// [`run_cell`] with a [`TimeSeriesSink`] riding along, for figures that
/// report load-imbalance indices over the per-worker gauges. The sink
/// never touches `RunMetrics`, so the summary is byte-identical to the
/// sink-free cell's.
pub fn run_cell_observed(
    fc: &FigureConfig,
    kind: EngineKind,
    which: &str,
    rate: f64,
    slice_len: u32,
) -> (Summary, TimeSeriesSink) {
    let trace = fc.trace(rate);
    let sim = Simulation::new(fc.sim(kind));
    let mut ts = TimeSeriesSink::default();
    let s = sim
        .run_named_with_sink(&trace, which, slice_len, &mut ts)
        .unwrap_or_else(|e| panic!("{e}"))
        .summarize();
    (s, ts)
}

// ---------------------------------------------------------------------------
// Fig. 5 — motivation: SLS vs ILS vs SCLS at rate 20 on DS
// ---------------------------------------------------------------------------

pub fn fig05(fc: &FigureConfig) -> FigureResult {
    let cells = vec!["SLS", "ILS", "SCLS"];
    let sums = parallel_map(fc.jobs, cells, |which| {
        (which, run_cell(fc, EngineKind::Ds, which, 20.0, fc.slice_len))
    });
    let mut rows = Vec::new();
    let mut json = Json::obj();
    for (which, s) in sums {
        rows.push(vec![
            which.to_string(),
            f2(s.throughput),
            f2(s.avg_invalid_tokens),
            f2(s.avg_batch_size),
            f2(s.avg_pad_tokens),
            f2(s.ct_std),
        ]);
        json.set(which, s.to_json());
    }
    FigureResult {
        id: "fig5".into(),
        title: "Motivation: inefficiency and load imbalance of SLS/ILS (DS, rate 20)".into(),
        header: vec![
            "scheduler".into(),
            "throughput (req/s)".into(),
            "invalid tok/req".into(),
            "batch size".into(),
            "pad tok/req".into(),
            "CT STD (s)".into(),
        ],
        rows,
        json,
    }
}

// ---------------------------------------------------------------------------
// Fig. 6 — generation-length distributions (PDF / CDF)
// ---------------------------------------------------------------------------

pub fn fig06(fc: &FigureConfig) -> FigureResult {
    let at: Vec<f64> = (0..=16).map(|i| (i * 64) as f64).collect();
    let mut rows = Vec::new();
    let mut json = Json::obj();
    for (name, kind) in [("CodeFuse", WorkloadKind::CodeFuse), ("ShareGPT", WorkloadKind::ShareGpt)] {
        let dist = kind.gen_dist(fc.max_len);
        let mut rng = Rng::new(fc.seed);
        let cdf = dist.empirical_cdf(&mut rng, 400_000, &at);
        let pdf: Vec<f64> = at.iter().map(|&x| dist.pdf(x.max(1.0))).collect();
        for (i, &x) in at.iter().enumerate() {
            rows.push(vec![
                name.to_string(),
                format!("{x:.0}"),
                format!("{:.5}", pdf[i]),
                f3(cdf[i]),
            ]);
        }
        let mut o = Json::obj();
        o.set("at", at.clone()).set("pdf", pdf).set("cdf", cdf.clone());
        json.set(name, o);
        // The paper's observation: vast majority < 512.
        let idx512 = at.iter().position(|&x| x == 512.0).unwrap();
        log::info!("{name}: P(len < 512) = {:.3}", cdf[idx512]);
    }
    FigureResult {
        id: "fig6".into(),
        title: "Generation-length PDF/CDF (synthetic CodeFuse/ShareGPT models)".into(),
        header: vec!["dataset".into(), "len".into(), "pdf".into(), "cdf".into()],
        rows,
        json,
    }
}

// ---------------------------------------------------------------------------
// Fig. 8 / Fig. 9 — prefill and per-iteration decode latency profiles
// ---------------------------------------------------------------------------

pub fn fig08_09(_fc: &FigureConfig, kind: EngineKind) -> FigureResult {
    let mut lat = EnginePreset::paper(kind).latency(7);
    let mut rows = Vec::new();
    let mut json = Json::obj();

    let input_lens = [16u32, 64, 128, 256, 512, 1024];
    let batch_sizes = [1u32, 2, 4, 8, 12, 16];
    let mut prefill = Vec::new();
    for &n in &batch_sizes {
        for &l in &input_lens {
            let t = lat.measure_prefill(n, l);
            rows.push(vec![
                "prefill".into(),
                n.to_string(),
                l.to_string(),
                f3(t),
            ]);
            let mut o = Json::obj();
            o.set("n", n).set("l", l).set("t", t);
            prefill.push(o);
        }
    }
    let cached = [64u32, 256, 512, 1024, 1536, 2048];
    let mut decode = Vec::new();
    for &n in &batch_sizes {
        for &l in &cached {
            let t = lat.measure_decode_iter(l, n);
            rows.push(vec![
                "decode".into(),
                n.to_string(),
                l.to_string(),
                format!("{:.4}", t),
            ]);
            let mut o = Json::obj();
            o.set("n", n).set("l", l).set("t", t);
            decode.push(o);
        }
    }
    json.set("prefill", Json::Arr(prefill))
        .set("decode", Json::Arr(decode));
    FigureResult {
        id: "fig8_9".into(),
        title: format!(
            "Prefill latency T_prefill(N,L_i) and decode latency τ(l,N) — {} profile",
            kind.name()
        ),
        header: vec!["phase".into(), "N".into(), "len".into(), "latency (s)".into()],
        rows,
        json,
    }
}

// ---------------------------------------------------------------------------
// Fig. 10 — serving-time estimation error (RMSE, 1 iter and 128 iters)
// ---------------------------------------------------------------------------

pub fn fig10(_fc: &FigureConfig) -> FigureResult {
    let mut rows = Vec::new();
    let mut json = Json::obj();
    for kind in [EngineKind::Hf, EngineKind::Ds] {
        let preset = EnginePreset::paper(kind);
        let mut src: EngineLatency = preset.latency(11);
        let res = profile_and_fit(&mut src, &ProfileGrid::default());
        // Holdout: fresh jitter stream, off-grid points.
        let mut holdout = preset.latency(12345);
        let rmse1p = {
            // per-phase single-iteration errors on holdout measurements
            let mut pred = Vec::new();
            let mut act = Vec::new();
            for &n in &[3u32, 6, 10, 14] {
                for &l in &[48u32, 200, 400, 800, 1600] {
                    pred.push(res.estimator.decode_iter(l, n));
                    act.push(holdout.measure_decode_iter(l, n));
                }
            }
            crate::util::stats::rmse(&pred, &act)
        };
        let rmse128 = validate_serving_time(
            &mut holdout,
            &res.estimator,
            &[2, 6, 10, 14],
            &[48, 200, 400, 800],
            128,
        );
        rows.push(vec![
            kind.name().into(),
            format!("{:.4}", res.prefill_rmse),
            format!("{:.4}", rmse1p),
            f3(rmse128),
        ]);
        let mut o = Json::obj();
        o.set("prefill_rmse", res.prefill_rmse)
            .set("decode_iter_rmse", rmse1p)
            .set("serve128_rmse", rmse128);
        json.set(kind.name(), o);
    }
    FigureResult {
        id: "fig10".into(),
        title: "Estimation error: fit RMSE per phase and accumulated over 128 iters".into(),
        header: vec![
            "engine".into(),
            "prefill RMSE (s)".into(),
            "decode-iter RMSE (s)".into(),
            "128-iter RMSE (s)".into(),
        ],
        rows,
        json,
    }
}

// ---------------------------------------------------------------------------
// Fig. 11 — together- vs separate-batching example
// ---------------------------------------------------------------------------

pub fn fig11(_fc: &FigureConfig) -> FigureResult {
    // Paper: 15 requests of input 10 + 1 of input 1024, slice 128, HF.
    let est = fitted_estimator(&EnginePreset::paper(EngineKind::Hf), 3);
    let together = est.serve(16, 1024, 128);
    let separate = est.serve(15, 10, 128) + est.serve(1, 1024, 128);
    let mut json = Json::obj();
    json.set("together", together).set("separate", separate);
    FigureResult {
        id: "fig11".into(),
        title: "Batching example (HF, S=128): 15×len-10 + 1×len-1024".into(),
        header: vec!["strategy".into(), "estimated serving time (s)".into()],
        rows: vec![
            vec!["together".into(), f2(together)],
            vec!["separate".into(), f2(separate)],
        ],
        json,
    }
}

// ---------------------------------------------------------------------------
// Fig. 12/13/14 — overall performance vs arrival rate
// ---------------------------------------------------------------------------

pub fn fig12_13_14(fc: &FigureConfig, rates: &[f64]) -> FigureResult {
    let cells: Vec<(EngineKind, &str)> = vec![
        (EngineKind::Hf, "SLS"),
        (EngineKind::Hf, "SCLS"),
        (EngineKind::Ds, "SLS"),
        (EngineKind::Ds, "ILS"),
        (EngineKind::Ds, "SCLS"),
    ];
    let mut items: Vec<(f64, EngineKind, &str)> = Vec::new();
    for &rate in rates {
        for &(kind, which) in &cells {
            items.push((rate, kind, which));
        }
    }
    let sums = parallel_map(fc.jobs, items, |(rate, kind, which)| {
        (rate, kind, which, run_cell(fc, kind, which, rate, fc.slice_len))
    });
    let mut rows = Vec::new();
    let mut arr = Vec::new();
    for (rate, kind, which, s) in sums {
        rows.push(vec![
            format!("{}-{}", kind.name(), which),
            format!("{rate:.0}"),
            f2(s.throughput),
            f2(s.avg_response_time),
            f2(s.p95_response_time),
            f2(s.avg_invalid_tokens),
            f2(s.avg_batch_size),
            f2(s.avg_pad_tokens),
            format!("{:?}", s.slice_histogram),
            format!("{:.4}", s.early_return_ratio),
        ]);
        let mut o = s.to_json();
        o.set("engine", kind.name())
            .set("scheduler", which)
            .set("rate", rate);
        arr.push(o);
    }
    FigureResult {
        id: "fig12_13_14".into(),
        title: "Overall: throughput / response times / dive-in counters vs arrival rate".into(),
        header: vec![
            "cell".into(),
            "rate".into(),
            "thpt".into(),
            "avg RT".into(),
            "p95 RT".into(),
            "invalid".into(),
            "batch".into(),
            "pads".into(),
            "slices[1,2,3,4+]".into(),
            "early".into(),
        ],
        rows,
        json: Json::Arr(arr),
    }
}

// ---------------------------------------------------------------------------
// Fig. 15/16 — ablation ladder at rate 20
// ---------------------------------------------------------------------------

pub fn fig15_16(fc: &FigureConfig, kind: EngineKind) -> FigureResult {
    let ladder = vec!["SLS", "SO", "PM", "AB", "LB", "SCLS"];
    let sums = parallel_map(fc.jobs, ladder, |which| {
        (which, run_cell(fc, kind, which, 20.0, fc.slice_len))
    });
    let mut rows = Vec::new();
    let mut arr = Vec::new();
    for (which, s) in sums {
        rows.push(vec![
            which.to_string(),
            f2(s.throughput),
            f2(s.avg_response_time),
            f2(s.p95_response_time),
            f2(s.avg_invalid_tokens),
            f2(s.avg_batch_size),
            f2(s.avg_pad_tokens),
        ]);
        let mut o = s.to_json();
        o.set("strategy", which);
        arr.push(o);
    }
    FigureResult {
        id: "fig15_16".into(),
        title: format!("Ablation ladder ({}, rate 20)", kind.name()),
        header: vec![
            "strategy".into(),
            "thpt".into(),
            "avg RT".into(),
            "p95 RT".into(),
            "invalid".into(),
            "batch".into(),
            "pads".into(),
        ],
        rows,
        json: Json::Arr(arr),
    }
}

// ---------------------------------------------------------------------------
// Fig. 17 — load imbalance (CT STD) vs arrival rate
// ---------------------------------------------------------------------------

pub fn fig17(fc: &FigureConfig, rates: &[f64]) -> FigureResult {
    let cells: Vec<(EngineKind, &str)> = vec![
        (EngineKind::Hf, "SLS"),
        (EngineKind::Hf, "SCLS"),
        (EngineKind::Ds, "SLS"),
        (EngineKind::Ds, "ILS"),
        (EngineKind::Ds, "SCLS"),
    ];
    let mut items: Vec<(f64, EngineKind, &str)> = Vec::new();
    for &rate in rates {
        for &(kind, which) in &cells {
            items.push((rate, kind, which));
        }
    }
    let sums = parallel_map(fc.jobs, items, |(rate, kind, which)| {
        (rate, kind, which, run_cell_observed(fc, kind, which, rate, fc.slice_len))
    });
    let mut rows = Vec::new();
    let mut arr = Vec::new();
    for (rate, kind, which, (s, ts)) in sums {
        let served = ts.served_imbalance();
        rows.push(vec![
            format!("{}-{}", kind.name(), which),
            format!("{rate:.0}"),
            f2(s.ct_std),
            f3(served.jains),
            f3(served.cv),
        ]);
        let mut o = Json::obj();
        o.set("engine", kind.name())
            .set("scheduler", which)
            .set("rate", rate)
            .set("ct_std", s.ct_std)
            .set("served_imbalance", served.to_json());
        arr.push(o);
    }
    FigureResult {
        id: "fig17".into(),
        title: "Load imbalance: completion-time STD and served-token fairness vs rate".into(),
        header: vec![
            "cell".into(),
            "rate".into(),
            "CT STD (s)".into(),
            "Jain".into(),
            "CV".into(),
        ],
        rows,
        json: Json::Arr(arr),
    }
}

// ---------------------------------------------------------------------------
// Observability figure — per-worker load gauges and imbalance indices
// ---------------------------------------------------------------------------

/// Extension figure: the telemetry view of the load-balance claim. Each
/// scheduler family runs at rate 20 on DS with a [`TimeSeriesSink`]
/// attached; the table reports the imbalance indices over served tokens
/// and busy time per worker, next to the paper's CT-STD endpoint. The
/// JSON payload carries the full per-worker binned series (KV occupancy,
/// queue depth, busy seconds per interval) for plotting.
pub fn figobs(fc: &FigureConfig) -> FigureResult {
    let ladder = vec!["SLS", "ILS", "SCLS", "SCLS-CB"];
    let sums = parallel_map(fc.jobs, ladder, |which| {
        (which, run_cell_observed(fc, EngineKind::Ds, which, 20.0, fc.slice_len))
    });
    let mut rows = Vec::new();
    let mut arr = Vec::new();
    for (which, (s, ts)) in sums {
        let served = ts.served_imbalance();
        let busy = ts.busy_imbalance();
        rows.push(vec![
            which.to_string(),
            f2(s.throughput),
            f3(served.jains),
            f2(served.max_over_mean),
            f3(served.cv),
            f3(busy.jains),
            f2(s.ct_std),
        ]);
        let mut o = Json::obj();
        o.set("scheduler", which)
            .set("throughput", s.throughput)
            .set("ct_std", s.ct_std)
            .set("series", ts.to_json(fc.duration));
        arr.push(o);
    }
    FigureResult {
        id: "figobs".into(),
        title: "Observability: per-worker served/busy imbalance indices (DS, rate 20)".into(),
        header: vec![
            "scheduler".into(),
            "thpt".into(),
            "served Jain".into(),
            "served max/mean".into(),
            "served CV".into(),
            "busy Jain".into(),
            "CT STD (s)".into(),
        ],
        rows,
        json: Json::Arr(arr),
    }
}

// ---------------------------------------------------------------------------
// Fig. 18–21 — impact of slice length
// ---------------------------------------------------------------------------

pub fn fig18_21(fc: &FigureConfig, kind: EngineKind, slice_lens: &[u32]) -> FigureResult {
    let sums = parallel_map(fc.jobs, slice_lens.to_vec(), |s_len| {
        (s_len, run_cell(fc, kind, "SCLS", 20.0, s_len))
    });
    let mut rows = Vec::new();
    let mut arr = Vec::new();
    for (s_len, s) in sums {
        rows.push(vec![
            s_len.to_string(),
            f2(s.throughput),
            f2(s.avg_response_time),
            f2(s.p95_response_time),
            f2(s.avg_invalid_tokens),
            f2(s.avg_batch_size),
            f2(s.avg_pad_tokens),
            format!("{:?}", s.slice_histogram),
            format!("{:.4}", s.early_return_ratio),
            f2(s.ct_std),
        ]);
        let mut o = s.to_json();
        o.set("slice_len", s_len);
        arr.push(o);
    }
    FigureResult {
        id: "fig18_21".into(),
        title: format!("Slice-length sweep (SCLS, {}, rate 20)", kind.name()),
        header: vec![
            "S".into(),
            "thpt".into(),
            "avg RT".into(),
            "p95 RT".into(),
            "invalid".into(),
            "batch".into(),
            "pads".into(),
            "slices[1,2,3,4+]".into(),
            "early".into(),
            "CT STD".into(),
        ],
        rows,
        json: Json::Arr(arr),
    }
}

// ---------------------------------------------------------------------------
// Prediction sweep — throughput vs prediction error (extension figure)
// ---------------------------------------------------------------------------

/// One prediction-sweep cell: run `which` with a noisy-oracle predictor of
/// the given σ and return the full metrics (the sweep reports the
/// prediction counters, which `Summary` does not carry).
fn run_pred_cell(
    fc: &FigureConfig,
    kind: EngineKind,
    which: &str,
    rate: f64,
    slice_len: u32,
    sigma: Option<f64>,
) -> crate::metrics::RunMetrics {
    let trace = fc.trace(rate);
    let mut cfg = fc.sim(kind);
    if let Some(sigma) = sigma {
        cfg.predictor = crate::predictor::PredictorSpec::Noisy { sigma };
    }
    Simulation::new(cfg)
        .run_named(&trace, which, slice_len)
        .unwrap_or_else(|e| panic!("{e}"))
}

/// Extension figure: throughput vs prediction error. P-SCLS and P-CB run
/// with a [`crate::predictor::NoisyOracle`] across σ (σ = 0 is the exact
/// oracle); SCLS, ILS, and SCLS-CB anchor the prediction-free baselines.
/// The acceptance shape: P-CB at σ = 0 beats SCLS-CB, and both
/// prediction-aware rows degrade (within noise) as σ grows.
pub fn fig_pred(fc: &FigureConfig, sigmas: &[f64]) -> FigureResult {
    let mut items: Vec<(&'static str, Option<f64>)> =
        vec![("SCLS", None), ("ILS", None), ("SCLS-CB", None)];
    for &s in sigmas {
        items.push(("P-SCLS", Some(s)));
        items.push(("P-CB", Some(s)));
    }
    let sums = parallel_map(fc.jobs, items, |(which, sigma)| {
        let m = run_pred_cell(fc, EngineKind::Ds, which, 20.0, fc.slice_len, sigma);
        let (under, over, wasted) = (m.underpredicted, m.overpredicted, m.wasted_kv_token_steps);
        (which, sigma, m.summarize(), under, over, wasted)
    });
    let mut rows = Vec::new();
    let mut arr = Vec::new();
    for (which, sigma, s, under, over, wasted) in sums {
        rows.push(vec![
            which.to_string(),
            sigma.map(|x| format!("{x}")).unwrap_or_else(|| "-".into()),
            f2(s.throughput),
            f2(s.avg_response_time),
            f2(s.p95_response_time),
            under.to_string(),
            over.to_string(),
            wasted.to_string(),
        ]);
        let mut o = s.to_json();
        o.set("scheduler", which)
            .set("underpredicted", under)
            .set("overpredicted", over)
            .set("wasted_kv_token_steps", wasted);
        if let Some(x) = sigma {
            o.set("sigma", x);
        }
        arr.push(o);
    }
    FigureResult {
        id: "figpred".into(),
        title: "Prediction sweep: throughput vs length-prediction error (DS, rate 20)".into(),
        header: vec![
            "scheduler".into(),
            "sigma".into(),
            "thpt".into(),
            "avg RT".into(),
            "p95 RT".into(),
            "underpred".into(),
            "overpred".into(),
            "wasted tok".into(),
        ],
        rows,
        json: Json::Arr(arr),
    }
}

// ---------------------------------------------------------------------------
// Drift sweep — online predictor refit under a mid-run length shift
// ---------------------------------------------------------------------------

/// A workload whose generation-length distribution shifts mid-run: the
/// first half is the configured CodeFuse trace; from `duration/2` on,
/// generation lengths remap to long-form territory (`cap/2 + len/2`, i.e.
/// the upper half of the range — a new long-generation tenant arrives).
/// The arrival process is untouched, so the only drift is in lengths —
/// the axis a static length predictor goes stale on: the pre-drift
/// quantile fit covers the upper half of the range with a single coarse
/// bucket, so every stale prediction there lands rungs away from the
/// truth.
fn drift_trace(fc: &FigureConfig, rate: f64) -> Trace {
    let mut trace = fc.trace(rate);
    let shift_at = fc.duration * 0.5;
    for r in &mut trace.requests {
        if r.arrival >= shift_at {
            r.target_gen_len = (fc.max_len / 2 + r.target_gen_len / 2).min(fc.max_len);
        }
    }
    trace
}

/// One drift-sweep cell: run `which` over the drift trace with the given
/// predictor (None = the scheduler ignores predictors anyway).
fn run_drift_cell(
    fc: &FigureConfig,
    which: &str,
    rate: f64,
    pspec: Option<crate::predictor::PredictorSpec>,
) -> crate::metrics::RunMetrics {
    let trace = drift_trace(fc, rate);
    let mut cfg = fc.sim(EngineKind::Ds);
    if let Some(p) = pspec {
        cfg.predictor = p;
    }
    Simulation::new(cfg)
        .run_named(&trace, which, fc.slice_len)
        .unwrap_or_else(|e| panic!("{e}"))
}

/// Extension figure: P-SCLS under a mid-run length drift, with the same
/// bucket classifier fit **statically** on the pre-drift distribution vs
/// its **online** variant that refits from served completions, anchored
/// by the oracle (perfect foresight) and prediction-free SCLS. Both
/// classifiers share the seed, so they draw identical per-request
/// confusions — the only difference is edge staleness. The acceptance
/// shape: after the shift the static fit's predictions overshoot into
/// stale coarse buckets (wasted reservations) and undershoot on confusion
/// slips (requeue passes), while the online fit walks its edges to the
/// new distribution within a window — strictly less wasted reservation,
/// and throughput at least matching the static fit.
pub fn fig_drift(fc: &FigureConfig) -> FigureResult {
    use crate::predictor::PredictorSpec;
    let buckets = PredictorSpec::DEFAULT_BUCKETS;
    let accuracy = PredictorSpec::DEFAULT_ACCURACY;
    let items: Vec<(&'static str, &'static str, Option<PredictorSpec>)> = vec![
        ("SCLS", "-", None),
        ("P-SCLS", "oracle", Some(PredictorSpec::Oracle)),
        (
            "P-SCLS",
            "bucket(static)",
            Some(PredictorSpec::Bucket {
                buckets,
                accuracy,
                workload: fc.workload,
            }),
        ),
        (
            "P-SCLS",
            "online:512",
            Some(PredictorSpec::Online {
                window: 512,
                buckets,
                accuracy,
                workload: fc.workload,
            }),
        ),
    ];
    let sums = parallel_map(fc.jobs, items, |(which, label, pspec)| {
        let m = run_drift_cell(fc, which, 20.0, pspec);
        let (under, over, wasted, refits) = (
            m.underpredicted,
            m.overpredicted,
            m.wasted_kv_token_steps,
            m.predictor_refits,
        );
        (which, label, m.summarize(), under, over, wasted, refits)
    });
    let mut rows = Vec::new();
    let mut arr = Vec::new();
    for (which, label, s, under, over, wasted, refits) in sums {
        rows.push(vec![
            which.to_string(),
            label.to_string(),
            f2(s.throughput),
            f2(s.avg_response_time),
            f2(s.p95_response_time),
            under.to_string(),
            over.to_string(),
            wasted.to_string(),
            refits.to_string(),
        ]);
        let mut o = s.to_json();
        o.set("scheduler", which)
            .set("predictor", label)
            .set("underpredicted", under)
            .set("overpredicted", over)
            .set("wasted_kv_token_steps", wasted)
            .set("predictor_refits", refits);
        arr.push(o);
    }
    FigureResult {
        id: "figdrift".into(),
        title: "Length-drift sweep: online refit vs static bucket fit vs oracle \
                (P-SCLS, DS, rate 20, lengths shift long-form at T/2)"
            .into(),
        header: vec![
            "scheduler".into(),
            "predictor".into(),
            "thpt".into(),
            "avg RT".into(),
            "p95 RT".into(),
            "underpred".into(),
            "overpred".into(),
            "wasted tok".into(),
            "refits".into(),
        ],
        rows,
        json: Json::Arr(arr),
    }
}

// ---------------------------------------------------------------------------
// Fault sweep — elastic-fleet robustness (extension figure)
// ---------------------------------------------------------------------------

/// The fault scenarios the robustness figure sweeps. Every scenario keeps
/// at least one worker alive at all times, so the 100%-completion
/// invariant is well-posed.
fn fault_scenarios(fc: &FigureConfig) -> Vec<(&'static str, crate::sim::FaultPlan)> {
    use crate::sim::FaultPlan;
    let w = fc.workers;
    // Rolling restart: drain each worker in turn, one joiner per drain —
    // the last join must land inside the trace window.
    let period = fc.duration / (w as f64 + 2.0);
    // Correlated failure: half the fleet crashes at T/3 (a rack goes
    // down); replacements join at 2T/3.
    let half = (w / 2).max(1).min(w - 1);
    let mut correlated = FaultPlan::none();
    for i in 0..half {
        correlated = correlated.crash(w - 1 - i, fc.duration / 3.0);
    }
    correlated = correlated.join(half as u32, 2.0 * fc.duration / 3.0);
    // Coordinator crash amid worker churn: the successor must rebuild a
    // ledger that already carries load AND a dead worker.
    let coord = FaultPlan::none()
        .crash(w - 1, fc.duration / 4.0)
        .coordinator_crash(fc.duration / 3.0);
    // Probabilistic churn: Poisson crashes (worker 0 spared) with repair
    // joins, expanded deterministically over the trace window by the
    // seeded grammar — the same plan byte-for-byte on every run.
    let mtbf = FaultPlan::parse_with_horizon(
        &format!(
            "mtbf:{:.3},mttr:{:.3},seed:7",
            fc.duration / 3.0,
            fc.duration / 20.0
        ),
        w,
        fc.duration,
    )
    .expect("figure mtbf spec is valid");
    vec![
        ("none", FaultPlan::none()),
        ("rolling", FaultPlan::rolling(w, period)),
        ("correlated", correlated),
        ("coord", coord),
        ("mtbf", mtbf),
    ]
}

/// One fault-sweep cell: run `which` through a fault plan and return the
/// full metrics (the sweep reports the fleet counters, which `Summary`
/// does not carry).
fn run_fault_cell(
    fc: &FigureConfig,
    which: &str,
    rate: f64,
    plan: &crate::sim::FaultPlan,
) -> crate::metrics::RunMetrics {
    use crate::estimator::TransferCost;
    let trace = fc.trace(rate);
    // The transfer model prices migration KV movement (2M tokens/s);
    // fault-free cells never migrate, so it cannot perturb the baseline.
    Simulation::new(
        fc.sim(EngineKind::Ds)
            .with_kv_transfer(Some(TransferCost::from_bandwidth(2e6))),
    )
    .run_named_faulted(&trace, which, fc.slice_len, plan)
    .unwrap_or_else(|e| panic!("{e}"))
}

/// Extension figure: throughput and tail latency through worker churn.
/// All five fault-aware policies (static SCLS, ILS, P-SCLS, and the
/// continuous-batching SCLS-CB / P-CB) run through a rolling restart, a
/// correlated half-fleet crash, a coordinator crash amid churn, and a
/// seeded probabilistic mtbf/mttr plan — against the no-fault baseline.
/// The acceptance shape: every request completes in every scenario (the
/// slice-boundary reclaim loses at most one slice per crashed batch, never
/// a request), and the faulted runs trade throughput/latency bands
/// (p50/p99) plus migration KV traffic, not completeness.
pub fn fig_fault(fc: &FigureConfig) -> FigureResult {
    let scenarios = fault_scenarios(fc);
    let mut items: Vec<(&'static str, &'static str, crate::sim::FaultPlan)> = Vec::new();
    for which in ["SCLS", "ILS", "P-SCLS", "SCLS-CB", "P-CB"] {
        for (label, plan) in &scenarios {
            items.push((which, label, plan.clone()));
        }
    }
    let sums = parallel_map(fc.jobs, items, |(which, label, plan)| {
        let m = run_fault_cell(fc, which, 20.0, &plan);
        let mut rts: Vec<f64> = m.completed.iter().map(|c| c.finished - c.arrival).collect();
        rts.sort_by(f64::total_cmp);
        let p50 = crate::util::stats::percentile_sorted(&rts, 0.50);
        let p99 = crate::util::stats::percentile_sorted(&rts, 0.99);
        let fleet = (
            m.worker_crashes,
            m.coordinator_crashes,
            m.reclaimed_requests,
            m.lost_slices,
            m.migrations,
            m.kv_tokens_migrated,
            m.migration_stall_s,
        );
        (which, label, m.summarize(), p50, p99, fleet)
    });
    let mut rows = Vec::new();
    let mut arr = Vec::new();
    for (which, label, s, p50, p99, fleet) in sums {
        let (crashes, coord_crashes, reclaimed, lost, migrations, kv_tokens, stall) = fleet;
        rows.push(vec![
            which.to_string(),
            label.to_string(),
            f2(s.throughput),
            f2(p50),
            f2(p99),
            s.completed.to_string(),
            crashes.to_string(),
            coord_crashes.to_string(),
            reclaimed.to_string(),
            lost.to_string(),
            migrations.to_string(),
            kv_tokens.to_string(),
            f2(stall),
        ]);
        let mut o = s.to_json();
        o.set("scheduler", which)
            .set("scenario", label)
            .set("p50_response_time", p50)
            .set("p99_response_time", p99)
            .set("worker_crashes", crashes)
            .set("coordinator_crashes", coord_crashes)
            .set("reclaimed_requests", reclaimed)
            .set("lost_slices", lost)
            .set("migrations", migrations)
            .set("kv_tokens_migrated", kv_tokens)
            .set("migration_stall_s", stall);
        arr.push(o);
    }
    FigureResult {
        id: "figfault".into(),
        title: "Fault sweep: latency bands through rolling restart, correlated \
                crash, coordinator crash, and seeded mtbf churn (DS, rate 20)"
            .into(),
        header: vec![
            "scheduler".into(),
            "scenario".into(),
            "thpt".into(),
            "p50 RT".into(),
            "p99 RT".into(),
            "completed".into(),
            "crashes".into(),
            "coord".into(),
            "reclaimed".into(),
            "lost slices".into(),
            "migrations".into(),
            "kv tok".into(),
            "stall s".into(),
        ],
        rows,
        json: Json::Arr(arr),
    }
}

// ---------------------------------------------------------------------------
// SLO sweep — arrival rate vs SLO attainment (extension figure)
// ---------------------------------------------------------------------------

/// One SLO-sweep cell: stamp the trace with a 4-tenant mix and the sweep's
/// base SLO (tighter tiers for lower-numbered tenants, per
/// [`crate::slo::stamp_trace`]), then run `which` and return the full
/// metrics (the sweep reports the goodput counters, which `Summary` does
/// not carry).
fn run_slo_cell(fc: &FigureConfig, which: &str, rate: f64) -> crate::metrics::RunMetrics {
    use crate::slo::{stamp_trace, SloSpec, TenantMix};
    let mut trace = fc.trace(rate);
    let mix = TenantMix::uniform(4);
    let base = SloSpec::parse("ttft:10,tpot:1,deadline:60").expect("static spec");
    stamp_trace(&mut trace, &mix, &base, fc.seed ^ 0x510);
    Simulation::new(fc.sim(EngineKind::Ds))
        .run_named(&trace, which, fc.slice_len)
        .unwrap_or_else(|e| panic!("{e}"))
}

/// Extension figure: SLO attainment (goodput) vs arrival rate. The
/// SLO-aware trio (D-SCLS, P-SRPT, SW-SLO) runs against the SLS / ILS /
/// SCLS baselines over SLO-stamped traces. The acceptance shape: at the
/// underloaded end everyone attains nearly everything and the SLO-aware
/// rows hold throughput within 10% of SCLS; past saturation the
/// deadline-aware rows degrade gracefully (shed infeasible work early,
/// keep the rest inside deadline) while the oblivious rows collapse.
pub fn fig_slo(fc: &FigureConfig, rates: &[f64]) -> FigureResult {
    let policies = ["SLS", "ILS", "SCLS", "D-SCLS", "P-SRPT", "SW-SLO"];
    let mut items: Vec<(&'static str, f64)> = Vec::new();
    for &rate in rates {
        for which in policies {
            items.push((which, rate));
        }
    }
    let sums = parallel_map(fc.jobs, items, |(which, rate)| {
        let m = run_slo_cell(fc, which, rate);
        let slo = (
            m.slo.tracked,
            m.slo.attainment(),
            m.slo.ttft_p99(),
            m.slo.deadline_misses,
            m.shed_requests,
        );
        (which, rate, m.summarize(), slo)
    });
    let mut rows = Vec::new();
    let mut arr = Vec::new();
    for (which, rate, s, (tracked, attainment, ttft_p99, misses, shed)) in sums {
        rows.push(vec![
            which.to_string(),
            format!("{rate:.0}"),
            f2(s.throughput),
            f3(attainment),
            f2(ttft_p99),
            misses.to_string(),
            shed.to_string(),
            s.completed.to_string(),
        ]);
        let mut o = s.to_json();
        o.set("scheduler", which)
            .set("rate", rate)
            .set("slo_tracked", tracked)
            .set("slo_attainment", attainment)
            .set("ttft_p99", ttft_p99)
            .set("deadline_misses", misses)
            .set("shed_requests", shed);
        arr.push(o);
    }
    FigureResult {
        id: "figslo".into(),
        title: "SLO sweep: attainment/goodput vs arrival rate, 4 tenants \
                (DS, ttft:10 tpot:1 deadline:60)"
            .into(),
        header: vec![
            "scheduler".into(),
            "rate".into(),
            "thpt".into(),
            "attain".into(),
            "ttft p99".into(),
            "ddl miss".into(),
            "shed".into(),
            "completed".into(),
        ],
        rows,
        json: Json::Arr(arr),
    }
}

// ---------------------------------------------------------------------------
// Fig. 22 — scalability: throughput vs number of workers
// ---------------------------------------------------------------------------

pub fn fig22(fc: &FigureConfig, worker_counts: &[usize]) -> FigureResult {
    let mut items: Vec<(EngineKind, usize)> = Vec::new();
    for kind in [EngineKind::Hf, EngineKind::Ds] {
        for &w in worker_counts {
            items.push((kind, w));
        }
    }
    let sums = parallel_map(fc.jobs, items, |(kind, w)| {
        let fcw = FigureConfig {
            workers: w,
            ..fc.clone()
        };
        (kind, w, run_cell(&fcw, kind, "SCLS", 20.0, fc.slice_len))
    });
    let mut rows = Vec::new();
    let mut arr = Vec::new();
    for (kind, w, s) in sums {
        rows.push(vec![
            kind.name().into(),
            w.to_string(),
            f2(s.throughput),
        ]);
        let mut o = Json::obj();
        o.set("engine", kind.name())
            .set("workers", w)
            .set("throughput", s.throughput);
        arr.push(o);
    }
    FigureResult {
        id: "fig22".into(),
        title: "Scalability: SCLS throughput vs worker count (rate 20)".into(),
        header: vec!["engine".into(), "workers".into(), "throughput (req/s)".into()],
        rows,
        json: Json::Arr(arr),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> FigureConfig {
        FigureConfig::quick(0.05) // 30-second traces
    }

    #[test]
    fn fig06_shapes() {
        let r = fig06(&quick());
        assert!(!r.rows.is_empty());
        // CDF at 512 must show "vast majority" for both datasets
        let cf = r.json.at(&["CodeFuse", "cdf"]).unwrap().as_arr().unwrap();
        let at = r.json.at(&["CodeFuse", "at"]).unwrap().as_arr().unwrap();
        let idx = at.iter().position(|x| x.as_f64() == Some(512.0)).unwrap();
        assert!(cf[idx].as_f64().unwrap() > 0.85);
    }

    #[test]
    fn fig11_separate_wins() {
        let r = fig11(&quick());
        let together = r.json.get("together").unwrap().as_f64().unwrap();
        let separate = r.json.get("separate").unwrap().as_f64().unwrap();
        assert!(separate < together, "{separate} !< {together}");
    }

    #[test]
    fn fig10_errors_small_and_ordered() {
        let r = fig10(&quick());
        for kind in ["HF", "DS"] {
            let o = r.json.get(kind).unwrap();
            let d1 = o.get("decode_iter_rmse").unwrap().as_f64().unwrap();
            let d128 = o.get("serve128_rmse").unwrap().as_f64().unwrap();
            assert!(d1 < 0.05, "{kind} decode RMSE {d1}");
            assert!(d128 < 3.0, "{kind} 128-iter RMSE {d128}");
        }
        // HF (noisier, bigger bases) > DS, as in the paper
        let hf = r.json.at(&["HF", "serve128_rmse"]).unwrap().as_f64().unwrap();
        let ds = r.json.at(&["DS", "serve128_rmse"]).unwrap().as_f64().unwrap();
        assert!(hf > ds, "HF {hf} !> DS {ds}");
    }

    #[test]
    fn fig05_scls_wins_motivation() {
        let fc = quick();
        let r = fig05(&fc);
        let get = |w: &str, k: &str| r.json.at(&[w, k]).unwrap().as_f64().unwrap();
        assert!(get("SCLS", "throughput") > get("SLS", "throughput"));
        assert!(get("SCLS", "throughput") > get("ILS", "throughput"));
        assert!(get("SCLS", "avg_invalid_tokens") < get("SLS", "avg_invalid_tokens"));
        assert!(get("SCLS", "avg_batch_size") > get("SLS", "avg_batch_size"));
    }

    #[test]
    fn figpred_covers_baselines_and_sigma_sweep() {
        let r = fig_pred(&quick(), &[0.0, 0.5]);
        // 3 baselines + 2 policies × 2 sigmas.
        assert_eq!(r.rows.len(), 7);
        let arr = r.json.as_arr().unwrap();
        let cell = |which: &str, sigma: Option<f64>| {
            arr.iter()
                .find(|o| {
                    o.get("scheduler").and_then(Json::as_str) == Some(which)
                        && o.get("sigma").and_then(Json::as_f64) == sigma
                })
                .unwrap_or_else(|| panic!("missing cell {which} {sigma:?}"))
        };
        let thpt = |which: &str, sigma: Option<f64>| {
            cell(which, sigma).get("throughput").unwrap().as_f64().unwrap()
        };
        assert!(thpt("P-CB", Some(0.0)) > 0.0);
        assert!(thpt("SCLS-CB", None) > 0.0);
        // Exact oracle: zero recovery events.
        let under0 = cell("P-CB", Some(0.0))
            .get("underpredicted")
            .unwrap()
            .as_i64()
            .unwrap();
        assert_eq!(under0, 0, "oracle P-CB must never evict");
        // Heavy noise produces recovery events on the sliced ladder too.
        let under_noisy = cell("P-CB", Some(0.5))
            .get("underpredicted")
            .unwrap()
            .as_i64()
            .unwrap();
        assert!(under_noisy > 0, "sigma 0.5 must under-predict sometimes");
    }

    #[test]
    fn figdrift_online_refit_beats_static_after_shift() {
        let r = fig_drift(&quick());
        assert_eq!(r.rows.len(), 4, "SCLS + 3 predictor rows");
        let arr = r.json.as_arr().unwrap();
        let cell = |label: &str| {
            arr.iter()
                .find(|o| o.get("predictor").and_then(Json::as_str) == Some(label))
                .unwrap_or_else(|| panic!("missing predictor row {label}"))
        };
        let num = |label: &str, key: &str| cell(label).get(key).unwrap().as_f64().unwrap();

        // Perfect foresight is untouched by the drift.
        assert_eq!(num("oracle", "underpredicted"), 0.0);
        assert_eq!(num("oracle", "wasted_kv_token_steps"), 0.0);
        // Only the online predictor refits; the static fit stays frozen.
        assert!(num("online:512", "predictor_refits") > 0.0, "online must refit");
        assert_eq!(num("bucket(static)", "predictor_refits"), 0.0);
        // The headline: after the shift the static fit keeps predicting
        // its stale quantiles — the whole drifted upper half of the range
        // sits in one coarse pre-drift bucket, so stale predictions land
        // rungs away from the truth in both directions — while the refit
        // walks the edges to the new distribution within a window. Both
        // mispredict measures must drop.
        let wasted_static = num("bucket(static)", "wasted_kv_token_steps");
        let wasted_online = num("online:512", "wasted_kv_token_steps");
        assert!(
            wasted_online < wasted_static,
            "online wasted {wasted_online} !< static wasted {wasted_static}"
        );
        let under_static = num("bucket(static)", "underpredicted");
        let under_online = num("online:512", "underpredicted");
        assert!(
            under_online < under_static,
            "online underpredictions {under_online} !< static {under_static}"
        );
        // And adapting must not cost throughput (allow simulation noise).
        let t_static = num("bucket(static)", "throughput");
        let t_online = num("online:512", "throughput");
        assert!(
            t_online >= t_static * 0.95,
            "online thpt {t_online} collapsed vs static {t_static}"
        );
    }

    #[test]
    fn figfault_every_scenario_completes_everything() {
        let r = fig_fault(&quick());
        assert_eq!(r.rows.len(), 25, "5 policies x 5 scenarios");
        let arr = r.json.as_arr().unwrap();
        let cell = |which: &str, scen: &str| {
            arr.iter()
                .find(|o| {
                    o.get("scheduler").and_then(Json::as_str) == Some(which)
                        && o.get("scenario").and_then(Json::as_str) == Some(scen)
                })
                .unwrap_or_else(|| panic!("missing cell {which}/{scen}"))
        };
        let num = |which: &str, scen: &str, key: &str| {
            cell(which, scen).get(key).unwrap().as_i64().unwrap()
        };
        for which in ["SCLS", "ILS", "P-SCLS", "SCLS-CB", "P-CB"] {
            // The no-fault baseline completes the whole trace and touches
            // no fleet counter.
            let base = num(which, "none", "completed");
            assert!(base > 0);
            for key in [
                "worker_crashes",
                "coordinator_crashes",
                "reclaimed_requests",
                "lost_slices",
                "migrations",
                "kv_tokens_migrated",
            ] {
                assert_eq!(num(which, "none", key), 0, "{which} none {key}");
            }
            for scen in ["rolling", "correlated", "coord", "mtbf"] {
                // The headline invariant: churn costs work, never requests.
                assert_eq!(
                    num(which, scen, "completed"),
                    base,
                    "{which} lost requests under {scen}"
                );
                // Per-crash loss is bounded by the interrupted slice: only
                // in-flight reclaims count as lost.
                assert!(
                    num(which, scen, "reclaimed_requests") >= num(which, scen, "lost_slices"),
                    "{which}/{scen} counter identity"
                );
                // KV-transfer accounting: pricing is on, so every
                // migration moved tokens.
                if num(which, scen, "migrations") > 0 {
                    assert!(
                        num(which, scen, "kv_tokens_migrated") > 0,
                        "{which}/{scen} migrated without moving KV"
                    );
                }
            }
            assert_eq!(
                num(which, "correlated", "worker_crashes"),
                4,
                "{which} must see the half-fleet crash"
            );
            assert_eq!(num(which, "rolling", "worker_crashes"), 0);
            // The coord scenario's crash is observed by every policy
            // (worker-locus recovery is a no-op, but the event counts).
            assert_eq!(num(which, "coord", "coordinator_crashes"), 1, "{which}");
            assert_eq!(num(which, "coord", "worker_crashes"), 1, "{which}");
            assert!(
                num(which, "mtbf", "worker_crashes") > 0,
                "{which} mtbf plan must generate crashes"
            );
        }
    }

    #[test]
    fn figslo_cells_cover_policies_and_bound_attainment() {
        let r = fig_slo(&quick(), &[10.0, 30.0]);
        assert_eq!(r.rows.len(), 12, "6 policies x 2 rates");
        for o in r.json.as_arr().unwrap() {
            let which = o.get("scheduler").and_then(Json::as_str).unwrap();
            let a = o.get("slo_attainment").unwrap().as_f64().unwrap();
            assert!((0.0..=1.0).contains(&a), "{which} attainment {a}");
            let tracked = o.get("slo_tracked").unwrap().as_i64().unwrap();
            assert!(tracked > 0, "{which} tracked no SLOs");
            assert!(o.get("completed").unwrap().as_i64().unwrap() > 0);
            assert!(o.get("ttft_p99").unwrap().as_f64().unwrap() >= 0.0);
            // Only the deadline-aware admission sheds; every other policy
            // serves the whole trace.
            if which != "D-SCLS" {
                let shed = o.get("shed_requests").unwrap().as_i64().unwrap();
                assert_eq!(shed, 0, "{which} must not shed");
            }
        }
    }

    #[test]
    fn figobs_indices_bounded_and_series_cover_fleet() {
        let fc = quick();
        let r = figobs(&fc);
        assert_eq!(r.rows.len(), 4, "SLS / ILS / SCLS / SCLS-CB");
        for o in r.json.as_arr().unwrap() {
            let which = o.get("scheduler").and_then(Json::as_str).unwrap();
            assert!(o.get("throughput").unwrap().as_f64().unwrap() > 0.0);
            let series = o.get("series").unwrap();
            let rep = series.get("served_imbalance").unwrap();
            let per_worker = rep.get("per_worker").unwrap().as_arr().unwrap();
            let n = per_worker.len();
            assert!(
                (1..=fc.workers).contains(&n),
                "{which}: {n} worker series for a {}-worker fleet",
                fc.workers
            );
            let jains = rep.get("jains").unwrap().as_f64().unwrap();
            let lo = 1.0 / fc.workers as f64 - 1e-9;
            assert!((lo..=1.0 + 1e-9).contains(&jains), "{which} Jain {jains}");
            assert!(rep.get("max_over_mean").unwrap().as_f64().unwrap() >= 1.0 - 1e-9);
            assert!(rep.get("cv").unwrap().as_f64().unwrap() >= 0.0);
            let total: f64 = per_worker.iter().map(|x| x.as_f64().unwrap()).sum();
            assert!(total > 0.0, "{which} served no tokens");
            // A 30-second rate-20 trace keeps the whole 8-worker fleet
            // busy under every static sliced family.
            if which == "SLS" || which == "SCLS" {
                assert_eq!(n, fc.workers, "{which} left workers idle");
            }
        }
    }

    #[test]
    fn fig17_reports_imbalance_alongside_ct_std() {
        let r = fig17(&quick(), &[20.0]);
        assert_eq!(r.rows.len(), 5, "5 cells at one rate");
        assert_eq!(r.header.len(), r.rows[0].len());
        for o in r.json.as_arr().unwrap() {
            let rep = o.get("served_imbalance").unwrap();
            let jains = rep.get("jains").unwrap().as_f64().unwrap();
            assert!(jains > 0.0 && jains <= 1.0 + 1e-9);
            assert!(o.get("ct_std").unwrap().as_f64().unwrap() >= 0.0);
        }
    }

    #[test]
    fn parallel_jobs_output_byte_identical() {
        // The acceptance bar for `--jobs N`: tables and JSON must match the
        // sequential run byte for byte.
        let seq = quick();
        let par = FigureConfig { jobs: 4, ..quick() };
        for (a, b) in [
            (fig05(&seq), fig05(&par)),
            (
                fig18_21(&seq, EngineKind::Ds, &[64, 128]),
                fig18_21(&par, EngineKind::Ds, &[64, 128]),
            ),
        ] {
            assert_eq!(a.rows, b.rows);
            assert_eq!(a.json.to_string_pretty(), b.json.to_string_pretty());
        }
    }
}
