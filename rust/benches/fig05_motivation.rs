//! Fig. 5 — motivation experiment: throughput, invalid tokens, batch size,
//! pad tokens and completion-time STD for SLS vs ILS vs SCLS on DS at
//! rate 20. Prints the reproduced table, then times one cell run.

use scls::bench::figures::{fig05, run_cell, FigureConfig};
use scls::bench::harness::{bench, report_header};
use scls::engine::presets::EngineKind;

fn main() {
    let fc = FigureConfig::quick(0.1);
    fig05(&fc).print();

    println!("{}", report_header());
    let small = FigureConfig::quick(0.05);
    for which in ["SLS", "ILS", "SCLS"] {
        let r = bench(&format!("fig05 cell DS-{which} (30 s trace)"), || {
            run_cell(&small, EngineKind::Ds, which, 20.0, small.slice_len)
        });
        println!("{}", r.report());
    }
}
