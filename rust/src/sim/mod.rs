//! Discrete-event simulation substrate: the generic policy-driven loop
//! ([`driver::run_policy`]), the built-in policies, the deterministic
//! event queue, deterministic fault schedules ([`faults::FaultPlan`]),
//! and the frozen pre-trait reference drivers.

pub mod driver;
pub mod events;
pub mod faults;
pub mod policies;
pub mod reference;
pub mod slo_policies;

pub use driver::{ClusterBuilder, SimConfig, Simulation};
pub use events::EventQueue;
pub use faults::{FaultEvent, FaultKind, FaultPlan};
