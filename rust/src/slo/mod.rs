//! Service-level objectives and multi-tenancy: per-request SLO targets
//! (TTFT / TPOT / completion deadline), tenant profiles, and the
//! attainment accounting the goodput metrics are built on.
//!
//! The paper buys *predictable* serving time per batch by slicing; this
//! module spends that predictability on deadlines. A [`SloSpec`] rides on
//! every [`Request`] (`SloSpec::none()` by default, so SLO-free traces
//! behave — and serialize — exactly as before). [`TenantMix`] describes a
//! weighted tenant population, [`stamp_trace`] samples per-request
//! tenant / priority / SLO assignments deterministically from a seed, and
//! [`SloTracker`] folds per-completion [`SloOutcome`]s into the
//! goodput/attainment counters surfaced by `RunMetrics`.
//!
//! **TTFT measurement caveat:** static-batching engines deliver all of a
//! slice's tokens at the slice boundary, so the first-token timestamp is
//! the end of the request's first scheduled slice. The continuous-batching
//! engines stamp `Request::first_token_at` at the end of the iteration
//! that decodes the request's first token. Any policy that never stamps
//! it falls back to `finished_at` as the first-token time — a
//! conservative over-estimate that can only *miss* a TTFT target, never
//! falsely attain it.

use std::collections::BTreeMap;

use crate::core::Request;
use crate::telemetry::StreamingHist;
use crate::util::rng::Rng;
use crate::workload::Trace;

/// Per-request service-level objective: any subset of a time-to-first-token
/// bound, a time-per-output-token bound, and a completion deadline (all in
/// seconds, measured from arrival; TPOT is per decoded token). `None`
/// fields are unconstrained; an all-`None` spec is SLO-free and keeps the
/// request invisible to every attainment counter.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SloSpec {
    /// Time-to-first-token bound (seconds from arrival).
    pub ttft: Option<f64>,
    /// Time-per-output-token bound (seconds per decoded token).
    pub tpot: Option<f64>,
    /// End-to-end completion deadline (seconds from arrival).
    pub deadline: Option<f64>,
}

impl SloSpec {
    /// The SLO-free spec every request starts with.
    pub fn none() -> SloSpec {
        SloSpec::default()
    }

    /// True when no target is set (the request is untracked).
    pub fn is_none(&self) -> bool {
        self.ttft.is_none() && self.tpot.is_none() && self.deadline.is_none()
    }

    /// Parse the `--slo` grammar: a comma list of `ttft:SECS`, `tpot:SECS`,
    /// `deadline:SECS`, each key at most once, every value finite and
    /// positive. `"none"` (or the empty string) is the SLO-free spec.
    pub fn parse(s: &str) -> Result<SloSpec, String> {
        let s = s.trim();
        if s.is_empty() || s.eq_ignore_ascii_case("none") {
            return Ok(SloSpec::none());
        }
        let mut spec = SloSpec::none();
        for part in s.split(',') {
            let part = part.trim();
            let (key, val) = part.split_once(':').ok_or_else(|| {
                format!("bad --slo clause '{part}': expected ttft:SECS, tpot:SECS, or deadline:SECS")
            })?;
            let secs: f64 = val
                .trim()
                .parse()
                .map_err(|_| format!("bad --slo value in '{part}': '{}' is not a number", val.trim()))?;
            if !secs.is_finite() || secs <= 0.0 {
                return Err(format!(
                    "bad --slo value in '{part}': must be finite and positive (got {secs})"
                ));
            }
            let slot = match key.trim().to_ascii_lowercase().as_str() {
                "ttft" => &mut spec.ttft,
                "tpot" => &mut spec.tpot,
                "deadline" => &mut spec.deadline,
                other => {
                    return Err(format!(
                        "unknown --slo key '{other}': valid keys are ttft, tpot, deadline"
                    ))
                }
            };
            if slot.replace(secs).is_some() {
                return Err(format!("duplicate --slo key '{}'", key.trim()));
            }
        }
        Ok(spec)
    }

    /// Every set target multiplied by `factor` (per-tenant tier scaling).
    pub fn scaled(&self, factor: f64) -> SloSpec {
        SloSpec {
            ttft: self.ttft.map(|t| t * factor),
            tpot: self.tpot.map(|t| t * factor),
            deadline: self.deadline.map(|d| d * factor),
        }
    }

    /// Judge a completed request against this spec at `finished_at`.
    ///
    /// TTFT uses `Request::first_token_at` when a policy stamped it, else
    /// falls back to `finished_at` (see the module docs); TPOT spreads the
    /// post-first-token span over the decoded tokens and is trivially
    /// attained when at most one token was generated.
    pub fn evaluate(&self, req: &Request, finished_at: f64) -> SloOutcome {
        let first = req.first_token_at.unwrap_or(finished_at);
        let ttft = (first - req.arrival).max(0.0);
        let decode_tokens = req.generated.saturating_sub(1);
        let tpot = if decode_tokens == 0 {
            0.0
        } else {
            ((finished_at - first).max(0.0)) / decode_tokens as f64
        };
        let ttft_ok = self.ttft.is_none_or(|t| ttft <= t);
        let tpot_ok = self.tpot.is_none_or(|t| tpot <= t);
        let deadline_ok = self.deadline.is_none_or(|d| finished_at - req.arrival <= d);
        SloOutcome {
            tenant: req.tenant,
            ttft,
            tpot,
            ttft_ok,
            tpot_ok,
            deadline_ok,
            attained: ttft_ok && tpot_ok && deadline_ok,
        }
    }
}

/// The judged result of one SLO-tracked completion (streamed through
/// `MetricsSink::on_slo` and folded into [`SloTracker`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloOutcome {
    pub tenant: u32,
    /// Measured time to first token (seconds).
    pub ttft: f64,
    /// Measured time per output token (seconds; 0 when ≤ 1 token).
    pub tpot: f64,
    pub ttft_ok: bool,
    pub tpot_ok: bool,
    pub deadline_ok: bool,
    /// All set targets met.
    pub attained: bool,
}

/// A weighted tenant population: `weights[t]` is tenant `t`'s arrival
/// share (unnormalized) — also the default service weight for the
/// coordinator's weighted-fairness path.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantMix {
    pub weights: Vec<f64>,
}

impl TenantMix {
    /// `n` equally weighted tenants.
    pub fn uniform(n: usize) -> TenantMix {
        assert!(n > 0);
        TenantMix {
            weights: vec![1.0; n],
        }
    }

    /// Parse the `--tenants` grammar: `N` (uniform) or `N:w1,...,wN`
    /// (explicit positive finite weights, one per tenant).
    pub fn parse(s: &str) -> Result<TenantMix, String> {
        let s = s.trim();
        let (count, weights) = match s.split_once(':') {
            None => (s, None),
            Some((c, w)) => (c.trim(), Some(w)),
        };
        let n: usize = count
            .parse()
            .map_err(|_| format!("bad --tenants count '{count}': expected a positive integer"))?;
        if n == 0 {
            return Err("--tenants needs at least 1 tenant".into());
        }
        let Some(wspec) = weights else {
            return Ok(TenantMix::uniform(n));
        };
        let ws: Vec<f64> = wspec
            .split(',')
            .map(|w| {
                let w = w.trim();
                w.parse::<f64>()
                    .map_err(|_| format!("bad --tenants weight '{w}': not a number"))
                    .and_then(|x| {
                        if x.is_finite() && x > 0.0 {
                            Ok(x)
                        } else {
                            Err(format!(
                                "bad --tenants weight '{w}': must be finite and positive"
                            ))
                        }
                    })
            })
            .collect::<Result<_, _>>()?;
        if ws.len() != n {
            return Err(format!(
                "--tenants {n} declares {n} tenants but lists {} weights",
                ws.len()
            ));
        }
        Ok(TenantMix { weights: ws })
    }

    pub fn tenants(&self) -> usize {
        self.weights.len()
    }
}

/// Per-tenant SLO tier scale: tenant 0 is the premium (tightest) tier;
/// each subsequent tenant's targets relax by 50%.
fn tenant_tier(tenant: u32) -> f64 {
    1.0 + 0.5 * tenant as f64
}

/// Stamp every request of `trace` with a tenant, a priority class, and a
/// per-tenant-scaled SLO, deterministically from `seed` (each request gets
/// its own splitmix-decorrelated stream, so stamping is order-independent
/// and stable under trace slicing). Tenant = weighted draw from `mix`;
/// priority mirrors the tenant class (0 = most urgent); SLO targets are
/// `base` scaled by the tenant tier, with ±10% per-request jitter on the
/// deadline so deadline ties don't collapse into one urgency class.
pub fn stamp_trace(trace: &mut Trace, mix: &TenantMix, base: &SloSpec, seed: u64) {
    for r in &mut trace.requests {
        let mut rng = Rng::new(seed ^ r.id.wrapping_mul(0x9E3779B97F4A7C15));
        let tenant = rng.weighted_index(&mix.weights) as u32;
        r.tenant = tenant;
        r.priority = tenant.min(u8::MAX as u32) as u8;
        let mut slo = base.scaled(tenant_tier(tenant));
        slo.deadline = slo.deadline.map(|d| d * (0.9 + 0.2 * rng.f64()));
        r.slo = slo;
    }
}

/// Per-tenant slice of the attainment counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TenantSlo {
    pub tracked: u64,
    pub attained: u64,
    pub ttft_misses: u64,
    pub tpot_misses: u64,
    pub deadline_misses: u64,
    /// Requests shed before service (counted as tracked-but-missed).
    pub shed: u64,
}

/// Run-level SLO accounting: every SLO-carrying completion or shed is
/// folded in; SLO-free requests never touch it (so SLO-free runs report
/// all-zero counters and stay byte-identical to the pre-SLO world).
#[derive(Debug, Clone, Default)]
pub struct SloTracker {
    /// SLO-carrying requests judged (completions + sheds).
    pub tracked: u64,
    /// Tracked requests that met every set target.
    pub attained: u64,
    pub ttft_misses: u64,
    pub tpot_misses: u64,
    pub deadline_misses: u64,
    /// SLO-carrying requests shed before service.
    pub shed: u64,
    /// Streaming sketch of measured TTFT across tracked completions
    /// (≤ 1% relative quantile error, O(1) memory per sample — the run
    /// never retains per-sample vectors).
    pub ttft_hist: StreamingHist,
    /// Streaming sketch of measured TPOT across tracked completions.
    pub tpot_hist: StreamingHist,
    pub per_tenant: BTreeMap<u32, TenantSlo>,
}

impl SloTracker {
    /// Fold one judged completion in.
    pub fn observe(&mut self, o: &SloOutcome) {
        self.tracked += 1;
        self.ttft_hist.add(o.ttft);
        self.tpot_hist.add(o.tpot);
        let t = self.per_tenant.entry(o.tenant).or_default();
        t.tracked += 1;
        if o.attained {
            self.attained += 1;
            t.attained += 1;
        }
        if !o.ttft_ok {
            self.ttft_misses += 1;
            t.ttft_misses += 1;
        }
        if !o.tpot_ok {
            self.tpot_misses += 1;
            t.tpot_misses += 1;
        }
        if !o.deadline_ok {
            self.deadline_misses += 1;
            t.deadline_misses += 1;
        }
    }

    /// An SLO-carrying request was shed before service: tracked, not
    /// attained, and its deadline counts as missed — shedding must lower
    /// goodput honestly, not hide the miss.
    pub fn observe_shed(&mut self, tenant: u32) {
        self.tracked += 1;
        self.shed += 1;
        self.deadline_misses += 1;
        let t = self.per_tenant.entry(tenant).or_default();
        t.tracked += 1;
        t.shed += 1;
        t.deadline_misses += 1;
    }

    /// Fraction of tracked requests that attained (1.0 when none tracked).
    pub fn attainment(&self) -> f64 {
        if self.tracked == 0 {
            1.0
        } else {
            self.attained as f64 / self.tracked as f64
        }
    }

    /// P99 of measured TTFT across tracked completions (0 when none),
    /// answered by the streaming sketch within its ≤ 1% relative bound.
    pub fn ttft_p99(&self) -> f64 {
        self.ttft_hist.percentile(99.0)
    }

    pub fn is_empty(&self) -> bool {
        self.tracked == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::distributions::WorkloadKind;
    use crate::workload::TraceConfig;

    #[test]
    fn parse_slo_grammar() {
        assert_eq!(SloSpec::parse("").unwrap(), SloSpec::none());
        assert_eq!(SloSpec::parse("none").unwrap(), SloSpec::none());
        let s = SloSpec::parse("ttft:2,deadline:120").unwrap();
        assert_eq!(s.ttft, Some(2.0));
        assert_eq!(s.tpot, None);
        assert_eq!(s.deadline, Some(120.0));
        let s = SloSpec::parse(" TPOT:0.5 , ttft:1.5 ").unwrap();
        assert_eq!(s.tpot, Some(0.5));
        assert_eq!(s.ttft, Some(1.5));
    }

    #[test]
    fn parse_slo_rejects_garbage() {
        for bad in [
            "ttft",
            "ttft:abc",
            "ttft:-1",
            "ttft:inf",
            "ttft:NaN",
            "latency:3",
            "ttft:1,ttft:2",
            "deadline:0",
        ] {
            let e = SloSpec::parse(bad).unwrap_err();
            assert!(!e.contains('\n'), "multi-line error for {bad:?}: {e}");
        }
        assert!(SloSpec::parse("ttft:1,ttft:2")
            .unwrap_err()
            .contains("duplicate"));
        assert!(SloSpec::parse("latency:3").unwrap_err().contains("valid keys"));
    }

    #[test]
    fn parse_tenants_grammar() {
        assert_eq!(TenantMix::parse("4").unwrap(), TenantMix::uniform(4));
        let m = TenantMix::parse("3:5,3,1").unwrap();
        assert_eq!(m.weights, vec![5.0, 3.0, 1.0]);
        for bad in ["0", "-1", "x", "2:1", "2:1,2,3", "2:1,-2", "2:1,inf", ""] {
            let e = TenantMix::parse(bad).unwrap_err();
            assert!(!e.contains('\n'), "multi-line error for {bad:?}: {e}");
        }
    }

    #[test]
    fn evaluate_judges_each_axis() {
        let spec = SloSpec {
            ttft: Some(1.0),
            tpot: Some(0.1),
            deadline: Some(10.0),
        };
        let mut r = Request::new(1, 100.0, 32, 64);
        r.generated = 11;
        r.first_token_at = Some(100.5);
        // 0.5s TTFT, 10 decode tokens over 0.5s = 0.05 TPOT, 1s total.
        let o = spec.evaluate(&r, 101.0);
        assert!(o.ttft_ok && o.tpot_ok && o.deadline_ok && o.attained);
        assert!((o.ttft - 0.5).abs() < 1e-12);
        assert!((o.tpot - 0.05).abs() < 1e-12);
        // Blow the deadline only.
        let o = spec.evaluate(&r, 111.0);
        assert!(o.ttft_ok && !o.deadline_ok && !o.attained);
        // Unstamped first token falls back to finished_at: TTFT == latency.
        r.first_token_at = None;
        let o = spec.evaluate(&r, 100.8);
        assert!((o.ttft - 0.8).abs() < 1e-12);
        assert_eq!(o.tpot, 0.0, "no post-first-token span to spread");
        // ≤ 1 generated token attains TPOT trivially.
        r.generated = 1;
        r.first_token_at = Some(100.2);
        assert!(spec.evaluate(&r, 100.2).tpot_ok);
    }

    #[test]
    fn slo_free_spec_is_always_attained() {
        let mut r = Request::new(1, 0.0, 32, 64);
        r.generated = 5;
        let o = SloSpec::none().evaluate(&r, 1e9);
        assert!(o.attained);
        assert!(SloSpec::none().is_none());
    }

    #[test]
    fn stamp_trace_is_deterministic_and_tier_scaled() {
        let cfg = TraceConfig {
            kind: WorkloadKind::CodeFuse,
            rate: 10.0,
            duration: 30.0,
            max_input_len: 512,
            max_gen_len: 512,
            seed: 42,
        };
        let mix = TenantMix::parse("3:4,2,1").unwrap();
        let base = SloSpec::parse("ttft:2,tpot:0.2,deadline:60").unwrap();
        let mut a = crate::workload::Trace::generate(&cfg);
        let mut b = crate::workload::Trace::generate(&cfg);
        stamp_trace(&mut a, &mix, &base, 7);
        stamp_trace(&mut b, &mix, &base, 7);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.slo, y.slo);
        }
        let mut seen = std::collections::BTreeSet::new();
        for r in &a.requests {
            assert!(r.tenant < 3);
            assert_eq!(r.priority as u32, r.tenant);
            seen.insert(r.tenant);
            let tier = tenant_tier(r.tenant);
            assert_eq!(r.slo.ttft, Some(2.0 * tier), "ttft is tier-exact");
            assert_eq!(r.slo.tpot, Some(0.2 * tier));
            let d = r.slo.deadline.unwrap();
            assert!(
                d >= 60.0 * tier * 0.9 - 1e-9 && d <= 60.0 * tier * 1.1 + 1e-9,
                "deadline jitter out of band: {d}"
            );
        }
        assert_eq!(seen.len(), 3, "every tenant appears at this volume");
        // A different seed reshuffles tenant assignments.
        let mut c = crate::workload::Trace::generate(&cfg);
        stamp_trace(&mut c, &mix, &base, 8);
        assert!(a
            .requests
            .iter()
            .zip(&c.requests)
            .any(|(x, y)| x.tenant != y.tenant));
    }

    #[test]
    fn tracker_counts_and_percentiles() {
        let mut t = SloTracker::default();
        assert_eq!(t.attainment(), 1.0);
        assert_eq!(t.ttft_p99(), 0.0);
        let spec = SloSpec {
            ttft: Some(1.0),
            tpot: None,
            deadline: Some(5.0),
        };
        let mut fast = Request::new(1, 0.0, 8, 8);
        fast.generated = 4;
        fast.first_token_at = Some(0.5);
        fast.tenant = 0;
        t.observe(&spec.evaluate(&fast, 2.0));
        let mut slow = Request::new(2, 0.0, 8, 8);
        slow.generated = 4;
        slow.first_token_at = Some(3.0);
        slow.tenant = 1;
        t.observe(&spec.evaluate(&slow, 9.0));
        t.observe_shed(1);
        assert_eq!(t.tracked, 3);
        assert_eq!(t.attained, 1);
        assert_eq!(t.ttft_misses, 1);
        assert_eq!(t.deadline_misses, 2, "miss + shed");
        assert_eq!(t.shed, 1);
        assert!((t.attainment() - 1.0 / 3.0).abs() < 1e-12);
        // The sketch answers within its ≤ 1% relative bound of the exact
        // nearest-rank p99 (= 3.0 here).
        assert!(t.ttft_p99() > 0.5 && t.ttft_p99() <= 3.0 * 1.02);
        assert_eq!(t.ttft_hist.count(), 2, "sheds never enter the sketch");
        assert_eq!(t.tpot_hist.count(), 2);
        assert_eq!(t.per_tenant.len(), 2);
        assert_eq!(t.per_tenant[&0].attained, 1);
        assert_eq!(t.per_tenant[&1].shed, 1);
        assert_eq!(t.per_tenant[&1].tracked, 2);
    }
}
