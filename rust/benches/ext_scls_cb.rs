//! §7 extension — SCLS over continuous batching ("we are working on
//! implementing SCLS on top of vllm to integrate with continuous
//! batching"). Compares DS-ILS (conservative cap, round-robin) against
//! SCLS-CB (slice-capped schedules, precise per-slice memory admission,
//! memory-balanced offloading) and the static-batching SCLS, across
//! arrival rates, then times the extension's DES cost and a slice-length
//! sensitivity row.

use scls::bench::figures::{run_cell, FigureConfig};
use scls::bench::harness::{bench, report_header};
use scls::engine::presets::EngineKind;

fn main() {
    let fc = FigureConfig::quick(0.1);
    println!("== ext — §7: SCLS × continuous batching (DS, 8 workers)");
    println!(
        "   {:<8} {:>5} {:>9} {:>9} {:>9} {:>8}",
        "cell", "rate", "thpt", "avgRT", "p95RT", "CTstd"
    );
    for &rate in &[12.0, 20.0, 28.0] {
        for which in ["ILS", "SCLS", "SCLS-CB"] {
            let s = run_cell(&fc, EngineKind::Ds, which, rate, fc.slice_len);
            println!(
                "   {:<8} {:>5.0} {:>9.2} {:>9.1} {:>9.1} {:>8.1}",
                which, rate, s.throughput, s.avg_response_time, s.p95_response_time, s.ct_std
            );
        }
    }
    println!();

    println!("== ext — SCLS-CB slice-length sensitivity (rate 20)");
    for s_len in [32u32, 128, 512] {
        let s = run_cell(&fc, EngineKind::Ds, "SCLS-CB", 20.0, s_len);
        println!(
            "   S={s_len:<4} thpt {:>6.2}  avgRT {:>7.1}  slices[1,2,3,4+] {:?}",
            s.throughput, s.avg_response_time, s.slice_histogram
        );
    }
    println!();

    println!("{}", report_header());
    let small = FigureConfig::quick(0.05);
    for which in ["ILS", "SCLS-CB"] {
        let r = bench(&format!("cell DS-{which} @ rate 20 (30 s trace)"), || {
            run_cell(&small, EngineKind::Ds, which, 20.0, small.slice_len)
        });
        println!("{}", r.report());
    }
}
