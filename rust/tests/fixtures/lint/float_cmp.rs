// Lint fixture (never compiled): float-cmp positives and suppressions.
// Scanned under "src/estimator/fixture.rs" (deterministic, checked) and
// "src/util/fixture.rs" (unchecked) by tests/props_lint.rs.

fn positives(x: f64, v: &mut [f64]) {
    if x == 0.0 {} // line 6: finding (float literal on the right)
    if 1.5 != x {} // line 7: finding (float literal on the left)
    v.sort_by(|a, b| a.partial_cmp(b).unwrap()); // line 8: finding
}

fn suppressed(x: f64) {
    if x == 0.0 {} // scls-lint: allow(float-cmp): exact zero sentinel
}

fn never_fire(x: f64, n: u32, v: &mut [f64]) {
    if n == 0 {} // integer comparison: no finding
    if x <= 1.0 {} // ordering operators are not equality: no finding
    v.sort_by(|a, b| a.total_cmp(b)); // the sanctioned comparator
    let r = 1..5; // range dots must not turn 1 into a float
    drop(r);
}

impl PartialOrd for Thing {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        // the `fn partial_cmp` definition itself is not a call site
        None
    }
}
