//! Offloading policies: the paper's max-min load balancer (§4.5) and the
//! round-robin baseline used by SLS/ILS and the SO/PM/AB ablations.

pub mod maxmin;
pub mod roundrobin;

pub use maxmin::MaxMinOffloader;
pub use roundrobin::RoundRobin;

/// A worker-load ledger shared by offloaders and the scheduler (Eq. 11):
/// the load of a worker is the estimated time to serve everything in its
/// local queue (plus the batch it is currently serving).
#[derive(Debug, Clone)]
pub struct LoadLedger {
    loads: Vec<f64>,
}

impl LoadLedger {
    pub fn new(workers: usize) -> LoadLedger {
        LoadLedger {
            loads: vec![0.0; workers],
        }
    }

    pub fn workers(&self) -> usize {
        self.loads.len()
    }

    pub fn load(&self, w: usize) -> f64 {
        self.loads[w]
    }

    /// Eq. (11): add an offloaded batch's estimated time.
    pub fn add(&mut self, w: usize, est: f64) {
        self.loads[w] += est;
    }

    /// §4.5: after a worker finishes a batch, subtract its estimate so
    /// estimation error does not accumulate in the ledger.
    pub fn complete(&mut self, w: usize, est: f64) {
        self.loads[w] = (self.loads[w] - est).max(0.0);
    }

    /// Index of the least-loaded worker (ties → lowest index).
    pub fn argmin(&self) -> usize {
        let mut best = 0;
        for (i, &l) in self.loads.iter().enumerate() {
            if l < self.loads[best] {
                best = i;
            }
        }
        best
    }

    pub fn min(&self) -> f64 {
        self.loads.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.loads.iter().cloned().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_add_complete() {
        let mut l = LoadLedger::new(3);
        l.add(0, 5.0);
        l.add(1, 2.0);
        assert_eq!(l.argmin(), 2);
        l.add(2, 10.0);
        assert_eq!(l.argmin(), 1);
        l.complete(2, 10.0);
        assert_eq!(l.load(2), 0.0);
    }

    #[test]
    fn complete_clamps_at_zero() {
        let mut l = LoadLedger::new(1);
        l.add(0, 1.0);
        l.complete(0, 5.0); // over-subtraction from estimation error
        assert_eq!(l.load(0), 0.0);
    }

    #[test]
    fn min_max() {
        let mut l = LoadLedger::new(2);
        l.add(0, 3.0);
        assert_eq!(l.min(), 0.0);
        assert_eq!(l.max(), 3.0);
    }
}
