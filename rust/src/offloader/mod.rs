//! Offloading policies: the paper's max-min load balancer (§4.5) and the
//! round-robin baseline used by SLS/ILS and the SO/PM/AB ablations.

pub mod maxmin;
pub mod roundrobin;

pub use maxmin::MaxMinOffloader;
pub use roundrobin::RoundRobin;

/// A worker-load ledger shared by offloaders and the scheduler (Eq. 11):
/// the load of a worker is the estimated time to serve everything in its
/// local queue (plus the batch it is currently serving).
///
/// The ledger also tracks a per-worker *accepting* flag for the elastic
/// fleet: dead and draining workers are masked out of `argmin`/`min`/`max`
/// so offloading only targets workers that may take new work. A ledger
/// with every worker accepting (the fixed-fleet world) behaves exactly as
/// it did before the mask existed.
#[derive(Debug, Clone)]
pub struct LoadLedger {
    loads: Vec<f64>,
    accepting: Vec<bool>,
}

impl LoadLedger {
    pub fn new(workers: usize) -> LoadLedger {
        LoadLedger {
            loads: vec![0.0; workers],
            accepting: vec![true; workers],
        }
    }

    pub fn workers(&self) -> usize {
        self.loads.len()
    }

    /// Register a cold joiner (zero load, accepting); returns its index.
    pub fn add_worker(&mut self) -> usize {
        self.loads.push(0.0);
        self.accepting.push(true);
        self.loads.len() - 1
    }

    /// Mark `w` as accepting new work (true) or masked out (false).
    pub fn set_accepting(&mut self, w: usize, on: bool) {
        self.accepting[w] = on;
    }

    pub fn is_accepting(&self, w: usize) -> bool {
        self.accepting[w]
    }

    pub fn accepting_count(&self) -> usize {
        self.accepting.iter().filter(|a| **a).count()
    }

    pub fn load(&self, w: usize) -> f64 {
        self.loads[w]
    }

    /// Eq. (11): add an offloaded batch's estimated time.
    pub fn add(&mut self, w: usize, est: f64) {
        self.loads[w] += est;
    }

    /// §4.5: after a worker finishes a batch, subtract its estimate so
    /// estimation error does not accumulate in the ledger.
    pub fn complete(&mut self, w: usize, est: f64) {
        self.loads[w] = (self.loads[w] - est).max(0.0);
    }

    /// Drop all load charged to `w` — the crash path releases everything a
    /// dead worker owned in one step.
    pub fn reset(&mut self, w: usize) {
        self.loads[w] = 0.0;
    }

    /// Index of the least-loaded **accepting** worker (ties → lowest
    /// index), or `None` when no worker accepts work.
    pub fn try_argmin(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, &l) in self.loads.iter().enumerate() {
            if !self.accepting[i] {
                continue;
            }
            match best {
                Some(b) if l >= self.loads[b] => {}
                _ => best = Some(i),
            }
        }
        best
    }

    /// Index of the least-loaded accepting worker (ties → lowest index).
    /// Panics if no worker accepts; callers on the elastic path should use
    /// [`Self::try_argmin`].
    pub fn argmin(&self) -> usize {
        self.try_argmin().expect("argmin on a ledger with no accepting worker")
    }

    /// Min load over accepting workers (0.0 when none accept).
    pub fn min(&self) -> f64 {
        let m = self
            .loads
            .iter()
            .zip(&self.accepting)
            .filter(|(_, a)| **a)
            .map(|(l, _)| *l)
            .fold(f64::INFINITY, f64::min);
        if m.is_finite() {
            m
        } else {
            0.0
        }
    }

    /// Max load over accepting workers (0.0 when none accept).
    pub fn max(&self) -> f64 {
        self.loads
            .iter()
            .zip(&self.accepting)
            .filter(|(_, a)| **a)
            .map(|(l, _)| *l)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_add_complete() {
        let mut l = LoadLedger::new(3);
        l.add(0, 5.0);
        l.add(1, 2.0);
        assert_eq!(l.argmin(), 2);
        l.add(2, 10.0);
        assert_eq!(l.argmin(), 1);
        l.complete(2, 10.0);
        assert_eq!(l.load(2), 0.0);
    }

    #[test]
    fn complete_clamps_at_zero() {
        let mut l = LoadLedger::new(1);
        l.add(0, 1.0);
        l.complete(0, 5.0); // over-subtraction from estimation error
        assert_eq!(l.load(0), 0.0);
    }

    #[test]
    fn min_max() {
        let mut l = LoadLedger::new(2);
        l.add(0, 3.0);
        assert_eq!(l.min(), 0.0);
        assert_eq!(l.max(), 3.0);
    }
}
