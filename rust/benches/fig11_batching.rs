//! Fig. 11 — the batching example that motivates Algorithm 1: batching 15
//! short requests with 1 long one costs far more than separating them.
//! Prints the reproduced comparison, then times Algorithm 1 on exactly the
//! paper's 16-request scenario and on larger pools.

use scls::batcher::{dp_batch, DpBatcherConfig};
use scls::bench::figures::{fig11, FigureConfig};
use scls::bench::harness::{bench, report_header};
use scls::core::Request;
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::sim::driver::fitted_estimator;

fn main() {
    fig11(&FigureConfig::default()).print();

    let preset = EnginePreset::paper(EngineKind::Hf);
    let est = fitted_estimator(&preset, 3);
    let mem = preset.memory_estimator();
    let cfg = DpBatcherConfig {
        slice_len: 128,
        max_batch_size: None,
        pred_corrected: false,
    };

    // The paper's exact scenario: 15 × len-10 + 1 × len-1024.
    let mut reqs: Vec<Request> = (0..15).map(|i| Request::new(i, 0.0, 10, 50)).collect();
    reqs.push(Request::new(15, 0.0, 1024, 50));

    let batches = dp_batch(reqs.clone(), &est, &mem, &cfg);
    println!(
        "Algorithm 1 splits the paper's scenario into {} batches: {:?}\n",
        batches.len(),
        batches
            .iter()
            .map(|b| (b.size(), b.input_len()))
            .collect::<Vec<_>>()
    );
    assert!(batches.len() >= 2, "DP must separate the long request");

    println!("{}", report_header());
    let r = bench("dp_batch(paper fig-11 scenario, 16 reqs)", || {
        dp_batch(reqs.clone(), &est, &mem, &cfg)
    });
    println!("{}", r.report());
}
