//! Serving-time-oriented batching — the paper's Algorithm 1.
//!
//! Sort requests ascending by input length; dynamic programming over
//! prefixes with state
//!
//!   T[i] = min_{0<j≤i} ( T[j−1] + T_serve(i−j+1, L_i, S) )        (10)
//!
//! where L_i is the i-th (sorted) request's input length — the batch input
//! length of any batch ending at i — and the inner loop is bounded by the
//! memory rule's maximal feasible batch at (L_i, S) (Eq. 8; feasibility is
//! monotone in batch size), making the naive DP O(n·N_max). By minimizing
//! total estimated serving time the DP trades padding waste against
//! batch-size gains (Fig. 11).
//!
//! ## Optimized plan (`dp_plan`)
//!
//! The coordinator re-runs this DP on every schedule tick, so the inner
//! minimization is the hottest loop in the system. [`dp_plan`] computes
//! **bit-identical** `T[·]`, split positions, and cuts to the retained
//! naive implementation ([`dp_plan_reference`] / [`dp_batch_reference`]),
//! but much faster:
//!
//! * **Monomorphized estimator calls** — generic over `E: ServeEstimate +
//!   ?Sized`, so concrete-estimator call sites inline the whole affine
//!   surface instead of paying a virtual call per DP cell.
//! * **Per-distinct-length caching** — sorted order puts equal `L_i` next
//!   to each other; `(N_max, serve_affine, serve_est(1,·,·))` are pure
//!   functions of `L_i`, computed once per run of equal lengths.
//! * **Certified branch-and-bound over the window** — when the estimator
//!   is affine in N at fixed `(L_i, S)` with slope `a ≥ 0` (guaranteed by
//!   `serve_affine`'s contract), the candidate for start position `j` is
//!   `c(j) = t[j−1] + A(size_j)` with `A(k) = fl(fl(a·k)+b)` and `size_j`
//!   *decreasing* in `j`. The scan starts at the largest feasible batch
//!   (where amortizing the batch-constant cost usually puts the optimum)
//!   and walks up towards smaller batches, skipping ranges `[j_a, j_hi]`
//!   wholesale via the certificate
//!
//!     c(j') ≥ t[j_a−1] + A(size_{j_hi}) + (j_hi − j_a)·min(γ, a_dn)
//!
//!   for every `j'` in the range, where γ is a rounded-down suffix
//!   minimum of the `T[·]` steps (maintained by a monotone deque over the
//!   sliding window — valid while the window's left edge only moves
//!   right, which is verified cell by cell since a user-constructed
//!   `MemoryRule::Table` may grow capacity with length) and `a_dn` is a
//!   rounded-down lower bound on the real per-size increment of `A`. The
//!   `T`-side gains at
//!   least γ per index while the serve side loses at most the increment,
//!   so the range's left end minimizes the bound; `T[·]` monotonicity is
//!   *verified* cell by cell (one comparison each), float rounding is
//!   monotone, and the computed bound subtracts 4 ulps to absorb its own
//!   roundings — making it a true lower bound *in float arithmetic*, not
//!   just in exact math.
//!
//! ## Predicted early-return correction (`pred_corrected`)
//!
//! The legacy DP budgets every batch at the full slice length S even when
//! length predictions say most members return early. With
//! `DpBatcherConfig::pred_corrected` set, a candidate batch is costed at
//! its *predicted* budget instead: `T_serve(N, L_i, S_eff)` where `S_eff`
//! is the largest predicted remaining generation among its members
//! (static batching serves until the slowest member finishes or the slice
//! cap hits, so the batch's predicted duration is its max), clamped to
//! `[1, S]`. Requests without a stamped prediction fall back to S, so the
//! correction is a no-op on prediction-free pools. Memory feasibility
//! (`N_max`) still uses the full S — an under-predicted batch can run to
//! the slice cap, so KV must be provisioned for the worst case; only the
//! *time estimate* is corrected. The corrected path is an explicit opt-in;
//! the frozen differential contract (`dp_batch_reference`,
//! `props_dp_differential.rs`) covers the default path only, which this
//! flag leaves bit-for-bit untouched.
//!
//! ### Corrected branch-and-bound (`dp_plan_corrected`)
//!
//! The corrected cost is not affine in N over the whole window — `S_eff`
//! varies as the window grows — so the legacy certificates don't apply
//! directly. But `S_eff(j) = max_{m ∈ [j, i]} predicted_iters(m)` is a
//! *running max*: monotone non-increasing in `j`, i.e. the window splits
//! into maximal segments of constant `S_eff` ("plateaus"). A sliding-
//! window max deque over the predicted iterations yields the plateaus in
//! O(1) amortized per cell (rebuilt in O(window) on the rare cells where
//! a capacity-growing `MemoryRule::Table` moves the window's left edge
//! left). *Within* a plateau the cost is affine in N again whenever
//! `serve_affine(L_i, S_eff)` applies, so each plateau runs a range
//! bisection:
//!
//! * a range `[j0, j1]` is skipped wholesale when
//!   `t[j0−1] + (a·size_{j1} + b) − σ + (j1−j0)·min(γ, a) > m` — the
//!   T-side gains at least γ per index (suffix minimum of the verified-
//!   monotone `T[·]` steps, the legacy deque) while the serve side loses
//!   at most the real slope `a` per size step, and σ =
//!   [`ServeEstimate::serve_affine_slack`] certifies the float gap
//!   between `serve_est`'s own rounding and the affine anchor (default
//!   `INFINITY` for custom estimators ⇒ no skipping, always sound);
//! * ranges that survive the bound are bisected until smaller than a
//!   chunk, then evaluated *exactly* through the bulk kernel
//!   [`ServeEstimate::serve_est_many`] (bit-identical to per-candidate
//!   `serve_est` calls by its contract, and vectorizable);
//! * plateaus whose clamp disables the affine form (or whose estimator
//!   is opaque) skip the certificates and go straight to the bulk
//!   kernel.
//!
//! Every *evaluated* candidate is the reference expression
//! `t[j−1] + serve_est(size, L_i, S_eff)` bit for bit, skipped ranges are
//! certified strictly worse than an already-seen candidate (so they can
//! neither lower the minimum nor win a tie — ties resolve to the largest
//! `j`, like the reference's descending strict `<`), and the scalar loop
//! is retained verbatim as [`dp_plan_corrected_reference`]: the corrected
//! differential suite (`props_dp_corrected_differential.rs`) proves
//! bit-exactness across ~1000 randomized pools, and a Python mirror of
//! both loops (IEEE-754 doubles, identical rounding) validated the
//! algorithm over 6000 more.
//!
//! Exactness of the result: every *evaluated* candidate uses bit-for-bit
//! the reference's expression; the minimum over the evaluated set equals
//! the minimum over all candidates (skipped ranges are certified strictly
//! worse than an already-seen candidate, so they can neither lower the
//! minimum nor win a tie); and ties resolve to the largest `j`, exactly
//! like the reference's descending scan with strict `<`. If `T[·]` is
//! ever observed non-monotone (pathological estimator), skipping is
//! disabled and the scan degenerates to the reference's full window.
//! Estimators whose `serve_affine` returns `None` (clamp could fire, or a
//! custom opaque estimator) take the reference scalar loop verbatim.
//!
//! `ServeEstimate` implementations must be pure (same inputs → same
//! outputs); the caching above relies on it, as does the paper's premise
//! that estimates are a deterministic function of `(N, L_i, S)`.

use crate::core::{Batch, Request};
use crate::estimator::serving_time::ServeEstimate;
use crate::estimator::MemoryEstimator;

/// Step a positive finite float down by `k` ulps — a cheap directed-rounding
/// lower bound (non-positive, infinite, and NaN inputs pass through, which
/// is conservative everywhere this is used).
#[inline]
fn down_ulps(x: f64, k: u64) -> f64 {
    if x > 0.0 && x.is_finite() {
        f64::from_bits(x.to_bits().saturating_sub(k))
    } else {
        x
    }
}

/// Knobs for Algorithm 1.
#[derive(Debug, Clone)]
pub struct DpBatcherConfig {
    /// Slice length S (the iteration limit per schedule).
    pub slice_len: u32,
    /// Optional hard cap on batch size (the PM ablation limits this to the
    /// engine's fixed SLS batch size; full AB/SCLS leaves it None).
    pub max_batch_size: Option<u32>,
    /// Cost batches at their predicted early-return budget instead of the
    /// full slice length (see module docs). Off by default: the legacy
    /// path stays bit-exact against `dp_batch_reference`.
    pub pred_corrected: bool,
}

/// Predicted iterations request `r` needs in its next pass under slice
/// budget `s`: predicted remaining generation (total prediction minus
/// tokens already generated), clamped to `[1, s]`. Falls back to the full
/// budget when no prediction is stamped — or when the prediction is
/// *exhausted* (`predicted_gen ≤ generated`: the request already outlived
/// it, e.g. a P-SCLS under-prediction requeued for another full pass of
/// S), because an overrun prediction says nothing about the remainder and
/// costing the next pass at ~1 iteration would poison the ledger, the LPT
/// offload, and the adaptive interval with drastic underestimates.
#[inline]
pub fn predicted_iters(r: &Request, s: u32) -> u32 {
    match r.predicted_gen {
        Some(p) if p > r.generated => (p - r.generated).min(s.max(1)),
        _ => s.max(1),
    }
}

/// Predicted serve budget of a batch under slice budget `s`: the slowest
/// member's [`predicted_iters`] (static batching runs until every member
/// is done or the cap hits).
pub fn predicted_batch_iters(members: &[Request], s: u32) -> u32 {
    members
        .iter()
        .map(|r| predicted_iters(r, s))
        .max()
        .unwrap_or_else(|| s.max(1))
}

/// Reusable workspace for [`dp_plan`] / [`dp_batch_into`]: the DP tables
/// and the resulting cuts. Holding one of these across schedule ticks
/// makes the planner allocation-free in steady state.
#[derive(Debug, Default)]
pub struct DpScratch {
    /// T[i]: minimal total serving time of the first i (sorted) requests.
    t: Vec<f64>,
    /// P[i]: split position (start index of the batch ending at i).
    p: Vec<usize>,
    /// Monotone deque over T[·] steps (index, step): ascending in both,
    /// giving O(1) sliding-window *suffix* minima for the skip certificate.
    steps: Vec<(usize, f64)>,
    /// The optimal partition as `(start, end)` half-open index pairs into
    /// the sorted request slice, in ascending order.
    cuts: Vec<(usize, usize)>,
    /// Batches of the most recent materialization costed at a predicted
    /// budget strictly below the slice cap (always 0 with the correction
    /// off).
    corrected: usize,
    /// `predicted_iters` per sorted request (corrected planner only).
    pred: Vec<u32>,
    /// Sliding-window max deque over `pred` (index, value): descending
    /// values front-to-back; entry `t` covers the constant-`S_eff` plateau
    /// `j ∈ (index_{t−1}, index_t]` of the corrected planner's window.
    smax: Vec<(usize, u32)>,
    /// Bulk-kernel output for the corrected planner's chunk evaluation.
    serve_buf: Vec<f64>,
    /// Per-distinct-length serve-by-size cache for the opaque fallback
    /// scan in `dp_plan`: `serve_by_size[k] = serve_est(k + 2, L_i, S)`
    /// at the currently cached length, extended lazily as the window
    /// grows.
    serve_by_size: Vec<f64>,
}

impl DpScratch {
    pub fn new() -> DpScratch {
        DpScratch::default()
    }

    /// The cuts produced by the most recent plan.
    pub fn cuts(&self) -> &[(usize, usize)] {
        &self.cuts
    }

    /// How many batches of the most recent `dp_batch_into` /
    /// `dp_batch_sorted_into` run were costed at a predicted budget
    /// strictly below the slice cap (0 unless `pred_corrected` is on).
    pub fn corrected_batches(&self) -> usize {
        self.corrected
    }

    /// Zero the corrected-batch count — for callers that skip the batcher
    /// on an empty tick but still want [`Self::corrected_batches`] to
    /// describe that tick rather than a stale earlier one.
    pub fn reset_corrected_batches(&mut self) {
        self.corrected = 0;
    }
}

/// Partition `requests` into batches minimizing total estimated serving
/// time. Returns batches with `est_serve_time` filled in.
///
/// Requests are consumed. Batches preserve the sorted order (each batch is
/// a contiguous run of the sorted request list).
pub fn dp_batch<E: ServeEstimate + ?Sized>(
    mut requests: Vec<Request>,
    est: &E,
    mem: &MemoryEstimator,
    cfg: &DpBatcherConfig,
) -> Vec<Batch> {
    let mut scratch = DpScratch::new();
    let mut out = Vec::new();
    dp_batch_into(&mut requests, est, mem, cfg, &mut scratch, &mut out);
    out
}

/// Allocation-lean variant of [`dp_batch`] for per-tick callers: drains
/// `requests` (leaving its capacity intact for reuse), reuses `scratch`,
/// and pushes the batches into `out` (cleared first).
pub fn dp_batch_into<E: ServeEstimate + ?Sized>(
    requests: &mut Vec<Request>,
    est: &E,
    mem: &MemoryEstimator,
    cfg: &DpBatcherConfig,
    scratch: &mut DpScratch,
    out: &mut Vec<Batch>,
) {
    out.clear();
    if requests.is_empty() {
        // Keep the scratch's public cuts() consistent with this run.
        scratch.cuts.clear();
        scratch.corrected = 0;
        return;
    }
    // Line 1: sort ascending by current input length (stable: equal-length
    // requests keep arrival order — FCFS among ties).
    requests.sort_by_key(|r| r.input_len);
    dp_plan(requests, est, mem, cfg, scratch);
    scratch.corrected = materialize_into(requests, &scratch.cuts, est, cfg, out);
}

/// [`dp_batch_into`] for callers that already hold the requests sorted
/// ascending by current input length — the incremental
/// [`crate::scheduler::RequestPool`] hands the coordinator exactly the
/// stable-sorted order `dp_batch_into`'s own sort would produce, so this
/// entry point skips the re-sort (debug-asserting the contract) and is
/// otherwise identical batch for batch, bit for bit.
pub fn dp_batch_sorted_into<E: ServeEstimate + ?Sized>(
    requests: &mut Vec<Request>,
    est: &E,
    mem: &MemoryEstimator,
    cfg: &DpBatcherConfig,
    scratch: &mut DpScratch,
    out: &mut Vec<Batch>,
) {
    out.clear();
    if requests.is_empty() {
        scratch.cuts.clear();
        scratch.corrected = 0;
        return;
    }
    debug_assert!(
        requests.windows(2).all(|w| w[0].input_len <= w[1].input_len),
        "dp_batch_sorted_into requires ascending input lengths"
    );
    dp_plan(requests, est, mem, cfg, scratch);
    scratch.corrected = materialize_into(requests, &scratch.cuts, est, cfg, out);
}

/// Run the optimized DP over an already-sorted request slice, leaving the
/// optimal cuts in `scratch` (see module docs for the exactness argument).
pub fn dp_plan<E: ServeEstimate + ?Sized>(
    sorted: &[Request],
    est: &E,
    mem: &MemoryEstimator,
    cfg: &DpBatcherConfig,
    scratch: &mut DpScratch,
) {
    debug_assert!(sorted.windows(2).all(|w| w[0].input_len <= w[1].input_len));
    if cfg.pred_corrected {
        let _t = crate::telemetry::profile::timer("dp_plan_corrected"); // scls-lint: allow(import-graph): opt-in profiling tap
        return dp_plan_corrected(sorted, est, mem, cfg, scratch);
    }
    // Opt-in hot-path profiling: one thread-local bool load when disabled.
    let _t = crate::telemetry::profile::timer("dp_plan"); // scls-lint: allow(import-graph): opt-in profiling tap
    let n = sorted.len();
    let s = cfg.slice_len;
    scratch.cuts.clear();
    if n == 0 {
        return;
    }
    scratch.t.clear();
    scratch.t.resize(n + 1, 0.0);
    scratch.p.clear();
    scratch.p.resize(n + 1, 0);
    scratch.steps.clear();
    scratch.serve_by_size.clear();
    let t = &mut scratch.t;
    let p = &mut scratch.p;
    let dq = &mut scratch.steps;
    let sbuf = &mut scratch.serve_by_size;
    let mut dq_head = 0usize;

    // Verified cell by cell; the skip certificate relies on it (see
    // module docs).
    let mut t_monotone = true;
    // The deque window only slides right when N_max is non-increasing
    // along the sorted order (true for the analytic rule and descending
    // tables, but `MemoryRule::Table` is user-constructible with growing
    // capacities). Once j_lo ever moves left, dropped deque entries
    // cannot be recovered, so skipping shuts off for good.
    let mut j_lo_monotone = true;
    let mut last_j_lo = 0usize;

    // (N_max, affine surface, singleton cost, A-increment lower bound)
    // are pure functions of L_i; sorted order makes equal lengths
    // adjacent, so cache per run.
    let mut have_cache = false;
    let mut cached_l = 0u32;
    let mut cached_n_max = 1u32;
    let mut cached_affine: Option<(f64, f64)> = None;
    let mut cached_single = 0.0f64;
    let mut cached_a_dn = 0.0f64;

    for i in 1..=n {
        let l_i = sorted[i - 1].input_len;
        if !have_cache || l_i != cached_l {
            // Feasibility is monotone in batch size (Eq. 8), so the window
            // bound is known up front: the memory rule's max batch at
            // (L_i, S) intersected with the PM cap.
            let mut n_max = mem.max_batch(l_i, s).max(1);
            if let Some(cap) = cfg.max_batch_size {
                n_max = n_max.min(cap.max(1));
            }
            cached_l = l_i;
            cached_n_max = n_max;
            sbuf.clear();
            // At fixed (L_i, S) both fitted estimators are affine in N, so
            // the candidate cost is one mul-add per step instead of a full
            // surface evaluation (None if the clamp could fire).
            cached_affine = est.serve_affine(l_i, s);
            cached_single = est.serve_est(1, l_i, s);
            cached_a_dn = 0.0;
            if let Some((a, b)) = cached_affine {
                // Conservative lower bound on the real per-size increment
                // of A(k) = fl(fl(a·k)+b): the rounding error of each A is
                // below ulp(|a|·K + |b|), so an 8·ε·magnitude slack is a
                // safe under-estimate of every real increment.
                let slack = (a.abs() * n_max as f64 + b.abs()) * (f64::EPSILON * 8.0);
                let a_dn = a - slack;
                if a_dn > 0.0 {
                    cached_a_dn = a_dn;
                }
            }
            have_cache = true;
        }
        let n_max = cached_n_max;

        // Lines 6–8: request i alone as a batch (wins ties against every
        // multi-request candidate, as in the reference's strict `<`).
        p[i] = i - 1;
        t[i] = t[i - 1] + cached_single;

        // Candidate batches end at i and start at j ∈ [j_lo, i−1]; the
        // candidate with start j has size i−j+1 ≤ N_max.
        let j_lo = if (n_max as usize) >= i {
            1
        } else {
            i + 1 - n_max as usize
        };
        if j_lo < last_j_lo {
            j_lo_monotone = false;
        }
        last_j_lo = j_lo;

        // Maintain the monotone step deque over indices [j_lo, i−1]: the
        // entry values ascend, so the suffix minimum of steps from any x
        // is the first entry with index ≥ x. The two-pointer slide is
        // valid only while j_lo is non-decreasing (j_lo_monotone above).
        // A NaN step means T[·] went through inf−inf; certificates shut
        // off for good in that case.
        if t_monotone && i >= 2 {
            let v = t[i - 1] - t[i - 2];
            if v.is_nan() {
                t_monotone = false;
            } else {
                while dq.len() > dq_head && dq[dq.len() - 1].1 >= v {
                    dq.pop();
                }
                dq.push((i - 1, v));
            }
        }
        while dq.len() > dq_head && dq[dq_head].0 < j_lo {
            dq_head += 1;
        }

        if j_lo < i {
            match cached_affine {
                Some((a, b)) => {
                    // Scan upward from the largest feasible batch (j = j_lo)
                    // towards size 2, tracking the exact running minimum
                    // (ties → larger j, like the reference's descending
                    // strict `<`). Between evaluations, try to certify and
                    // skip ranges [j, hi] wholesale: every candidate there
                    // costs at least
                    //   t[j−1] + (a·size_hi + b) + (hi−j)·min(γ, a_dn)
                    // where γ under-estimates every T-step in the range
                    // (suffix minimum from the deque, rounded down) and
                    // a_dn under-estimates every real A-increment — the
                    // T-side gains at least γ per index while the serve
                    // side loses at most the increment, so the range's
                    // left end minimizes the bound. Computed with 4 ulps
                    // of downward slack to absorb the three roundings, it
                    // is a true lower bound in float arithmetic; skipped
                    // candidates are strictly worse than an already-seen
                    // one, so they can neither lower the minimum nor win
                    // a tie (ties prefer the largest j, i.e. ranges
                    // already passed).
                    let mut m = f64::INFINITY;
                    let mut jb = 0usize;
                    let mut j = j_lo;
                    let mut next_try = j_lo + 1;
                    let mut ptr = dq_head;
                    // `serve_affine`'s contract guarantees a ≥ 0, but the
                    // certificate depends on it, so gate defensively.
                    let can_skip = t_monotone && j_lo_monotone && a >= 0.0;
                    while j < i {
                        if can_skip && m < f64::INFINITY && j >= next_try {
                            while ptr < dq.len() && dq[ptr].0 < j {
                                ptr += 1;
                            }
                            let gamma = if ptr < dq.len() {
                                down_ulps(dq[ptr].1, 2)
                            } else {
                                0.0
                            };
                            let mut coef = if gamma < cached_a_dn {
                                gamma
                            } else {
                                cached_a_dn
                            };
                            if coef < 0.0 {
                                coef = 0.0;
                            }
                            // Attempt the whole remainder, then half of it;
                            // on failure back off until the distance from
                            // j_lo doubles (keeps worst-case probes within
                            // a constant factor of the reference).
                            let hi = i - 1;
                            let extra = (hi - j) as f64 * coef;
                            let bound =
                                down_ulps(t[j - 1] + (a * ((i - hi + 1) as f64) + b) + extra, 4);
                            if bound > m {
                                break;
                            }
                            if hi > j + 1 {
                                let hi = j + (hi - j) / 2;
                                let extra = (hi - j) as f64 * coef;
                                let bound = down_ulps(
                                    t[j - 1] + (a * ((i - hi + 1) as f64) + b) + extra,
                                    4,
                                );
                                if bound > m {
                                    j = hi + 1;
                                    next_try = j;
                                    continue;
                                }
                            }
                            next_try = j + (j - j_lo).max(1);
                        }
                        let c = t[j - 1] + (a * ((i - j + 1) as f64) + b);
                        if c < m || (c == m && j > jb) {
                            m = c;
                            jb = j;
                        }
                        j += 1;
                    }
                    // Strict `<`: the singleton wins exact ties, as in the
                    // reference.
                    if m < t[i] {
                        t[i] = m;
                        p[i] = jb - 1;
                    }
                }
                None => {
                    // Opaque estimator: the reference scan (lines 9–15),
                    // but candidates come out of the per-distinct-length
                    // serve-by-size cache — at fixed (L_i, S) the cost
                    // depends only on the batch size, so each value is
                    // computed once per run of equal lengths (through the
                    // bulk kernel, extended lazily as the window grows)
                    // instead of once per DP cell. `serve_est_many` is
                    // bit-identical to per-candidate `serve_est` calls,
                    // so the plan stays bit-exact against the reference.
                    let max_size = i - j_lo + 1; // ≥ 2 since j_lo < i
                    if sbuf.len() < max_size - 1 {
                        let lo_size = sbuf.len() as u32 + 2;
                        let hi_size = max_size as u32 + 1;
                        let from = sbuf.len();
                        sbuf.resize(max_size - 1, 0.0);
                        est.serve_est_many(lo_size..hi_size, l_i, s, &mut sbuf[from..]);
                    }
                    let mut j = i - 1;
                    while j >= j_lo {
                        let size = i - j + 1;
                        let cand = t[j - 1] + sbuf[size - 2];
                        if cand < t[i] {
                            t[i] = cand;
                            p[i] = j - 1;
                        }
                        j -= 1;
                    }
                }
            }
        }
        // NaN enters t[·] only through its own cell, so checking the new
        // cell for NaN keeps the flag sound without negated comparisons.
        if t[i] < t[i - 1] || t[i].is_nan() {
            t_monotone = false;
        }
    }

    // Lines 16–20: walk the split positions backwards.
    let mut i = n;
    while i > 0 {
        let start = p[i];
        scratch.cuts.push((start, i));
        i = start;
    }
    scratch.cuts.reverse();
}

/// Bisection chunk width of the corrected branch-and-bound: ranges that
/// survive the skip certificate are halved until below this, then
/// evaluated exactly through the bulk kernel.
const CORRECTED_CHUNK: usize = 16;

/// One range `[j0, j1]` of a constant-`S_eff` plateau in the corrected
/// planner's window scan: try to certify-and-skip the whole range, bisect
/// on failure, bulk-evaluate surviving chunks (see module docs). `cert`
/// carries `(a, b, slack)` when the affine surface and its float slack
/// are available (`None` ⇒ no skipping, pure bulk evaluation — always
/// sound). Evaluated candidates are bit-for-bit the reference expression;
/// `(m, jb)` track the running minimum with ties to the largest `j`.
#[allow(clippy::too_many_arguments)]
fn corrected_scan_range<E: ServeEstimate + ?Sized>(
    est: &E,
    t: &[f64],
    steps: &[(usize, f64)],
    ptr: &mut usize,
    i: usize,
    l_i: u32,
    v: u32,
    mut j0: usize,
    j1: usize,
    cert: Option<(f64, f64, f64)>,
    serve_buf: &mut Vec<f64>,
    m: &mut f64,
    jb: &mut usize,
) {
    loop {
        if let Some((a, b, slack)) = cert {
            if *m < f64::INFINITY {
                // Lower-bound every candidate in [j0, j1]: the T side
                // gains at least γ per index past j0 (suffix minimum of
                // the verified-monotone T steps, rounded down 2 ulps),
                // the serve side loses at most the real slope a per size
                // step, and `slack` certifies the float gap between
                // serve_est and the affine anchor at the range's smallest
                // size. 8 ulps of downward slop absorb this expression's
                // own roundings; `bound > m` is then a strict-worseness
                // certificate for the whole range.
                while *ptr < steps.len() && steps[*ptr].0 < j0 {
                    *ptr += 1;
                }
                let gamma = if *ptr < steps.len() {
                    down_ulps(steps[*ptr].1, 2)
                } else {
                    0.0
                };
                let mut coef = if gamma < a { gamma } else { a };
                if coef < 0.0 {
                    coef = 0.0;
                }
                let bound = down_ulps(
                    t[j0 - 1] + (a * ((i - j1 + 1) as f64) + b) - slack + (j1 - j0) as f64 * coef,
                    8,
                );
                if bound > *m {
                    return;
                }
            }
        }
        if j1 - j0 < CORRECTED_CHUNK {
            let n0 = (i - j1 + 1) as u32;
            let count = j1 - j0 + 1;
            serve_buf.resize(count, 0.0);
            est.serve_est_many(n0..n0 + count as u32, l_i, v, serve_buf);
            for j in j0..=j1 {
                let c = t[j - 1] + serve_buf[j1 - j];
                if c < *m || (c == *m && j > *jb) {
                    *m = c;
                    *jb = j;
                }
            }
            return;
        }
        let mid = j0 + (j1 - j0) / 2;
        corrected_scan_range(est, t, steps, ptr, i, l_i, v, j0, mid, cert, serve_buf, m, jb);
        j0 = mid + 1;
    }
}

/// The corrected planning loop, rebuilt as a running-max-aware branch-and-
/// bound (see the module's corrected-branch-and-bound section): a sliding-
/// window max deque over the predicted iterations yields the constant-
/// `S_eff` plateaus of each cell's window; each plateau is scanned by
/// [`corrected_scan_range`] (certify-and-skip where the affine surface
/// and its slack apply, bulk-kernel evaluation elsewhere). Bit-exact
/// against [`dp_plan_corrected_reference`] — the retained scalar loop —
/// by the corrected differential suite.
fn dp_plan_corrected<E: ServeEstimate + ?Sized>(
    sorted: &[Request],
    est: &E,
    mem: &MemoryEstimator,
    cfg: &DpBatcherConfig,
    scratch: &mut DpScratch,
) {
    let n = sorted.len();
    let s = cfg.slice_len;
    scratch.cuts.clear();
    if n == 0 {
        return;
    }
    scratch.t.clear();
    scratch.t.resize(n + 1, 0.0);
    scratch.p.clear();
    scratch.p.resize(n + 1, 0);
    scratch.steps.clear();
    scratch.smax.clear();
    scratch.pred.clear();
    scratch.pred.extend(sorted.iter().map(|r| predicted_iters(r, s)));
    let t = &mut scratch.t;
    let p = &mut scratch.p;
    let dq = &mut scratch.steps;
    let smax = &mut scratch.smax;
    let serve_buf = &mut scratch.serve_buf;
    let pred = &scratch.pred;
    let mut dq_head = 0usize;
    let mut smax_head = 0usize;

    // Same soundness flags as the legacy planner: certificates need the
    // T-step deque, which needs verified T monotonicity and a window
    // whose left edge never moves left (both re-checked cell by cell).
    let mut t_monotone = true;
    let mut j_lo_monotone = true;
    let mut last_j_lo = 0usize;

    // N_max is a pure function of L_i (memory feasibility stays at the
    // full S); the affine surface is NOT cacheable per length here — it
    // depends on each plateau's S_eff.
    let mut have_cache = false;
    let mut cached_l = 0u32;
    let mut cached_n_max = 1u32;

    for i in 1..=n {
        let l_i = sorted[i - 1].input_len;
        if !have_cache || l_i != cached_l {
            // A batch whose predictions all fall short can still run to
            // the slice cap, so feasibility provisions the full S.
            let mut n_max = mem.max_batch(l_i, s).max(1);
            if let Some(cap) = cfg.max_batch_size {
                n_max = n_max.min(cap.max(1));
            }
            cached_l = l_i;
            cached_n_max = n_max;
            have_cache = true;
        }
        let n_max = cached_n_max;

        // Singleton first (wins exact ties, like the reference's strict
        // `<`): its budget is the request's own predicted iterations.
        p[i] = i - 1;
        t[i] = t[i - 1] + est.serve_est(1, l_i, pred[i - 1]);

        let j_lo = if (n_max as usize) >= i {
            1
        } else {
            i + 1 - n_max as usize
        };
        let moved_left = j_lo < last_j_lo;
        if moved_left {
            j_lo_monotone = false;
        }
        last_j_lo = j_lo;

        // T-step deque (certificates only; same maintenance as legacy).
        if t_monotone && i >= 2 {
            let v = t[i - 1] - t[i - 2];
            if v.is_nan() {
                t_monotone = false;
            } else {
                while dq.len() > dq_head && dq[dq.len() - 1].1 >= v {
                    dq.pop();
                }
                dq.push((i - 1, v));
            }
        }
        while dq.len() > dq_head && dq[dq_head].0 < j_lo {
            dq_head += 1;
        }

        // Sliding-window max deque over pred[j_lo..=i]: front-dropped
        // entries are unrecoverable, so a left-moving window (capacity-
        // growing table rule) rebuilds it for correctness — unlike the
        // T-step deque, this one is structural, not an optimization.
        if moved_left {
            smax.clear();
            smax_head = 0;
            for m in j_lo..=i {
                let v = pred[m - 1];
                while smax.len() > smax_head && smax[smax.len() - 1].1 <= v {
                    smax.pop();
                }
                smax.push((m, v));
            }
        } else {
            let v = pred[i - 1];
            while smax.len() > smax_head && smax[smax.len() - 1].1 <= v {
                smax.pop();
            }
            smax.push((i, v));
            while smax[smax_head].0 < j_lo {
                smax_head += 1;
            }
        }

        if j_lo < i {
            let mut m = f64::INFINITY;
            let mut jb = 0usize;
            let mut ptr = dq_head;
            // Plateaus ascend in j (deque values descend): entry (e, v)
            // covers j ∈ (prev_e, e], i.e. S_eff(j) = v there. The last
            // entry is always (i, pred[i−1]) and covers the singleton,
            // which was costed above — `phi` caps at i−1.
            let mut prev_e = j_lo - 1;
            for &(e, v) in smax.iter().skip(smax_head) {
                let plo = prev_e + 1;
                let phi = e.min(i - 1);
                prev_e = e;
                if plo > phi {
                    continue;
                }
                let cert = match est.serve_affine(l_i, v) {
                    // `serve_affine`'s contract guarantees a ≥ 0, but the
                    // certificate depends on it, so gate defensively.
                    Some((a, b)) if t_monotone && j_lo_monotone && a >= 0.0 => {
                        let slack = est.serve_affine_slack(l_i, v, n_max);
                        if slack.is_finite() && slack >= 0.0 {
                            Some((a, b, slack))
                        } else {
                            None
                        }
                    }
                    _ => None,
                };
                corrected_scan_range(
                    est,
                    t,
                    dq,
                    &mut ptr,
                    i,
                    l_i,
                    v,
                    plo,
                    phi,
                    cert,
                    serve_buf,
                    &mut m,
                    &mut jb,
                );
            }
            // Strict `<`: the singleton wins exact ties, as in the
            // reference.
            if m < t[i] {
                t[i] = m;
                p[i] = jb - 1;
            }
        }
        if t[i] < t[i - 1] || t[i].is_nan() {
            t_monotone = false;
        }
    }

    let mut i = n;
    while i > 0 {
        let start = p[i];
        scratch.cuts.push((start, i));
        i = start;
    }
    scratch.cuts.reverse();
}

/// The PR 4 scalar corrected loop, retained verbatim as the differential-
/// testing and benchmarking baseline (the corrected analogue of
/// [`dp_plan_reference`], self-allocating like it): the reference scan
/// with the candidate budget replaced by the window's running maximum of
/// predicted remaining iterations. `dp_plan` with
/// `DpBatcherConfig::pred_corrected` set must produce identical cuts (and
/// hence bit-identical `est_serve_time`) on every input.
pub fn dp_plan_corrected_reference(
    sorted: &[Request],
    est: &dyn ServeEstimate,
    mem: &MemoryEstimator,
    cfg: &DpBatcherConfig,
) -> Vec<(usize, usize)> {
    let n = sorted.len();
    if n == 0 {
        return Vec::new();
    }
    let s = cfg.slice_len;
    let mut t = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1];

    for i in 1..=n {
        let l_i = sorted[i - 1].input_len;
        // Memory feasibility stays at the full slice length: a batch whose
        // predictions all fall short can still run to the cap.
        let mut n_max = mem.max_batch(l_i, s).max(1);
        if let Some(cap) = cfg.max_batch_size {
            n_max = n_max.min(cap.max(1));
        }
        // Running max of predicted iterations over the candidate window,
        // grown as j walks backwards (the batch [j, i] gains member j).
        let mut s_eff = predicted_iters(&sorted[i - 1], s);
        p[i] = i - 1;
        t[i] = t[i - 1] + est.serve_est(1, l_i, s_eff);
        let mut j = i - 1;
        while j > 0 {
            let size = (i - j + 1) as u32;
            if size > n_max {
                break;
            }
            s_eff = s_eff.max(predicted_iters(&sorted[j - 1], s));
            let cand = t[j - 1] + est.serve_est(size, l_i, s_eff);
            if cand < t[i] {
                t[i] = cand;
                p[i] = j - 1;
            }
            j -= 1;
        }
    }

    let mut cuts = Vec::new();
    let mut i = n;
    while i > 0 {
        let start = p[i];
        cuts.push((start, i));
        i = start;
    }
    cuts.reverse();
    cuts
}

/// Materialize batches from cuts by draining the sorted request buffer in
/// one pass (buffer keeps its capacity for reuse by per-tick callers).
/// Under predicted correction each batch's `est_serve_time` uses the same
/// corrected budget the plan costed it at; returns how many batches came
/// in strictly below the slice cap (the correction counter callers fold
/// into `RunMetrics::corrected_batches`).
fn materialize_into<E: ServeEstimate + ?Sized>(
    requests: &mut Vec<Request>,
    cuts: &[(usize, usize)],
    est: &E,
    cfg: &DpBatcherConfig,
    out: &mut Vec<Batch>,
) -> usize {
    out.reserve(cuts.len());
    let mut corrected = 0usize;
    let mut drain = requests.drain(..);
    for &(start, end) in cuts {
        let members: Vec<Request> = drain.by_ref().take(end - start).collect();
        debug_assert_eq!(members.len(), end - start);
        let budget = if cfg.pred_corrected {
            let b = predicted_batch_iters(&members, cfg.slice_len);
            corrected += (b < cfg.slice_len) as usize;
            b
        } else {
            cfg.slice_len
        };
        let mut b = Batch::new(members);
        b.est_serve_time = est.serve_est(b.size() as u32, b.input_len(), budget);
        out.push(b);
    }
    corrected
}

// ---------------------------------------------------------------------------
// Retained naive reference (the seed's quadratic implementation, verbatim)
// ---------------------------------------------------------------------------

/// The original O(n·N_max) DP, retained as the differential-testing and
/// benchmarking baseline. [`dp_batch`] must produce bit-identical cuts and
/// `est_serve_time` values to this function on every input — with
/// `pred_corrected` off; the reference predates predictions and ignores
/// the flag.
pub fn dp_batch_reference(
    mut requests: Vec<Request>,
    est: &dyn ServeEstimate,
    mem: &MemoryEstimator,
    cfg: &DpBatcherConfig,
) -> Vec<Batch> {
    if requests.is_empty() {
        return Vec::new();
    }
    requests.sort_by_key(|r| r.input_len);
    let cuts = dp_plan_reference(&requests, est, mem, cfg);

    // Materialize batches (preserve sorted order).
    let mut batches = Vec::with_capacity(cuts.len());
    let mut rest = requests;
    for &(start, end) in cuts.iter().rev() {
        let tail = rest.split_off(start);
        debug_assert_eq!(tail.len(), end - start);
        let mut b = Batch::new(tail);
        b.est_serve_time = est.serve_est(b.size() as u32, b.input_len(), cfg.slice_len);
        batches.push(b);
    }
    batches.reverse();
    batches
}

/// The seed's quadratic planning loop over an already-sorted slice,
/// allocating its tables per call exactly as the original did.
pub fn dp_plan_reference(
    sorted: &[Request],
    est: &dyn ServeEstimate,
    mem: &MemoryEstimator,
    cfg: &DpBatcherConfig,
) -> Vec<(usize, usize)> {
    let n = sorted.len();
    if n == 0 {
        return Vec::new();
    }
    let s = cfg.slice_len;
    let mut t = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1];

    for i in 1..=n {
        let l_i = sorted[i - 1].input_len;
        let mut n_max = mem.max_batch(l_i, s).max(1);
        if let Some(cap) = cfg.max_batch_size {
            n_max = n_max.min(cap.max(1));
        }
        let affine = est.serve_affine(l_i, s);

        p[i] = i - 1;
        t[i] = t[i - 1] + est.serve_est(1, l_i, s);
        let mut j = i - 1;
        while j > 0 {
            let size = (i - j + 1) as u32;
            if size > n_max {
                break;
            }
            let serve = match affine {
                Some((a, b)) => a * size as f64 + b,
                None => est.serve_est(size, l_i, s),
            };
            let cand = t[j - 1] + serve;
            if cand < t[i] {
                t[i] = cand;
                p[i] = j - 1;
            }
            j -= 1;
        }
    }

    let mut cuts = Vec::new();
    let mut i = n;
    while i > 0 {
        let start = p[i];
        cuts.push((start, i));
        i = start;
    }
    cuts.reverse();
    cuts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::serving_time::{LinearLatency, ServingTimeEstimator};

    fn est() -> ServingTimeEstimator {
        // HF-like magnitudes so padding costs are visible.
        ServingTimeEstimator {
            prefill: LinearLatency {
                c1: 3.8e-4,
                c2: 1.7e-3,
                c3: 3.5e-4,
                c4: 0.029,
            },
            decode: LinearLatency {
                c1: 1.3e-6,
                c2: 1.8e-3,
                c3: 6.5e-6,
                c4: 0.05,
            },
        }
    }

    fn mem_loose() -> MemoryEstimator {
        MemoryEstimator::analytic(800 * 1024, 48 << 30, 0.9)
    }

    fn reqs(lens: &[u32]) -> Vec<Request> {
        lens.iter()
            .enumerate()
            .map(|(i, &l)| Request::new(i as u64, 0.0, l, 100))
            .collect()
    }

    fn cfg(s: u32) -> DpBatcherConfig {
        DpBatcherConfig {
            slice_len: s,
            max_batch_size: None,
            pred_corrected: false,
        }
    }

    /// Optimized and reference plans must agree bit-for-bit on cuts and
    /// estimated serving times.
    fn assert_matches_reference(
        lens: &[u32],
        e: &ServingTimeEstimator,
        mem: &MemoryEstimator,
        c: &DpBatcherConfig,
    ) {
        let fast = dp_batch(reqs(lens), e, mem, c);
        let slow = dp_batch_reference(reqs(lens), e, mem, c);
        assert_eq!(fast.len(), slow.len(), "batch count differs");
        for (f, s) in fast.iter().zip(&slow) {
            let fi: Vec<u64> = f.requests.iter().map(|r| r.id).collect();
            let si: Vec<u64> = s.requests.iter().map(|r| r.id).collect();
            assert_eq!(fi, si, "cut membership differs");
            assert_eq!(
                f.est_serve_time.to_bits(),
                s.est_serve_time.to_bits(),
                "est_serve_time differs"
            );
        }
    }

    #[test]
    fn partition_is_complete_and_disjoint() {
        let batches = dp_batch(reqs(&[10, 1024, 30, 500, 10, 80]), &est(), &mem_loose(), &cfg(128));
        let mut ids: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id))
            .collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn paper_fig11_separates_long_straggler() {
        // 15 requests of length 10 + 1 of length 1024 (paper Fig. 11):
        // separate batching beats together-batching, so the DP must split.
        let mut lens = vec![10u32; 15];
        lens.push(1024);
        let batches = dp_batch(reqs(&lens), &est(), &mem_loose(), &cfg(128));
        assert_eq!(batches.len(), 2, "straggler must be isolated");
        let sizes: Vec<usize> = batches.iter().map(|b| b.size()).collect();
        assert!(sizes.contains(&15) && sizes.contains(&1));

        // and the DP total beats the single-batch alternative:
        let dp_total: f64 = batches.iter().map(|b| b.est_serve_time).sum();
        let together = est().serve(16, 1024, 128);
        assert!(dp_total < together, "{dp_total} !< {together}");
    }

    #[test]
    fn homogeneous_requests_batch_together() {
        let batches = dp_batch(reqs(&[64; 20]), &est(), &mem_loose(), &cfg(128));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].size(), 20);
    }

    #[test]
    fn respects_memory_limit() {
        // Tight memory: max 4 requests of (64 + 128) tokens.
        let delta = 1u64 << 20;
        let budget = (4 * (64 + 128)) as u64 * delta;
        let mem = MemoryEstimator::analytic(delta, budget, 1.0);
        let batches = dp_batch(reqs(&[64; 20]), &est(), &mem, &cfg(128));
        assert!(batches.iter().all(|b| b.size() <= 4));
        assert_eq!(batches.iter().map(|b| b.size()).sum::<usize>(), 20);
    }

    #[test]
    fn respects_batch_cap() {
        let batches = dp_batch(
            reqs(&[64; 20]),
            &est(),
            &mem_loose(),
            &DpBatcherConfig {
                slice_len: 128,
                max_batch_size: Some(6),
                pred_corrected: false,
            },
        );
        assert!(batches.iter().all(|b| b.size() <= 6));
    }

    #[test]
    fn single_request() {
        let batches = dp_batch(reqs(&[100]), &est(), &mem_loose(), &cfg(128));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].size(), 1);
        assert!(batches[0].est_serve_time > 0.0);
    }

    #[test]
    fn empty_input() {
        assert!(dp_batch(vec![], &est(), &mem_loose(), &cfg(128)).is_empty());
    }

    #[test]
    fn est_serve_time_consistent() {
        let e = est();
        let batches = dp_batch(reqs(&[10, 20, 900]), &e, &mem_loose(), &cfg(64));
        for b in &batches {
            let expect = e.serve(b.size() as u32, b.input_len(), 64);
            assert!((b.est_serve_time - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn dp_never_worse_than_naive_splits() {
        // DP total must be <= both all-singletons and one-big-batch
        // (when feasible) — it optimizes over all contiguous partitions.
        let e = est();
        let mem = mem_loose();
        let lens = [5u32, 17, 40, 64, 64, 128, 300, 700];
        let batches = dp_batch(reqs(&lens), &e, &mem, &cfg(128));
        let dp_total: f64 = batches.iter().map(|b| b.est_serve_time).sum();

        let singles: f64 = lens.iter().map(|&l| e.serve(1, l, 128)).sum();
        assert!(dp_total <= singles + 1e-9);

        let max_len = *lens.iter().max().unwrap();
        if !mem.would_oom(lens.len() as u32, max_len, 128) {
            let together = e.serve(lens.len() as u32, max_len, 128);
            assert!(dp_total <= together + 1e-9);
        }
    }

    #[test]
    fn optimized_matches_reference_on_shapes() {
        let e = est();
        let mem = mem_loose();
        // Fig. 11 shape, homogeneous, strictly increasing, duplicates,
        // window-straddling sizes.
        let mut fig11 = vec![10u32; 15];
        fig11.push(1024);
        let shapes: Vec<Vec<u32>> = vec![
            fig11,
            vec![64; 20],
            (1..=64).collect(),
            vec![5, 5, 5, 900, 900, 900, 5, 5],
            vec![1],
            vec![1, 1024],
            (1..=200).map(|x| (x * 37) % 1024 + 1).collect(),
        ];
        for lens in &shapes {
            for s in [32u32, 128, 512] {
                assert_matches_reference(lens, &e, &mem, &cfg(s));
                assert_matches_reference(
                    lens,
                    &e,
                    &mem,
                    &DpBatcherConfig {
                        slice_len: s,
                        max_batch_size: Some(6),
                        pred_corrected: false,
                    },
                );
            }
        }
    }

    #[test]
    fn optimized_matches_reference_with_ascending_capacity_table() {
        // Capacity growing with length moves the window's left edge left
        // mid-scan; skipping must shut off rather than mis-certify.
        use crate::estimator::MemoryRule;
        let e = est();
        let mem = MemoryEstimator {
            rule: MemoryRule::Table(vec![(512, 28), (0, 2)]),
        };
        let lens: Vec<u32> = (0..120).map(|x| (x * 17) % 1024 + 1).collect();
        for s in [16u32, 64, 128] {
            assert_matches_reference(&lens, &e, &mem, &cfg(s));
        }
    }

    #[test]
    fn optimized_matches_reference_under_tight_memory() {
        let e = est();
        let delta = 1u64 << 20;
        for cap_reqs in [1u64, 2, 4, 7] {
            let budget = cap_reqs * (64 + 128) * delta;
            let mem = MemoryEstimator::analytic(delta, budget, 1.0);
            let lens: Vec<u32> = (0..40).map(|x| (x * 13) % 64 + 1).collect();
            assert_matches_reference(&lens, &e, &mem, &cfg(128));
        }
    }

    /// Requests with oracle-stamped predictions for the corrected-path
    /// tests: predicted == target generation length.
    fn predicted_reqs(lens_preds: &[(u32, u32)]) -> Vec<Request> {
        lens_preds
            .iter()
            .enumerate()
            .map(|(i, &(l, pred))| {
                let mut r = Request::new(i as u64, 0.0, l, pred);
                r.predicted_gen = Some(pred);
                r
            })
            .collect()
    }

    #[test]
    fn predicted_iters_clamps_and_falls_back() {
        let mut r = Request::new(1, 0.0, 64, 500);
        assert_eq!(predicted_iters(&r, 128), 128, "no prediction → full budget");
        r.predicted_gen = Some(40);
        assert_eq!(predicted_iters(&r, 128), 40);
        r.generated = 30;
        assert_eq!(predicted_iters(&r, 128), 10, "prediction is a total, not remaining");
        r.generated = 45;
        assert_eq!(
            predicted_iters(&r, 128),
            128,
            "an exhausted prediction says nothing — next pass costs the full budget \
             (a requeued under-prediction really can run all of it)"
        );
        r.generated = 0;
        r.predicted_gen = Some(9999);
        assert_eq!(predicted_iters(&r, 128), 128, "caps at the slice budget");
    }

    #[test]
    fn corrected_partition_is_complete_and_feasible() {
        let e = est();
        let mem = mem_loose();
        let c = DpBatcherConfig {
            slice_len: 128,
            max_batch_size: Some(6),
            pred_corrected: true,
        };
        let reqs = predicted_reqs(&[
            (10, 30),
            (1024, 500),
            (30, 128),
            (500, 20),
            (10, 900),
            (80, 64),
            (80, 64),
            (80, 64),
            (80, 64),
            (80, 64),
            (80, 64),
            (80, 64),
        ]);
        let n = reqs.len();
        let batches = dp_batch(reqs, &e, &mem, &c);
        let mut ids: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id))
            .collect();
        ids.sort();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
        assert!(batches.iter().all(|b| b.size() <= 6));
        for b in &batches {
            let budget = predicted_batch_iters(&b.requests, c.slice_len);
            let expect = e.serve(b.size() as u32, b.input_len(), budget);
            assert!(
                (b.est_serve_time - expect).abs() < 1e-12,
                "est must use the corrected budget"
            );
        }
    }

    #[test]
    fn correction_never_raises_the_estimated_total() {
        // The corrected cost of ANY candidate batch is ≤ its uncorrected
        // cost (serve time is monotone in the iteration budget and
        // S_eff ≤ S), so the corrected DP's minimal total is ≤ the
        // uncorrected DP's total for the same pool.
        let e = est();
        let mem = mem_loose();
        let lens_preds: Vec<(u32, u32)> = (0..60)
            .map(|x: u32| ((x * 37) % 800 + 1, (x * 53) % 128 + 1))
            .collect();
        for s in [32u32, 128, 512] {
            let base = DpBatcherConfig {
                slice_len: s,
                max_batch_size: None,
                pred_corrected: false,
            };
            let corr = DpBatcherConfig {
                pred_corrected: true,
                ..base.clone()
            };
            let uncorrected: f64 = dp_batch(predicted_reqs(&lens_preds), &e, &mem, &base)
                .iter()
                .map(|b| b.est_serve_time)
                .sum();
            let corrected: f64 = dp_batch(predicted_reqs(&lens_preds), &e, &mem, &corr)
                .iter()
                .map(|b| b.est_serve_time)
                .sum();
            assert!(
                corrected <= uncorrected + 1e-9,
                "S={s}: corrected {corrected} !<= uncorrected {uncorrected}"
            );
        }
    }

    #[test]
    fn correction_without_predictions_matches_full_budget_costs() {
        // No stamped predictions → every S_eff == S: the corrected planner
        // must form batches costed exactly at the full budget (the flag is
        // a semantic no-op; only the scalar evaluation path differs).
        let e = est();
        let mem = mem_loose();
        let c = DpBatcherConfig {
            slice_len: 128,
            max_batch_size: None,
            pred_corrected: true,
        };
        let batches = dp_batch(reqs(&[10, 20, 900, 64, 64]), &e, &mem, &c);
        assert_eq!(
            batches.iter().map(|b| b.size()).sum::<usize>(),
            5,
            "no request lost"
        );
        for b in &batches {
            let expect = e.serve(b.size() as u32, b.input_len(), 128);
            assert!((b.est_serve_time - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn corrected_dp_separates_predicted_long_tail() {
        // All same input length, so the legacy DP sees one homogeneous
        // batch; predictions say one member runs the full slice while the
        // rest return after ~8 iterations. The corrected DP must isolate
        // the long-predicted straggler whenever doing so lowers the
        // estimated total — and never produce a worse total than batching
        // everything together.
        let e = est();
        let mem = mem_loose();
        let c = DpBatcherConfig {
            slice_len: 512,
            max_batch_size: None,
            pred_corrected: true,
        };
        let mut lens_preds = vec![(64u32, 8u32); 15];
        lens_preds.push((64, 512));
        let batches = dp_batch(predicted_reqs(&lens_preds), &e, &mem, &c);
        let total: f64 = batches.iter().map(|b| b.est_serve_time).sum();
        let together = e.serve(16, 64, 512);
        assert!(
            total <= together + 1e-9,
            "corrected total {total} !<= together {together}"
        );
    }

    /// The branch-and-bound corrected planner must produce identical cuts
    /// to the retained scalar reference (the full randomized contract is
    /// `tests/props_dp_corrected_differential.rs`; these are the shaped
    /// cases).
    fn assert_corrected_matches_reference(
        lens_preds: &[(u32, u32)],
        e: &dyn ServeEstimate,
        mem: &MemoryEstimator,
        c: &DpBatcherConfig,
    ) {
        let mut sorted = predicted_reqs(lens_preds);
        sorted.sort_by_key(|r| r.input_len);
        let mut scratch = DpScratch::new();
        dp_plan(&sorted, e, mem, c, &mut scratch);
        let slow = dp_plan_corrected_reference(&sorted, e, mem, c);
        assert_eq!(scratch.cuts(), &slow[..], "corrected cuts diverge");
    }

    #[test]
    fn corrected_bnb_matches_scalar_reference_on_shapes() {
        let e = est();
        let mem = mem_loose();
        // Constant predictions (one plateau), oracle-ish spread (many),
        // anti-correlated with the sort key (max plateaus), prediction
        // gaps, and a duplicate-heavy pool.
        let shapes: Vec<Vec<(u32, u32)>> = vec![
            (0..120).map(|x: u32| ((x * 37) % 1024 + 1, 64)).collect(),
            (0..150)
                .map(|x: u32| ((x * 37) % 1024 + 1, (x * 53) % 1024 + 1))
                .collect(),
            (0..150)
                .map(|x: u32| {
                    let l = (x * 37) % 1024 + 1;
                    (l, 1025 - l)
                })
                .collect(),
            (0..90)
                .map(|x: u32| ((x * 13) % 64 + 1, [8u32, 64, 512][(x % 3) as usize]))
                .collect(),
            vec![(64, 8); 40],
        ];
        for lens_preds in &shapes {
            for s in [16u32, 128, 512] {
                for cap in [None, Some(6)] {
                    let c = DpBatcherConfig {
                        slice_len: s,
                        max_batch_size: cap,
                        pred_corrected: true,
                    };
                    assert_corrected_matches_reference(lens_preds, &e, &mem, &c);
                }
            }
        }
    }

    #[test]
    fn corrected_bnb_matches_reference_with_ascending_capacity_table() {
        // Capacity growing with length moves the window's left edge left
        // mid-scan: the plateau deque must REBUILD (it is structural for
        // the corrected planner, not just an optimization) and the skip
        // certificates must shut off.
        use crate::estimator::MemoryRule;
        let e = est();
        let mem = MemoryEstimator {
            rule: MemoryRule::Table(vec![(512, 28), (0, 2)]),
        };
        let lens_preds: Vec<(u32, u32)> = (0..120)
            .map(|x: u32| ((x * 17) % 1024 + 1, (x * 29) % 256 + 1))
            .collect();
        for s in [16u32, 64, 128] {
            let c = DpBatcherConfig {
                slice_len: s,
                max_batch_size: None,
                pred_corrected: true,
            };
            assert_corrected_matches_reference(&lens_preds, &e, &mem, &c);
        }
    }

    #[test]
    fn corrected_bnb_matches_reference_on_opaque_estimator() {
        // serve_affine == None everywhere: every plateau takes the bulk
        // path with no certificates, and must still agree with the
        // reference exactly.
        struct Opaque(ServingTimeEstimator);
        impl ServeEstimate for Opaque {
            fn serve_est(&self, n: u32, l_i: u32, s: u32) -> f64 {
                self.0.serve_est(n, l_i, s)
            }
        }
        let e = Opaque(est());
        let mem = mem_loose();
        let lens_preds: Vec<(u32, u32)> = (0..100)
            .map(|x: u32| ((x * 41) % 900 + 1, (x * 7) % 300 + 1))
            .collect();
        let c = DpBatcherConfig {
            slice_len: 128,
            max_batch_size: None,
            pred_corrected: true,
        };
        assert_corrected_matches_reference(&lens_preds, &e, &mem, &c);
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        // Repeated dp_batch_into calls with one scratch must match fresh
        // calls exactly.
        let e = est();
        let mem = mem_loose();
        let c = cfg(128);
        let mut scratch = DpScratch::new();
        let mut out = Vec::new();
        for round in 0..4u64 {
            let lens: Vec<u32> = (0..50u64)
                .map(|x| ((x * 29 + round * 7) % 800 + 1) as u32)
                .collect();
            let mut buf = reqs(&lens);
            dp_batch_into(&mut buf, &e, &mem, &c, &mut scratch, &mut out);
            assert!(buf.is_empty(), "input buffer must be drained");
            let fresh = dp_batch(reqs(&lens), &e, &mem, &c);
            assert_eq!(out.len(), fresh.len());
            for (a, b) in out.iter().zip(&fresh) {
                assert_eq!(
                    a.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
                    b.requests.iter().map(|r| r.id).collect::<Vec<_>>()
                );
                assert_eq!(a.est_serve_time.to_bits(), b.est_serve_time.to_bits());
            }
        }
        // An empty tick must not leak the previous run's cuts.
        let mut empty: Vec<Request> = Vec::new();
        dp_batch_into(&mut empty, &e, &mem, &c, &mut scratch, &mut out);
        assert!(out.is_empty());
        assert!(scratch.cuts().is_empty());
    }
}
