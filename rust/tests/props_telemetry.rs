//! Property suite for the telemetry subsystem:
//!
//! 1. **Sketch accuracy** — [`StreamingHist`] quantiles stay within the
//!    documented relative-error bound α of the exact sorted-sample
//!    nearest-rank quantile, on randomized draws across distribution
//!    shapes, sample counts, and α settings.
//! 2. **Observation is free** — attaching the full telemetry stack
//!    (timeline + time-series sinks, hot-path profiling enabled) to a run
//!    leaves `RunMetrics::to_json` byte-identical to the bare run, across
//!    the entire built-in policy registry on an SLO-stamped trace (so the
//!    TTFT/TPOT sketches are populated, not vacuously empty).
//! 3. **Timelines are faithful** — a faulted multi-worker run's Chrome
//!    trace carries one named span track per worker and an instant for
//!    every crash/drain/join the `FaultPlan` fires, and every JSONL line
//!    parses standalone.

use scls::engine::presets::{EngineKind, EnginePreset};
use scls::metrics::{Fanout, MetricsSink};
use scls::scheduler::BUILTIN_POLICIES;
use scls::sim::driver::{SimConfig, Simulation};
use scls::sim::{FaultKind, FaultPlan};
use scls::slo::{stamp_trace, SloSpec, TenantMix};
use scls::telemetry::{profile, StreamingHist, TimeSeriesSink, TimelineSink};
use scls::testprop::{check, Gen};
use scls::util::json::Json;
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};
use scls::{prop_assert, prop_assert_eq};

fn trace(rate: f64, duration: f64, seed: u64) -> Trace {
    Trace::generate(&TraceConfig {
        kind: WorkloadKind::CodeFuse,
        rate,
        duration,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed,
    })
}

// ---------------------------------------------------------------------------
// 1. Streaming histogram vs exact nearest-rank quantiles
// ---------------------------------------------------------------------------

/// The exact quantile definition the sketch documents its bound against.
fn exact_nearest_rank(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len() as u64;
    let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
    sorted[(rank - 1) as usize]
}

#[test]
fn hist_quantiles_within_alpha_of_exact_on_random_draws() {
    check("hist-quantile-bound", 40, |g: &mut Gen| {
        let alpha = *g.pick(&[0.005, 0.01, 0.02, 0.05]);
        let n = g.usize(1, 4000);
        // Mix distribution shapes: uniform, heavy-tailed (exponentiated
        // uniform over decades), and tightly clustered.
        let shape = g.u32(0, 2);
        let mut vals: Vec<f64> = (0..n)
            .map(|_| match shape {
                0 => g.f64(1e-6, 500.0),
                1 => 1e-4 * g.f64(0.0, 16.0).exp(),
                _ => 40.0 + g.f64(0.0, 2.0),
            })
            .collect();
        let mut h = StreamingHist::with_alpha(alpha);
        for &v in &vals {
            h.add(v);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        prop_assert_eq!(h.count(), n as u64, "count mismatch");
        for q in [0.0, 0.01, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let exact = exact_nearest_rank(&vals, q);
            let got = h.quantile(q);
            prop_assert!(
                (got - exact).abs() <= exact * (alpha + 1e-9) + 1e-12,
                "alpha={alpha} n={n} shape={shape} q={q}: sketch {got} vs exact {exact}"
            );
        }
        // min/max/mean are exact, not sketched.
        prop_assert!((h.min() - vals[0]).abs() < 1e-12, "min drifted");
        prop_assert!((h.max() - vals[n - 1]).abs() < 1e-12, "max drifted");
        Ok(())
    });
}

#[test]
fn hist_merge_equals_single_sketch_over_concatenation() {
    check("hist-merge", 30, |g: &mut Gen| {
        let alpha = *g.pick(&[0.01, 0.02]);
        let a_vals = g.vec(0, 500, |g| g.f64(1e-3, 100.0));
        let b_vals = g.vec(0, 500, |g| 1e-2 * g.f64(0.0, 10.0).exp());
        let mut a = StreamingHist::with_alpha(alpha);
        let mut b = StreamingHist::with_alpha(alpha);
        let mut whole = StreamingHist::with_alpha(alpha);
        for &v in &a_vals {
            a.add(v);
            whole.add(v);
        }
        for &v in &b_vals {
            b.add(v);
            whole.add(v);
        }
        a.merge(&b);
        prop_assert_eq!(a.count(), whole.count(), "merged count");
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            // Merge is lossless bucket addition: quantiles must agree with
            // the single sketch exactly, not just within α.
            prop_assert!(
                (a.quantile(q) - whole.quantile(q)).abs() < 1e-12,
                "q={q}: merged {} vs whole {}",
                a.quantile(q),
                whole.quantile(q)
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 2. Telemetry-on runs are byte-identical to bare runs
// ---------------------------------------------------------------------------

#[test]
fn telemetry_sinks_never_move_the_run_fingerprint() {
    // SLO-stamped trace so the SloTracker sketches actually observe
    // samples (the interesting case for the lazily-computed distribution
    // keys in `RunMetrics::to_json`).
    let mut t = trace(6.0, 20.0, 71);
    let mix = TenantMix::uniform(2);
    let slo = SloSpec::parse("ttft:10,tpot:1,deadline:60").expect("static spec");
    stamp_trace(&mut t, &mix, &slo, 71);
    let sim = Simulation::new(SimConfig::new(3, EnginePreset::paper(EngineKind::Ds), 1024, 71));
    for which in BUILTIN_POLICIES {
        let bare = sim.run_named(&t, which, 128).unwrap_or_else(|e| panic!("{e}"));
        let mut timeline = TimelineSink::new();
        let mut series = TimeSeriesSink::default();
        profile::enable();
        let observed = {
            let mut fan = Fanout(vec![&mut timeline as &mut dyn MetricsSink, &mut series]);
            sim.run_named_with_sink(&t, which, 128, &mut fan)
                .unwrap_or_else(|e| panic!("{e}"))
        };
        profile::disable();
        let prof = profile::take();
        assert_eq!(
            bare.to_json().to_string_pretty(),
            observed.to_json().to_string_pretty(),
            "{which}: telemetry sinks moved the deterministic fingerprint"
        );
        // The sinks did observe the run — this is not a vacuous identity.
        // Batch spans come from the static-batching families; the
        // iteration-level (continuous-batching) policies report through
        // the per-worker sample hook instead.
        if !matches!(which, "ILS" | "SCLS-CB" | "P-CB") {
            assert!(!timeline.spans().is_empty(), "{which}: no spans recorded");
        }
        assert!(
            series.served_imbalance().per_worker.iter().sum::<f64>() > 0.0,
            "{which}: no served tokens recorded"
        );
        // Sliced-family policies exercise the instrumented planner/offload
        // paths; the profile must have seen them with profiling enabled.
        if which == "SCLS" {
            assert!(
                prof.sections.contains_key("schedule_tick")
                    && prof.sections.contains_key("offload"),
                "SCLS profile missing hot sections: {:?}",
                prof.sections.keys().collect::<Vec<_>>()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Faulted-run timelines: tracks and fleet instants
// ---------------------------------------------------------------------------

#[test]
fn faulted_chrome_trace_has_worker_tracks_and_fleet_instants() {
    let t = trace(8.0, 25.0, 99);
    let plan = FaultPlan::none().crash(2, 6.0).drain(1, 9.0).join(2, 12.0);
    let sim = Simulation::new(SimConfig::new(3, EnginePreset::paper(EngineKind::Ds), 1024, 99));
    let mut timeline = TimelineSink::new();
    let m = sim
        .run_named_faulted_with_sink(&t, "SCLS", 128, &plan, &mut timeline)
        .unwrap_or_else(|e| panic!("{e}"));
    assert_eq!(m.completed.len(), t.len(), "faulted run lost requests");
    assert_eq!(m.worker_crashes, 1);

    // Expected instants, derived from the plan itself: one per crash and
    // drain, one per joining worker.
    let (mut crashes, mut drains, mut joins) = (0usize, 0usize, 0usize);
    for e in &plan.events {
        match e.kind {
            FaultKind::Crash { .. } => crashes += 1,
            FaultKind::Drain { .. } => drains += 1,
            FaultKind::Join { count } => joins += count as usize,
        }
    }
    assert_eq!((crashes, drains, joins), (1, 1, 2));
    let count = |name: &str| timeline.instants().iter().filter(|i| i.name == name).count();
    assert_eq!(count("crash"), crashes, "crash instants");
    assert_eq!(count("drain"), drains, "drain instants");
    assert_eq!(count("join"), joins, "join instants");
    // Reclaim markers agree with the run's reclaim counter: stale work
    // was reclaimed iff the timeline shows it.
    assert_eq!(
        count("reclaim") > 0,
        m.reclaimed_requests > 0,
        "reclaim instants disagree with the reclaimed_requests counter"
    );

    // Chrome document: one thread_name metadata track per distinct worker,
    // and serving spread across the fleet (a multi-worker trace, not one
    // busy track).
    let doc = timeline.to_chrome_trace();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let phase = |e: &Json| e.get("ph").unwrap().as_str().unwrap().to_string();
    let tracks = events.iter().filter(|e| phase(e) == "M").count();
    assert_eq!(tracks, timeline.workers().len(), "one track per worker");
    let mut span_workers: Vec<usize> = timeline.spans().iter().map(|s| s.worker).collect();
    span_workers.sort_unstable();
    span_workers.dedup();
    assert!(span_workers.len() >= 2, "spans on one worker only: {span_workers:?}");
    // Instants appear in the document with the instant phase and a scope.
    let insts = events.iter().filter(|e| phase(e) == "i").count();
    assert_eq!(insts, timeline.instants().len());
    // The document round-trips through the JSON parser (Perfetto-loadable
    // shape is covered by unit tests; this guards the integration output).
    let back = Json::parse(&doc.to_string_pretty()).expect("chrome trace parses");
    assert_eq!(
        back.get("traceEvents").unwrap().as_arr().unwrap().len(),
        events.len()
    );

    // Every JSONL line is a standalone JSON object.
    let jsonl = timeline.to_jsonl();
    let mut lines = 0;
    for line in jsonl.lines() {
        let j = Json::parse(line).expect("JSONL line parses");
        assert!(j.get("type").is_some());
        lines += 1;
    }
    assert_eq!(lines, timeline.spans().len() + timeline.instants().len());
}
