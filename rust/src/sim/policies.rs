//! The built-in [`SchedulingPolicy`] implementations.
//!
//! * [`SlicedPolicy`] — the whole sliced family (SLS, SO, PM, AB, LB,
//!   SCLS): static batching workers driven by a [`SlicedCoordinator`]
//!   built from a `SchedulerSpec`'s four axes.
//! * [`IlsPolicy`] — the DeepSpeed-FastGen-style iteration-level baseline
//!   (continuous batching, conservative parallel cap, §5.1).
//! * [`SclsCbPolicy`] — the §7 extension: slice-level scheduling over
//!   continuous batching with precise per-slice memory admission and
//!   memory-balanced placement.
//! * [`PredictiveSlicedPolicy`] (P-SCLS) — SCLS seeded by a
//!   [`LengthPredictor`]: each request enters the slice ladder at the rung
//!   matching its predicted length bucket instead of the bottom, with
//!   under-predictions re-queued one rung at a time.
//! * [`PredictiveCbPolicy`] (P-CB) — continuous batching that admits
//!   against *predicted* KV demand instead of the worst case, with
//!   eviction/re-admission recovery when predictions fall short.
//!
//! Each pre-existing policy is a faithful port of the corresponding
//! pre-trait driver loop (`sim::reference`); the differential suite
//! (`tests/props_policy_differential.rs`) asserts the ports are
//! byte-identical on the full `RunMetrics` event log.

use std::collections::VecDeque;

use crate::batcher::{dp_batch_sorted_into, fcfs_batches, DpBatcherConfig, DpScratch};
use crate::core::{Batch, Request};
use crate::engine::continuous::ContinuousWorker;
use crate::engine::continuous_pred::PredictiveContinuousWorker;
use crate::engine::continuous_scls::SlicedContinuousWorker;
use crate::engine::sim::SimEngine;
use crate::estimator::{MemoryEstimator, ServingTimeEstimator};
use crate::metrics::{BatchRecord, PredictionRecord, RunMetrics};
use crate::offloader::{LoadLedger, RoundRobin};
use crate::predictor::LengthPredictor;
use crate::scheduler::coordinator::SlicedCoordinator;
use crate::scheduler::policy::{SchedulingPolicy, SimCtx};
use crate::scheduler::spec::{BatchingSpec, IntervalSpec, OffloadSpec, SchedulerSpec};
use crate::scheduler::{IntervalController, RequestPool};
use crate::sim::driver::{fitted_estimator, SimConfig};

// ---------------------------------------------------------------------------
// Shared static-batching serving start
// ---------------------------------------------------------------------------

/// Serving-start accounting shared by every static-batching policy
/// (sliced family and P-SCLS): charge each request its pads and a pass,
/// serve one slice of `iter_limit` iterations, log the batch record,
/// apply token outcomes (the SCLS reschedule prefill recomputes over
/// input + generated), park the batch in the worker's serving slot, and
/// schedule the completion event.
fn start_static_batch(
    engine: &mut SimEngine,
    serving: &mut Option<Batch>,
    w: usize,
    mut batch: Batch,
    iter_limit: u32,
    ctx: &mut SimCtx,
) {
    debug_assert!(serving.is_none(), "worker {w} already serving");
    let li = batch.input_len();
    for r in &mut batch.requests {
        r.slices += 1;
        r.pad_tokens += (li - r.input_len) as u64;
    }
    let outcome = engine.serve_slice(&batch, iter_limit);
    ctx.record_batch(BatchRecord {
        start: ctx.now,
        worker: w,
        size: batch.size() as u32,
        input_len: li,
        pad_tokens: batch.pad_tokens(),
        est_serve_time: batch.est_serve_time,
        actual_serve_time: outcome.duration,
        early_return: outcome.early_return,
    });
    // Apply token effects now, deliver at done-time (the serving slot
    // pairs the batch with its outcome).
    let done_at = ctx.now + outcome.duration;
    for (r, o) in batch.requests.iter_mut().zip(&outcome.per_request) {
        debug_assert_eq!(r.id, o.id);
        r.generated += o.new_tokens;
        r.invalid_tokens += o.invalid_tokens as u64;
        // SCLS reschedule: the next prefill recomputes over input +
        // everything generated so far.
        r.input_len += o.new_tokens;
        if o.finished {
            r.finished_at = Some(done_at);
        }
    }
    *serving = Some(batch);
    ctx.complete_at(done_at, w);
}

// ---------------------------------------------------------------------------
// Sliced family (SLS / SO / PM / AB / LB / SCLS)
// ---------------------------------------------------------------------------

/// Per-worker state for the sliced-family policy.
struct WorkerState {
    /// Coordinator-formed batches waiting in the local queue.
    batch_queue: VecDeque<Batch>,
    /// Worker-locus FCFS: raw requests waiting locally (SLS/SO).
    req_queue: VecDeque<Request>,
    /// The batch currently being served (None = idle).
    serving: Option<Batch>,
    engine: SimEngine,
    last_done: f64,
}

/// Static-batching sliced-family scheduler: any `SchedulerSpec` point
/// (slice length × batching × offload × interval) over simulated workers.
pub struct SlicedPolicy {
    coord: SlicedCoordinator,
    est: ServingTimeEstimator,
    mem: MemoryEstimator,
    workers: Vec<WorkerState>,
}

impl SlicedPolicy {
    /// Build the policy the way the SCLS deployment starts up (§4.2):
    /// profile the engine's latency model once, fit Eq. (3)/(4), then
    /// instantiate per-worker engines on decorrelated seed streams.
    pub fn new(spec: &SchedulerSpec, cfg: &SimConfig) -> SlicedPolicy {
        assert!(cfg.workers > 0);
        let est = fitted_estimator(&cfg.engine, cfg.seed);
        let mem = cfg.engine.memory_estimator();
        let workers: Vec<WorkerState> = (0..cfg.workers)
            .map(|w| WorkerState {
                batch_queue: VecDeque::new(),
                req_queue: VecDeque::new(),
                serving: None,
                engine: SimEngine::new(
                    cfg.engine.latency(cfg.seed ^ (w as u64).wrapping_mul(0x9E37)),
                    cfg.max_gen_len,
                ),
                last_done: 0.0,
            })
            .collect();
        // `pred_corrected_dp` is deliberately NOT forwarded here: plain
        // sliced policies never stamp `predicted_gen`, so the correction
        // would change nothing semantically while trading the optimized
        // DP planner for the scalar corrected loop. Prediction-aware
        // callers that share this coordinator (the real-mode driver, or a
        // custom policy stamping predictions before `admit`) opt in via
        // `SlicedCoordinator::set_pred_correction`.
        SlicedPolicy {
            coord: SlicedCoordinator::new(spec, cfg.workers),
            est,
            mem,
            workers,
        }
    }

    /// Start serving on worker `w` if idle and work is queued.
    fn try_start(&mut self, w: usize, ctx: &mut SimCtx) {
        let slice_len = self.coord.spec().slice_len;
        let batching = self.coord.spec().batching.clone();
        let ws = &mut self.workers[w];
        if ws.serving.is_some() {
            return;
        }
        // Worker-locus FCFS: form a batch from the local request queue.
        if let BatchingSpec::WorkerFcfs { batch_size } = batching {
            if ws.batch_queue.is_empty() && !ws.req_queue.is_empty() {
                let take = (batch_size as usize).min(ws.req_queue.len());
                let reqs: Vec<Request> = ws.req_queue.drain(..take).collect();
                let mut batches = fcfs_batches(reqs, batch_size, &self.est, slice_len);
                debug_assert_eq!(batches.len(), 1);
                ws.batch_queue.push_back(batches.pop().unwrap());
            }
        }
        let Some(batch) = ws.batch_queue.pop_front() else {
            return;
        };
        start_static_batch(&mut ws.engine, &mut ws.serving, w, batch, slice_len, ctx);
    }
}

impl SchedulingPolicy for SlicedPolicy {
    fn init(&mut self, ctx: &mut SimCtx) {
        self.coord.reserve_pool(ctx.arrivals_left().min(1 << 16));
        if self.coord.has_ticks() {
            ctx.tick_at(0.0);
        }
    }

    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx) {
        // SLS/SO: round-robin to a worker queue; otherwise pooled.
        if let Some((w, r)) = self.coord.admit(req) {
            self.workers[w].req_queue.push_back(r);
            self.try_start(w, ctx);
        }
    }

    fn on_tick(&mut self, ctx: &mut SimCtx) {
        if !self.coord.has_ticks() {
            return;
        }
        let drained = self.coord.schedule_tick(&self.est, &self.mem);
        if drained > 0 {
            ctx.observe_pool(drained);
            let mut assign = self.coord.take_assignments();
            for (w, b) in assign.drain(..) {
                self.workers[w].batch_queue.push_back(b);
                self.try_start(w, ctx);
            }
            self.coord.recycle_assignments(assign);
        }
        // Re-arm the tick while any work can still appear.
        let work_pending = ctx.arrivals_left() > 0
            || !self.coord.pool_is_empty()
            || self
                .workers
                .iter()
                .any(|w| w.serving.is_some() || !w.batch_queue.is_empty());
        if work_pending {
            let t = self
                .coord
                .next_interval()
                .expect("on_tick only fires for ticked policies");
            ctx.tick_at(ctx.now + t.max(1e-3));
        }
    }

    fn on_worker_done(&mut self, w: usize, ctx: &mut SimCtx) {
        let batch = self.workers[w].serving.take().expect("done without serving");
        self.coord.batch_done(w, batch.est_serve_time);
        self.workers[w].last_done = ctx.now;
        for r in batch.requests {
            if r.is_finished() {
                ctx.record_completion(&r);
            } else if let Some((tw, r)) = self.coord.admit(r) {
                // SO: re-send unfinished requests round-robin.
                self.workers[tw].req_queue.push_back(r);
                self.try_start(tw, ctx);
            }
        }
        self.try_start(w, ctx);
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.workers.iter().map(|w| w.last_done).collect();
    }
}

// ---------------------------------------------------------------------------
// ILS: iteration-level scheduling with continuous batching (FastGen-like)
// ---------------------------------------------------------------------------

/// The ILS baseline: per-iteration joins and exits, no padding, no invalid
/// tokens — but a conservative cap on parallel requests plus a KV-memory
/// admission check (§1, §5.1). Requests are offloaded round-robin, as the
/// paper's baselines do (§3.2).
pub struct IlsPolicy {
    workers: Vec<ContinuousWorker>,
    looping: Vec<bool>,
    last_done: Vec<f64>,
    rr: RoundRobin,
    kv_budget: u64,
    max_kv_seen: u64,
}

impl IlsPolicy {
    pub fn new(cfg: &SimConfig) -> IlsPolicy {
        assert!(cfg.workers > 0);
        let kv_budget = (0.9 * cfg.engine.m_ava as f64) as u64;
        let workers: Vec<ContinuousWorker> = (0..cfg.workers)
            .map(|w| {
                ContinuousWorker::new(
                    cfg.engine
                        .latency(cfg.seed ^ (w as u64).wrapping_mul(0xA5A5)),
                    cfg.engine.ils_max_parallel,
                    kv_budget,
                    cfg.engine.kv_delta,
                    cfg.max_gen_len,
                )
            })
            .collect();
        let n = workers.len();
        IlsPolicy {
            workers,
            looping: vec![false; n],
            last_done: vec![0.0; n],
            rr: RoundRobin::new(n),
            kv_budget,
            max_kv_seen: 0,
        }
    }

    /// Per-instance KV budget the admission check enforces.
    pub fn kv_budget(&self) -> u64 {
        self.kv_budget
    }

    /// Largest KV-in-use observed on any instance (no-OOM invariant:
    /// never exceeds [`Self::kv_budget`]).
    pub fn max_kv_observed(&self) -> u64 {
        self.max_kv_seen
    }

    /// Kick worker `w`'s iteration loop if it is idle.
    fn kick(&mut self, w: usize, ctx: &mut SimCtx) {
        if !self.looping[w] {
            if let Some(d) = self.workers[w].begin_iteration() {
                self.looping[w] = true;
                self.max_kv_seen = self.max_kv_seen.max(self.workers[w].kv_in_use());
                ctx.complete_at(ctx.now + d, w);
            }
        }
    }
}

impl SchedulingPolicy for IlsPolicy {
    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx) {
        let w = self.rr.next_worker();
        self.workers[w].waiting.push_back(req);
        self.kick(w, ctx);
    }

    fn on_worker_done(&mut self, wi: usize, ctx: &mut SimCtx) {
        for r in self.workers[wi].finish_iteration(ctx.now) {
            self.last_done[wi] = ctx.now;
            ctx.record_completion(&r);
        }
        if let Some(d) = self.workers[wi].begin_iteration() {
            self.max_kv_seen = self.max_kv_seen.max(self.workers[wi].kv_in_use());
            ctx.complete_at(ctx.now + d, wi);
        } else {
            self.looping[wi] = false;
        }
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.last_done.clone();
    }
}

// ---------------------------------------------------------------------------
// SCLS-CB: slice-level scheduling over continuous batching (paper §7)
// ---------------------------------------------------------------------------

/// The §7 extension: continuous batching per instance (no pads, no invalid
/// tokens), each schedule capped at `slice_len` generated tokens,
/// **precise** per-slice memory admission instead of ILS's conservative
/// cap, and coordinator-side offloading of new and rescheduled requests to
/// the instance with the most free projected KV memory.
pub struct SclsCbPolicy {
    workers: Vec<SlicedContinuousWorker>,
    looping: Vec<bool>,
    last_done: Vec<f64>,
    kv_budget: u64,
    max_kv_seen: u64,
}

impl SclsCbPolicy {
    pub fn new(cfg: &SimConfig, slice_len: u32) -> SclsCbPolicy {
        assert!(cfg.workers > 0);
        let kv_budget = (0.9 * cfg.engine.m_ava as f64) as u64;
        let workers: Vec<SlicedContinuousWorker> = (0..cfg.workers)
            .map(|w| {
                SlicedContinuousWorker::new(
                    cfg.engine
                        .latency(cfg.seed ^ (w as u64).wrapping_mul(0x5A5A)),
                    slice_len,
                    kv_budget,
                    cfg.engine.kv_delta,
                    cfg.max_gen_len,
                )
            })
            .collect();
        let n = workers.len();
        SclsCbPolicy {
            workers,
            looping: vec![false; n],
            last_done: vec![0.0; n],
            kv_budget,
            max_kv_seen: 0,
        }
    }

    /// Per-instance KV budget the precise admission enforces.
    pub fn kv_budget(&self) -> u64 {
        self.kv_budget
    }

    /// Largest *projected* KV observed on any instance after admission
    /// (no-OOM invariant: never exceeds [`Self::kv_budget`]).
    pub fn max_kv_observed(&self) -> u64 {
        self.max_kv_seen
    }

    /// Offload to the instance with the most free projected memory (ties:
    /// shortest local queue); kick its iteration loop if idle.
    fn assign(&mut self, r: Request, ctx: &mut SimCtx) {
        let w = (0..self.workers.len())
            .min_by(|&a, &b| {
                self.workers[a]
                    .kv_projected()
                    .cmp(&self.workers[b].kv_projected())
                    .then_with(|| {
                        self.workers[a]
                            .waiting
                            .len()
                            .cmp(&self.workers[b].waiting.len())
                    })
            })
            .unwrap();
        self.workers[w].waiting.push_back(r);
        if !self.looping[w] {
            if let Some(d) = self.workers[w].begin_iteration() {
                self.looping[w] = true;
                self.max_kv_seen = self.max_kv_seen.max(self.workers[w].kv_projected());
                ctx.complete_at(ctx.now + d, w);
            }
        }
    }
}

impl SchedulingPolicy for SclsCbPolicy {
    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx) {
        self.assign(req, ctx);
    }

    fn on_worker_done(&mut self, wi: usize, ctx: &mut SimCtx) {
        let exits = self.workers[wi].finish_iteration(ctx.now);
        for r in exits.done {
            self.last_done[wi] = ctx.now;
            ctx.record_completion(&r);
        }
        // §7: slice-capped requests are rescheduled to the least
        // memory-loaded instance (their KV was just released).
        for r in exits.rescheduled {
            self.assign(r, ctx);
        }
        if let Some(d) = self.workers[wi].begin_iteration() {
            self.max_kv_seen = self.max_kv_seen.max(self.workers[wi].kv_projected());
            ctx.complete_at(ctx.now + d, wi);
        } else {
            self.looping[wi] = false;
        }
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.last_done.clone();
    }
}

// ---------------------------------------------------------------------------
// P-SCLS: prediction-seeded slice-level scheduling (static batching)
// ---------------------------------------------------------------------------

/// Per-worker state for P-SCLS: coordinator-formed batches carry the
/// iteration budget of the rung they were cut for.
struct PredWorkerState {
    /// (iteration budget, batch) pairs waiting in the local queue.
    batch_queue: VecDeque<(u32, Batch)>,
    /// The batch currently being served (None = idle).
    serving: Option<Batch>,
    engine: SimEngine,
    last_done: f64,
}

/// **P-SCLS** — SCLS with prediction-seeded ladder entry.
///
/// Baseline SCLS serves every request S tokens per schedule: a request
/// that generates `k·S` tokens climbs the ladder in `k` passes, paying a
/// full re-prefill (input + generated so far) at each rung. P-SCLS asks a
/// [`LengthPredictor`] once at arrival and seeds the request at the rung
/// matching its predicted bucket: its *first* schedule gets an iteration
/// budget of `k·S` (k = ⌈pred/S⌉), so an accurately predicted request
/// completes in one pass with one prefill. Requests are pooled per rung;
/// each tick runs the Alg. 1 DP batcher *within* each rung (so co-batched
/// requests share both input-length affinity and iteration budget) and
/// offloads all rung batches together via the spec's offload axis.
///
/// Mispredict recovery:
/// * **under-prediction** — a request unfinished after its seeded pass is
///   re-queued to the next rung: one more pass of S (vanilla SCLS
///   behaviour from there on), counted in `RunMetrics::underpredicted`;
/// * **over-prediction** — a completion whose reserved rungs exceed
///   ⌈generated/S⌉ logs the unused rungs as `wasted_kv_token_steps`
///   (rung-granular: `(reserved − needed)·S` token-slots).
///
/// Every completion is also fed back through
/// [`LengthPredictor::observe`], so an online predictor
/// ([`crate::predictor::OnlineBuckets`]) refits its buckets from the
/// traffic it actually served. With `SimConfig::pred_corrected_dp` the
/// per-rung DP additionally costs batches at their *predicted* budget
/// instead of the rung's worst case (see [`crate::batcher::dp`]), so the
/// load ledger and LPT offload see estimates that anticipate early
/// returns. The corrected planner is a running-max-aware branch-and-bound
/// over the bulk estimator kernels — on par with the legacy optimized
/// path — so the correction no longer costs P-SCLS its tick budget at
/// large pools.
///
/// With the [`crate::predictor::Oracle`] predictor every request completes
/// in exactly one pass, which is never more passes than baseline SCLS —
/// the invariant `props_predictor.rs` checks on fixed seeds.
pub struct PredictiveSlicedPolicy {
    spec: SchedulerSpec,
    predictor: Box<dyn LengthPredictor>,
    est: ServingTimeEstimator,
    mem: MemoryEstimator,
    ledger: LoadLedger,
    rr: RoundRobin,
    interval: IntervalController,
    /// One pool per rung: `pools[b-1]` holds requests whose next pass gets
    /// an iteration budget of `b·S` (requeues always land on rung 1).
    pools: Vec<RequestPool>,
    workers: Vec<PredWorkerState>,
    max_gen_len: u32,
    max_rung: u32,
    /// Cost rung batches at their predicted budget (`SimConfig::pred_corrected_dp`).
    pred_corrected: bool,
    // Reused per-tick buffers (allocation-lean discipline from PR 1).
    tick_reqs: Vec<Request>,
    batch_buf: Vec<Batch>,
    staged: Vec<(u32, Batch)>,
    assign_buf: Vec<(usize, u32, Batch)>,
    dp_scratch: DpScratch,
}

impl PredictiveSlicedPolicy {
    pub fn new(
        spec: &SchedulerSpec,
        cfg: &SimConfig,
        predictor: Box<dyn LengthPredictor>,
    ) -> PredictiveSlicedPolicy {
        assert!(cfg.workers > 0);
        let s = spec.slice_len.max(1);
        let max_rung = ((cfg.max_gen_len + s - 1) / s).max(1);
        let est = fitted_estimator(&cfg.engine, cfg.seed);
        let mem = cfg.engine.memory_estimator();
        let workers: Vec<PredWorkerState> = (0..cfg.workers)
            .map(|w| PredWorkerState {
                batch_queue: VecDeque::new(),
                serving: None,
                engine: SimEngine::new(
                    cfg.engine.latency(cfg.seed ^ (w as u64).wrapping_mul(0x7A3D)),
                    cfg.max_gen_len,
                ),
                last_done: 0.0,
            })
            .collect();
        let interval = match spec.interval {
            IntervalSpec::Fixed(t) => IntervalController::Fixed(t),
            IntervalSpec::Adaptive { lambda, gamma } => {
                IntervalController::Adaptive { lambda, gamma }
            }
            // P-SCLS is inherently ticked: pooling per rung needs a tick.
            IntervalSpec::Immediate => IntervalController::Fixed(cfg.engine.gamma),
        };
        PredictiveSlicedPolicy {
            spec: spec.clone(),
            predictor,
            est,
            mem,
            ledger: LoadLedger::new(cfg.workers),
            rr: RoundRobin::new(cfg.workers),
            interval,
            pools: (0..max_rung).map(|_| RequestPool::new()).collect(),
            workers,
            max_gen_len: cfg.max_gen_len,
            max_rung,
            pred_corrected: cfg.pred_corrected_dp,
            tick_reqs: Vec::new(),
            batch_buf: Vec::new(),
            staged: Vec::new(),
            assign_buf: Vec::new(),
            dp_scratch: DpScratch::new(),
        }
    }

    /// Ladder rung for a predicted total generation length.
    fn rung_of(&self, predicted: u32) -> u32 {
        let s = self.spec.slice_len.max(1);
        let eff = predicted.min(self.max_gen_len).max(1);
        ((eff + s - 1) / s).clamp(1, self.max_rung)
    }

    /// Iteration budget of rung `b` (the whole ladder up to the rung).
    fn rung_budget(&self, b: u32) -> u32 {
        (b * self.spec.slice_len).min(self.max_gen_len).max(1)
    }

    fn pooled(&self) -> usize {
        self.pools.iter().map(|p| p.len()).sum()
    }

    /// Start serving on worker `w` if idle and work is queued.
    fn try_start(&mut self, w: usize, ctx: &mut SimCtx) {
        if self.workers[w].serving.is_some() {
            return;
        }
        let Some((budget, batch)) = self.workers[w].batch_queue.pop_front() else {
            return;
        };
        let ws = &mut self.workers[w];
        start_static_batch(&mut ws.engine, &mut ws.serving, w, batch, budget, ctx);
    }
}

impl SchedulingPolicy for PredictiveSlicedPolicy {
    fn init(&mut self, ctx: &mut SimCtx) {
        self.pools[0].reserve(ctx.arrivals_left().min(1 << 16));
        ctx.tick_at(0.0);
    }

    fn on_arrival(&mut self, mut req: Request, _ctx: &mut SimCtx) {
        // Pooled until the next tick; the seeded rung is the prediction's.
        let pred = self.predictor.predict(&req).max(1);
        req.predicted_gen = Some(pred);
        let rung = self.rung_of(pred);
        self.pools[rung as usize - 1].push(req);
    }

    fn on_tick(&mut self, ctx: &mut SimCtx) {
        let drained = self.pooled();
        if drained > 0 {
            ctx.observe_pool(drained);
            // DP-batch each rung with the rung's iteration budget, then
            // offload everything together.
            for b in 1..=self.max_rung {
                if self.pools[b as usize - 1].is_empty() {
                    continue;
                }
                let budget = self.rung_budget(b);
                self.pools[b as usize - 1].drain_sorted_into(&mut self.tick_reqs);
                let dp_cfg = DpBatcherConfig {
                    slice_len: budget,
                    max_batch_size: match self.spec.batching {
                        BatchingSpec::Dp { max_batch_size } => max_batch_size,
                        BatchingSpec::WorkerFcfs { batch_size } => Some(batch_size),
                    },
                    pred_corrected: self.pred_corrected,
                };
                dp_batch_sorted_into(
                    &mut self.tick_reqs,
                    &self.est,
                    &self.mem,
                    &dp_cfg,
                    &mut self.dp_scratch,
                    &mut self.batch_buf,
                );
                // Correction accounting: the batcher counted how many
                // batches it costed strictly below the rung's slice cap.
                for _ in 0..self.dp_scratch.corrected_batches() {
                    ctx.record_corrected_batch();
                }
                self.staged
                    .extend(self.batch_buf.drain(..).map(|batch| (budget, batch)));
            }
            match self.spec.offload {
                OffloadSpec::MaxMin => {
                    // LPT over all rung batches: longest estimate first to
                    // the least-loaded worker (paper §4.5).
                    self.staged
                        .sort_by(|a, b| b.1.est_serve_time.total_cmp(&a.1.est_serve_time));
                    for (budget, batch) in self.staged.drain(..) {
                        let w = self.ledger.argmin();
                        self.ledger.add(w, batch.est_serve_time);
                        self.assign_buf.push((w, budget, batch));
                    }
                }
                OffloadSpec::RoundRobin => {
                    for (budget, batch) in self.staged.drain(..) {
                        let w = self.rr.next_worker();
                        self.ledger.add(w, batch.est_serve_time);
                        self.assign_buf.push((w, budget, batch));
                    }
                }
            }
            let mut assign = std::mem::take(&mut self.assign_buf);
            for (w, budget, batch) in assign.drain(..) {
                self.workers[w].batch_queue.push_back((budget, batch));
                self.try_start(w, ctx);
            }
            self.assign_buf = assign;
        }
        // Re-arm the tick while any work can still appear.
        let work_pending = ctx.arrivals_left() > 0
            || self.pooled() > 0
            || self
                .workers
                .iter()
                .any(|w| w.serving.is_some() || !w.batch_queue.is_empty());
        if work_pending {
            let t = self.interval.next_interval(&self.ledger);
            ctx.tick_at(ctx.now + t.max(1e-3));
        }
    }

    fn on_worker_done(&mut self, w: usize, ctx: &mut SimCtx) {
        let batch = self.workers[w].serving.take().expect("done without serving");
        self.ledger.complete(w, batch.est_serve_time);
        self.workers[w].last_done = ctx.now;
        let s = self.spec.slice_len.max(1);
        for r in batch.requests {
            if r.is_finished() {
                // Completion feedback: online predictors refit from the
                // true generated length.
                if self.predictor.observe(&r, r.generated) {
                    ctx.record_refit();
                }
                // Over-prediction accounting, rung-granular: rungs reserved
                // (seeded rung + one per extra pass) vs rungs needed.
                let k0 = self.rung_of(r.predicted_gen.unwrap_or(1)) as u64;
                let reserved = k0 + (r.slices.max(1) as u64 - 1);
                let needed = ((r.generated.max(1) + s - 1) / s) as u64;
                if reserved > needed {
                    ctx.record_prediction(PredictionRecord {
                        id: r.id,
                        underpredicted: false,
                        wasted_tokens: (reserved - needed) * s as u64,
                    });
                }
                ctx.record_completion(&r);
            } else {
                // Under-prediction: re-queue to the next rung (one more
                // pass of S from here on).
                ctx.record_prediction(PredictionRecord {
                    id: r.id,
                    underpredicted: true,
                    wasted_tokens: 0,
                });
                self.pools[0].push(r);
            }
        }
        self.try_start(w, ctx);
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.workers.iter().map(|w| w.last_done).collect();
    }
}

// ---------------------------------------------------------------------------
// P-CB: continuous batching with predicted-KV admission
// ---------------------------------------------------------------------------

/// **P-CB** — continuous batching that admits against *predicted* KV
/// demand instead of the worst-case `max_gen_len` reservation.
///
/// Each request is stamped with a [`LengthPredictor`] estimate at arrival
/// and placed on the instance with the most free *reserved* memory; the
/// instance admits it iff its predicted remaining generation fits
/// alongside the reservations already running
/// ([`PredictiveContinuousWorker`]). Recovery: under-predicted requests
/// are evicted at the boundary where their reservation runs out and
/// re-admitted with a doubled prediction (so recoveries per request are
/// logarithmic), paying a fresh prefill like an SCLS-CB slice exit;
/// over-predicted completions log their unused reservation. The KV-budget
/// invariant therefore holds under arbitrary prediction error — the
/// property `props_predictor.rs` hammers across randomized error draws.
/// Every completion is fed back through [`LengthPredictor::observe`], so
/// an online predictor refits its reservation model from served traffic.
pub struct PredictiveCbPolicy {
    workers: Vec<PredictiveContinuousWorker>,
    looping: Vec<bool>,
    last_done: Vec<f64>,
    predictor: Box<dyn LengthPredictor>,
    max_gen_len: u32,
    kv_budget: u64,
    max_kv_seen: u64,
}

impl PredictiveCbPolicy {
    pub fn new(cfg: &SimConfig, predictor: Box<dyn LengthPredictor>) -> PredictiveCbPolicy {
        assert!(cfg.workers > 0);
        let kv_budget = (0.9 * cfg.engine.m_ava as f64) as u64;
        let workers: Vec<PredictiveContinuousWorker> = (0..cfg.workers)
            .map(|w| {
                PredictiveContinuousWorker::new(
                    cfg.engine
                        .latency(cfg.seed ^ (w as u64).wrapping_mul(0xD1CE)),
                    kv_budget,
                    cfg.engine.kv_delta,
                    cfg.max_gen_len,
                )
            })
            .collect();
        let n = workers.len();
        PredictiveCbPolicy {
            workers,
            looping: vec![false; n],
            last_done: vec![0.0; n],
            predictor,
            max_gen_len: cfg.max_gen_len,
            kv_budget,
            max_kv_seen: 0,
        }
    }

    /// Per-instance KV budget the predicted admission enforces.
    pub fn kv_budget(&self) -> u64 {
        self.kv_budget
    }

    /// Largest *projected* (reservation-sum) KV observed on any instance
    /// after admission — the no-OOM invariant bounds actual use by it, and
    /// it never exceeds [`Self::kv_budget`].
    pub fn max_kv_observed(&self) -> u64 {
        self.max_kv_seen
    }

    /// Offload to the instance with the most free reserved memory (ties:
    /// shortest local queue); kick its iteration loop if idle.
    fn assign(&mut self, r: Request, ctx: &mut SimCtx) {
        let w = (0..self.workers.len())
            .min_by(|&a, &b| {
                self.workers[a]
                    .kv_projected()
                    .cmp(&self.workers[b].kv_projected())
                    .then_with(|| {
                        self.workers[a]
                            .waiting
                            .len()
                            .cmp(&self.workers[b].waiting.len())
                    })
            })
            .unwrap();
        self.workers[w].waiting.push_back(r);
        if !self.looping[w] {
            if let Some(d) = self.workers[w].begin_iteration() {
                self.looping[w] = true;
                self.max_kv_seen = self.max_kv_seen.max(self.workers[w].kv_projected());
                ctx.complete_at(ctx.now + d, w);
            }
        }
    }
}

impl SchedulingPolicy for PredictiveCbPolicy {
    fn on_arrival(&mut self, mut req: Request, ctx: &mut SimCtx) {
        req.predicted_gen = Some(self.predictor.predict(&req).max(1));
        self.assign(req, ctx);
    }

    fn on_worker_done(&mut self, wi: usize, ctx: &mut SimCtx) {
        let exits = self.workers[wi].finish_iteration(ctx.now);
        for (r, unused) in exits.done {
            self.last_done[wi] = ctx.now;
            // Completion feedback: online predictors refit from the true
            // generated length.
            if self.predictor.observe(&r, r.generated) {
                ctx.record_refit();
            }
            if unused > 0 {
                ctx.record_prediction(PredictionRecord {
                    id: r.id,
                    underpredicted: false,
                    wasted_tokens: unused as u64,
                });
            }
            ctx.record_completion(&r);
        }
        // Mispredict recovery: evicted requests re-enter with a doubled
        // prediction (capped at the generation limit), so each request is
        // re-admitted at most O(log max_gen_len) times.
        for mut r in exits.evicted {
            ctx.record_prediction(PredictionRecord {
                id: r.id,
                underpredicted: true,
                wasted_tokens: 0,
            });
            let old = r.predicted_gen.unwrap_or(self.max_gen_len);
            let bumped = old
                .max(r.generated)
                .saturating_mul(2)
                .min(self.max_gen_len.max(r.generated + 1));
            r.predicted_gen = Some(bumped);
            self.assign(r, ctx);
        }
        if let Some(d) = self.workers[wi].begin_iteration() {
            self.max_kv_seen = self.max_kv_seen.max(self.workers[wi].kv_projected());
            ctx.complete_at(ctx.now + d, wi);
        } else {
            self.looping[wi] = false;
        }
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.last_done.clone();
    }
}
