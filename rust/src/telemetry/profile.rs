//! Opt-in hot-path profiling: cheap wall-clock section timers on the
//! coordinator's schedule-tick paths (`dp_plan` / `dp_plan_corrected`,
//! max-min offload, pool drain-sort).
//!
//! Profiling is **off by default** and gated by one thread-local boolean:
//! an instrumented site costs a single TLS load when disabled and never
//! allocates, so the default simulation path carries zero instrumentation
//! overhead that could perturb benchmarks. Timings are *wall-clock* and
//! never enter `RunMetrics` or any deterministic result JSON — they are
//! surfaced separately (the `simulate --profile` report and the
//! `micro_hotpaths` bench), so enabling profiling cannot move a run's
//! byte-identical fingerprint.
//!
//! Usage at an instrumented site (the guard must be bound to a named
//! variable — binding to `_` drops it immediately and times nothing):
//!
//! ```
//! let _t = scls::telemetry::profile::timer("dp_plan");
//! // ... hot path ...
//! // guard drop records the elapsed time when profiling is enabled
//! ```
//!
//! Collection is per-thread: `enable()` / `take()` operate on the calling
//! thread's profile, matching the single-threaded DES loop. Profiles from
//! worker threads can be combined with [`HotPathProfile::merge`].

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::time::Instant;

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static PROFILE: RefCell<HotPathProfile> = RefCell::new(HotPathProfile::default());
}

/// Thin wall-clock stopwatch (monotonic, ns resolution).
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed_ns(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }
}

/// Accumulated timings of one instrumented section.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SectionStat {
    pub count: u64,
    pub total_ns: u64,
    pub max_ns: u64,
}

impl SectionStat {
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.count as f64
        }
    }
}

/// Per-thread profile: section name → accumulated stat. Section names are
/// static strings so recording never allocates.
#[derive(Debug, Clone, Default)]
pub struct HotPathProfile {
    pub sections: BTreeMap<&'static str, SectionStat>,
}

impl HotPathProfile {
    pub fn is_empty(&self) -> bool {
        self.sections.is_empty()
    }

    fn record(&mut self, section: &'static str, ns: u64) {
        let s = self.sections.entry(section).or_default();
        s.count += 1;
        s.total_ns += ns;
        s.max_ns = s.max_ns.max(ns);
    }

    /// Fold another profile in (e.g. from a worker thread).
    pub fn merge(&mut self, other: &HotPathProfile) {
        for (name, o) in &other.sections {
            let s = self.sections.entry(name).or_default();
            s.count += o.count;
            s.total_ns += o.total_ns;
            s.max_ns = s.max_ns.max(o.max_ns);
        }
    }

    /// Human-readable per-section report (one line per section).
    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        if self.sections.is_empty() {
            out.push_str("hot-path profile: no sections recorded\n");
            return out;
        }
        out.push_str("hot-path profile (wall-clock):\n");
        for (name, s) in &self.sections {
            let _ = writeln!(
                out,
                "  {name:<18} calls {:>8}  total {:>10.3} ms  mean {:>9.1} ns  max {:>9} ns",
                s.count,
                s.total_ns as f64 / 1e6,
                s.mean_ns(),
                s.max_ns
            );
        }
        out
    }
}

/// Turn profiling on for the calling thread (idempotent).
pub fn enable() {
    ENABLED.with(|e| e.set(true));
}

/// Turn profiling off for the calling thread. Accumulated sections are
/// kept until [`take`].
pub fn disable() {
    ENABLED.with(|e| e.set(false));
}

pub fn is_enabled() -> bool {
    ENABLED.with(|e| e.get())
}

/// Take (and reset) the calling thread's accumulated profile.
pub fn take() -> HotPathProfile {
    PROFILE.with(|p| std::mem::take(&mut *p.borrow_mut()))
}

/// RAII section timer: records elapsed wall time into the thread profile
/// on drop. Obtain through [`timer`].
#[derive(Debug)]
pub struct TimerGuard {
    section: &'static str,
    sw: Stopwatch,
}

impl Drop for TimerGuard {
    fn drop(&mut self) {
        let ns = self.sw.elapsed_ns();
        PROFILE.with(|p| p.borrow_mut().record(self.section, ns));
    }
}

/// Start timing `section` when profiling is enabled; `None` (one TLS bool
/// load, no allocation) otherwise. Bind the result to a named variable.
#[inline]
pub fn timer(section: &'static str) -> Option<TimerGuard> {
    if !is_enabled() {
        return None;
    }
    Some(TimerGuard {
        section,
        sw: Stopwatch::start(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_timer_records_nothing() {
        disable();
        let _ = take(); // reset any prior state on this test thread
        {
            let _t = timer("noop");
            assert!(_t.is_none());
        }
        assert!(take().is_empty());
    }

    #[test]
    fn enabled_timer_accumulates_sections() {
        disable();
        let _ = take();
        enable();
        for _ in 0..3 {
            let _t = timer("section_a");
            std::hint::black_box(0u64);
        }
        {
            let _t = timer("section_b");
        }
        disable();
        let prof = take();
        assert_eq!(prof.sections["section_a"].count, 3);
        assert_eq!(prof.sections["section_b"].count, 1);
        assert!(prof.sections["section_a"].total_ns >= prof.sections["section_a"].max_ns);
        let report = prof.report();
        assert!(report.contains("section_a") && report.contains("section_b"));
    }

    #[test]
    fn merge_folds_counts_and_maxima() {
        let mut a = HotPathProfile::default();
        a.record("x", 10);
        let mut b = HotPathProfile::default();
        b.record("x", 30);
        b.record("y", 5);
        a.merge(&b);
        assert_eq!(a.sections["x"].count, 2);
        assert_eq!(a.sections["x"].total_ns, 40);
        assert_eq!(a.sections["x"].max_ns, 30);
        assert_eq!(a.sections["y"].count, 1);
    }
}
