//! Workload generation: the paper's request traces (§3.3, §5.1).

pub mod distributions;
pub mod trace;

pub use distributions::{LengthDistribution, LengthSample};
pub use trace::{Trace, TraceConfig};
