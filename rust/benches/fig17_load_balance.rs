//! Fig. 17 — load imbalance: the standard deviation of per-instance
//! completion times vs arrival rate, for all five cells. Prints the
//! reproduced series, then times the max-min offloader against its
//! round-robin baseline at tick scale.

use scls::batcher::{dp_batch, DpBatcherConfig};
use scls::bench::figures::{fig17, FigureConfig};
use scls::bench::harness::{bench, report_header};
use scls::core::{Batch, Request};
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::offloader::{LoadLedger, MaxMinOffloader, RoundRobin};
use scls::sim::driver::fitted_estimator;
use scls::util::rng::Rng;

fn main() {
    let fc = FigureConfig::quick(0.1);
    fig17(&fc, &[12.0, 16.0, 20.0, 24.0, 28.0]).print();

    // One tick's worth of batches for the offloader micro-bench.
    let preset = EnginePreset::paper(EngineKind::Ds);
    let est = fitted_estimator(&preset, 7);
    let mem = preset.memory_estimator();
    let mut rng = Rng::new(21);
    let reqs: Vec<Request> = (0..128)
        .map(|i| {
            Request::new(
                i,
                0.0,
                1 + (rng.next_u64() % 1024) as u32,
                1 + (rng.next_u64() % 1024) as u32,
            )
        })
        .collect();
    let batches: Vec<Batch> = dp_batch(
        reqs,
        &est,
        &mem,
        &DpBatcherConfig {
            slice_len: 128,
            max_batch_size: None,
            pred_corrected: false,
        },
    );
    println!("{}", report_header());
    let r = bench(
        &format!("maxmin offload ({} batches → 8 workers)", batches.len()),
        || {
            let mut ledger = LoadLedger::new(8);
            MaxMinOffloader.offload(batches.clone(), &mut ledger)
        },
    );
    println!("{}", r.report());
    let r = bench(
        &format!("round-robin offload ({} batches → 8 workers)", batches.len()),
        || {
            let mut rr = RoundRobin::new(8);
            batches
                .iter()
                .map(|b| (rr.next_worker(), b.size()))
                .collect::<Vec<_>>()
        },
    );
    println!("{}", r.report());
}
