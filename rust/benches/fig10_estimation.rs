//! Fig. 10 — serving-time estimation error: per-phase RMSE of the fitted
//! Eq. (3)/(4) surfaces and the accumulated error over 128 decode
//! iterations, for both engines. Prints the reproduced errors, then times
//! the fit and the closed-form multi-iteration estimate.

use scls::bench::figures::{fig10, FigureConfig};
use scls::bench::harness::{bench, report_header};
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::estimator::profiler::{profile_and_fit, ProfileGrid};
use scls::sim::driver::fitted_estimator;

fn main() {
    fig10(&FigureConfig::default()).print();

    println!("{}", report_header());
    let r = bench("profile_and_fit DS (full grid)", || {
        let mut src = EnginePreset::paper(EngineKind::Ds).latency(13);
        profile_and_fit(&mut src, &ProfileGrid::default())
    });
    println!("{}", r.report());

    let est = fitted_estimator(&EnginePreset::paper(EngineKind::Ds), 13);
    // black_box the inputs so the constant-folded answer isn't benched.
    let r = bench("estimator.serve closed-form (128 iters)", || {
        let (n, l, s) = std::hint::black_box((12u32, 512u32, 128u32));
        est.serve(n, l, s)
    });
    println!("{}", r.report());
    // The naive per-iteration loop the closed form replaces:
    let r = bench("estimator decode loop (128 iters, naive)", || {
        let (n, l0) = std::hint::black_box((12u32, 512u32));
        let mut acc = est.prefill(n, l0);
        for l in l0 + 1..=l0 + 128 {
            acc += est.decode_iter(l, n);
        }
        acc
    });
    println!("{}", r.report());
}
