//! Figs. 8 & 9 — the prefill-latency and per-iteration decode-latency
//! profiles of the calibrated DS/HF engine models (the grids §4.2 fits
//! Eq. (3)/(4) against). Prints both engines' grids, then times the
//! individual latency queries and a full profile pass.

use scls::bench::figures::{fig08_09, FigureConfig};
use scls::bench::harness::{bench, report_header};
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::estimator::profiler::{profile_and_fit, LatencySource, ProfileGrid};

fn main() {
    let fc = FigureConfig::default();
    fig08_09(&fc, EngineKind::Ds).print();
    fig08_09(&fc, EngineKind::Hf).print();

    println!("{}", report_header());
    for kind in [EngineKind::Ds, EngineKind::Hf] {
        let mut lat = EnginePreset::paper(kind).latency(5);
        let r = bench(&format!("{} measure_prefill(8, 1024)", kind.name()), || {
            lat.measure_prefill(8, 1024)
        });
        println!("{}", r.report());
        let r = bench(&format!("{} measure_decode_iter(1536, 12)", kind.name()), || {
            lat.measure_decode_iter(1536, 12)
        });
        println!("{}", r.report());
        let r = bench(&format!("{} profile_and_fit(default grid)", kind.name()), || {
            let mut src = EnginePreset::paper(kind).latency(6);
            profile_and_fit(&mut src, &ProfileGrid::default())
        });
        println!("{}", r.report());
    }
}
