//! Exportable run timelines: batches as per-worker spans, fleet/shed/
//! reclaim/migration events as instants.
//!
//! [`TimelineSink`] records the event stream a run emits through the
//! [`MetricsSink`] hooks and serializes it two ways:
//!
//! * **JSONL** ([`TimelineSink::to_jsonl`] / `write_jsonl`): one JSON
//!   object per line — `{"type":"span",...}` for batch servings,
//!   `{"type":"instant",...}` for point events — trivially streamable
//!   into pandas / jq.
//! * **Chrome `trace_event` JSON** ([`TimelineSink::to_chrome_trace`] /
//!   `write_chrome_trace`): one `{"traceEvents":[...]}` document with a
//!   named thread per worker (`"ph":"M"` metadata), complete spans
//!   (`"ph":"X"`, µs timestamps), and global instants (`"ph":"i"`) —
//!   loadable directly in Perfetto (<https://ui.perfetto.dev>) or
//!   `chrome://tracing`.
//!
//! Virtual seconds map to trace microseconds (`ts = now · 1e6`), so a
//! 600 s run renders as a 600 s timeline. The sink only observes — it
//! never touches `RunMetrics` — so attaching it cannot move a run's
//! deterministic fingerprint.

use std::io::Write as _;
use std::path::Path;

use crate::core::Request;
use crate::metrics::{BatchRecord, FleetEventKind, FleetRecord, MetricsSink};
use crate::util::json::Json;

/// One batch serving: `worker` was busy on `[start, start + dur)`.
#[derive(Debug, Clone)]
pub struct Span {
    pub worker: usize,
    pub start: f64,
    pub dur: f64,
    pub size: u32,
    pub input_len: u32,
    pub early_return: bool,
}

/// A point event on the timeline.
#[derive(Debug, Clone)]
pub struct InstantEvent {
    /// Event kind: `join` / `drain` / `crash` / `reclaim` / `migration` /
    /// `shed`.
    pub name: &'static str,
    /// Worker the event belongs to (`None` for fleet-wide events like
    /// sheds, which have no worker yet).
    pub worker: Option<usize>,
    pub at: f64,
    /// Kind-specific detail (reclaimed counts, migrated counts, request
    /// id), already rendered.
    pub detail: String,
}

/// Streaming timeline collector (see module docs).
#[derive(Debug, Clone, Default)]
pub struct TimelineSink {
    spans: Vec<Span>,
    instants: Vec<InstantEvent>,
}

impl TimelineSink {
    pub fn new() -> TimelineSink {
        TimelineSink::default()
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    pub fn instants(&self) -> &[InstantEvent] {
        &self.instants
    }

    /// Distinct workers appearing in spans or worker-carrying instants,
    /// ascending — the span tracks of the Chrome trace.
    pub fn workers(&self) -> Vec<usize> {
        let mut ws: Vec<usize> = self
            .spans
            .iter()
            .map(|s| s.worker)
            .chain(self.instants.iter().filter_map(|i| i.worker))
            .collect();
        ws.sort_unstable();
        ws.dedup();
        ws
    }

    /// One JSON object per line (`span` and `instant` records, in event
    /// order: all spans, then all instants).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for s in &self.spans {
            let mut j = Json::obj();
            j.set("type", "span")
                .set("worker", s.worker)
                .set("start", s.start)
                .set("dur", s.dur)
                .set("size", s.size)
                .set("input_len", s.input_len)
                .set("early_return", s.early_return);
            out.push_str(&j.to_string_compact());
            out.push('\n');
        }
        for i in &self.instants {
            let mut j = Json::obj();
            j.set("type", "instant").set("name", i.name).set("at", i.at);
            if let Some(w) = i.worker {
                j.set("worker", w);
            }
            if !i.detail.is_empty() {
                j.set("detail", i.detail.as_str());
            }
            out.push_str(&j.to_string_compact());
            out.push('\n');
        }
        out
    }

    /// The Chrome `trace_event` document (see module docs).
    pub fn to_chrome_trace(&self) -> Json {
        let mut events: Vec<Json> = Vec::with_capacity(self.spans.len() + self.instants.len() + 8);
        // Named thread per worker so Perfetto labels the tracks.
        for w in self.workers() {
            let mut m = Json::obj();
            let mut args = Json::obj();
            args.set("name", format!("worker {w}"));
            m.set("ph", "M")
                .set("name", "thread_name")
                .set("pid", 0u32)
                .set("tid", w)
                .set("args", args);
            events.push(m);
        }
        for s in &self.spans {
            let mut args = Json::obj();
            args.set("size", s.size)
                .set("input_len", s.input_len)
                .set("early_return", s.early_return);
            let mut e = Json::obj();
            e.set("ph", "X")
                .set("name", format!("batch N={}", s.size))
                .set("cat", "serve")
                .set("pid", 0u32)
                .set("tid", s.worker)
                .set("ts", s.start * 1e6)
                .set("dur", s.dur * 1e6)
                .set("args", args);
            events.push(e);
        }
        for i in &self.instants {
            let mut args = Json::obj();
            if !i.detail.is_empty() {
                args.set("detail", i.detail.as_str());
            }
            let mut e = Json::obj();
            e.set("ph", "i")
                .set("name", i.name)
                .set("cat", "fleet")
                .set("pid", 0u32)
                .set("tid", i.worker.unwrap_or(0))
                .set("ts", i.at * 1e6)
                // Scope: thread-local mark when worker-bound, global
                // otherwise.
                .set("s", if i.worker.is_some() { "t" } else { "g" })
                .set("args", args);
            events.push(e);
        }
        let mut doc = Json::obj();
        doc.set("traceEvents", Json::Arr(events))
            .set("displayTimeUnit", "ms");
        doc
    }

    pub fn write_jsonl(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_jsonl().as_bytes())
    }

    pub fn write_chrome_trace(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_trace().to_string_pretty())
    }
}

impl MetricsSink for TimelineSink {
    fn on_batch(&mut self, now: f64, rec: &BatchRecord) {
        self.spans.push(Span {
            worker: rec.worker,
            start: now,
            dur: rec.actual_serve_time.max(0.0),
            size: rec.size,
            input_len: rec.input_len,
            early_return: rec.early_return,
        });
    }

    fn on_fleet(&mut self, now: f64, rec: &FleetRecord) {
        let name = match rec.kind {
            FleetEventKind::Join => "join",
            FleetEventKind::Drain => "drain",
            FleetEventKind::Crash => "crash",
        };
        self.instants.push(InstantEvent {
            name,
            worker: Some(rec.worker),
            at: now,
            detail: String::new(),
        });
    }

    fn on_reclaim(&mut self, now: f64, worker: usize, in_flight: usize, queued: usize) {
        self.instants.push(InstantEvent {
            name: "reclaim",
            worker: Some(worker),
            at: now,
            detail: format!("in_flight={in_flight} queued={queued}"),
        });
    }

    fn on_migration(&mut self, now: f64, worker: usize, count: usize) {
        self.instants.push(InstantEvent {
            name: "migration",
            worker: Some(worker),
            at: now,
            detail: format!("count={count}"),
        });
    }

    fn on_shed(&mut self, now: f64, req: &Request) {
        self.instants.push(InstantEvent {
            name: "shed",
            worker: None,
            at: now,
            detail: format!("req={}", req.id),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(worker: usize, start: f64, dur: f64) -> BatchRecord {
        BatchRecord {
            start,
            worker,
            size: 2,
            input_len: 32,
            pad_tokens: 0,
            est_serve_time: dur,
            actual_serve_time: dur,
            early_return: false,
        }
    }

    #[test]
    fn jsonl_lines_parse_individually() {
        let mut t = TimelineSink::new();
        t.on_batch(1.0, &batch(0, 1.0, 0.5));
        t.on_batch(2.0, &batch(1, 2.0, 0.25));
        t.on_fleet(
            3.0,
            &FleetRecord {
                worker: 1,
                kind: FleetEventKind::Crash,
            },
        );
        t.on_reclaim(3.0, 1, 2, 1);
        let mut shed = Request::new(9, 0.0, 8, 8);
        shed.slo.deadline = Some(0.1);
        t.on_shed(4.0, &shed);
        let jsonl = t.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 5);
        for line in &lines {
            let j = Json::parse(line).expect("every JSONL line parses");
            assert!(j.get("type").is_some());
        }
        assert!(lines[0].contains("\"span\""));
        assert!(lines[2].contains("\"crash\""));
        assert!(lines[4].contains("\"shed\""));
    }

    #[test]
    fn chrome_trace_has_tracks_spans_and_instants() {
        let mut t = TimelineSink::new();
        t.on_batch(0.5, &batch(0, 0.5, 1.0));
        t.on_batch(1.0, &batch(2, 1.0, 1.0));
        t.on_fleet(
            2.0,
            &FleetRecord {
                worker: 2,
                kind: FleetEventKind::Drain,
            },
        );
        t.on_migration(2.0, 2, 3);
        let doc = t.to_chrome_trace();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let phase = |e: &Json| e.get("ph").unwrap().as_str().unwrap().to_string();
        let meta: Vec<&Json> = events.iter().filter(|e| phase(e) == "M").collect();
        let spans: Vec<&Json> = events.iter().filter(|e| phase(e) == "X").collect();
        let insts: Vec<&Json> = events.iter().filter(|e| phase(e) == "i").collect();
        assert_eq!(meta.len(), 2, "one thread_name per distinct worker");
        assert_eq!(spans.len(), 2);
        assert_eq!(insts.len(), 2);
        // µs mapping: a 1 s span at t=0.5 s is ts=5e5, dur=1e6.
        assert_eq!(spans[0].get("ts").unwrap().as_f64(), Some(5e5));
        assert_eq!(spans[0].get("dur").unwrap().as_f64(), Some(1e6));
        // The whole document round-trips through the parser.
        let s = doc.to_string_pretty();
        let back = Json::parse(&s).unwrap();
        assert_eq!(
            back.get("traceEvents").unwrap().as_arr().unwrap().len(),
            events.len()
        );
    }
}
