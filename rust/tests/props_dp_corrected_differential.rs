//! Differential property tests for the corrected DP planner: the
//! running-max-aware branch-and-bound (`dp_plan` with
//! `DpBatcherConfig::pred_corrected`) must be bit-exact against the
//! retained scalar loop (`dp_plan_corrected_reference`) — identical cuts
//! and bit-identical `est_serve_time` on every materialized batch —
//! across ~1000 randomized pools: random and fitted estimator surfaces,
//! opaque (`serve_affine == None`) estimators, `max_batch_size` caps,
//! tight memory, adversarial rule tables (including capacity-growing
//! tables that force the plateau deque to rebuild), and adversarial
//! prediction patterns (constant, oracle-like, anti-correlated with the
//! sort key, plateau-heavy, gaps, exhausted predictions).
//!
//! The legacy suite (`props_dp_differential.rs`) stays frozen and covers
//! the `pred_corrected: false` path only.

use std::cell::RefCell;

use scls::batcher::{
    dp_batch, dp_plan, dp_plan_corrected_reference, predicted_batch_iters, DpBatcherConfig,
    DpScratch,
};
use scls::core::{Batch, Request};
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::estimator::serving_time::{LinearLatency, ServeEstimate, ServingTimeEstimator};
use scls::estimator::{MemoryEstimator, MemoryRule};
use scls::prop_assert;
use scls::sim::driver::fitted_estimator;
use scls::testprop::{check, Gen};

/// Wrap an estimator so `serve_affine` always reports `None`: every
/// plateau takes the bulk-kernel path with no certificates.
struct Opaque(ServingTimeEstimator);

impl ServeEstimate for Opaque {
    fn serve_est(&self, n: u32, l_i: u32, s: u32) -> f64 {
        self.0.serve_est(n, l_i, s)
    }
}

/// Random pool with a prediction pattern chosen per case — the shapes the
/// plateau structure is sensitive to.
fn gen_pool(g: &mut Gen, max_n: usize) -> Vec<Request> {
    let pattern = g.u32(0, 7);
    (0..g.usize(1, max_n))
        .map(|i| {
            let li = g.u32(1, 1024);
            let gl = g.u32(1, 1024);
            let mut r = Request::new(i as u64, 0.0, li, gl);
            r.predicted_gen = match pattern {
                0 => None, // prediction-free: the correction is a no-op
                1 => Some(gl), // oracle
                2 => Some(g.u32(1, 1024)),
                3 => Some(64), // constant: a single plateau
                4 => Some(li), // correlated with the sort key: monotone
                5 => Some(1025 - li), // anti-correlated: max plateaus
                6 => Some(*g.pick(&[8u32, 64, 512])), // plateau-heavy
                _ => {
                    if g.u32(0, 2) > 0 {
                        Some(g.u32(1, 1024))
                    } else {
                        None // gaps: unstamped members fall back to S
                    }
                }
            };
            if g.u32(0, 3) == 0 {
                // Mid-flight requeues: nonzero progress, sometimes past
                // the prediction (exhausted ⇒ full-budget fallback).
                r.generated = g.u32(0, 200);
            }
            r
        })
        .collect()
}

/// Random bilinear surfaces around fitted magnitudes; occasionally
/// negative constants so the `max(0, ·)` clamp can fire and
/// `serve_affine` returns `None` for some plateaus but not others.
fn gen_estimator(g: &mut Gen) -> ServingTimeEstimator {
    let mut coeff = |scale: f64| {
        let x = g.f64(0.0, scale);
        if g.u32(0, 9) == 0 {
            -x * 0.25
        } else {
            x
        }
    };
    ServingTimeEstimator {
        prefill: LinearLatency {
            c1: coeff(5e-4),
            c2: coeff(2e-3),
            c3: coeff(5e-4),
            c4: coeff(0.05),
        },
        decode: LinearLatency {
            c1: coeff(2e-6),
            c2: coeff(1e-3),
            c3: coeff(5e-6),
            c4: coeff(0.05),
        },
    }
}

fn gen_memory(g: &mut Gen) -> MemoryEstimator {
    match g.u32(0, 2) {
        0 => MemoryEstimator::ds_rules(),
        1 => MemoryEstimator::analytic(800 * 1024, 48 << 30, 0.9),
        _ => {
            let delta = 1u64 << 20;
            let cap = g.u32(1, 12) as u64;
            MemoryEstimator::analytic(delta, cap * (1024 + 512) * delta, 1.0)
        }
    }
}

fn gen_cfg(g: &mut Gen) -> DpBatcherConfig {
    DpBatcherConfig {
        slice_len: *g.pick(&[16u32, 32, 64, 128, 256, 512]),
        max_batch_size: if g.bool() { Some(g.u32(1, 24)) } else { None },
        pred_corrected: true,
    }
}

/// Reference-side materialization: the retained scalar plan plus the
/// production corrected budget (`predicted_batch_iters`) per batch.
fn corrected_batches_reference(
    pool: Vec<Request>,
    est: &dyn ServeEstimate,
    mem: &MemoryEstimator,
    cfg: &DpBatcherConfig,
) -> Vec<Batch> {
    let mut sorted = pool;
    sorted.sort_by_key(|r| r.input_len);
    let cuts = dp_plan_corrected_reference(&sorted, est, mem, cfg);
    let mut batches = Vec::with_capacity(cuts.len());
    let mut drain = sorted.drain(..);
    for &(start, end) in &cuts {
        let members: Vec<Request> = drain.by_ref().take(end - start).collect();
        let budget = predicted_batch_iters(&members, cfg.slice_len);
        let mut b = Batch::new(members);
        b.est_serve_time = est.serve_est(b.size() as u32, b.input_len(), budget);
        batches.push(b);
    }
    batches
}

/// Full-stack check: plan-level cuts through a REUSED scratch (the
/// steady-state production shape) and batch-level membership plus
/// bit-identical serve estimates.
fn assert_corrected_bit_exact(
    pool: Vec<Request>,
    est: &dyn ServeEstimate,
    mem: &MemoryEstimator,
    cfg: &DpBatcherConfig,
    scratch: &mut DpScratch,
    ctx: &str,
) -> Result<(), scls::testprop::PropFail> {
    let mut sorted = pool.clone();
    sorted.sort_by_key(|r| r.input_len);
    dp_plan(&sorted, est, mem, cfg, scratch);
    let ref_cuts = dp_plan_corrected_reference(&sorted, est, mem, cfg);
    prop_assert!(
        scratch.cuts() == &ref_cuts[..],
        "{ctx}: cuts {:?} vs {:?}",
        scratch.cuts(),
        ref_cuts
    );

    let fast = dp_batch(pool.clone(), est, mem, cfg);
    let slow = corrected_batches_reference(pool, est, mem, cfg);
    prop_assert!(
        fast.len() == slow.len(),
        "{ctx}: batch count {} vs {}",
        fast.len(),
        slow.len()
    );
    for (idx, (f, s)) in fast.iter().zip(&slow).enumerate() {
        let fi: Vec<u64> = f.requests.iter().map(|r| r.id).collect();
        let si: Vec<u64> = s.requests.iter().map(|r| r.id).collect();
        prop_assert!(fi == si, "{ctx}: batch {idx} members {fi:?} vs {si:?}");
        prop_assert!(
            f.est_serve_time.to_bits() == s.est_serve_time.to_bits(),
            "{ctx}: batch {idx} est {} vs {}",
            f.est_serve_time,
            s.est_serve_time
        );
    }
    Ok(())
}

#[test]
fn corrected_bnb_matches_reference_on_random_surfaces() {
    let scratch = RefCell::new(DpScratch::new());
    check("dp-corrected-differential-random", 200, |g| {
        let est = gen_estimator(g);
        let mem = gen_memory(g);
        let cfg = gen_cfg(g);
        let pool = gen_pool(g, 200);
        assert_corrected_bit_exact(pool, &est, &mem, &cfg, &mut scratch.borrow_mut(), "random")
    });
}

#[test]
fn corrected_bnb_matches_reference_with_fitted_estimators() {
    let scratch = RefCell::new(DpScratch::new());
    check("dp-corrected-differential-fitted", 200, |g| {
        let kind = if g.bool() { EngineKind::Hf } else { EngineKind::Ds };
        let preset = EnginePreset::paper(kind);
        let est = fitted_estimator(&preset, g.u64());
        let mem = preset.memory_estimator();
        let cfg = gen_cfg(g);
        let pool = gen_pool(g, 200);
        assert_corrected_bit_exact(pool, &est, &mem, &cfg, &mut scratch.borrow_mut(), "fitted")
    });
}

#[test]
fn corrected_bnb_matches_reference_on_opaque_estimators() {
    // serve_affine == None everywhere: no certificates, pure bulk-kernel
    // plateau evaluation — must still agree bit-for-bit.
    let scratch = RefCell::new(DpScratch::new());
    check("dp-corrected-differential-opaque", 200, |g| {
        let est = Opaque(gen_estimator(g));
        let mem = gen_memory(g);
        let cfg = gen_cfg(g);
        let pool = gen_pool(g, 120);
        assert_corrected_bit_exact(pool, &est, &mem, &cfg, &mut scratch.borrow_mut(), "opaque")
    });
}

#[test]
fn corrected_bnb_matches_reference_under_tight_memory_and_caps() {
    let scratch = RefCell::new(DpScratch::new());
    check("dp-corrected-differential-tight", 200, |g| {
        let est = fitted_estimator(&EnginePreset::paper(EngineKind::Ds), 7);
        let delta = 1u64 << 20;
        let n_cap = g.u32(1, 6) as u64;
        let mem = MemoryEstimator::analytic(delta, n_cap * (1024 + 128) * delta, 1.0);
        let cfg = DpBatcherConfig {
            slice_len: 128,
            max_batch_size: Some(g.u32(1, 4)),
            pred_corrected: true,
        };
        let pool = gen_pool(g, 150);
        assert_corrected_bit_exact(pool, &est, &mem, &cfg, &mut scratch.borrow_mut(), "tight")
    });
}

#[test]
fn corrected_bnb_matches_reference_on_adversarial_tables() {
    // Abrupt window steps (descending tables) and capacity GROWING with
    // length (ascending tables): the latter moves the DP window's left
    // edge left mid-scan, which must rebuild the plateau deque and shut
    // off the skip certificates rather than mis-certify.
    let scratch = RefCell::new(DpScratch::new());
    check("dp-corrected-differential-tables", 200, |g| {
        let est = fitted_estimator(&EnginePreset::paper(EngineKind::Hf), 11);
        let mem = if g.bool() {
            MemoryEstimator {
                rule: MemoryRule::Table(vec![
                    (g.u32(700, 1100), g.u32(1, 4)),
                    (g.u32(300, 699), g.u32(5, 20)),
                    (0, g.u32(21, 64)),
                ]),
            }
        } else {
            MemoryEstimator {
                rule: MemoryRule::Table(vec![
                    (g.u32(200, 900), g.u32(8, 40)),
                    (0, g.u32(1, 6)),
                ]),
            }
        };
        let cfg = gen_cfg(g);
        let pool = gen_pool(g, 180);
        assert_corrected_bit_exact(pool, &est, &mem, &cfg, &mut scratch.borrow_mut(), "table")
    });
}
