//! The request pool (paper Fig. 7): newly arrived requests and uncompleted
//! rescheduled requests wait here between schedule ticks.
//!
//! ## Incremental ordering
//!
//! The DP batcher (Alg. 1) consumes the pool *sorted ascending by input
//! length* on every tick. Re-sorting the whole pool per tick is wasted
//! work under backlog, where a late tick drains hundreds of thousands of
//! requests most of which were already ordered at the previous merge. The
//! pool therefore keeps its contents incrementally sorted: pushes land in
//! an insertion buffer, and whenever the buffer grows to the size of the
//! sorted store it is stable-sorted and merged in (a doubling schedule, so
//! total merge work stays O(n log n) while each individual push is O(1)
//! amortized). [`RequestPool::drain_sorted_into`] finalizes the pending
//! merge and hands the batcher a fully sorted buffer; only the new
//! arrivals since the last merge were sorted — the unchanged prefix is
//! merged, not re-sorted.
//!
//! **Order contract** (what keeps the frozen differential suite
//! byte-identical): every element of the sorted store was pushed before
//! every element of the insertion buffer, and both keep equal input
//! lengths in push order, so a stable merge that prefers the sorted side
//! on ties yields *exactly* the stable sort of the raw push sequence —
//! bit-for-bit the order `dp_batch_into`'s internal sort would produce.

use crate::core::Request;

/// Pending-buffer size below which merging is deferred (keeps tiny pools
/// and unit tests in pure push order, and bounds per-push overhead).
const MERGE_MIN: usize = 64;

#[derive(Debug, Default)]
pub struct RequestPool {
    /// Merged store: ascending `input_len`, push order among equals. Every
    /// element here was pushed before everything in `pending`.
    sorted: Vec<Request>,
    /// Pushes since the last merge, in push order.
    pending: Vec<Request>,
    /// Merge scratch, retained for capacity reuse across ticks.
    scratch: Vec<Request>,
}

impl RequestPool {
    pub fn new() -> RequestPool {
        RequestPool::default()
    }

    /// Pre-size the pool for a known workload (per-tick drains then never
    /// reallocate in steady state).
    pub fn with_capacity(n: usize) -> RequestPool {
        RequestPool {
            sorted: Vec::new(),
            pending: Vec::with_capacity(n),
            scratch: Vec::new(),
        }
    }

    /// Grow the backing store for an expected workload (same steady-state
    /// no-realloc property as [`RequestPool::with_capacity`]).
    pub fn reserve(&mut self, n: usize) {
        self.pending.reserve(n);
    }

    pub fn push(&mut self, r: Request) {
        self.pending.push(r);
        if self.pending.len() >= MERGE_MIN && self.pending.len() >= self.sorted.len() {
            self.merge_pending();
        }
    }

    /// Stable-sort the insertion buffer and merge it into the sorted
    /// store. Ties take the sorted side first: those elements were pushed
    /// earlier, so the result equals the stable sort of the push sequence.
    fn merge_pending(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        self.pending.sort_by_key(|r| r.input_len);
        if self.sorted.is_empty() {
            std::mem::swap(&mut self.sorted, &mut self.pending);
            return;
        }
        let mut out = std::mem::take(&mut self.scratch);
        out.clear();
        out.reserve(self.sorted.len() + self.pending.len());
        {
            let mut a = self.sorted.drain(..).peekable();
            let mut b = self.pending.drain(..).peekable();
            loop {
                let take_a = match (a.peek(), b.peek()) {
                    (Some(x), Some(y)) => x.input_len <= y.input_len,
                    (Some(_), None) => true,
                    (None, Some(_)) => false,
                    (None, None) => break,
                };
                if take_a {
                    out.push(a.next().unwrap());
                } else {
                    out.push(b.next().unwrap());
                }
            }
        }
        // `sorted`/`pending` are drained but keep their capacity; recycle
        // the larger one as the next merge's scratch.
        std::mem::swap(&mut self.sorted, &mut out);
        self.scratch = out;
    }

    /// Drain everything **sorted ascending by input length** (stable: push
    /// order among equal lengths) — the order Alg. 1 wants, finalized by
    /// merging only the arrivals since the last background merge. `out` is
    /// cleared and swapped so the drain allocates nothing in steady state.
    pub fn drain_sorted_into(&mut self, out: &mut Vec<Request>) {
        // Opt-in hot-path profiling: one thread-local bool load when
        // disabled.
        let _t = crate::telemetry::profile::timer("drain_sort"); // scls-lint: allow(import-graph): opt-in profiling tap
        self.merge_pending();
        out.clear();
        std::mem::swap(&mut self.sorted, out);
        // The swapped-in buffer becomes the next merge target; keep the
        // larger of it and the old scratch as future merge scratch.
        if self.sorted.capacity() < self.scratch.capacity() {
            std::mem::swap(&mut self.sorted, &mut self.scratch);
        }
    }

    /// Drain everything in pool order: the merged (sorted) prefix followed
    /// by pushes since the last merge. For consumers that re-sort stably
    /// by input length — the DP batcher — this is indistinguishable from
    /// raw push order; pools that never crossed the merge threshold return
    /// pure push order.
    pub fn fetch_all(&mut self) -> Vec<Request> {
        if self.sorted.is_empty() {
            return std::mem::take(&mut self.pending);
        }
        let mut all = std::mem::take(&mut self.sorted);
        all.append(&mut self.pending);
        all
    }

    /// Buffer-swap drain: `out` is cleared and swapped with the pool's
    /// backing store (same order contract as [`RequestPool::fetch_all`]),
    /// so a tick-loop caller cycles two buffers and the drain allocates
    /// nothing in steady state.
    pub fn fetch_all_into(&mut self, out: &mut Vec<Request>) {
        out.clear();
        if self.sorted.is_empty() {
            std::mem::swap(&mut self.pending, out);
        } else {
            std::mem::swap(&mut self.sorted, out);
            out.append(&mut self.pending);
        }
    }

    /// Drain at most `n` from the front of the pool order (pure insertion
    /// order while the pool stays under the merge threshold — the FCFS
    /// baselines' case).
    pub fn fetch_up_to(&mut self, n: usize) -> Vec<Request> {
        if n >= self.len() {
            return self.fetch_all();
        }
        let from_sorted = n.min(self.sorted.len());
        let mut out: Vec<Request> = self.sorted.drain(..from_sorted).collect();
        let rest = n - from_sorted;
        out.extend(self.pending.drain(..rest));
        out
    }

    pub fn len(&self) -> usize {
        self.sorted.len() + self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty() && self.pending.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, 0.0, 10, 10)
    }

    fn req_len(id: u64, input_len: u32) -> Request {
        Request::new(id, 0.0, input_len, 10)
    }

    #[test]
    fn fetch_all_drains() {
        let mut p = RequestPool::new();
        p.push(req(1));
        p.push(req(2));
        let all = p.fetch_all();
        assert_eq!(all.len(), 2);
        assert!(p.is_empty());
    }

    #[test]
    fn fetch_all_into_swaps_buffers() {
        let mut p = RequestPool::with_capacity(8);
        p.push(req(1));
        p.push(req(2));
        let mut buf = Vec::with_capacity(16);
        p.fetch_all_into(&mut buf);
        assert_eq!(buf.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert!(p.is_empty());
        // The pool inherited the (cleared) caller buffer's capacity.
        p.push(req(3));
        p.fetch_all_into(&mut buf);
        assert_eq!(buf.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3]);
    }

    #[test]
    fn fetch_up_to_preserves_order() {
        let mut p = RequestPool::new();
        for i in 0..5 {
            p.push(req(i));
        }
        let first = p.fetch_up_to(2);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(p.len(), 3);
        let rest = p.fetch_up_to(10);
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3, 4]);
    }

    /// The byte-identity contract: for any push sequence, the incremental
    /// drain equals the stable sort of the raw push order — ties resolve
    /// to the earlier push.
    #[test]
    fn drain_sorted_matches_full_stable_sort() {
        let mut p = RequestPool::new();
        let mut model: Vec<Request> = Vec::new();
        let mut out = Vec::new();
        // Three tick cycles, each pushing enough to trigger background
        // merges, with duplicate lengths to exercise tie stability.
        for round in 0..3u64 {
            for i in 0..300u64 {
                let id = round * 1000 + i;
                let len = ((id * 37) % 50) as u32 + 1; // many duplicates
                p.push(req_len(id, len));
                model.push(req_len(id, len));
            }
            p.drain_sorted_into(&mut out);
            model.sort_by_key(|r| r.input_len); // stable
            assert_eq!(out.len(), model.len());
            for (a, b) in out.iter().zip(&model) {
                assert_eq!((a.id, a.input_len), (b.id, b.input_len));
            }
            model.clear();
            assert!(p.is_empty());
        }
    }

    #[test]
    fn interleaved_push_orders_still_sort_stably() {
        // Push under the merge threshold, drain, push over it, drain:
        // both drains must be stable sorts of their own push windows.
        let mut p = RequestPool::new();
        let mut out = Vec::new();
        p.push(req_len(1, 5));
        p.push(req_len(2, 5));
        p.push(req_len(3, 1));
        p.drain_sorted_into(&mut out);
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 1, 2]);
        for i in 0..200u64 {
            p.push(req_len(100 + i, 7));
        }
        p.push(req_len(999, 3));
        p.drain_sorted_into(&mut out);
        assert_eq!(out[0].id, 999);
        // Equal-length run keeps push order after background merges.
        let ids: Vec<u64> = out[1..].iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..200u64).map(|i| 100 + i).collect::<Vec<_>>());
    }

    #[test]
    fn len_spans_sorted_and_pending() {
        let mut p = RequestPool::new();
        for i in 0..130u64 {
            p.push(req_len(i, (i % 9) as u32 + 1));
        }
        assert_eq!(p.len(), 130);
        assert!(!p.is_empty());
        let all = p.fetch_all();
        assert_eq!(all.len(), 130);
        assert!(p.is_empty());
    }
}
