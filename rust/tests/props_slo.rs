//! Property suite for the SLO / multi-tenant subsystem.
//!
//! Three families of guarantees:
//!
//! 1. **SLO-free invisibility** — on a trace with no SLO stamps, every
//!    registry policy keeps every SLO counter at zero, sheds nothing, and
//!    is deterministic (run-twice byte-identity on the
//!    `RunMetrics::to_json` event log). The subsystem must be unobservable
//!    until a trace opts in.
//!
//! 2. **Stamp obliviousness** — stamping tenancy/SLO metadata onto a trace
//!    must not move a single completion of the throughput-only policies:
//!    they schedule on arrivals and lengths alone, so the completion
//!    stream is bit-identical with and without stamps.
//!
//! 3. **Starvation freedom** — under sustained overload with weighted
//!    fair service enabled, every tenant's work completes and service is
//!    interleaved across tenants (no tenant is parked until the heavy
//!    tenants drain).

use scls::engine::presets::{EngineKind, EnginePreset};
use scls::scheduler::BUILTIN_POLICIES;
use scls::sim::driver::{SimConfig, Simulation};
use scls::slo::{stamp_trace, SloSpec, TenantMix};
use scls::testprop::{check, Gen};
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};
use scls::{prop_assert, prop_assert_eq};

fn trace(rate: f64, duration: f64, seed: u64) -> Trace {
    Trace::generate(&TraceConfig {
        kind: WorkloadKind::CodeFuse,
        rate,
        duration,
        max_input_len: 512,
        max_gen_len: 512,
        seed,
    })
}

fn stamped(rate: f64, duration: f64, seed: u64, mix: &TenantMix, slo: &str) -> Trace {
    let mut t = trace(rate, duration, seed);
    let base = SloSpec::parse(slo).expect("static spec");
    stamp_trace(&mut t, mix, &base, seed ^ 0x510);
    t
}

fn cfg(workers: usize, seed: u64) -> SimConfig {
    SimConfig::new(workers, EnginePreset::paper(EngineKind::Ds), 512, seed)
}

/// The byte-level fingerprint two runs must share to count as identical.
fn fingerprint(m: &scls::metrics::RunMetrics) -> String {
    m.to_json().to_string_pretty()
}

/// The completion stream alone, bit-exact — the part of the event log the
/// throughput-only policies must not move when stamps appear.
fn completions(m: &scls::metrics::RunMetrics) -> Vec<(u64, u64, u32)> {
    m.completed
        .iter()
        .map(|c| (c.id, c.finished.to_bits(), c.generated))
        .collect()
}

// ---------------------------------------------------------------------------
// 1. SLO-free invisibility + determinism, every registry policy
// ---------------------------------------------------------------------------

#[test]
fn slo_free_runs_have_zero_counters_and_are_deterministic() {
    let t = trace(5.0, 30.0, 701);
    let sim = Simulation::new(cfg(4, 701));
    for name in BUILTIN_POLICIES {
        let a = sim.run_named(&t, name, 128).unwrap();
        let b = sim.run_named(&t, name, 128).unwrap();
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{name} is not deterministic on an SLO-free trace"
        );
        assert!(a.slo.is_empty(), "{name} tracked SLOs on an SLO-free trace");
        assert_eq!(a.shed_requests, 0, "{name} shed on an SLO-free trace");
        assert!(!a.completed.is_empty(), "{name} completed nothing");
    }
}

#[test]
fn slo_stamped_runs_are_deterministic_for_every_policy() {
    check("slo-stamped-determinism", 4, |g: &mut Gen| {
        let seed = g.u64();
        let mix = TenantMix::parse(g.pick(&["2:3,1", "4"])).expect("static mix");
        let t = stamped(6.0, 20.0, seed, &mix, "ttft:5,deadline:45");
        let sim = Simulation::new(cfg(3, seed));
        for name in BUILTIN_POLICIES {
            let a = sim.run_named(&t, name, 128).unwrap();
            let b = sim.run_named(&t, name, 128).unwrap();
            prop_assert_eq!(
                fingerprint(&a),
                fingerprint(&b),
                "{} is not deterministic on a stamped trace (seed {})",
                name,
                seed
            );
            // Conservation: every request either completes or is shed (and
            // only the deadline-aware admission ever sheds).
            prop_assert_eq!(
                a.completed.len() as u64 + a.shed_requests,
                t.len() as u64,
                "{} lost requests (seed {})",
                name,
                seed
            );
            if name != "D-SCLS" {
                prop_assert_eq!(a.shed_requests, 0, "{} must not shed", name);
            }
            // Every request carries a stamp, so every outcome is tracked.
            prop_assert_eq!(
                a.slo.tracked,
                t.len() as u64,
                "{} dropped SLO outcomes (seed {})",
                name,
                seed
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 2. Stamps never move an oblivious policy's schedule
// ---------------------------------------------------------------------------

#[test]
fn stamps_leave_oblivious_policy_completions_bit_identical() {
    check("slo-stamp-obliviousness", 6, |g: &mut Gen| {
        let seed = g.u64();
        let rate = *g.pick(&[4.0, 12.0]);
        let plain = trace(rate, 25.0, seed);
        let mix = TenantMix::parse("3:4,2,1").expect("static mix");
        let with_slo = stamped(rate, 25.0, seed, &mix, "ttft:2,tpot:0.5,deadline:60");
        let sim = Simulation::new(cfg(4, seed));
        for name in ["SLS", "ILS", "SCLS", "SCLS-CB", "P-SCLS"] {
            let a = sim.run_named(&plain, name, 128).unwrap();
            let b = sim.run_named(&with_slo, name, 128).unwrap();
            prop_assert_eq!(
                completions(&a),
                completions(&b),
                "{} moved completions when stamps appeared (seed {})",
                name,
                seed
            );
            prop_assert!(
                a.makespan.to_bits() == b.makespan.to_bits(),
                "{} makespan drifted under stamps (seed {})",
                name,
                seed
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 3. Weighted fair service: starvation freedom under sustained overload
// ---------------------------------------------------------------------------

#[test]
fn weighted_fair_service_starves_no_tenant_under_overload() {
    check("slo-starvation-freedom", 4, |g: &mut Gen| {
        let seed = g.u64();
        let weights = vec![8.0, 4.0, 2.0, 1.0];
        let mix = TenantMix {
            weights: weights.clone(),
        };
        // Sustained overload: arrivals far outrun 2 workers, so the pool
        // stays deep and the per-tick budget actually bites.
        let t = stamped(30.0, 15.0, seed, &mix, "deadline:600");
        let base = cfg(2, seed);
        let fair = Simulation::new(base.clone().with_tenant_weights(Some(weights.clone())));
        let a = fair.run_named(&t, "SCLS", 128).unwrap();
        let b = fair.run_named(&t, "SCLS", 128).unwrap();
        prop_assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "weighted SCLS is not deterministic (seed {})",
            seed
        );
        // No starvation: every request of every tenant completes.
        prop_assert_eq!(
            a.completed.len(),
            t.len(),
            "weighted run lost requests (seed {})",
            seed
        );
        // Interleaved service: the lightest tenant's first completion
        // lands before the heaviest tenant's last one — the budget delays
        // light tenants, it never parks them until the heavy queue drains.
        let finished_of = |tenant: u32| -> Vec<f64> {
            let ids: std::collections::HashSet<u64> = t
                .requests
                .iter()
                .filter(|r| r.tenant == tenant)
                .map(|r| r.id)
                .collect();
            a.completed
                .iter()
                .filter(|c| ids.contains(&c.id))
                .map(|c| c.finished)
                .collect()
        };
        let heavy = finished_of(0);
        let light = finished_of(3);
        if let (Some(heavy_last), Some(light_first)) = (
            heavy.iter().copied().reduce(f64::max),
            light.iter().copied().reduce(f64::min),
        ) {
            prop_assert!(
                light_first < heavy_last,
                "tenant 3 was parked to the end (first {} vs heavy last {}, seed {})",
                light_first,
                heavy_last,
                seed
            );
        }
        // The fairness path must actually engage under this overload: the
        // weighted schedule differs from the legacy drain-everything one.
        let legacy = Simulation::new(base).run_named(&t, "SCLS", 128).unwrap();
        prop_assert!(
            fingerprint(&legacy) != fingerprint(&a),
            "weighted fairness never engaged under overload (seed {})",
            seed
        );
        Ok(())
    });
}
