//! Fig. 6 — generation-length PDF/CDF of the synthetic CodeFuse and
//! ShareGPT workload models (the paper's motivation: the vast majority of
//! generations are < 512 tokens). Prints the distributions, then times
//! sampling and the empirical-CDF construction.

use scls::bench::figures::{fig06, FigureConfig};
use scls::bench::harness::{bench, report_header};
use scls::util::rng::Rng;
use scls::workload::distributions::WorkloadKind;

fn main() {
    let fc = FigureConfig::default();
    fig06(&fc).print();

    println!("{}", report_header());
    for (name, kind) in [
        ("codefuse", WorkloadKind::CodeFuse),
        ("sharegpt", WorkloadKind::ShareGpt),
    ] {
        let dist = kind.gen_dist(1024);
        let mut rng = Rng::new(9);
        let r = bench(&format!("{name} gen-length sample"), || dist.sample(&mut rng));
        println!("{}", r.report());
    }
    let dist = WorkloadKind::CodeFuse.gen_dist(1024);
    let at: Vec<f64> = (0..=16).map(|i| (i * 64) as f64).collect();
    let r = bench("empirical_cdf(10k samples, 17 pts)", || {
        let mut rng = Rng::new(11);
        dist.empirical_cdf(&mut rng, 10_000, &at)
    });
    println!("{}", r.report());
}
