//! Deterministic fault schedules for the elastic-fleet DES.
//!
//! A [`FaultPlan`] is an ordered list of worker-lifecycle events — joins,
//! drains, crashes — stamped with simulation times. The driver turns each
//! entry into an `Ev::Fleet` heap event *after* pushing the trace arrivals,
//! so at equal timestamps arrivals are delivered first, then fleet events
//! in plan order, then any runtime `WorkerDone` pushed later (the
//! [`crate::sim::events::EventQueue`] FIFO tie-break). Delivery order is
//! therefore exactly (time, plan index) — the same order [`FaultPlan::validate`]
//! walks, so a plan that validates can never reference a worker the run
//! has not yet materialized.
//!
//! The CLI spec grammar (`--faults`) is a comma-separated list of:
//!
//! - `crash:w3@120`  — worker 3 fails abruptly at t=120 (in-flight slice lost)
//! - `drain:w2@60`   — worker 2 stops accepting at t=60, finishes in-flight work
//! - `join:2@300`    — two cold workers join at t=300
//! - `rolling:30s`   — rolling restart: drain worker *i* at `(i+1)·P`, replace
//!   it with a fresh join one period later, for every initial worker
//!
//! Times accept an optional trailing `s` (`120` and `120s` are the same).

use std::fmt;

/// What happens to the fleet at a scheduled instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `count` cold workers join the fleet (empty queues, zero load).
    Join { count: u32 },
    /// Worker stops accepting new work but finishes what it holds.
    Drain { worker: usize },
    /// Worker dies abruptly; its in-flight slice is lost and survivors are
    /// re-queued at the last completed slice boundary.
    Crash { worker: usize },
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::Join { count } => write!(f, "join:{count}"),
            FaultKind::Drain { worker } => write!(f, "drain:w{worker}"),
            FaultKind::Crash { worker } => write!(f, "crash:w{worker}"),
        }
    }
}

/// One scheduled lifecycle event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Simulation time the event fires (finite, ≥ 0).
    pub at: f64,
    pub kind: FaultKind,
}

/// A deterministic, validated schedule of fleet events.
///
/// Plans are pure data: the same plan against the same trace and seed
/// reproduces the same run byte-for-byte. [`FaultPlan::none`] is the
/// canonical empty plan; drivers treat it as "the fixed-fleet world" and
/// produce event logs bit-identical to the pre-elastic code.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: no faults, byte-identical runs to a fixed fleet.
    pub fn none() -> Self {
        FaultPlan { events: Vec::new() }
    }

    pub fn new() -> Self {
        Self::none()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Builder: schedule an abrupt failure of `worker` at `at`.
    pub fn crash(mut self, worker: usize, at: f64) -> Self {
        self.events.push(FaultEvent {
            at,
            kind: FaultKind::Crash { worker },
        });
        self
    }

    /// Builder: schedule a graceful drain of `worker` at `at`.
    pub fn drain(mut self, worker: usize, at: f64) -> Self {
        self.events.push(FaultEvent {
            at,
            kind: FaultKind::Drain { worker },
        });
        self
    }

    /// Builder: schedule `count` cold workers joining at `at`.
    pub fn join(mut self, count: u32, at: f64) -> Self {
        self.events.push(FaultEvent {
            at,
            kind: FaultKind::Join { count },
        });
        self
    }

    /// A rolling restart over an initial fleet of `workers`: worker *i*
    /// drains at `(i+1)·period` and its replacement joins one period
    /// later. At any instant at most one initial worker is draining and
    /// the accepting capacity never drops below `workers - 1`.
    pub fn rolling(workers: usize, period: f64) -> Self {
        let mut plan = FaultPlan::none();
        for w in 0..workers {
            let t = (w as f64 + 1.0) * period;
            plan = plan.drain(w, t).join(1, t + period);
        }
        plan
    }

    /// Events in delivery order: stable-sorted by time, plan order among
    /// ties. The driver relies on this matching the heap's (t, seq) order.
    pub fn delivery_order(&self) -> Vec<FaultEvent> {
        let mut evs = self.events.clone();
        evs.sort_by(|a, b| a.at.total_cmp(&b.at));
        evs
    }

    /// Check the plan against an initial fleet of `workers`: every time
    /// finite and non-negative, every join count ≥ 1, and every
    /// drain/crash naming a worker index that exists by the time the
    /// event fires (initial workers plus earlier joins).
    pub fn validate(&self, workers: usize) -> Result<(), String> {
        for ev in &self.events {
            if !ev.at.is_finite() {
                return Err(format!("fault time for '{}' is not a finite number", ev.kind));
            }
            if ev.at < 0.0 {
                return Err(format!(
                    "fault time for '{}' is negative ({}); times are seconds from t=0",
                    ev.kind, ev.at
                ));
            }
            if let FaultKind::Join { count: 0 } = ev.kind {
                return Err("join count must be at least 1 (got 0)".to_string());
            }
        }
        // Walk in delivery order so joins extend the known index range for
        // everything that fires after them.
        let mut known = workers;
        for ev in self.delivery_order() {
            match ev.kind {
                FaultKind::Join { count } => known += count as usize,
                FaultKind::Drain { worker } | FaultKind::Crash { worker } => {
                    if worker >= known {
                        return Err(format!(
                            "'{}' at t={} names an unknown worker: only {} worker(s) \
                             exist at that time (indices 0..{})",
                            ev.kind, ev.at, known, known
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Parse the CLI `--faults` grammar against an initial fleet of
    /// `workers`, validating as it goes. Errors are friendly, single-line
    /// messages suitable for direct CLI display.
    pub fn parse(spec: &str, workers: usize) -> Result<Self, String> {
        let mut plan = FaultPlan::none();
        for raw in spec.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            let (op, rest) = entry
                .split_once(':')
                .ok_or_else(|| format!("bad fault entry '{entry}': expected op:args, e.g. crash:w3@120"))?;
            match op {
                "crash" | "drain" => {
                    let (wtok, ttok) = rest.split_once('@').ok_or_else(|| {
                        format!("bad fault entry '{entry}': expected {op}:wN@TIME, e.g. {op}:w3@120")
                    })?;
                    let worker = parse_worker(wtok, entry)?;
                    let at = parse_time(ttok, entry)?;
                    plan = if op == "crash" {
                        plan.crash(worker, at)
                    } else {
                        plan.drain(worker, at)
                    };
                }
                "join" => {
                    let (ctok, ttok) = rest.split_once('@').ok_or_else(|| {
                        format!("bad fault entry '{entry}': expected join:COUNT@TIME, e.g. join:2@300")
                    })?;
                    let count: u32 = ctok
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad join count '{ctok}' in '{entry}'"))?;
                    let at = parse_time(ttok, entry)?;
                    plan = plan.join(count, at);
                }
                "rolling" => {
                    let period = parse_time(rest, entry)?;
                    if period <= 0.0 {
                        return Err(format!(
                            "rolling period must be positive (got '{rest}' in '{entry}')"
                        ));
                    }
                    let rolled = FaultPlan::rolling(workers, period);
                    plan.events.extend(rolled.events);
                }
                other => {
                    return Err(format!(
                        "unknown fault op '{other}' in '{entry}': expected crash, drain, join, or rolling"
                    ))
                }
            }
        }
        plan.validate(workers)?;
        Ok(plan)
    }
}

fn parse_worker(tok: &str, entry: &str) -> Result<usize, String> {
    let tok = tok.trim();
    let digits = tok.strip_prefix('w').unwrap_or(tok);
    digits
        .parse()
        .map_err(|_| format!("bad worker index '{tok}' in '{entry}': expected wN (e.g. w3)"))
}

fn parse_time(tok: &str, entry: &str) -> Result<f64, String> {
    let tok = tok.trim();
    let digits = tok.strip_suffix('s').unwrap_or(tok);
    let t: f64 = digits
        .parse()
        .map_err(|_| format!("bad time '{tok}' in '{entry}': expected seconds, e.g. 120 or 120s"))?;
    if !t.is_finite() {
        return Err(format!("time '{tok}' in '{entry}' is not a finite number"));
    }
    if t < 0.0 {
        return Err(format!("time '{tok}' in '{entry}' is negative; times are seconds from t=0"));
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_empty() {
        assert!(FaultPlan::none().is_empty());
        assert!(FaultPlan::none().validate(4).is_ok());
    }

    #[test]
    fn parse_round_trip() {
        let plan = FaultPlan::parse("crash:w3@120,join:2@300", 4).unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.events[0].kind, FaultKind::Crash { worker: 3 });
        assert_eq!(plan.events[0].at, 120.0);
        assert_eq!(plan.events[1].kind, FaultKind::Join { count: 2 });
        assert_eq!(plan.events[1].at, 300.0);
    }

    #[test]
    fn parse_accepts_seconds_suffix_and_bare_index() {
        let plan = FaultPlan::parse("drain:2@60s", 4).unwrap();
        assert_eq!(plan.events[0].kind, FaultKind::Drain { worker: 2 });
        assert_eq!(plan.events[0].at, 60.0);
    }

    #[test]
    fn rolling_expands_per_worker() {
        let plan = FaultPlan::parse("rolling:30s", 3).unwrap();
        // drain w0@30 join@60, drain w1@60 join@90, drain w2@90 join@120
        assert_eq!(plan.events.len(), 6);
        assert_eq!(plan.events[0].kind, FaultKind::Drain { worker: 0 });
        assert_eq!(plan.events[0].at, 30.0);
        assert_eq!(plan.events[1].kind, FaultKind::Join { count: 1 });
        assert_eq!(plan.events[1].at, 60.0);
        assert_eq!(plan.events[5].at, 120.0);
    }

    #[test]
    fn unknown_worker_is_friendly() {
        let err = FaultPlan::parse("crash:w7@10", 4).unwrap_err();
        assert!(err.contains("unknown worker"), "{err}");
        // ... but a join before the crash makes the index known.
        let ok = FaultPlan::parse("join:4@5,crash:w7@10", 4);
        assert!(ok.is_ok(), "{ok:?}");
    }

    #[test]
    fn join_after_crash_time_does_not_legitimize_index() {
        let err = FaultPlan::parse("crash:w5@10,join:4@50", 4).unwrap_err();
        assert!(err.contains("unknown worker"), "{err}");
    }

    #[test]
    fn negative_and_nan_times_rejected() {
        let err = FaultPlan::parse("crash:w1@-5", 4).unwrap_err();
        assert!(err.contains("negative"), "{err}");
        let err = FaultPlan::parse("crash:w1@NaN", 4).unwrap_err();
        assert!(err.contains("finite"), "{err}");
    }

    #[test]
    fn zero_join_count_rejected() {
        let err = FaultPlan::parse("join:0@10", 4).unwrap_err();
        assert!(err.contains("at least 1"), "{err}");
    }

    #[test]
    fn junk_rejected_with_context() {
        assert!(FaultPlan::parse("explode:w1@10", 4)
            .unwrap_err()
            .contains("unknown fault op"));
        assert!(FaultPlan::parse("crash:w1", 4).unwrap_err().contains("@TIME"));
        assert!(FaultPlan::parse("crash:banana@10", 4)
            .unwrap_err()
            .contains("worker index"));
    }

    #[test]
    fn delivery_order_is_time_then_plan_order() {
        let plan = FaultPlan::none().crash(1, 50.0).drain(2, 10.0).join(1, 50.0);
        let order = plan.delivery_order();
        assert_eq!(order[0].kind, FaultKind::Drain { worker: 2 });
        assert_eq!(order[1].kind, FaultKind::Crash { worker: 1 });
        assert_eq!(order[2].kind, FaultKind::Join { count: 1 });
    }
}
