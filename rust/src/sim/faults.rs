//! Fault schedules for the elastic-fleet DES: deterministic plans plus a
//! seeded stochastic generator that expands to deterministic plans.
//!
//! A [`FaultPlan`] is an ordered list of lifecycle events — joins, drains,
//! crashes, coordinator crashes — stamped with simulation times. The driver
//! turns each entry into an `Ev::Fleet` heap event *after* pushing the trace
//! arrivals, so at equal timestamps arrivals are delivered first, then fleet
//! events in plan order, then any runtime `WorkerDone` pushed later (the
//! [`crate::sim::events::EventQueue`] FIFO tie-break). Delivery order is
//! therefore exactly (time, plan index) — the same order [`FaultPlan::validate`]
//! walks, so a plan that validates can never reference a worker the run
//! has not yet materialized.
//!
//! The CLI spec grammar (`--faults`) is a comma-separated list of:
//!
//! - `crash:w3@120`  — worker 3 fails abruptly at t=120 (in-flight slice lost)
//! - `drain:w2@60`   — worker 2 stops accepting at t=60, finishes in-flight work
//! - `join:2@300`    — two cold workers join at t=300
//! - `rolling:30s`   — rolling restart: drain worker *i* at `(i+1)·P`, replace
//!   it with a fresh join one period later, for every initial worker
//! - `coord@15`      — the coordinator crashes at t=15 and a successor
//!   reconstructs its ledger from worker-side state (see
//!   `SchedulingPolicy::on_coordinator_crash`)
//! - `mtbf:30`       — stochastic churn: each worker (sparing worker 0, so the
//!   fleet always keeps a survivor) fails after an Exp(1/MTBF) lifetime
//! - `mttr:5`        — each stochastic failure is repaired by a fresh join
//!   after an Exp(1/MTTR) repair time (requires `mtbf:` or `burst:`)
//! - `burst:3@0.01`  — correlated failures: at Poisson instants with the given
//!   rate (events/s), 3 distinct alive workers crash simultaneously
//! - `seed:7`        — RNG seed for the stochastic entries; the same seed
//!   expands to a byte-identical concrete schedule every time
//!
//! Stochastic entries are expanded **at parse time** into ordinary
//! crash/join events over a horizon (the run duration via
//! [`FaultPlan::parse_with_horizon`]); from there on the plan is pure data
//! and replays are byte-identical. Times accept an optional trailing `s`
//! (`120` and `120s` are the same).

use std::fmt;

use crate::util::rng::Rng;

/// What happens to the fleet at a scheduled instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `count` cold workers join the fleet (empty queues, zero load).
    Join { count: u32 },
    /// Worker stops accepting new work but finishes what it holds.
    Drain { worker: usize },
    /// Worker dies abruptly; its in-flight slice is lost and survivors are
    /// re-queued at the last completed slice boundary.
    Crash { worker: usize },
    /// The coordinator's in-memory state (pools, load ledger, deficit
    /// counters) is lost; a successor rebuilds it from worker reports and
    /// the arrival log. Workers keep computing through the failover.
    CoordinatorCrash,
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::Join { count } => write!(f, "join:{count}"),
            FaultKind::Drain { worker } => write!(f, "drain:w{worker}"),
            FaultKind::Crash { worker } => write!(f, "crash:w{worker}"),
            FaultKind::CoordinatorCrash => write!(f, "coord"),
        }
    }
}

/// One scheduled lifecycle event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Simulation time the event fires (finite, ≥ 0).
    pub at: f64,
    pub kind: FaultKind,
}

/// A deterministic, validated schedule of fleet events.
///
/// Plans are pure data: the same plan against the same trace and seed
/// reproduces the same run byte-for-byte. [`FaultPlan::none`] is the
/// canonical empty plan; drivers treat it as "the fixed-fleet world" and
/// produce event logs bit-identical to the pre-elastic code.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

/// Horizon used by [`FaultPlan::parse`] for stochastic entries when the
/// caller has no run duration at hand (the paper's default trace length).
pub const DEFAULT_HORIZON: f64 = 600.0;

/// Seed used for stochastic entries when the spec has no `seed:N`.
pub const DEFAULT_FAULT_SEED: u64 = 0x5c15_fa17;

/// Backstop on stochastic expansion size: a runaway rate (tiny MTBF or a
/// huge burst rate) fails loudly instead of materializing an absurd plan.
const MAX_GENERATED_EVENTS: usize = 100_000;

impl FaultPlan {
    /// The empty plan: no faults, byte-identical runs to a fixed fleet.
    pub fn none() -> Self {
        FaultPlan { events: Vec::new() }
    }

    pub fn new() -> Self {
        Self::none()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Builder: schedule an abrupt failure of `worker` at `at`.
    pub fn crash(mut self, worker: usize, at: f64) -> Self {
        self.events.push(FaultEvent {
            at,
            kind: FaultKind::Crash { worker },
        });
        self
    }

    /// Builder: schedule a graceful drain of `worker` at `at`.
    pub fn drain(mut self, worker: usize, at: f64) -> Self {
        self.events.push(FaultEvent {
            at,
            kind: FaultKind::Drain { worker },
        });
        self
    }

    /// Builder: schedule `count` cold workers joining at `at`.
    pub fn join(mut self, count: u32, at: f64) -> Self {
        self.events.push(FaultEvent {
            at,
            kind: FaultKind::Join { count },
        });
        self
    }

    /// Builder: schedule a coordinator crash (ledger loss + successor
    /// reconstruction) at `at`.
    pub fn coordinator_crash(mut self, at: f64) -> Self {
        self.events.push(FaultEvent {
            at,
            kind: FaultKind::CoordinatorCrash,
        });
        self
    }

    /// A rolling restart over an initial fleet of `workers`: worker *i*
    /// drains at `(i+1)·period` and its replacement joins one period
    /// later. At any instant at most one initial worker is draining and
    /// the accepting capacity never drops below `workers - 1`.
    pub fn rolling(workers: usize, period: f64) -> Self {
        let mut plan = FaultPlan::none();
        for w in 0..workers {
            let t = (w as f64 + 1.0) * period;
            plan = plan.drain(w, t).join(1, t + period);
        }
        plan
    }

    /// Events in delivery order: stable-sorted by time, plan order among
    /// ties. The driver relies on this matching the heap's (t, seq) order.
    pub fn delivery_order(&self) -> Vec<FaultEvent> {
        let mut evs = self.events.clone();
        evs.sort_by(|a, b| a.at.total_cmp(&b.at));
        evs
    }

    /// Check the plan against an initial fleet of `workers`: every time
    /// finite and non-negative, every join count ≥ 1, and every
    /// drain/crash naming a worker index that exists by the time the
    /// event fires (initial workers plus earlier joins).
    pub fn validate(&self, workers: usize) -> Result<(), String> {
        for ev in &self.events {
            if !ev.at.is_finite() {
                return Err(format!("fault time for '{}' is not a finite number", ev.kind));
            }
            if ev.at < 0.0 {
                return Err(format!(
                    "fault time for '{}' is negative ({}); times are seconds from t=0",
                    ev.kind, ev.at
                ));
            }
            if let FaultKind::Join { count: 0 } = ev.kind {
                return Err("join count must be at least 1 (got 0)".to_string());
            }
        }
        // Walk in delivery order so joins extend the known index range for
        // everything that fires after them.
        let mut known = workers;
        for ev in self.delivery_order() {
            match ev.kind {
                FaultKind::Join { count } => known += count as usize,
                FaultKind::Drain { worker } | FaultKind::Crash { worker } => {
                    if worker >= known {
                        return Err(format!(
                            "'{}' at t={} names an unknown worker: only {} worker(s) \
                             exist at that time (indices 0..{})",
                            ev.kind, ev.at, known, known
                        ));
                    }
                }
                FaultKind::CoordinatorCrash => {}
            }
        }
        Ok(())
    }

    /// Parse the CLI `--faults` grammar against an initial fleet of
    /// `workers`. Stochastic entries (`mtbf:`/`mttr:`/`burst:`/`seed:`)
    /// expand over [`DEFAULT_HORIZON`]; callers that know the run duration
    /// should use [`FaultPlan::parse_with_horizon`] instead. Errors are
    /// friendly, single-line messages suitable for direct CLI display.
    pub fn parse(spec: &str, workers: usize) -> Result<Self, String> {
        Self::parse_with_horizon(spec, workers, DEFAULT_HORIZON)
    }

    /// [`FaultPlan::parse`] with an explicit expansion horizon (seconds)
    /// for the stochastic entries: generated events all fire at
    /// `t ≤ horizon`. Deterministic entries are unaffected by the horizon.
    pub fn parse_with_horizon(spec: &str, workers: usize, horizon: f64) -> Result<Self, String> {
        let mut det = FaultPlan::none();
        let mut st = Stochastic::default();
        for raw in spec.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            // `coord@T` carries no `op:args` colon — special-case it first.
            if let Some(ttok) = entry.strip_prefix("coord@") {
                let at = parse_time(ttok, entry)?;
                det = det.coordinator_crash(at);
                continue;
            }
            if entry == "coord" {
                return Err(format!(
                    "bad fault entry '{entry}': expected coord@TIME, e.g. coord@15"
                ));
            }
            let (op, rest) = entry
                .split_once(':')
                .ok_or_else(|| format!("bad fault entry '{entry}': expected op:args, e.g. crash:w3@120"))?;
            match op {
                "crash" | "drain" => {
                    let (wtok, ttok) = rest.split_once('@').ok_or_else(|| {
                        format!("bad fault entry '{entry}': expected {op}:wN@TIME, e.g. {op}:w3@120")
                    })?;
                    let worker = parse_worker(wtok, entry)?;
                    let at = parse_time(ttok, entry)?;
                    det = if op == "crash" {
                        det.crash(worker, at)
                    } else {
                        det.drain(worker, at)
                    };
                }
                "join" => {
                    let (ctok, ttok) = rest.split_once('@').ok_or_else(|| {
                        format!("bad fault entry '{entry}': expected join:COUNT@TIME, e.g. join:2@300")
                    })?;
                    let count: u32 = ctok
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad join count '{ctok}' in '{entry}'"))?;
                    let at = parse_time(ttok, entry)?;
                    det = det.join(count, at);
                }
                "rolling" => {
                    let period = parse_time(rest, entry)?;
                    if period <= 0.0 {
                        return Err(format!(
                            "rolling period must be positive (got '{rest}' in '{entry}')"
                        ));
                    }
                    let rolled = FaultPlan::rolling(workers, period);
                    det.events.extend(rolled.events);
                }
                "mtbf" => {
                    if st.mtbf.is_some() {
                        return Err(format!("duplicate 'mtbf:' entry ('{entry}')"));
                    }
                    st.mtbf = Some(parse_positive_secs(rest, entry, "mtbf")?);
                }
                "mttr" => {
                    if st.mttr.is_some() {
                        return Err(format!("duplicate 'mttr:' entry ('{entry}')"));
                    }
                    st.mttr = Some(parse_positive_secs(rest, entry, "mttr")?);
                }
                "seed" => {
                    if st.seed.is_some() {
                        return Err(format!("duplicate 'seed:' entry ('{entry}')"));
                    }
                    let s: u64 = rest
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad seed '{rest}' in '{entry}': expected an unsigned integer"))?;
                    st.seed = Some(s);
                }
                "burst" => {
                    if st.burst.is_some() {
                        return Err(format!("duplicate 'burst:' entry ('{entry}')"));
                    }
                    let (ktok, rtok) = rest.split_once('@').ok_or_else(|| {
                        format!("bad fault entry '{entry}': expected burst:K@RATE, e.g. burst:3@0.01")
                    })?;
                    let k: u32 = ktok
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad burst size '{ktok}' in '{entry}'"))?;
                    if k == 0 {
                        return Err(format!("burst size must be at least 1 (got 0 in '{entry}')"));
                    }
                    let rate: f64 = rtok
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad burst rate '{rtok}' in '{entry}': expected events/s"))?;
                    if !(rate.is_finite() && rate > 0.0) {
                        return Err(format!(
                            "burst rate must be finite and positive (got '{rtok}' in '{entry}')"
                        ));
                    }
                    st.burst = Some((k, rate));
                }
                other => {
                    return Err(format!(
                        "unknown fault op '{other}' in '{entry}': expected crash, drain, join, \
                         rolling, coord@TIME, mtbf, mttr, burst, or seed"
                    ))
                }
            }
        }
        let plan = if st.is_some() {
            if st.mtbf.is_none() && st.burst.is_none() {
                return Err(
                    "'mttr:'/'seed:' need a stochastic source ('mtbf:' or 'burst:') in the same spec"
                        .to_string(),
                );
            }
            if !(horizon.is_finite() && horizon > 0.0) {
                return Err(format!(
                    "stochastic fault entries need a finite, positive horizon (got {horizon})"
                ));
            }
            st.expand(det, workers, horizon)?
        } else {
            det
        };
        plan.validate(workers)?;
        Ok(plan)
    }
}

/// Stochastic spec collected from `mtbf:`/`mttr:`/`burst:`/`seed:` entries.
#[derive(Debug, Default)]
struct Stochastic {
    mtbf: Option<f64>,
    mttr: Option<f64>,
    seed: Option<u64>,
    burst: Option<(u32, f64)>,
}

/// One pending event on the expansion timeline.
enum Pending {
    /// Deterministic entry, emitted verbatim (index into the det plan).
    Det(FaultEvent),
    /// Stochastic failure of a concrete worker index.
    Fail(usize),
    /// Repair join (replacement worker gets the next fresh index).
    Repair,
    /// Correlated-failure burst instant.
    Burst,
}

impl Stochastic {
    fn is_some(&self) -> bool {
        self.mtbf.is_some() || self.mttr.is_some() || self.seed.is_some() || self.burst.is_some()
    }

    /// Expand to a concrete plan: a virtual fault timeline is walked in
    /// (time, insertion) order, mirroring the driver's delivery order, so
    /// fresh join indices assigned here match the indices the driver will
    /// hand out — generated crash events always name real workers.
    ///
    /// Worker 0 is spared from stochastic failure so the fleet always
    /// keeps at least one survivor (the same convention the randomized
    /// property plans use). Deterministic entries ride the same timeline:
    /// their joins advance the fresh-index counter and their drains and
    /// crashes remove victims from the alive set.
    fn expand(&self, det: FaultPlan, workers: usize, horizon: f64) -> Result<FaultPlan, String> {
        let mut rng = Rng::new(self.seed.unwrap_or(DEFAULT_FAULT_SEED));
        let mut pending: Vec<(f64, u64, Pending)> = Vec::new();
        let mut seq: u64 = 0;
        let mut push = |pending: &mut Vec<(f64, u64, Pending)>, seq: &mut u64, at: f64, p: Pending| {
            pending.push((at, *seq, p));
            *seq += 1;
        };
        for ev in &det.events {
            push(&mut pending, &mut seq, ev.at, Pending::Det(*ev));
        }
        // Worker 0 is the spared survivor; everyone else draws a lifetime.
        let mut alive: Vec<usize> = (1..workers).collect();
        let mut next_fresh = workers;
        if let Some(mtbf) = self.mtbf {
            for &w in &alive {
                let t = rng.exponential(1.0 / mtbf);
                push(&mut pending, &mut seq, t, Pending::Fail(w));
            }
        }
        if let Some((_, rate)) = self.burst {
            let t = rng.exponential(rate);
            push(&mut pending, &mut seq, t, Pending::Burst);
        }

        let mut out = FaultPlan::none();
        while !pending.is_empty() {
            // Deterministic pop: earliest time, insertion order on ties.
            let mut best = 0;
            for i in 1..pending.len() {
                let (ta, sa) = (pending[i].0, pending[i].1);
                let (tb, sb) = (pending[best].0, pending[best].1);
                if ta.total_cmp(&tb).then(sa.cmp(&sb)).is_lt() {
                    best = i;
                }
            }
            let (t, _, p) = pending.remove(best);
            if out.events.len() > MAX_GENERATED_EVENTS {
                return Err(format!(
                    "stochastic fault spec expands to more than {MAX_GENERATED_EVENTS} events \
                     over a {horizon}s horizon — lower the rates or shorten the horizon"
                ));
            }
            match p {
                Pending::Det(ev) => {
                    match ev.kind {
                        FaultKind::Join { count } => {
                            for _ in 0..count {
                                let idx = next_fresh;
                                next_fresh += 1;
                                alive.push(idx);
                                if let Some(mtbf) = self.mtbf {
                                    let tf = t + rng.exponential(1.0 / mtbf);
                                    push(&mut pending, &mut seq, tf, Pending::Fail(idx));
                                }
                            }
                        }
                        FaultKind::Drain { worker } | FaultKind::Crash { worker } => {
                            alive.retain(|&w| w != worker);
                        }
                        FaultKind::CoordinatorCrash => {}
                    }
                    out.events.push(ev);
                }
                Pending::Fail(w) => {
                    if t > horizon || !alive.contains(&w) {
                        continue;
                    }
                    alive.retain(|&x| x != w);
                    out = out.crash(w, t);
                    if let Some(mttr) = self.mttr {
                        let tr = t + rng.exponential(1.0 / mttr);
                        push(&mut pending, &mut seq, tr, Pending::Repair);
                    }
                }
                Pending::Repair => {
                    if t > horizon {
                        continue;
                    }
                    out = out.join(1, t);
                    let idx = next_fresh;
                    next_fresh += 1;
                    alive.push(idx);
                    if let Some(mtbf) = self.mtbf {
                        let tf = t + rng.exponential(1.0 / mtbf);
                        push(&mut pending, &mut seq, tf, Pending::Fail(idx));
                    }
                }
                Pending::Burst => {
                    let (k, rate) = self.burst.expect("burst event without burst spec");
                    if t > horizon {
                        continue;
                    }
                    let hits = (k as usize).min(alive.len());
                    for _ in 0..hits {
                        let j = rng.range_u32(0, alive.len() as u32 - 1) as usize;
                        let w = alive.remove(j);
                        out = out.crash(w, t);
                        if let Some(mttr) = self.mttr {
                            let tr = t + rng.exponential(1.0 / mttr);
                            push(&mut pending, &mut seq, tr, Pending::Repair);
                        }
                    }
                    let tn = t + rng.exponential(rate);
                    push(&mut pending, &mut seq, tn, Pending::Burst);
                }
            }
        }
        Ok(out)
    }
}

fn parse_worker(tok: &str, entry: &str) -> Result<usize, String> {
    let tok = tok.trim();
    let digits = tok.strip_prefix('w').unwrap_or(tok);
    digits
        .parse()
        .map_err(|_| format!("bad worker index '{tok}' in '{entry}': expected wN (e.g. w3)"))
}

fn parse_time(tok: &str, entry: &str) -> Result<f64, String> {
    let tok = tok.trim();
    let digits = tok.strip_suffix('s').unwrap_or(tok);
    let t: f64 = digits
        .parse()
        .map_err(|_| format!("bad time '{tok}' in '{entry}': expected seconds, e.g. 120 or 120s"))?;
    if !t.is_finite() {
        return Err(format!("time '{tok}' in '{entry}' is not a finite number"));
    }
    if t < 0.0 {
        return Err(format!("time '{tok}' in '{entry}' is negative; times are seconds from t=0"));
    }
    Ok(t)
}

/// `mtbf:`/`mttr:` operand: a strictly positive, finite number of seconds.
fn parse_positive_secs(tok: &str, entry: &str, what: &str) -> Result<f64, String> {
    let tok = tok.trim();
    let digits = tok.strip_suffix('s').unwrap_or(tok);
    let t: f64 = digits
        .parse()
        .map_err(|_| format!("bad {what} '{tok}' in '{entry}': expected seconds, e.g. 30 or 30s"))?;
    if !(t.is_finite() && t > 0.0) {
        return Err(format!(
            "{what} must be a finite, positive number of seconds (got '{tok}' in '{entry}')"
        ));
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_empty() {
        assert!(FaultPlan::none().is_empty());
        assert!(FaultPlan::none().validate(4).is_ok());
    }

    #[test]
    fn parse_round_trip() {
        let plan = FaultPlan::parse("crash:w3@120,join:2@300", 4).unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.events[0].kind, FaultKind::Crash { worker: 3 });
        assert_eq!(plan.events[0].at, 120.0);
        assert_eq!(plan.events[1].kind, FaultKind::Join { count: 2 });
        assert_eq!(plan.events[1].at, 300.0);
    }

    #[test]
    fn parse_accepts_seconds_suffix_and_bare_index() {
        let plan = FaultPlan::parse("drain:2@60s", 4).unwrap();
        assert_eq!(plan.events[0].kind, FaultKind::Drain { worker: 2 });
        assert_eq!(plan.events[0].at, 60.0);
    }

    #[test]
    fn rolling_expands_per_worker() {
        let plan = FaultPlan::parse("rolling:30s", 3).unwrap();
        // drain w0@30 join@60, drain w1@60 join@90, drain w2@90 join@120
        assert_eq!(plan.events.len(), 6);
        assert_eq!(plan.events[0].kind, FaultKind::Drain { worker: 0 });
        assert_eq!(plan.events[0].at, 30.0);
        assert_eq!(plan.events[1].kind, FaultKind::Join { count: 1 });
        assert_eq!(plan.events[1].at, 60.0);
        assert_eq!(plan.events[5].at, 120.0);
    }

    #[test]
    fn unknown_worker_is_friendly() {
        let err = FaultPlan::parse("crash:w7@10", 4).unwrap_err();
        assert!(err.contains("unknown worker"), "{err}");
        // ... but a join before the crash makes the index known.
        let ok = FaultPlan::parse("join:4@5,crash:w7@10", 4);
        assert!(ok.is_ok(), "{ok:?}");
    }

    #[test]
    fn join_after_crash_time_does_not_legitimize_index() {
        let err = FaultPlan::parse("crash:w5@10,join:4@50", 4).unwrap_err();
        assert!(err.contains("unknown worker"), "{err}");
    }

    #[test]
    fn negative_and_nan_times_rejected() {
        let err = FaultPlan::parse("crash:w1@-5", 4).unwrap_err();
        assert!(err.contains("negative"), "{err}");
        let err = FaultPlan::parse("crash:w1@NaN", 4).unwrap_err();
        assert!(err.contains("finite"), "{err}");
    }

    #[test]
    fn zero_join_count_rejected() {
        let err = FaultPlan::parse("join:0@10", 4).unwrap_err();
        assert!(err.contains("at least 1"), "{err}");
    }

    #[test]
    fn junk_rejected_with_context() {
        assert!(FaultPlan::parse("explode:w1@10", 4)
            .unwrap_err()
            .contains("unknown fault op"));
        assert!(FaultPlan::parse("crash:w1", 4).unwrap_err().contains("@TIME"));
        assert!(FaultPlan::parse("crash:banana@10", 4)
            .unwrap_err()
            .contains("worker index"));
    }

    #[test]
    fn delivery_order_is_time_then_plan_order() {
        let plan = FaultPlan::none().crash(1, 50.0).drain(2, 10.0).join(1, 50.0);
        let order = plan.delivery_order();
        assert_eq!(order[0].kind, FaultKind::Drain { worker: 2 });
        assert_eq!(order[1].kind, FaultKind::Crash { worker: 1 });
        assert_eq!(order[2].kind, FaultKind::Join { count: 1 });
    }

    // ------------------------------------------------ coordinator crashes

    #[test]
    fn coord_entry_parses_and_round_trips() {
        let plan = FaultPlan::parse("coord@15,crash:w1@10", 4).unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.events[0].kind, FaultKind::CoordinatorCrash);
        assert_eq!(plan.events[0].at, 15.0);
        assert_eq!(FaultKind::CoordinatorCrash.to_string(), "coord");
        // With the seconds suffix too.
        let plan = FaultPlan::parse("coord@15s", 4).unwrap();
        assert_eq!(plan.events[0].at, 15.0);
    }

    #[test]
    fn coord_without_time_is_friendly() {
        let err = FaultPlan::parse("coord", 4).unwrap_err();
        assert!(err.contains("coord@TIME"), "{err}");
        let err = FaultPlan::parse("coord@-3", 4).unwrap_err();
        assert!(err.contains("negative"), "{err}");
        let err = FaultPlan::parse("coord@nan", 4).unwrap_err();
        assert!(err.contains("finite"), "{err}");
    }

    // ------------------------------------------------ stochastic expansion

    #[test]
    fn mtbf_expansion_is_byte_stable_and_seed_sensitive() {
        let a = FaultPlan::parse_with_horizon("mtbf:30,mttr:5,seed:7", 8, 600.0).unwrap();
        let b = FaultPlan::parse_with_horizon("mtbf:30,mttr:5,seed:7", 8, 600.0).unwrap();
        assert_eq!(a, b);
        assert!(!a.is_empty(), "600s horizon at 30s MTBF must generate churn");
        let c = FaultPlan::parse_with_horizon("mtbf:30,mttr:5,seed:8", 8, 600.0).unwrap();
        assert_ne!(a, c, "different seeds must expand differently");
    }

    #[test]
    fn mtbf_expansion_validates_and_spares_worker_zero() {
        let plan = FaultPlan::parse_with_horizon("mtbf:20,mttr:10,seed:3", 6, 400.0).unwrap();
        assert!(plan.validate(6).is_ok());
        // All events in time order (plan order == delivery order).
        let order = plan.delivery_order();
        assert_eq!(order, plan.events);
        for ev in &plan.events {
            assert!(ev.at <= 400.0, "{ev:?} beyond horizon");
            if let FaultKind::Crash { worker } = ev.kind {
                assert_ne!(worker, 0, "worker 0 is the spared survivor");
            }
        }
    }

    #[test]
    fn mtbf_without_mttr_kills_each_worker_at_most_once() {
        let plan = FaultPlan::parse_with_horizon("mtbf:10,seed:1", 5, 1000.0).unwrap();
        let mut seen = Vec::new();
        for ev in &plan.events {
            match ev.kind {
                FaultKind::Crash { worker } => {
                    assert!(!seen.contains(&worker), "worker {worker} crashed twice");
                    seen.push(worker);
                }
                other => panic!("unexpected event {other} in mttr-free plan"),
            }
        }
        assert!(seen.len() <= 4, "only workers 1..5 can fail");
    }

    #[test]
    fn burst_crashes_k_distinct_workers_at_one_instant() {
        let plan = FaultPlan::parse_with_horizon("burst:3@0.05,mttr:5,seed:9", 8, 600.0).unwrap();
        assert!(plan.validate(8).is_ok());
        assert!(!plan.is_empty());
        // Group crashes by timestamp: each burst hits distinct workers.
        let mut i = 0;
        let evs = &plan.events;
        while i < evs.len() {
            if let FaultKind::Crash { .. } = evs[i].kind {
                let t = evs[i].at;
                let mut victims = Vec::new();
                while i < evs.len() && evs[i].at == t {
                    if let FaultKind::Crash { worker } = evs[i].kind {
                        assert!(!victims.contains(&worker), "duplicate victim in burst");
                        victims.push(worker);
                    }
                    i += 1;
                }
                assert!(victims.len() <= 3, "burst size exceeded: {victims:?}");
            } else {
                i += 1;
            }
        }
    }

    #[test]
    fn stochastic_layered_on_deterministic_keeps_join_indices_consistent() {
        // A deterministic join advances the fresh-index counter inside the
        // expansion too, so generated crashes never name phantom workers.
        let plan =
            FaultPlan::parse_with_horizon("join:2@5,mtbf:15,mttr:5,seed:4", 4, 300.0).unwrap();
        assert!(plan.validate(4).is_ok());
        assert!(plan.events.iter().any(|e| e.kind == FaultKind::Join { count: 2 }));
    }

    #[test]
    fn stochastic_junk_is_friendly() {
        for (spec, needle) in [
            ("mtbf:0", "positive"),
            ("mtbf:-3", "positive"),
            ("mtbf:nan", "positive"),
            ("mttr:5", "stochastic source"),
            ("seed:7", "stochastic source"),
            ("mtbf:30,mtbf:40", "duplicate"),
            ("mtbf:30,seed:x", "seed"),
            ("burst:0@0.1", "at least 1"),
            ("burst:2@0", "finite and positive"),
            ("burst:2@-1", "finite and positive"),
            ("burst:2@nan", "finite and positive"),
            ("burst:2", "K@RATE"),
        ] {
            let err = FaultPlan::parse_with_horizon(spec, 4, 600.0).unwrap_err();
            assert!(err.contains(needle), "spec {spec}: {err}");
        }
        // A stochastic spec against a degenerate horizon fails loudly.
        let err = FaultPlan::parse_with_horizon("mtbf:30", 4, f64::NAN).unwrap_err();
        assert!(err.contains("horizon"), "{err}");
    }
}
