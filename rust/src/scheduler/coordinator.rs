//! The sliced-family coordinator core (paper Fig. 7) — the single
//! "scheduling brain" shared by the calibrated DES
//! ([`crate::sim::policies::SlicedPolicy`]) and the wall-clock PJRT
//! cluster ([`crate::worker::real_driver`]).
//!
//! It owns the request pool, the DP batcher invocation (with its reusable
//! scratch), the offloader, the worker-load ledger (Eq. 11), and the
//! schedule-interval controller (Eq. 12); the drivers own clocks, worker
//! state, and metrics. Keeping the decision logic here means a policy
//! tweak lands in simulation and real serving at once.

use crate::batcher::{dp_batch_sorted_into, DpBatcherConfig, DpScratch};
use crate::core::{Batch, Request};
use crate::estimator::serving_time::ServeEstimate;
use crate::estimator::MemoryEstimator;
use crate::offloader::{LoadLedger, MaxMinOffloader, RoundRobin};
use crate::scheduler::fleet::{WorkerHealth, WorkerLedger, WorkerReport};
use crate::scheduler::spec::{BatchingSpec, IntervalSpec, OffloadSpec, SchedulerSpec};
use crate::scheduler::{IntervalController, RequestPool};

/// Per-tick fair-service quantum, in KV token-slots per accepting worker:
/// with weighted fairness on, each tick distributes
/// `FAIR_TOKENS_PER_WORKER × accepting_workers` of admission capacity
/// across tenants in proportion to their weights (one request costs
/// `input_len + slice_len` slots — the KV footprint its next pass
/// reserves).
const FAIR_TOKENS_PER_WORKER: f64 = 16_384.0;

/// Coordinator state for one sliced-family scheduler over `workers`
/// instances. All per-tick buffers are reused across the whole run (the
/// allocation-lean discipline from the PR 1 hot-path work).
pub struct SlicedCoordinator {
    spec: SchedulerSpec,
    pool: RequestPool,
    ledger: LoadLedger,
    rr: RoundRobin,
    /// Worker-lifecycle ledger (heartbeats, in-flight ownership, progress
    /// cursors). On a fixed fleet every worker stays `Alive` and this is
    /// pure bookkeeping.
    fleet: WorkerLedger,
    dp_cfg: Option<DpBatcherConfig>,
    interval: Option<IntervalController>,
    /// Weighted-fairness opt-in ([`Self::set_tenant_weights`]); `None`
    /// keeps the exact legacy drain path.
    tenant_weights: Option<Vec<f64>>,
    /// Deficit counters (KV token-slots) per tenant, classic DRR: grow by
    /// the weighted quantum each tick, pay per admitted request, reset
    /// when the tenant has no queued work.
    deficits: Vec<f64>,
    tenant_seen: Vec<bool>,
    tick_reqs: Vec<Request>,
    batch_buf: Vec<Batch>,
    assign_buf: Vec<(usize, Batch)>,
    fair_scratch: Vec<Request>,
    defer_buf: Vec<Request>,
    dp_scratch: DpScratch,
}

impl SlicedCoordinator {
    pub fn new(spec: &SchedulerSpec, workers: usize) -> SlicedCoordinator {
        assert!(workers > 0);
        // `Some` exactly for coordinator (DP) batching.
        let dp_cfg = match spec.batching {
            BatchingSpec::Dp { max_batch_size } => Some(DpBatcherConfig {
                slice_len: spec.slice_len,
                max_batch_size,
                pred_corrected: false,
            }),
            BatchingSpec::WorkerFcfs { .. } => None,
        };
        let interval = match spec.interval {
            IntervalSpec::Immediate => None,
            IntervalSpec::Fixed(t) => Some(IntervalController::Fixed(t)),
            IntervalSpec::Adaptive { lambda, gamma } => {
                Some(IntervalController::Adaptive { lambda, gamma })
            }
        };
        SlicedCoordinator {
            spec: spec.clone(),
            pool: RequestPool::new(),
            ledger: LoadLedger::new(workers),
            rr: RoundRobin::new(workers),
            fleet: WorkerLedger::new(workers),
            dp_cfg,
            interval,
            tenant_weights: None,
            deficits: Vec::new(),
            tenant_seen: Vec::new(),
            tick_reqs: Vec::new(),
            batch_buf: Vec::new(),
            assign_buf: Vec::new(),
            fair_scratch: Vec::new(),
            defer_buf: Vec::new(),
            dp_scratch: DpScratch::new(),
        }
    }

    /// Opt in to deficit-weighted per-tenant service (`weights[t]` is
    /// tenant `t`'s share; requests from tenants beyond the vector clamp
    /// to its last entry). Each tick admits requests against a per-tenant
    /// KV-token budget — `FAIR_TOKENS_PER_WORKER × accepting workers`
    /// split by weight, with unspent budget carried as classic
    /// deficit-round-robin credit — and defers the rest to later ticks.
    /// Any tenant with a positive weight accumulates credit every tick it
    /// stays backlogged, so no tenant starves under sustained overload
    /// (`tests/props_slo.rs` hammers this). `None` (the default) restores
    /// the exact legacy drain-everything path, byte for byte.
    pub fn set_tenant_weights(&mut self, weights: Option<Vec<f64>>) {
        if let Some(w) = &weights {
            assert!(
                !w.is_empty() && w.iter().all(|x| x.is_finite() && *x > 0.0),
                "tenant weights must be finite and positive"
            );
            self.deficits = vec![0.0; w.len()];
            self.tenant_seen = vec![false; w.len()];
        } else {
            self.deficits.clear();
            self.tenant_seen.clear();
        }
        self.tenant_weights = weights;
    }

    /// The active weighted-fairness shares, if any.
    pub fn tenant_weights(&self) -> Option<&[f64]> {
        self.tenant_weights.as_deref()
    }

    pub fn spec(&self) -> &SchedulerSpec {
        &self.spec
    }

    /// True when batches are formed centrally (DP) rather than per worker.
    pub fn coordinator_batching(&self) -> bool {
        self.dp_cfg.is_some()
    }

    /// Opt in to predicted early-return correction in the DP batcher (see
    /// [`crate::batcher::dp`]'s module docs): batches whose members carry
    /// `predicted_gen` stamps are costed at their predicted budget instead
    /// of the full slice length. A semantic no-op under prediction-free
    /// policies (unstamped requests fall back to the full budget). The
    /// corrected path runs its own running-max-aware branch-and-bound
    /// (plateau certificates + bulk estimator kernels), so flipping this
    /// on no longer trades away the optimized planner's speed — enable it
    /// whenever requests actually carry predictions, e.g. a coordinator
    /// embedder (real-mode or custom policy) stamping proxy-model
    /// estimates before `admit`. The built-in DES P-SCLS policy pools per
    /// rung and builds its own corrected `DpBatcherConfig` from
    /// `SimConfig::pred_corrected_dp` rather than going through this
    /// coordinator. No effect under worker-locus (FCFS) batching.
    pub fn set_pred_correction(&mut self, on: bool) {
        if let Some(cfg) = self.dp_cfg.as_mut() {
            cfg.pred_corrected = on;
        }
    }

    /// Whether predicted early-return correction is active.
    pub fn pred_correction(&self) -> bool {
        self.dp_cfg.as_ref().map(|c| c.pred_corrected).unwrap_or(false)
    }

    /// Batches the most recent [`Self::schedule_tick`] costed at a
    /// predicted budget strictly below the slice cap (always 0 with the
    /// correction off, and 0 after a tick that drained nothing) —
    /// embedders fold this into `RunMetrics::corrected_batches` after
    /// each tick.
    pub fn corrected_batches_last_tick(&self) -> usize {
        self.dp_scratch.corrected_batches()
    }

    /// True when this policy runs on schedule ticks (PM/AB/LB/SCLS).
    pub fn has_ticks(&self) -> bool {
        self.interval.is_some()
    }

    /// Pre-size the pool for an expected request volume.
    pub fn reserve_pool(&mut self, n: usize) {
        self.pool.reserve(n);
    }

    /// Route one new or rescheduled request: pooled under coordinator
    /// batching (`None`), otherwise round-robined to an **accepting**
    /// worker whose local queue the caller owns (the request is handed
    /// back for delivery). If no worker currently accepts — mid-fault,
    /// before a joiner arrives — the request parks in the pool
    /// (`None` again) and is released by [`Self::take_parked`]. On a
    /// fixed fleet the first round-robin probe always accepts, so the
    /// routing sequence is exactly the pre-elastic one.
    pub fn admit(&mut self, r: Request) -> Option<(usize, Request)> {
        if self.coordinator_batching() {
            self.pool.push(r);
            None
        } else {
            for _ in 0..self.rr.workers() {
                let w = self.rr.next_worker();
                if self.fleet.accepts(w) {
                    return Some((w, r));
                }
            }
            self.pool.push(r);
            None
        }
    }

    pub fn pool_is_empty(&self) -> bool {
        self.pool.is_empty()
    }

    /// Drain requests parked by [`Self::admit`] while no worker accepted
    /// (worker-locus policies re-route them when a joiner arrives). `out`
    /// is cleared first.
    pub fn take_parked(&mut self, out: &mut Vec<Request>) {
        self.pool.fetch_all_into(out);
    }

    /// Run one schedule tick: drain the pool (already incrementally
    /// sorted — only arrivals since the last merge get sorted, the
    /// unchanged prefix is merged), form batches with the DP batcher
    /// (Alg. 1) on the presorted buffer, and assign them to workers
    /// (charging the load ledger). Returns the number of requests drained;
    /// the assignments wait in the buffer handed out by
    /// [`Self::take_assignments`].
    pub fn schedule_tick<E: ServeEstimate + ?Sized>(
        &mut self,
        est: &E,
        mem: &MemoryEstimator,
    ) -> usize {
        // Opt-in hot-path profiling: one thread-local bool load when
        // disabled.
        let _t = crate::telemetry::profile::timer("schedule_tick"); // scls-lint: allow(import-graph): opt-in profiling tap
        self.pool.drain_sorted_into(&mut self.tick_reqs);
        let drained = self.tick_reqs.len();
        if drained == 0 {
            self.assign_buf.clear();
            // An empty tick forms no batches: keep the corrected-batch
            // accessor truthful instead of re-reporting the last one.
            self.dp_scratch.reset_corrected_batches();
            return 0;
        }
        if self.tenant_weights.is_some() {
            self.fair_admission_pass();
            if self.tick_reqs.is_empty() {
                // Every drained request was deferred: nothing to batch
                // this tick, but the deferred work is back in the pool so
                // the caller keeps ticking.
                self.assign_buf.clear();
                self.dp_scratch.reset_corrected_batches();
                return drained;
            }
        }
        let dp_cfg = self
            .dp_cfg
            .as_ref()
            .expect("ticks only exist under coordinator batching");
        dp_batch_sorted_into(
            &mut self.tick_reqs,
            est,
            mem,
            dp_cfg,
            &mut self.dp_scratch,
            &mut self.batch_buf,
        );
        match self.spec.offload {
            OffloadSpec::MaxMin => MaxMinOffloader.offload_into(
                &mut self.batch_buf,
                &mut self.ledger,
                &mut self.assign_buf,
            ),
            OffloadSpec::RoundRobin => {
                self.assign_buf.clear();
                if self.fleet.accepting_count() > 0 {
                    for b in self.batch_buf.drain(..) {
                        // Probe the cycle until an accepting worker turns
                        // up (first probe, on a fixed fleet).
                        let w = loop {
                            let w = self.rr.next_worker();
                            if self.fleet.accepts(w) {
                                break w;
                            }
                        };
                        self.ledger.add(w, b.est_serve_time);
                        self.assign_buf.push((w, b));
                    }
                }
            }
        }
        // Whatever the offloader could not place (no accepting worker)
        // goes back to the pool intact; the next tick — or a joiner —
        // picks it up.
        for b in self.batch_buf.drain(..) {
            for r in b.requests {
                self.pool.push(r);
            }
        }
        drained
    }

    /// Deficit-weighted admission over the drained (input-length-sorted)
    /// request list. A stable filter: kept requests stay in sorted order
    /// (the DP batcher's contract), deferred ones go straight back to the
    /// pool for a later tick. Deficits follow classic DRR — accrue the
    /// weighted quantum, pay `input_len + slice_len` token-slots per
    /// admitted request, reset when the tenant has no queued work (so an
    /// idle tenant cannot bank an unbounded burst).
    fn fair_admission_pass(&mut self) {
        let weights = self
            .tenant_weights
            .as_ref()
            .expect("fairness pass requires weights");
        let total: f64 = weights.iter().sum();
        let quantum = FAIR_TOKENS_PER_WORKER * self.fleet.accepting_count().max(1) as f64;
        for (t, w) in weights.iter().enumerate() {
            self.deficits[t] += quantum * w / total;
        }
        self.tenant_seen.fill(false);
        let slice_len = self.spec.slice_len as f64;
        let mut reqs =
            std::mem::replace(&mut self.tick_reqs, std::mem::take(&mut self.fair_scratch));
        for r in reqs.drain(..) {
            let t = (r.tenant as usize).min(weights.len() - 1);
            self.tenant_seen[t] = true;
            let cost = r.input_len as f64 + slice_len;
            if self.deficits[t] >= cost {
                self.deficits[t] -= cost;
                self.tick_reqs.push(r);
            } else {
                self.defer_buf.push(r);
            }
        }
        self.fair_scratch = reqs;
        for t in 0..self.deficits.len() {
            if !self.tenant_seen[t] {
                self.deficits[t] = 0.0;
            }
        }
        for r in self.defer_buf.drain(..) {
            self.pool.push(r);
        }
    }

    /// Hand out the tick's assignment buffer (drain it, then give it back
    /// via [`Self::recycle_assignments`] so its capacity is reused).
    pub fn take_assignments(&mut self) -> Vec<(usize, Batch)> {
        std::mem::take(&mut self.assign_buf)
    }

    /// Return a drained assignment buffer for reuse.
    pub fn recycle_assignments(&mut self, buf: Vec<(usize, Batch)>) {
        debug_assert!(buf.is_empty(), "recycled buffer must be drained");
        self.assign_buf = buf;
    }

    /// Charge the ledger for a worker-locus (FCFS) batch the caller formed
    /// itself (coordinator batches are charged inside `schedule_tick`).
    pub fn charge(&mut self, worker: usize, est_serve_time: f64) {
        self.ledger.add(worker, est_serve_time);
    }

    /// A worker finished a batch: release its estimated load (§4.5 keeps
    /// estimation error from accumulating in the ledger).
    pub fn batch_done(&mut self, worker: usize, est_serve_time: f64) {
        self.ledger.complete(worker, est_serve_time);
    }

    /// Next schedule interval (Eq. 12 under SCLS; the fixed Γ otherwise).
    /// `None` for tickless (Immediate) policies.
    pub fn next_interval(&self) -> Option<f64> {
        self.interval.as_ref().map(|c| c.next_interval(&self.ledger))
    }

    pub fn ledger(&self) -> &LoadLedger {
        &self.ledger
    }

    // -----------------------------------------------------------------
    // Elastic fleet: lifecycle transitions + heartbeat bookkeeping
    // -----------------------------------------------------------------

    pub fn fleet(&self) -> &WorkerLedger {
        &self.fleet
    }

    /// A cold worker joined: register it with the load ledger (zero load),
    /// the lifecycle ledger, and the round-robin cycle. Returns its fresh
    /// index.
    pub fn worker_join(&mut self, now: f64) -> usize {
        let w = self.ledger.add_worker();
        let fw = self.fleet.add_worker(now);
        debug_assert_eq!(w, fw);
        self.rr.grow(self.fleet.workers());
        w
    }

    /// `worker` starts draining: masked out of offloading, finishes what
    /// it holds.
    pub fn worker_drain(&mut self, worker: usize) {
        self.fleet.set_health(worker, WorkerHealth::Draining);
        self.ledger.set_accepting(worker, false);
    }

    /// `worker` crashed: dead, masked out, its charged load dropped (the
    /// caller reclaims the actual requests and re-admits them), in-flight
    /// ownership forgotten without progress credit.
    pub fn worker_crash(&mut self, worker: usize) {
        self.fleet.set_health(worker, WorkerHealth::Dead);
        self.fleet.clear_in_flight(worker);
        self.ledger.set_accepting(worker, false);
        self.ledger.reset(worker);
    }

    /// A draining worker emptied its queues: it is gone for good.
    pub fn worker_retired(&mut self, worker: usize) {
        self.fleet.set_health(worker, WorkerHealth::Dead);
    }

    pub fn is_draining(&self, worker: usize) -> bool {
        self.fleet.health(worker) == WorkerHealth::Draining
    }

    /// Heartbeat: a batch of `size` requests started serving on `worker`.
    pub fn note_batch_start(&mut self, worker: usize, size: usize, now: f64) {
        self.fleet.batch_started(worker, size, now);
    }

    /// Heartbeat: `worker` reached a slice boundary (its progress cursor
    /// advances, in-flight ownership clears).
    pub fn note_progress(&mut self, worker: usize, now: f64) {
        self.fleet.batch_completed(worker, now);
    }

    /// Reconstruct this coordinator's soft state after a coordinator
    /// crash, from authoritative worker-side reports plus the arrival
    /// log's unassigned requests (`recovered`, drained into the pool).
    ///
    /// What is recovered exactly:
    /// * the load ledger — each worker's `charged_load` (serving + queued
    ///   estimated serve time) equals the pre-crash entry, because the
    ///   ledger charges per assignment and releases per batch completion,
    ///   both replayable from worker state;
    /// * worker health / in-flight ownership / progress cursors — copied
    ///   from the reports ([`WorkerLedger::from_reports`]).
    ///
    /// What is soft-state loss, by design:
    /// * the round-robin cursor restarts at 0 (routing order may differ
    ///   post-crash; the differential property is completion-*set*
    ///   equality, not byte identity);
    /// * deficit counters reset to 0 — at most one tick quantum of
    ///   banked fairness credit per tenant is forfeited.
    pub fn rebuild_after_crash(
        &mut self,
        now: f64,
        reports: &[WorkerReport],
        recovered: &mut Vec<Request>,
    ) {
        let n = reports.len();
        self.ledger = LoadLedger::new(n);
        self.rr = RoundRobin::new(n);
        self.fleet = WorkerLedger::from_reports(now, reports);
        for r in reports {
            if r.health != WorkerHealth::Alive {
                self.ledger.set_accepting(r.worker, false);
            }
            if r.charged_load > 0.0 {
                self.ledger.add(r.worker, r.charged_load);
            }
        }
        for d in self.deficits.iter_mut() {
            *d = 0.0;
        }
        for r in recovered.drain(..) {
            self.pool.push(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::presets::{EngineKind, EnginePreset};
    use crate::sim::driver::fitted_estimator;

    fn requests(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request::new(i as u64, 0.1 * i as f64, 16 + 8 * (i as u32 % 9), 200))
            .collect()
    }

    #[test]
    fn scls_tick_forms_and_assigns_batches() {
        let preset = EnginePreset::paper(EngineKind::Ds);
        let spec = SchedulerSpec::scls(&preset, 128);
        let mut c = SlicedCoordinator::new(&spec, 4);
        assert!(c.coordinator_batching() && c.has_ticks());
        for r in requests(24) {
            assert!(c.admit(r).is_none(), "SCLS pools everything");
        }
        let est = fitted_estimator(&preset, 7);
        let mem = preset.memory_estimator();
        let drained = c.schedule_tick(&est, &mem);
        assert_eq!(drained, 24);
        let mut a = c.take_assignments();
        let total: usize = a.iter().map(|(_, b)| b.size()).sum();
        assert_eq!(total, 24, "no request lost in batching/offload");
        assert!(a.iter().all(|&(w, _)| w < 4));
        // Ledger was charged for every assignment.
        assert!((0..4).map(|w| c.ledger().load(w)).sum::<f64>() > 0.0);
        a.clear();
        c.recycle_assignments(a);
        // Adaptive interval floors at gamma while any worker is idle-ish.
        let t = c.next_interval().unwrap();
        assert!(t >= preset.gamma * 0.5);
    }

    #[test]
    fn pred_correction_toggles_only_under_dp_batching() {
        let preset = EnginePreset::paper(EngineKind::Ds);
        let mut c = SlicedCoordinator::new(&SchedulerSpec::scls(&preset, 128), 2);
        assert!(!c.pred_correction());
        c.set_pred_correction(true);
        assert!(c.pred_correction());
        c.set_pred_correction(false);
        assert!(!c.pred_correction());
        // Worker-locus batching has no DP config to flag.
        let mut f = SlicedCoordinator::new(&SchedulerSpec::sls(&preset, 1024), 2);
        f.set_pred_correction(true);
        assert!(!f.pred_correction());
    }

    #[test]
    fn weighted_fairness_admits_tenants_by_share_without_starvation() {
        let preset = EnginePreset::paper(EngineKind::Ds);
        let spec = SchedulerSpec::scls(&preset, 128);
        let mut c = SlicedCoordinator::new(&spec, 2);
        c.set_tenant_weights(Some(vec![1.0, 1.0]));
        assert_eq!(c.tenant_weights(), Some(&[1.0, 1.0][..]));
        for i in 0..200u64 {
            let mut r = Request::new(i, 0.0, 1024, 200);
            r.tenant = (i % 2) as u32;
            assert!(c.admit(r).is_none());
        }
        let est = fitted_estimator(&preset, 7);
        let mem = preset.memory_estimator();
        let drained = c.schedule_tick(&est, &mem);
        assert_eq!(drained, 200, "deferred requests still count as drained");
        let mut a = c.take_assignments();
        let by_tenant = |t: u32, a: &[(usize, Batch)]| -> usize {
            a.iter()
                .flat_map(|(_, b)| b.requests.iter())
                .filter(|r| r.tenant == t)
                .count()
        };
        let (t0, t1) = (by_tenant(0, &a), by_tenant(1, &a));
        assert!(t0 > 0 && t1 > 0, "both tenants served in the first tick");
        assert_eq!(t0, t1, "equal weights admit equal counts");
        assert!(t0 + t1 < 200, "the per-tick budget defers the overflow");
        assert!(!c.pool_is_empty());
        for (w, b) in a.drain(..) {
            c.batch_done(w, b.est_serve_time);
        }
        c.recycle_assignments(a);
        // Deficit carryover drains the whole backlog in bounded ticks
        // even under a lopsided 8:1 share — the light tenant never
        // starves.
        c.set_tenant_weights(Some(vec![8.0, 1.0]));
        let mut served = t0 + t1;
        for _ in 0..200 {
            if c.pool_is_empty() {
                break;
            }
            c.schedule_tick(&est, &mem);
            let mut a = c.take_assignments();
            served += a.iter().map(|(_, b)| b.size()).sum::<usize>();
            for (w, b) in a.drain(..) {
                c.batch_done(w, b.est_serve_time);
            }
            c.recycle_assignments(a);
        }
        assert!(c.pool_is_empty(), "backlog fully drained under 8:1 weights");
        assert_eq!(served, 200, "every request was eventually admitted");
    }

    #[test]
    fn sls_routes_round_robin_without_ticks() {
        let preset = EnginePreset::paper(EngineKind::Ds);
        let spec = SchedulerSpec::sls(&preset, 1024);
        let mut c = SlicedCoordinator::new(&spec, 3);
        assert!(!c.coordinator_batching() && !c.has_ticks());
        let ws: Vec<usize> = requests(5)
            .into_iter()
            .map(|r| c.admit(r).unwrap().0)
            .collect();
        assert_eq!(ws, vec![0, 1, 2, 0, 1]);
        assert_eq!(c.next_interval(), None);
    }

    #[test]
    fn worker_locus_admit_skips_lost_workers_and_parks_when_fleet_empty() {
        let preset = EnginePreset::paper(EngineKind::Ds);
        let mut c = SlicedCoordinator::new(&SchedulerSpec::sls(&preset, 1024), 3);
        c.worker_drain(1);
        c.worker_crash(2);
        // Only worker 0 accepts: every admit lands there.
        let ws: Vec<usize> = requests(3)
            .into_iter()
            .map(|r| c.admit(r).unwrap().0)
            .collect();
        assert_eq!(ws, vec![0, 0, 0]);
        // Kill the last one: admits park instead of routing.
        c.worker_crash(0);
        assert!(c.admit(Request::new(99, 0.0, 16, 8)).is_none());
        let mut parked = Vec::new();
        c.take_parked(&mut parked);
        assert_eq!(parked.len(), 1);
        assert_eq!(parked[0].id, 99);
        // A joiner restores routing under its fresh index.
        let w = c.worker_join(1.0);
        assert_eq!(w, 3);
        assert_eq!(c.admit(parked.pop().unwrap()).unwrap().0, 3);
    }

    #[test]
    fn rebuild_after_crash_restores_ledger_and_pool() {
        let preset = EnginePreset::paper(EngineKind::Ds);
        let mut c = SlicedCoordinator::new(&SchedulerSpec::scls(&preset, 128), 3);
        c.charge(0, 2.0);
        c.charge(1, 0.5);
        c.worker_drain(2);
        // Successor state: pretend the coordinator just restarted and the
        // workers reported the truth it had been mirroring.
        let reports = [
            WorkerReport {
                worker: 0,
                health: WorkerHealth::Alive,
                in_flight: 4,
                progress: 2,
                charged_load: 2.0,
            },
            WorkerReport {
                worker: 1,
                health: WorkerHealth::Alive,
                in_flight: 0,
                progress: 1,
                charged_load: 0.5,
            },
            WorkerReport {
                worker: 2,
                health: WorkerHealth::Draining,
                in_flight: 0,
                progress: 0,
                charged_load: 0.0,
            },
        ];
        let mut recovered = requests(5);
        c.rebuild_after_crash(3.0, &reports, &mut recovered);
        assert!(recovered.is_empty(), "recovered requests drained to pool");
        assert!(!c.pool_is_empty());
        assert_eq!(c.ledger().load(0), 2.0);
        assert_eq!(c.ledger().load(1), 0.5);
        assert!(!c.ledger().is_accepting(2), "drain status survives");
        assert_eq!(c.fleet().health(2), WorkerHealth::Draining);
        assert_eq!(c.fleet().in_flight(0), 4);
        assert_eq!(c.fleet().last_progress(0), 2);
        assert_eq!(c.fleet().last_heartbeat(1), 3.0);
        // The rebuilt coordinator keeps scheduling: the recovered pool
        // drains through a normal tick onto the accepting workers.
        let est = fitted_estimator(&preset, 7);
        let mem = preset.memory_estimator();
        let drained = c.schedule_tick(&est, &mem);
        assert_eq!(drained, 5);
        let a = c.take_assignments();
        let total: usize = a.iter().map(|(_, b)| b.size()).sum();
        assert_eq!(total, 5);
        assert!(a.iter().all(|(w, _)| *w < 2), "nothing lands on the drainer");
    }

    #[test]
    fn unplaceable_tick_batches_return_to_pool() {
        let preset = EnginePreset::paper(EngineKind::Ds);
        let mut c = SlicedCoordinator::new(&SchedulerSpec::scls(&preset, 128), 2);
        for r in requests(8) {
            c.admit(r);
        }
        c.worker_crash(0);
        c.worker_drain(1);
        let est = fitted_estimator(&preset, 7);
        let mem = preset.memory_estimator();
        let drained = c.schedule_tick(&est, &mem);
        assert_eq!(drained, 8);
        assert!(c.take_assignments().is_empty(), "nothing placeable");
        assert!(!c.pool_is_empty(), "requests must survive in the pool");
        // A joiner makes the next tick place everything on it.
        let w = c.worker_join(2.0);
        let drained = c.schedule_tick(&est, &mem);
        assert_eq!(drained, 8);
        let a = c.take_assignments();
        let total: usize = a.iter().map(|(_, b)| b.size()).sum();
        assert_eq!(total, 8);
        assert!(a.iter().all(|(aw, _)| *aw == w));
    }
}
