//! Deterministic time-ordered event queue for the DES.
//!
//! Ties at equal timestamps break by insertion order (monotone sequence
//! number), so simulations are exactly reproducible. This FIFO tie-break
//! is load-bearing across event *kinds*, not just within one: the driver
//! pushes all arrivals first and all fleet (join/drain/crash) events
//! second, so at an equal timestamp an arrival is always delivered before
//! the fault that would have re-routed it, and a `FaultPlan`'s
//! `delivery_order()` (stable sort by time) matches heap order exactly.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<T> {
    t: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest-first.
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap of timestamped events.
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Pre-size the heap (drivers know the trace length up front, so the
    /// heap never reallocates mid-simulation).
    pub fn with_capacity(n: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(n),
            seq: 0,
        }
    }

    pub fn push(&mut self, t: f64, payload: T) {
        debug_assert!(t.is_finite(), "event time must be finite");
        self.heap.push(Entry {
            t,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.t, e.payload))
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.t)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn equal_timestamp_ties_break_fifo_across_event_kinds() {
        // The driver relies on push order to sequence different event
        // kinds at the same instant: arrivals (pushed first) beat fleet
        // events (pushed second) beat runtime completions (pushed last).
        #[derive(Debug, PartialEq)]
        enum Kind {
            Arrival,
            Fleet,
            Done,
        }
        let mut q = EventQueue::new();
        q.push(10.0, Kind::Arrival);
        q.push(10.0, Kind::Fleet);
        q.push(10.0, Kind::Done);
        assert_eq!(q.pop().unwrap().1, Kind::Arrival);
        assert_eq!(q.pop().unwrap().1, Kind::Fleet);
        assert_eq!(q.pop().unwrap().1, Kind::Done);
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(5.0, "late");
        q.push(1.0, "early");
        assert_eq!(q.pop().unwrap().1, "early");
        q.push(2.0, "mid");
        assert_eq!(q.pop().unwrap().1, "mid");
        assert_eq!(q.pop().unwrap().1, "late");
    }
}
