//! The request pool (paper Fig. 7): newly arrived requests and uncompleted
//! rescheduled requests wait here between schedule ticks.

use crate::core::Request;

#[derive(Debug, Default)]
pub struct RequestPool {
    requests: Vec<Request>,
}

impl RequestPool {
    pub fn new() -> RequestPool {
        RequestPool {
            requests: Vec::new(),
        }
    }

    /// Pre-size the pool for a known workload (per-tick drains then never
    /// reallocate in steady state).
    pub fn with_capacity(n: usize) -> RequestPool {
        RequestPool {
            requests: Vec::with_capacity(n),
        }
    }

    /// Grow the backing store for an expected workload (same steady-state
    /// no-realloc property as [`RequestPool::with_capacity`]).
    pub fn reserve(&mut self, n: usize) {
        self.requests.reserve(n);
    }

    pub fn push(&mut self, r: Request) {
        self.requests.push(r);
    }

    /// Drain everything (SCLS "periodically fetches all requests", §4.1).
    pub fn fetch_all(&mut self) -> Vec<Request> {
        std::mem::take(&mut self.requests)
    }

    /// Buffer-swap drain: `out` is cleared and swapped with the pool's
    /// backing store, so a tick-loop caller cycles two buffers and the
    /// drain allocates nothing in steady state.
    pub fn fetch_all_into(&mut self, out: &mut Vec<Request>) {
        out.clear();
        std::mem::swap(&mut self.requests, out);
    }

    /// Drain at most `n`, in arrival order of insertion (FCFS baselines).
    pub fn fetch_up_to(&mut self, n: usize) -> Vec<Request> {
        if n >= self.requests.len() {
            return self.fetch_all();
        }
        let rest = self.requests.split_off(n);
        std::mem::replace(&mut self.requests, rest)
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, 0.0, 10, 10)
    }

    #[test]
    fn fetch_all_drains() {
        let mut p = RequestPool::new();
        p.push(req(1));
        p.push(req(2));
        let all = p.fetch_all();
        assert_eq!(all.len(), 2);
        assert!(p.is_empty());
    }

    #[test]
    fn fetch_all_into_swaps_buffers() {
        let mut p = RequestPool::with_capacity(8);
        p.push(req(1));
        p.push(req(2));
        let mut buf = Vec::with_capacity(16);
        p.fetch_all_into(&mut buf);
        assert_eq!(buf.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert!(p.is_empty());
        // The pool inherited the (cleared) caller buffer's capacity.
        p.push(req(3));
        p.fetch_all_into(&mut buf);
        assert_eq!(buf.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3]);
    }

    #[test]
    fn fetch_up_to_preserves_order() {
        let mut p = RequestPool::new();
        for i in 0..5 {
            p.push(req(i));
        }
        let first = p.fetch_up_to(2);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(p.len(), 3);
        let rest = p.fetch_up_to(10);
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3, 4]);
    }
}
