//! Implement your own scheduler against the `SchedulingPolicy` trait.
//!
//! The policy below — greedy least-loaded single-request dispatch, no
//! batching, no slicing — takes ~20 lines of actual scheduling logic: pick
//! a worker on arrival, serve, record, refill on completion. The same
//! generic DES loop that runs the paper's eight policies runs this one,
//! so it gets the virtual clock, metrics, and streaming sinks for free.
//!
//! Run: `cargo run --release --example custom_policy`

use std::collections::VecDeque;

use scls::core::{Batch, Request};
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::engine::sim::SimEngine;
use scls::metrics::{BatchRecord, RunMetrics};
use scls::scheduler::{SchedulingPolicy, SimCtx};
use scls::sim::Simulation;
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};

/// Greedy baseline: each request is served alone (batch of 1, no slice
/// cap) on the worker with the shortest queue.
struct GreedyPolicy {
    engines: Vec<SimEngine>,
    queues: Vec<VecDeque<Request>>,
    serving: Vec<Option<Batch>>,
    last_done: Vec<f64>,
}

impl GreedyPolicy {
    fn new(preset: &EnginePreset, workers: usize, max_gen_len: u32, seed: u64) -> GreedyPolicy {
        GreedyPolicy {
            engines: (0..workers)
                .map(|w| SimEngine::new(preset.latency(seed ^ w as u64), max_gen_len))
                .collect(),
            queues: vec![VecDeque::new(); workers],
            serving: (0..workers).map(|_| None).collect(),
            last_done: vec![0.0; workers],
        }
    }

    fn try_serve(&mut self, w: usize, ctx: &mut SimCtx) {
        if self.serving[w].is_some() {
            return;
        }
        let Some(r) = self.queues[w].pop_front() else {
            return;
        };
        let mut batch = Batch::new(vec![r]);
        batch.requests[0].slices += 1;
        // No iteration cap: the request runs to EOS in one schedule.
        let out = self.engines[w].serve_slice(&batch, 1 << 20);
        let done_at = ctx.now + out.duration;
        let o = &out.per_request[0];
        batch.requests[0].generated += o.new_tokens;
        batch.requests[0].finished_at = Some(done_at);
        ctx.record_batch(BatchRecord {
            start: ctx.now,
            worker: w,
            size: 1,
            input_len: batch.input_len(),
            pad_tokens: 0,
            est_serve_time: out.duration,
            actual_serve_time: out.duration,
            early_return: out.early_return,
        });
        self.serving[w] = Some(batch);
        ctx.complete_at(done_at, w);
    }
}

impl SchedulingPolicy for GreedyPolicy {
    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx) {
        let w = (0..self.queues.len())
            .min_by_key(|&w| self.queues[w].len() + self.serving[w].is_some() as usize)
            .unwrap();
        self.queues[w].push_back(req);
        self.try_serve(w, ctx);
    }

    fn on_worker_done(&mut self, w: usize, ctx: &mut SimCtx) {
        let batch = self.serving[w].take().expect("done without serving");
        self.last_done[w] = ctx.now;
        for r in batch.requests {
            ctx.record_completion(&r);
        }
        self.try_serve(w, ctx);
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.last_done.clone();
    }
}

fn main() {
    let preset = EnginePreset::paper(EngineKind::Ds);
    let trace = Trace::generate(&TraceConfig {
        kind: WorkloadKind::CodeFuse,
        rate: 8.0,
        duration: 60.0,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed: 42,
    });
    let sim = Simulation::builder()
        .workers(4)
        .engine(preset.clone())
        .seed(42)
        .build();

    let mut greedy = GreedyPolicy::new(&preset, 4, 1024, 42);
    let g = sim.run(&trace, &mut greedy).summarize();
    let scls = sim.run_named(&trace, "SCLS", 128).unwrap().summarize();

    println!("policy   throughput  avg RT   p95 RT   CT std");
    println!(
        "greedy   {:>8.2}    {:>6.2}   {:>6.2}   {:>6.2}",
        g.throughput, g.avg_response_time, g.p95_response_time, g.ct_std
    );
    println!(
        "SCLS     {:>8.2}    {:>6.2}   {:>6.2}   {:>6.2}",
        scls.throughput, scls.avg_response_time, scls.p95_response_time, scls.ct_std
    );
    println!(
        "\nSCLS should win on throughput: batching amortizes the per-iteration\n\
         cost the greedy policy pays per request."
    );
}
