//! Small dense linear algebra: least-squares fitting via normal equations.
//!
//! The serving-time estimator (paper §4.2) fits 4-parameter linear models
//! (Eq. 3 and Eq. 4) to profiled latency data. `scipy.curve_fit` in the
//! paper; here a Gaussian-elimination solve of `(XᵀX) β = Xᵀy` with partial
//! pivoting and Tikhonov fallback for rank-deficient designs.

/// Solve `A x = b` in place (n×n, row-major) with partial pivoting.
/// Returns None if A is (numerically) singular.
pub fn solve(a: &mut [f64], b: &mut [f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = a[col * n + col].abs();
        for row in (col + 1)..n {
            let v = a[row * n + col].abs();
            if v > best {
                best = v;
                piv = row;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if piv != col {
            for k in 0..n {
                a.swap(col * n + k, piv * n + k);
            }
            b.swap(col, piv);
        }
        // eliminate
        for row in (col + 1)..n {
            let f = a[row * n + col] / a[col * n + col];
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                a[row * n + k] -= f * a[col * n + k];
            }
            b[row] -= f * b[col];
        }
    }
    // back substitution
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in (row + 1)..n {
            acc -= a[row * n + k] * x[k];
        }
        x[row] = acc / a[row * n + row];
    }
    Some(x)
}

/// Ordinary least squares: find β minimizing ‖Xβ − y‖².
/// `rows` are the design-matrix rows (each of length `p`).
/// Falls back to ridge (λ = 1e-9·tr) if the normal matrix is singular.
pub fn least_squares(rows: &[Vec<f64>], y: &[f64]) -> Option<Vec<f64>> {
    let m = rows.len();
    if m == 0 {
        return None;
    }
    let p = rows[0].len();
    assert_eq!(y.len(), m);
    // Normal equations: XtX (p×p), Xty (p)
    let mut xtx = vec![0.0; p * p];
    let mut xty = vec![0.0; p];
    for (row, &yi) in rows.iter().zip(y) {
        assert_eq!(row.len(), p);
        for i in 0..p {
            xty[i] += row[i] * yi;
            for j in 0..p {
                xtx[i * p + j] += row[i] * row[j];
            }
        }
    }
    let mut a = xtx.clone();
    let mut b = xty.clone();
    if let Some(x) = solve(&mut a, &mut b, p) {
        return Some(x);
    }
    // ridge fallback
    let tr: f64 = (0..p).map(|i| xtx[i * p + i]).sum();
    let lam = 1e-9 * tr.max(1.0);
    for i in 0..p {
        xtx[i * p + i] += lam;
    }
    solve(&mut xtx, &mut xty, p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_identity() {
        let mut a = vec![1.0, 0.0, 0.0, 1.0];
        let mut b = vec![3.0, 4.0];
        assert_eq!(solve(&mut a, &mut b, 2).unwrap(), vec![3.0, 4.0]);
    }

    #[test]
    fn solve_2x2() {
        // x + y = 3; 2x - y = 0 -> x = 1, y = 2
        let mut a = vec![1.0, 1.0, 2.0, -1.0];
        let mut b = vec![3.0, 0.0];
        let x = solve(&mut a, &mut b, 2).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn solve_needs_pivot() {
        // zero on the diagonal forces a row swap
        let mut a = vec![0.0, 1.0, 1.0, 0.0];
        let mut b = vec![2.0, 5.0];
        let x = solve(&mut a, &mut b, 2).unwrap();
        assert!((x[0] - 5.0).abs() < 1e-12 && (x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn solve_singular_none() {
        let mut a = vec![1.0, 2.0, 2.0, 4.0];
        let mut b = vec![1.0, 2.0];
        assert!(solve(&mut a, &mut b, 2).is_none());
    }

    #[test]
    fn least_squares_recovers_exact_linear() {
        // y = 2*a + 3*b - 1 over a grid
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for a in 0..5 {
            for b in 0..5 {
                rows.push(vec![a as f64, b as f64, 1.0]);
                y.push(2.0 * a as f64 + 3.0 * b as f64 - 1.0);
            }
        }
        let beta = least_squares(&rows, &y).unwrap();
        assert!((beta[0] - 2.0).abs() < 1e-9);
        assert!((beta[1] - 3.0).abs() < 1e-9);
        assert!((beta[2] + 1.0).abs() < 1e-9);
    }

    #[test]
    fn least_squares_overdetermined_noise() {
        // noisy y = 5x + 10; enough points -> close fit
        let rows: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64, 1.0]).collect();
        let y: Vec<f64> = (0..100)
            .map(|i| 5.0 * i as f64 + 10.0 + if i % 2 == 0 { 0.5 } else { -0.5 })
            .collect();
        let beta = least_squares(&rows, &y).unwrap();
        assert!((beta[0] - 5.0).abs() < 0.01);
        assert!((beta[1] - 10.0).abs() < 0.6);
    }
}
