//! Integration tests: full DES experiments exercising the pool → batcher →
//! offloader → worker pipeline across schedulers, engines, workloads and
//! rates, checking the cross-module invariants the paper's design relies
//! on (request conservation, token accounting, scheduling semantics, and
//! the headline performance orderings).

use scls::engine::presets::{EngineKind, EnginePreset};
use scls::metrics::RunMetrics;
use scls::scheduler::spec::SchedulerSpec;
use scls::sim::driver::{run_ils, run_sliced, SimConfig};
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};

fn trace(kind: WorkloadKind, rate: f64, duration: f64, seed: u64) -> Trace {
    Trace::generate(&TraceConfig {
        kind,
        rate,
        duration,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed,
    })
}

fn sim(workers: usize, kind: EngineKind, seed: u64) -> SimConfig {
    SimConfig::new(workers, EnginePreset::paper(kind), 1024, seed)
}

/// Every request injected must complete exactly once, with plausible
/// token counts and non-negative response times.
fn assert_conservation(trace: &Trace, m: &RunMetrics) {
    assert_eq!(m.completed.len(), trace.len(), "requests lost or duplicated");
    let mut seen = vec![false; trace.len()];
    for c in &m.completed {
        assert!(!seen[c.id as usize], "request {} completed twice", c.id);
        seen[c.id as usize] = true;
        assert!(c.finished >= c.arrival, "finished before arrival");
        assert!(c.generated >= 1 && c.generated <= 1024);
    }
    // Generated tokens are capped by the request's own oracle + limit.
    for c in &m.completed {
        let want = trace.requests[c.id as usize].target_gen_len.min(1024);
        assert_eq!(c.generated, want, "request {} token count", c.id);
    }
}

#[test]
fn all_schedulers_conserve_requests_on_both_engines() {
    for kind in [EngineKind::Hf, EngineKind::Ds] {
        let preset = EnginePreset::paper(kind);
        let t = trace(WorkloadKind::CodeFuse, 6.0, 40.0, 101);
        for spec in SchedulerSpec::ablation_ladder(&preset, 128, 1024) {
            let m = run_sliced(&t, &spec, &sim(4, kind, 101));
            assert_conservation(&t, &m);
        }
    }
}

#[test]
fn ils_conserves_requests() {
    let t = trace(WorkloadKind::CodeFuse, 6.0, 40.0, 102);
    let m = run_ils(&t, &sim(4, EngineKind::Ds, 102));
    assert_conservation(&t, &m);
    // Continuous batching never pads and never generates invalid tokens.
    assert!(m.completed.iter().all(|c| c.pad_tokens == 0));
    assert!(m.completed.iter().all(|c| c.invalid_tokens == 0));
    // Exactly one schedule per request.
    assert!(m.completed.iter().all(|c| c.slices == 1));
}

#[test]
fn sharegpt_workload_also_served() {
    let preset = EnginePreset::paper(EngineKind::Ds);
    let t = trace(WorkloadKind::ShareGpt, 6.0, 40.0, 103);
    let m = run_sliced(&t, &SchedulerSpec::scls(&preset, 128), &sim(4, EngineKind::Ds, 103));
    assert_conservation(&t, &m);
}

#[test]
fn sls_serves_each_request_exactly_once() {
    // SLS's iteration limit equals the max generation length, so no
    // request is ever rescheduled (paper Fig. 1a).
    let preset = EnginePreset::paper(EngineKind::Ds);
    let t = trace(WorkloadKind::CodeFuse, 4.0, 30.0, 104);
    let m = run_sliced(&t, &SchedulerSpec::sls(&preset, 1024), &sim(4, EngineKind::Ds, 104));
    assert!(m.completed.iter().all(|c| c.slices == 1));
    // ... and therefore batches never exceed the fixed batch size.
    assert!(m.batches.iter().all(|b| b.size <= preset.sls_batch_size));
}

#[test]
fn scls_slice_counts_cover_generation() {
    // ceil(generated / S) ≤ slices (a request may also ride along in
    // batches whose other members cut the slice short — early returns —
    // so equality need not hold, but coverage must).
    let preset = EnginePreset::paper(EngineKind::Ds);
    let t = trace(WorkloadKind::CodeFuse, 4.0, 30.0, 105);
    for s_len in [64u32, 128, 256] {
        let m = run_sliced(&t, &SchedulerSpec::scls(&preset, s_len), &sim(4, EngineKind::Ds, 105));
        for c in &m.completed {
            let min_slices = (c.generated as f64 / s_len as f64).ceil() as u32;
            assert!(
                c.slices >= min_slices,
                "S={s_len} req {}: {} slices for {} tokens",
                c.id,
                c.slices,
                c.generated
            );
        }
    }
}

#[test]
fn scls_batches_respect_memory_rules() {
    // Every batch the DP forms must be feasible under Algorithm 2 (DS).
    let preset = EnginePreset::paper(EngineKind::Ds);
    let mem = preset.memory_estimator();
    let t = trace(WorkloadKind::CodeFuse, 10.0, 60.0, 106);
    let m = run_sliced(&t, &SchedulerSpec::scls(&preset, 128), &sim(4, EngineKind::Ds, 106));
    for b in &m.batches {
        assert!(
            !mem.would_oom(b.size, b.input_len, 128),
            "batch (N={}, L={}) violates Algorithm 2",
            b.size,
            b.input_len
        );
    }
}

#[test]
fn batch_input_len_is_max_member_and_pads_consistent() {
    let preset = EnginePreset::paper(EngineKind::Hf);
    let t = trace(WorkloadKind::CodeFuse, 6.0, 40.0, 107);
    let m = run_sliced(&t, &SchedulerSpec::scls(&preset, 128), &sim(4, EngineKind::Hf, 107));
    // Pad accounting: per-batch pad counter equals Σ (L_batch − L_req).
    // We can't see members here, but the total per-request pad sum across
    // completions must equal the per-batch records' total.
    let batch_pads: u64 = m.batches.iter().map(|b| b.pad_tokens).sum();
    let req_pads: u64 = m.completed.iter().map(|c| c.pad_tokens).sum();
    assert_eq!(batch_pads, req_pads, "pad token books disagree");
}

#[test]
fn headline_orderings_hold_at_saturation() {
    // Fig. 5 / Fig. 12 shapes at modest scale: SCLS beats SLS and ILS on
    // throughput; ILS beats SLS (continuous batching helps); SCLS has the
    // lowest completion-time spread.
    let t = trace(WorkloadKind::CodeFuse, 16.0, 90.0, 108);
    let preset = EnginePreset::paper(EngineKind::Ds);
    let cfg = sim(8, EngineKind::Ds, 108);
    let scls = run_sliced(&t, &SchedulerSpec::scls(&preset, 128), &cfg).summarize();
    let sls = run_sliced(&t, &SchedulerSpec::sls(&preset, 1024), &cfg).summarize();
    let ils = run_ils(&t, &cfg).summarize();
    assert!(scls.throughput > sls.throughput);
    assert!(scls.throughput > ils.throughput);
    assert!(ils.throughput > sls.throughput);
    assert!(scls.avg_response_time < sls.avg_response_time);
    assert!(scls.ct_std <= sls.ct_std, "{} > {}", scls.ct_std, sls.ct_std);
}

#[test]
fn ablation_ladder_improves_monotonically_ish() {
    // Each added feature should not collapse throughput; the full ladder
    // end-to-end must strictly improve on its start (Fig. 15).
    let t = trace(WorkloadKind::CodeFuse, 16.0, 90.0, 109);
    let preset = EnginePreset::paper(EngineKind::Ds);
    let cfg = sim(8, EngineKind::Ds, 109);
    let ladder = SchedulerSpec::ablation_ladder(&preset, 128, 1024);
    let thpt: Vec<f64> = ladder
        .iter()
        .map(|spec| run_sliced(&t, spec, &cfg).summarize().throughput)
        .collect();
    let names: Vec<&str> = ladder.iter().map(|s| s.name.as_str()).collect();
    // SLS -> SCLS strictly better.
    assert!(
        thpt[5] > 1.5 * thpt[0],
        "ladder {names:?} throughput {thpt:?}"
    );
    // AB (uncapped DP) ≥ PM (capped): larger batches can only help here.
    assert!(thpt[3] > 0.9 * thpt[2], "AB vs PM: {thpt:?}");
    // LB (max-min) must not hurt throughput relative to AB.
    assert!(thpt[4] > 0.9 * thpt[3], "LB vs AB: {thpt:?}");
}

#[test]
fn throughput_scales_with_workers() {
    // Fig. 22: linear-ish scaling while saturated.
    let t = trace(WorkloadKind::CodeFuse, 24.0, 60.0, 110);
    let preset = EnginePreset::paper(EngineKind::Ds);
    let t1 = run_sliced(&t, &SchedulerSpec::scls(&preset, 128), &sim(1, EngineKind::Ds, 110))
        .summarize()
        .throughput;
    let t4 = run_sliced(&t, &SchedulerSpec::scls(&preset, 128), &sim(4, EngineKind::Ds, 110))
        .summarize()
        .throughput;
    assert!(t4 > 2.5 * t1, "4 workers {t4} vs 1 worker {t1}");
}

#[test]
fn empty_trace_is_a_noop() {
    let t = Trace {
        requests: vec![],
        config_rate: 0.0,
        duration: 0.0,
    };
    let preset = EnginePreset::paper(EngineKind::Ds);
    let m = run_sliced(&t, &SchedulerSpec::scls(&preset, 128), &sim(2, EngineKind::Ds, 1));
    assert_eq!(m.completed.len(), 0);
    assert!(m.batches.is_empty());
    let m = run_ils(&t, &sim(2, EngineKind::Ds, 1));
    assert_eq!(m.completed.len(), 0);
}

#[test]
fn single_request_burst_and_tail_arrival() {
    // A burst of identical arrivals at t=0 plus one straggler arriving
    // long after the burst drains.
    let mut requests: Vec<scls::core::Request> = (0..20)
        .map(|i| scls::core::Request::new(i, 0.0, 100, 50))
        .collect();
    requests.push(scls::core::Request::new(20, 500.0, 100, 50));
    let t = Trace {
        requests,
        config_rate: 0.0,
        duration: 501.0,
    };
    let preset = EnginePreset::paper(EngineKind::Ds);
    let m = run_sliced(&t, &SchedulerSpec::scls(&preset, 128), &sim(2, EngineKind::Ds, 7));
    assert_eq!(m.completed.len(), 21);
    let straggler = m.completed.iter().find(|c| c.id == 20).unwrap();
    assert!(straggler.finished > 500.0);
    // The straggler should not have waited for the burst (system idle).
    assert!(straggler.finished - straggler.arrival < 60.0);
}

#[test]
fn deterministic_across_runs_all_schedulers() {
    let t = trace(WorkloadKind::CodeFuse, 6.0, 30.0, 112);
    for kind in [EngineKind::Hf, EngineKind::Ds] {
        let preset = EnginePreset::paper(kind);
        for spec in SchedulerSpec::ablation_ladder(&preset, 128, 1024) {
            let a = run_sliced(&t, &spec, &sim(3, kind, 112));
            let b = run_sliced(&t, &spec, &sim(3, kind, 112));
            assert_eq!(a.batches.len(), b.batches.len(), "{}", spec.name);
            assert_eq!(
                a.summarize().avg_response_time,
                b.summarize().avg_response_time,
                "{}",
                spec.name
            );
        }
    }
}

#[test]
fn adaptive_interval_outperforms_na_fixed_interval_on_response_time() {
    // Eq. (12)'s purpose: when load is light, shrink T so requests don't
    // sit in the pool. Compare SCLS (adaptive) against LB with a very long
    // fixed interval at a light rate.
    use scls::scheduler::spec::IntervalSpec;
    let t = trace(WorkloadKind::CodeFuse, 2.0, 60.0, 113);
    let preset = EnginePreset::paper(EngineKind::Ds);
    let cfg = sim(4, EngineKind::Ds, 113);
    let scls = run_sliced(&t, &SchedulerSpec::scls(&preset, 128), &cfg).summarize();
    let mut slow = SchedulerSpec::load_balancing(&preset, 128);
    slow.interval = IntervalSpec::Fixed(12.0);
    let fixed = run_sliced(&t, &slow, &cfg).summarize();
    assert!(
        scls.avg_response_time < fixed.avg_response_time,
        "adaptive {} !< fixed-12s {}",
        scls.avg_response_time,
        fixed.avg_response_time
    );
}

#[test]
fn early_returns_are_rare_at_paper_settings() {
    // Fig. 14b: < 1% of batch servings early-return at S=128.
    let t = trace(WorkloadKind::CodeFuse, 16.0, 90.0, 114);
    let preset = EnginePreset::paper(EngineKind::Ds);
    let m = run_sliced(&t, &SchedulerSpec::scls(&preset, 128), &sim(8, EngineKind::Ds, 114));
    let s = m.summarize();
    assert!(
        s.early_return_ratio < 0.05,
        "early-return ratio {}",
        s.early_return_ratio
    );
}
