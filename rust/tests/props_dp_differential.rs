//! Differential property tests: the optimized DP batcher must be
//! bit-exact against the retained naive quadratic reference — identical
//! batch cuts (membership and order) and bit-identical `est_serve_time`
//! on every batch — across random pools, random estimator surfaces,
//! `max_batch_size` caps, tight-memory configurations, and the
//! `serve_affine == None` fallback path.

use scls::batcher::{dp_batch, dp_batch_reference, DpBatcherConfig};
use scls::core::{Batch, Request};
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::estimator::serving_time::{LinearLatency, ServeEstimate, ServingTimeEstimator};
use scls::estimator::{MemoryEstimator, MemoryRule};
use scls::prop_assert;
use scls::sim::driver::fitted_estimator;
use scls::testprop::{check, Gen};

/// Wrap an estimator so `serve_affine` always reports `None`, forcing the
/// opaque fallback path through both implementations.
struct Opaque(ServingTimeEstimator);

impl ServeEstimate for Opaque {
    fn serve_est(&self, n: u32, l_i: u32, s: u32) -> f64 {
        self.0.serve_est(n, l_i, s)
    }
}

fn gen_pool(g: &mut Gen, max_n: usize) -> Vec<Request> {
    (0..g.usize(1, max_n))
        .map(|i| Request::new(i as u64, 0.0, g.u32(1, 1024), g.u32(1, 1024)))
        .collect()
}

/// Random bilinear surfaces around fitted magnitudes; occasionally negative
/// constants so the `max(0, ·)` clamp can fire and `serve_affine` returns
/// `None` for some (or all) lengths.
fn gen_estimator(g: &mut Gen) -> ServingTimeEstimator {
    let mut coeff = |scale: f64| {
        let x = g.f64(0.0, scale);
        if g.u32(0, 9) == 0 {
            -x * 0.25
        } else {
            x
        }
    };
    ServingTimeEstimator {
        prefill: LinearLatency {
            c1: coeff(5e-4),
            c2: coeff(2e-3),
            c3: coeff(5e-4),
            c4: coeff(0.05),
        },
        decode: LinearLatency {
            c1: coeff(2e-6),
            c2: coeff(1e-3),
            c3: coeff(5e-6),
            c4: coeff(0.05),
        },
    }
}

fn gen_memory(g: &mut Gen) -> MemoryEstimator {
    match g.u32(0, 2) {
        0 => MemoryEstimator::ds_rules(),
        1 => MemoryEstimator::analytic(800 * 1024, 48 << 30, 0.9),
        _ => {
            // Tight analytic budgets: N_max anywhere from 1 to a handful.
            let delta = 1u64 << 20;
            let cap = g.u32(1, 12) as u64;
            MemoryEstimator::analytic(delta, cap * (1024 + 512) * delta, 1.0)
        }
    }
}

fn gen_cfg(g: &mut Gen) -> DpBatcherConfig {
    DpBatcherConfig {
        slice_len: *g.pick(&[16u32, 32, 64, 128, 256, 512]),
        max_batch_size: if g.bool() { Some(g.u32(1, 24)) } else { None },
        pred_corrected: false,
    }
}

fn assert_bit_exact(
    fast: &[Batch],
    slow: &[Batch],
    ctx: &str,
) -> Result<(), scls::testprop::PropFail> {
    prop_assert!(
        fast.len() == slow.len(),
        "{ctx}: batch count {} vs {}",
        fast.len(),
        slow.len()
    );
    for (idx, (f, s)) in fast.iter().zip(slow).enumerate() {
        let fi: Vec<u64> = f.requests.iter().map(|r| r.id).collect();
        let si: Vec<u64> = s.requests.iter().map(|r| r.id).collect();
        prop_assert!(fi == si, "{ctx}: batch {idx} members {fi:?} vs {si:?}");
        prop_assert!(
            f.est_serve_time.to_bits() == s.est_serve_time.to_bits(),
            "{ctx}: batch {idx} est {} vs {}",
            f.est_serve_time,
            s.est_serve_time
        );
    }
    Ok(())
}

#[test]
fn optimized_dp_matches_reference_on_random_surfaces() {
    check("dp-differential-random", 200, |g| {
        let est = gen_estimator(g);
        let mem = gen_memory(g);
        let cfg = gen_cfg(g);
        let pool = gen_pool(g, 200);
        let fast = dp_batch(pool.clone(), &est, &mem, &cfg);
        let slow = dp_batch_reference(pool, &est, &mem, &cfg);
        assert_bit_exact(&fast, &slow, "random-surface")
    });
}

#[test]
fn optimized_dp_matches_reference_with_fitted_estimators() {
    check("dp-differential-fitted", 200, |g| {
        let kind = if g.bool() { EngineKind::Hf } else { EngineKind::Ds };
        let preset = EnginePreset::paper(kind);
        let est = fitted_estimator(&preset, g.u64());
        let mem = preset.memory_estimator();
        let cfg = gen_cfg(g);
        let pool = gen_pool(g, 200);
        let fast = dp_batch(pool.clone(), &est, &mem, &cfg);
        let slow = dp_batch_reference(pool, &est, &mem, &cfg);
        assert_bit_exact(&fast, &slow, "fitted")
    });
}

#[test]
fn optimized_dp_matches_reference_on_opaque_estimators() {
    // serve_affine == None everywhere: both sides must take the fallback
    // scalar path and still agree bit-for-bit.
    check("dp-differential-opaque", 200, |g| {
        let est = Opaque(gen_estimator(g));
        let mem = gen_memory(g);
        let cfg = gen_cfg(g);
        let pool = gen_pool(g, 120);
        let fast = dp_batch(pool.clone(), &est, &mem, &cfg);
        let slow = dp_batch_reference(pool, &est, &mem, &cfg);
        assert_bit_exact(&fast, &slow, "opaque")
    });
}

#[test]
fn optimized_dp_matches_reference_under_tight_memory_and_caps() {
    check("dp-differential-tight", 200, |g| {
        let est = fitted_estimator(&EnginePreset::paper(EngineKind::Ds), 7);
        // N_max from 1 (all singletons) upward, crossed with a hard cap.
        let delta = 1u64 << 20;
        let n_cap = g.u32(1, 6) as u64;
        let mem = MemoryEstimator::analytic(delta, n_cap * (1024 + 128) * delta, 1.0);
        let cfg = DpBatcherConfig {
            slice_len: 128,
            max_batch_size: Some(g.u32(1, 4)),
            pred_corrected: false,
        };
        let pool = gen_pool(g, 150);
        let fast = dp_batch(pool.clone(), &est, &mem, &cfg);
        let slow = dp_batch_reference(pool, &est, &mem, &cfg);
        assert_bit_exact(&fast, &slow, "tight")
    });
}

#[test]
fn optimized_dp_matches_reference_on_adversarial_tables() {
    // Profiled rule tables with abrupt steps (Alg. 2 generalization):
    // window sizes change discontinuously along the sorted order.
    check("dp-differential-tables", 150, |g| {
        let est = fitted_estimator(&EnginePreset::paper(EngineKind::Hf), 11);
        let mem = MemoryEstimator {
            rule: MemoryRule::Table(vec![
                (g.u32(700, 1100), g.u32(1, 4)),
                (g.u32(300, 699), g.u32(5, 20)),
                (0, g.u32(21, 64)),
            ]),
        };
        let cfg = gen_cfg(g);
        let pool = gen_pool(g, 180);
        let fast = dp_batch(pool.clone(), &est, &mem, &cfg);
        let slow = dp_batch_reference(pool, &est, &mem, &cfg);
        assert_bit_exact(&fast, &slow, "table")
    });
}

#[test]
fn optimized_dp_matches_reference_on_ascending_capacity_tables() {
    // Capacity that GROWS with length makes the DP window's left edge move
    // left mid-scan; the planner must detect that and shut off its skip
    // certificate (this shape once broke bit-exactness).
    check("dp-differential-ascending-tables", 200, |g| {
        let est = fitted_estimator(&EnginePreset::paper(EngineKind::Ds), 17);
        let mem = MemoryEstimator {
            rule: MemoryRule::Table(vec![
                (g.u32(200, 900), g.u32(8, 40)),
                (0, g.u32(1, 6)),
            ]),
        };
        let cfg = DpBatcherConfig {
            slice_len: *g.pick(&[16u32, 32, 64, 128]),
            max_batch_size: None,
            pred_corrected: false,
        };
        let pool = gen_pool(g, 150);
        let fast = dp_batch(pool.clone(), &est, &mem, &cfg);
        let slow = dp_batch_reference(pool, &est, &mem, &cfg);
        assert_bit_exact(&fast, &slow, "ascending-table")
    });
}

#[test]
fn duplicate_heavy_pools_match_reference() {
    // Long runs of equal lengths exercise the per-distinct-length cache
    // and the range-skip on flat T[·] stretches.
    check("dp-differential-duplicates", 150, |g| {
        let est = fitted_estimator(&EnginePreset::paper(EngineKind::Ds), 13);
        let preset = EnginePreset::paper(EngineKind::Ds);
        let mem = preset.memory_estimator();
        let cfg = gen_cfg(g);
        let distinct = g.usize(1, 4);
        let lens: Vec<u32> = (0..distinct).map(|_| g.u32(1, 1024)).collect();
        let pool: Vec<Request> = (0..g.usize(1, 160))
            .map(|i| Request::new(i as u64, 0.0, *g.pick(&lens), g.u32(1, 1024)))
            .collect();
        let fast = dp_batch(pool.clone(), &est, &mem, &cfg);
        let slow = dp_batch_reference(pool, &est, &mem, &cfg);
        assert_bit_exact(&fast, &slow, "duplicates")
    });
}
