//! Streaming metrics observers.
//!
//! A [`MetricsSink`] watches a run *as it executes*: the generic DES loop
//! ([`crate::sim::driver::run_policy`]) and the real-cluster driver
//! ([`crate::worker::real_driver::run_real_streaming`]) invoke the hooks
//! the moment a batch starts, a request completes, or a schedule tick
//! drains the pool. `RunMetrics` itself is always populated by the driver
//! (it is the record of truth the figures summarize); sinks are for
//! consumers that want the event stream live — progress displays, bench
//! tallies that must not retain full logs, or exporters.
//!
//! Sinks must be cheap and must not assume event ordering beyond
//! monotonically non-decreasing `now` within one run.

use super::{BatchRecord, CompletedRequest, FleetRecord, PredictionRecord, RunMetrics};
use crate::core::Request;
use crate::slo::SloOutcome;

/// Observer of one experiment run's event stream. All hooks default to
/// no-ops so implementations override only what they consume.
pub trait MetricsSink {
    /// A batch was handed to a worker and started serving. In real mode
    /// `rec.actual_serve_time` is still 0.0 at this point (it is patched
    /// into `RunMetrics` when the slice completes).
    fn on_batch(&mut self, _now: f64, _rec: &BatchRecord) {}
    /// A request finished and its completion record was logged.
    fn on_completion(&mut self, _now: f64, _req: &CompletedRequest) {}
    /// A schedule tick drained `depth` pooled requests.
    fn on_pool_depth(&mut self, _now: f64, _depth: usize) {}
    /// A prediction-aware policy logged a mispredict-recovery or
    /// over-prediction event (never fires under prediction-free policies).
    fn on_prediction(&mut self, _now: f64, _rec: &PredictionRecord) {}
    /// An online predictor refit its model from completion observations
    /// (never fires under offline predictors).
    fn on_predictor_refit(&mut self, _now: f64) {}
    /// The DP batcher costed a batch at a predicted budget strictly below
    /// the slice cap (predicted-correction opt-in only).
    fn on_corrected_batch(&mut self, _now: f64) {}
    /// A worker-lifecycle event was applied by a fault-aware policy
    /// (elastic-fleet runs only; never fires on `FaultPlan::none()`).
    fn on_fleet(&mut self, _now: f64, _rec: &FleetRecord) {}
    /// A crash reclaimed stale work from `worker`: `in_flight` survivors
    /// lost their current slice, `queued` requests were re-queued intact.
    fn on_reclaim(&mut self, _now: f64, _worker: usize, _in_flight: usize, _queued: usize) {}
    /// `count` requests migrated off `worker` at a slice boundary (drain).
    fn on_migration(&mut self, _now: f64, _worker: usize, _count: usize) {}
    /// The coordinator crashed and a successor reconstructed its ledger
    /// from worker-side state (elastic-fleet runs with `coord@T` only).
    fn on_coordinator_crash(&mut self, _now: f64) {}
    /// A migrated request's resident context (`tokens`) was shipped off
    /// `worker`; `stall_s` is the modeled transfer stall charged before the
    /// request is servable again (0 when no transfer cost is configured).
    fn on_kv_transfer(&mut self, _now: f64, _worker: usize, _tokens: u64, _stall_s: f64) {}
    /// An SLO-carrying request completed and was judged (never fires for
    /// SLO-free requests, so SLO-free runs see no new events).
    fn on_slo(&mut self, _now: f64, _outcome: &SloOutcome) {}
    /// An SLO-aware policy shed `req` before service (deadline-infeasible
    /// admission or an expired requeue).
    fn on_shed(&mut self, _now: f64, _req: &Request) {}
    /// A worker finished a serving iteration / settled a slice:
    /// `new_tokens` were decoded this iteration, `kv_in_use` KV tokens are
    /// resident on the worker afterwards (0 for static-batching engines,
    /// which release the batch at the slice boundary), and `queue_depth`
    /// requests are still queued on that worker. Telemetry-only — the
    /// sample never enters `RunMetrics`, so sink-free runs are unaffected.
    fn on_worker_sample(
        &mut self,
        _now: f64,
        _worker: usize,
        _new_tokens: u64,
        _kv_in_use: u64,
        _queue_depth: usize,
    ) {
    }
    /// The run drained; `metrics` is the final event log.
    fn on_run_end(&mut self, _metrics: &RunMetrics) {}
}

/// Discards everything (the default sink).
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl MetricsSink for NullSink {}

/// Streaming counters — what the bench harness consumes instead of
/// re-walking the full `RunMetrics` logs after the fact.
#[derive(Debug, Default, Clone)]
pub struct Tally {
    pub batches: u64,
    pub completions: u64,
    pub generated_tokens: u64,
    pub pad_tokens: u64,
    pub invalid_tokens: u64,
    pub peak_pool: usize,
    /// Virtual/wall time of the last completion seen.
    pub last_completion: f64,
    /// Prediction-aware policies only (see [`RunMetrics`]): recovery
    /// events, over-predicted completions, and unused reserved capacity.
    pub underpredicted: u64,
    pub overpredicted: u64,
    pub wasted_kv_token_steps: u64,
    /// Online-predictor refits and predicted-budget-corrected batches
    /// (see [`RunMetrics`]).
    pub predictor_refits: u64,
    pub corrected_batches: u64,
    /// Elastic-fleet counters (see [`RunMetrics`]); all 0 on fault-free
    /// runs.
    pub worker_crashes: u64,
    pub reclaimed_requests: u64,
    pub lost_slices: u64,
    pub migrations: u64,
    pub coordinator_crashes: u64,
    pub kv_tokens_migrated: u64,
    pub migration_stall_s: f64,
    /// SLO counters (see [`RunMetrics`]); all 0 on SLO-free runs.
    pub slo_tracked: u64,
    pub slo_attained: u64,
    pub deadline_misses: u64,
    pub shed_requests: u64,
    /// Per-worker telemetry samples seen (0 unless a gauge-sampling
    /// driver is attached) and runs drained through this tally.
    pub worker_samples: u64,
    pub runs: u64,
}

impl MetricsSink for Tally {
    fn on_batch(&mut self, _now: f64, _rec: &BatchRecord) {
        self.batches += 1;
    }

    fn on_completion(&mut self, now: f64, req: &CompletedRequest) {
        self.completions += 1;
        self.generated_tokens += req.generated as u64;
        self.pad_tokens += req.pad_tokens;
        self.invalid_tokens += req.invalid_tokens;
        self.last_completion = now;
    }

    fn on_pool_depth(&mut self, _now: f64, depth: usize) {
        self.peak_pool = self.peak_pool.max(depth);
    }

    fn on_prediction(&mut self, _now: f64, rec: &PredictionRecord) {
        if rec.underpredicted {
            self.underpredicted += 1;
        } else {
            self.overpredicted += 1;
        }
        self.wasted_kv_token_steps += rec.wasted_tokens;
    }

    fn on_predictor_refit(&mut self, _now: f64) {
        self.predictor_refits += 1;
    }

    fn on_corrected_batch(&mut self, _now: f64) {
        self.corrected_batches += 1;
    }

    fn on_fleet(&mut self, _now: f64, rec: &FleetRecord) {
        if rec.kind == super::FleetEventKind::Crash {
            self.worker_crashes += 1;
        }
    }

    fn on_reclaim(&mut self, _now: f64, _worker: usize, in_flight: usize, queued: usize) {
        self.reclaimed_requests += (in_flight + queued) as u64;
        self.lost_slices += in_flight as u64;
        self.migrations += queued as u64;
    }

    fn on_migration(&mut self, _now: f64, _worker: usize, count: usize) {
        self.migrations += count as u64;
    }

    fn on_coordinator_crash(&mut self, _now: f64) {
        self.coordinator_crashes += 1;
    }

    fn on_kv_transfer(&mut self, _now: f64, _worker: usize, tokens: u64, stall_s: f64) {
        self.kv_tokens_migrated += tokens;
        self.migration_stall_s += stall_s;
    }

    fn on_slo(&mut self, _now: f64, outcome: &SloOutcome) {
        self.slo_tracked += 1;
        if outcome.attained {
            self.slo_attained += 1;
        }
        if !outcome.deadline_ok {
            self.deadline_misses += 1;
        }
    }

    fn on_shed(&mut self, _now: f64, req: &Request) {
        self.shed_requests += 1;
        if !req.slo.is_none() {
            self.slo_tracked += 1;
            self.deadline_misses += 1;
        }
    }

    fn on_worker_sample(
        &mut self,
        _now: f64,
        _worker: usize,
        _new_tokens: u64,
        _kv_in_use: u64,
        _queue_depth: usize,
    ) {
        self.worker_samples += 1;
    }

    fn on_run_end(&mut self, _metrics: &RunMetrics) {
        self.runs += 1;
    }
}

/// Fans one event stream out to several sinks, in order.
pub struct Fanout<'a>(pub Vec<&'a mut dyn MetricsSink>);

impl MetricsSink for Fanout<'_> {
    fn on_batch(&mut self, now: f64, rec: &BatchRecord) {
        for s in self.0.iter_mut() {
            s.on_batch(now, rec);
        }
    }

    fn on_completion(&mut self, now: f64, req: &CompletedRequest) {
        for s in self.0.iter_mut() {
            s.on_completion(now, req);
        }
    }

    fn on_pool_depth(&mut self, now: f64, depth: usize) {
        for s in self.0.iter_mut() {
            s.on_pool_depth(now, depth);
        }
    }

    fn on_prediction(&mut self, now: f64, rec: &PredictionRecord) {
        for s in self.0.iter_mut() {
            s.on_prediction(now, rec);
        }
    }

    fn on_predictor_refit(&mut self, now: f64) {
        for s in self.0.iter_mut() {
            s.on_predictor_refit(now);
        }
    }

    fn on_corrected_batch(&mut self, now: f64) {
        for s in self.0.iter_mut() {
            s.on_corrected_batch(now);
        }
    }

    fn on_fleet(&mut self, now: f64, rec: &FleetRecord) {
        for s in self.0.iter_mut() {
            s.on_fleet(now, rec);
        }
    }

    fn on_reclaim(&mut self, now: f64, worker: usize, in_flight: usize, queued: usize) {
        for s in self.0.iter_mut() {
            s.on_reclaim(now, worker, in_flight, queued);
        }
    }

    fn on_migration(&mut self, now: f64, worker: usize, count: usize) {
        for s in self.0.iter_mut() {
            s.on_migration(now, worker, count);
        }
    }

    fn on_coordinator_crash(&mut self, now: f64) {
        for s in self.0.iter_mut() {
            s.on_coordinator_crash(now);
        }
    }

    fn on_kv_transfer(&mut self, now: f64, worker: usize, tokens: u64, stall_s: f64) {
        for s in self.0.iter_mut() {
            s.on_kv_transfer(now, worker, tokens, stall_s);
        }
    }

    fn on_slo(&mut self, now: f64, outcome: &SloOutcome) {
        for s in self.0.iter_mut() {
            s.on_slo(now, outcome);
        }
    }

    fn on_shed(&mut self, now: f64, req: &Request) {
        for s in self.0.iter_mut() {
            s.on_shed(now, req);
        }
    }

    fn on_worker_sample(
        &mut self,
        now: f64,
        worker: usize,
        new_tokens: u64,
        kv_in_use: u64,
        queue_depth: usize,
    ) {
        for s in self.0.iter_mut() {
            s.on_worker_sample(now, worker, new_tokens, kv_in_use, queue_depth);
        }
    }

    fn on_run_end(&mut self, metrics: &RunMetrics) {
        for s in self.0.iter_mut() {
            s.on_run_end(metrics);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tally_accumulates() {
        let mut t = Tally::default();
        t.on_batch(
            0.5,
            &BatchRecord {
                start: 0.5,
                worker: 0,
                size: 3,
                input_len: 10,
                pad_tokens: 2,
                est_serve_time: 1.0,
                actual_serve_time: 1.1,
                early_return: false,
            },
        );
        t.on_completion(
            2.0,
            &CompletedRequest {
                id: 1,
                arrival: 0.0,
                finished: 2.0,
                generated: 40,
                slices: 1,
                pad_tokens: 2,
                invalid_tokens: 3,
            },
        );
        t.on_pool_depth(1.0, 7);
        t.on_pool_depth(2.0, 4);
        assert_eq!(t.batches, 1);
        assert_eq!(t.completions, 1);
        assert_eq!(t.generated_tokens, 40);
        assert_eq!(t.pad_tokens, 2);
        assert_eq!(t.invalid_tokens, 3);
        assert_eq!(t.peak_pool, 7);
        assert_eq!(t.last_completion, 2.0);
    }

    #[test]
    fn tally_prediction_counters() {
        let mut t = Tally::default();
        t.on_prediction(
            1.0,
            &PredictionRecord {
                id: 1,
                underpredicted: true,
                wasted_tokens: 0,
            },
        );
        t.on_prediction(
            2.0,
            &PredictionRecord {
                id: 2,
                underpredicted: false,
                wasted_tokens: 40,
            },
        );
        assert_eq!(t.underpredicted, 1);
        assert_eq!(t.overpredicted, 1);
        assert_eq!(t.wasted_kv_token_steps, 40);
        t.on_predictor_refit(3.0);
        t.on_predictor_refit(4.0);
        t.on_corrected_batch(5.0);
        assert_eq!(t.predictor_refits, 2);
        assert_eq!(t.corrected_batches, 1);
    }

    #[test]
    fn tally_slo_counters() {
        let mut t = Tally::default();
        t.on_slo(
            1.0,
            &SloOutcome {
                tenant: 0,
                ttft: 0.2,
                tpot: 0.01,
                ttft_ok: true,
                tpot_ok: true,
                deadline_ok: true,
                attained: true,
            },
        );
        t.on_slo(
            2.0,
            &SloOutcome {
                tenant: 1,
                ttft: 5.0,
                tpot: 0.01,
                ttft_ok: false,
                tpot_ok: true,
                deadline_ok: false,
                attained: false,
            },
        );
        let mut shed = Request::new(7, 0.0, 8, 8);
        shed.slo.deadline = Some(1.0);
        t.on_shed(3.0, &shed);
        // SLO-free sheds count the shed only.
        t.on_shed(4.0, &Request::new(8, 0.0, 8, 8));
        assert_eq!(t.slo_tracked, 3);
        assert_eq!(t.slo_attained, 1);
        assert_eq!(t.deadline_misses, 2);
        assert_eq!(t.shed_requests, 2);
    }

    #[test]
    fn tally_telemetry_counters() {
        let mut t = Tally::default();
        t.on_worker_sample(1.0, 0, 16, 128, 2);
        t.on_worker_sample(2.0, 1, 8, 64, 0);
        t.on_run_end(&RunMetrics::default());
        assert_eq!(t.worker_samples, 2);
        assert_eq!(t.runs, 1);
    }

    /// Appends `"<id>:<hook>"` to a shared log on every hook — proves the
    /// fanout forwards the *full* trait surface to every child, children
    /// in declaration order for each event.
    struct RecordingSink {
        id: &'static str,
        log: std::rc::Rc<std::cell::RefCell<Vec<String>>>,
    }

    impl RecordingSink {
        fn note(&mut self, hook: &str) {
            self.log.borrow_mut().push(format!("{}:{hook}", self.id));
        }
    }

    impl MetricsSink for RecordingSink {
        fn on_batch(&mut self, _now: f64, _rec: &BatchRecord) {
            self.note("on_batch");
        }
        fn on_completion(&mut self, _now: f64, _req: &CompletedRequest) {
            self.note("on_completion");
        }
        fn on_pool_depth(&mut self, _now: f64, _depth: usize) {
            self.note("on_pool_depth");
        }
        fn on_prediction(&mut self, _now: f64, _rec: &PredictionRecord) {
            self.note("on_prediction");
        }
        fn on_predictor_refit(&mut self, _now: f64) {
            self.note("on_predictor_refit");
        }
        fn on_corrected_batch(&mut self, _now: f64) {
            self.note("on_corrected_batch");
        }
        fn on_fleet(&mut self, _now: f64, _rec: &FleetRecord) {
            self.note("on_fleet");
        }
        fn on_reclaim(&mut self, _now: f64, _worker: usize, _in_flight: usize, _queued: usize) {
            self.note("on_reclaim");
        }
        fn on_migration(&mut self, _now: f64, _worker: usize, _count: usize) {
            self.note("on_migration");
        }
        fn on_coordinator_crash(&mut self, _now: f64) {
            self.note("on_coordinator_crash");
        }
        fn on_kv_transfer(&mut self, _now: f64, _worker: usize, _tokens: u64, _stall_s: f64) {
            self.note("on_kv_transfer");
        }
        fn on_slo(&mut self, _now: f64, _outcome: &SloOutcome) {
            self.note("on_slo");
        }
        fn on_shed(&mut self, _now: f64, _req: &Request) {
            self.note("on_shed");
        }
        fn on_worker_sample(
            &mut self,
            _now: f64,
            _worker: usize,
            _new_tokens: u64,
            _kv_in_use: u64,
            _queue_depth: usize,
        ) {
            self.note("on_worker_sample");
        }
        fn on_run_end(&mut self, _metrics: &RunMetrics) {
            self.note("on_run_end");
        }
    }

    #[test]
    fn fanout_forwards_full_hook_surface_in_order() {
        let log = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let mut a = RecordingSink {
            id: "a",
            log: log.clone(),
        };
        let mut b = RecordingSink {
            id: "b",
            log: log.clone(),
        };
        {
            let mut f = Fanout(vec![&mut a, &mut b]);
            f.on_batch(
                0.1,
                &BatchRecord {
                    start: 0.1,
                    worker: 0,
                    size: 1,
                    input_len: 4,
                    pad_tokens: 0,
                    est_serve_time: 0.5,
                    actual_serve_time: 0.5,
                    early_return: false,
                },
            );
            f.on_completion(
                0.6,
                &CompletedRequest {
                    id: 0,
                    arrival: 0.0,
                    finished: 0.6,
                    generated: 1,
                    slices: 1,
                    pad_tokens: 0,
                    invalid_tokens: 0,
                },
            );
            f.on_pool_depth(0.7, 3);
            f.on_prediction(
                0.8,
                &PredictionRecord {
                    id: 1,
                    underpredicted: true,
                    wasted_tokens: 0,
                },
            );
            f.on_predictor_refit(0.9);
            f.on_corrected_batch(1.0);
            f.on_fleet(
                1.1,
                &FleetRecord {
                    worker: 1,
                    kind: super::super::FleetEventKind::Crash,
                },
            );
            f.on_reclaim(1.1, 1, 2, 3);
            f.on_migration(1.2, 1, 4);
            f.on_coordinator_crash(1.25);
            f.on_kv_transfer(1.26, 1, 640, 0.05);
            f.on_slo(
                1.3,
                &SloOutcome {
                    tenant: 0,
                    ttft: 0.1,
                    tpot: 0.01,
                    ttft_ok: true,
                    tpot_ok: true,
                    deadline_ok: true,
                    attained: true,
                },
            );
            f.on_shed(1.4, &Request::new(5, 0.0, 4, 4));
            f.on_worker_sample(1.5, 2, 64, 512, 1);
            f.on_run_end(&RunMetrics::default());
        }
        let hooks = [
            "on_batch",
            "on_completion",
            "on_pool_depth",
            "on_prediction",
            "on_predictor_refit",
            "on_corrected_batch",
            "on_fleet",
            "on_reclaim",
            "on_migration",
            "on_coordinator_crash",
            "on_kv_transfer",
            "on_slo",
            "on_shed",
            "on_worker_sample",
            "on_run_end",
        ];
        let want: Vec<String> = hooks
            .iter()
            .flat_map(|h| [format!("a:{h}"), format!("b:{h}")])
            .collect();
        assert_eq!(
            *log.borrow(),
            want,
            "every hook must reach every child, children in order per event"
        );
    }

    #[test]
    fn fanout_forwards_to_all() {
        let mut a = Tally::default();
        let mut b = Tally::default();
        {
            let mut f = Fanout(vec![&mut a, &mut b]);
            f.on_pool_depth(0.0, 5);
            f.on_completion(
                1.0,
                &CompletedRequest {
                    id: 0,
                    arrival: 0.0,
                    finished: 1.0,
                    generated: 1,
                    slices: 1,
                    pad_tokens: 0,
                    invalid_tokens: 0,
                },
            );
        }
        assert_eq!(a.peak_pool, 5);
        assert_eq!(b.peak_pool, 5);
        assert_eq!(a.completions, 1);
        assert_eq!(b.completions, 1);
    }
}
