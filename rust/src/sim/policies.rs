//! The built-in [`SchedulingPolicy`] implementations.
//!
//! * [`SlicedPolicy`] — the whole sliced family (SLS, SO, PM, AB, LB,
//!   SCLS): static batching workers driven by a [`SlicedCoordinator`]
//!   built from a `SchedulerSpec`'s four axes.
//! * [`IlsPolicy`] — the DeepSpeed-FastGen-style iteration-level baseline
//!   (continuous batching, conservative parallel cap, §5.1).
//! * [`SclsCbPolicy`] — the §7 extension: slice-level scheduling over
//!   continuous batching with precise per-slice memory admission and
//!   memory-balanced placement.
//!
//! Each policy is a faithful port of the corresponding pre-trait driver
//! loop (`sim::reference`); the differential suite
//! (`tests/props_policy_differential.rs`) asserts the ports are
//! byte-identical on the full `RunMetrics` event log.

use std::collections::VecDeque;

use crate::batcher::fcfs_batches;
use crate::core::{Batch, Request};
use crate::engine::continuous::ContinuousWorker;
use crate::engine::continuous_scls::SlicedContinuousWorker;
use crate::engine::sim::SimEngine;
use crate::estimator::{MemoryEstimator, ServingTimeEstimator};
use crate::metrics::{BatchRecord, RunMetrics};
use crate::offloader::RoundRobin;
use crate::scheduler::coordinator::SlicedCoordinator;
use crate::scheduler::policy::{SchedulingPolicy, SimCtx};
use crate::scheduler::spec::{BatchingSpec, SchedulerSpec};
use crate::sim::driver::{fitted_estimator, SimConfig};

// ---------------------------------------------------------------------------
// Sliced family (SLS / SO / PM / AB / LB / SCLS)
// ---------------------------------------------------------------------------

/// Per-worker state for the sliced-family policy.
struct WorkerState {
    /// Coordinator-formed batches waiting in the local queue.
    batch_queue: VecDeque<Batch>,
    /// Worker-locus FCFS: raw requests waiting locally (SLS/SO).
    req_queue: VecDeque<Request>,
    /// The batch currently being served (None = idle).
    serving: Option<Batch>,
    engine: SimEngine,
    last_done: f64,
}

/// Static-batching sliced-family scheduler: any `SchedulerSpec` point
/// (slice length × batching × offload × interval) over simulated workers.
pub struct SlicedPolicy {
    coord: SlicedCoordinator,
    est: ServingTimeEstimator,
    mem: MemoryEstimator,
    workers: Vec<WorkerState>,
}

impl SlicedPolicy {
    /// Build the policy the way the SCLS deployment starts up (§4.2):
    /// profile the engine's latency model once, fit Eq. (3)/(4), then
    /// instantiate per-worker engines on decorrelated seed streams.
    pub fn new(spec: &SchedulerSpec, cfg: &SimConfig) -> SlicedPolicy {
        assert!(cfg.workers > 0);
        let est = fitted_estimator(&cfg.engine, cfg.seed);
        let mem = cfg.engine.memory_estimator();
        let workers: Vec<WorkerState> = (0..cfg.workers)
            .map(|w| WorkerState {
                batch_queue: VecDeque::new(),
                req_queue: VecDeque::new(),
                serving: None,
                engine: SimEngine::new(
                    cfg.engine.latency(cfg.seed ^ (w as u64).wrapping_mul(0x9E37)),
                    cfg.max_gen_len,
                ),
                last_done: 0.0,
            })
            .collect();
        SlicedPolicy {
            coord: SlicedCoordinator::new(spec, cfg.workers),
            est,
            mem,
            workers,
        }
    }

    /// Start serving on worker `w` if idle and work is queued.
    fn try_start(&mut self, w: usize, ctx: &mut SimCtx) {
        let slice_len = self.coord.spec().slice_len;
        let batching = self.coord.spec().batching.clone();
        let ws = &mut self.workers[w];
        if ws.serving.is_some() {
            return;
        }
        // Worker-locus FCFS: form a batch from the local request queue.
        if let BatchingSpec::WorkerFcfs { batch_size } = batching {
            if ws.batch_queue.is_empty() && !ws.req_queue.is_empty() {
                let take = (batch_size as usize).min(ws.req_queue.len());
                let reqs: Vec<Request> = ws.req_queue.drain(..take).collect();
                let mut batches = fcfs_batches(reqs, batch_size, &self.est, slice_len);
                debug_assert_eq!(batches.len(), 1);
                ws.batch_queue.push_back(batches.pop().unwrap());
            }
        }
        let Some(mut batch) = ws.batch_queue.pop_front() else {
            return;
        };
        // Serving-start accounting: each request pays its pads and a slice.
        let li = batch.input_len();
        for r in &mut batch.requests {
            r.slices += 1;
            r.pad_tokens += (li - r.input_len) as u64;
        }
        let outcome = ws.engine.serve_slice(&batch, slice_len);
        ctx.record_batch(BatchRecord {
            start: ctx.now,
            worker: w,
            size: batch.size() as u32,
            input_len: li,
            pad_tokens: batch.pad_tokens(),
            est_serve_time: batch.est_serve_time,
            actual_serve_time: outcome.duration,
            early_return: outcome.early_return,
        });
        // Apply token effects now, deliver at done-time (the serving slot
        // pairs the batch with its outcome).
        let done_at = ctx.now + outcome.duration;
        for (r, o) in batch.requests.iter_mut().zip(&outcome.per_request) {
            debug_assert_eq!(r.id, o.id);
            r.generated += o.new_tokens;
            r.invalid_tokens += o.invalid_tokens as u64;
            // SCLS reschedule: the next prefill recomputes over input +
            // everything generated so far.
            r.input_len += o.new_tokens;
            if o.finished {
                r.finished_at = Some(done_at);
            }
        }
        ws.serving = Some(batch);
        ctx.complete_at(done_at, w);
    }
}

impl SchedulingPolicy for SlicedPolicy {
    fn init(&mut self, ctx: &mut SimCtx) {
        self.coord.reserve_pool(ctx.arrivals_left().min(1 << 16));
        if self.coord.has_ticks() {
            ctx.tick_at(0.0);
        }
    }

    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx) {
        // SLS/SO: round-robin to a worker queue; otherwise pooled.
        if let Some((w, r)) = self.coord.admit(req) {
            self.workers[w].req_queue.push_back(r);
            self.try_start(w, ctx);
        }
    }

    fn on_tick(&mut self, ctx: &mut SimCtx) {
        if !self.coord.has_ticks() {
            return;
        }
        let drained = self.coord.schedule_tick(&self.est, &self.mem);
        if drained > 0 {
            ctx.observe_pool(drained);
            let mut assign = self.coord.take_assignments();
            for (w, b) in assign.drain(..) {
                self.workers[w].batch_queue.push_back(b);
                self.try_start(w, ctx);
            }
            self.coord.recycle_assignments(assign);
        }
        // Re-arm the tick while any work can still appear.
        let work_pending = ctx.arrivals_left() > 0
            || !self.coord.pool_is_empty()
            || self
                .workers
                .iter()
                .any(|w| w.serving.is_some() || !w.batch_queue.is_empty());
        if work_pending {
            let t = self
                .coord
                .next_interval()
                .expect("on_tick only fires for ticked policies");
            ctx.tick_at(ctx.now + t.max(1e-3));
        }
    }

    fn on_worker_done(&mut self, w: usize, ctx: &mut SimCtx) {
        let batch = self.workers[w].serving.take().expect("done without serving");
        self.coord.batch_done(w, batch.est_serve_time);
        self.workers[w].last_done = ctx.now;
        for r in batch.requests {
            if r.is_finished() {
                ctx.record_completion(&r);
            } else if let Some((tw, r)) = self.coord.admit(r) {
                // SO: re-send unfinished requests round-robin.
                self.workers[tw].req_queue.push_back(r);
                self.try_start(tw, ctx);
            }
        }
        self.try_start(w, ctx);
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.workers.iter().map(|w| w.last_done).collect();
    }
}

// ---------------------------------------------------------------------------
// ILS: iteration-level scheduling with continuous batching (FastGen-like)
// ---------------------------------------------------------------------------

/// The ILS baseline: per-iteration joins and exits, no padding, no invalid
/// tokens — but a conservative cap on parallel requests plus a KV-memory
/// admission check (§1, §5.1). Requests are offloaded round-robin, as the
/// paper's baselines do (§3.2).
pub struct IlsPolicy {
    workers: Vec<ContinuousWorker>,
    looping: Vec<bool>,
    last_done: Vec<f64>,
    rr: RoundRobin,
    kv_budget: u64,
    max_kv_seen: u64,
}

impl IlsPolicy {
    pub fn new(cfg: &SimConfig) -> IlsPolicy {
        assert!(cfg.workers > 0);
        let kv_budget = (0.9 * cfg.engine.m_ava as f64) as u64;
        let workers: Vec<ContinuousWorker> = (0..cfg.workers)
            .map(|w| {
                ContinuousWorker::new(
                    cfg.engine
                        .latency(cfg.seed ^ (w as u64).wrapping_mul(0xA5A5)),
                    cfg.engine.ils_max_parallel,
                    kv_budget,
                    cfg.engine.kv_delta,
                    cfg.max_gen_len,
                )
            })
            .collect();
        let n = workers.len();
        IlsPolicy {
            workers,
            looping: vec![false; n],
            last_done: vec![0.0; n],
            rr: RoundRobin::new(n),
            kv_budget,
            max_kv_seen: 0,
        }
    }

    /// Per-instance KV budget the admission check enforces.
    pub fn kv_budget(&self) -> u64 {
        self.kv_budget
    }

    /// Largest KV-in-use observed on any instance (no-OOM invariant:
    /// never exceeds [`Self::kv_budget`]).
    pub fn max_kv_observed(&self) -> u64 {
        self.max_kv_seen
    }

    /// Kick worker `w`'s iteration loop if it is idle.
    fn kick(&mut self, w: usize, ctx: &mut SimCtx) {
        if !self.looping[w] {
            if let Some(d) = self.workers[w].begin_iteration() {
                self.looping[w] = true;
                self.max_kv_seen = self.max_kv_seen.max(self.workers[w].kv_in_use());
                ctx.complete_at(ctx.now + d, w);
            }
        }
    }
}

impl SchedulingPolicy for IlsPolicy {
    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx) {
        let w = self.rr.next_worker();
        self.workers[w].waiting.push_back(req);
        self.kick(w, ctx);
    }

    fn on_worker_done(&mut self, wi: usize, ctx: &mut SimCtx) {
        for r in self.workers[wi].finish_iteration(ctx.now) {
            self.last_done[wi] = ctx.now;
            ctx.record_completion(&r);
        }
        if let Some(d) = self.workers[wi].begin_iteration() {
            self.max_kv_seen = self.max_kv_seen.max(self.workers[wi].kv_in_use());
            ctx.complete_at(ctx.now + d, wi);
        } else {
            self.looping[wi] = false;
        }
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.last_done.clone();
    }
}

// ---------------------------------------------------------------------------
// SCLS-CB: slice-level scheduling over continuous batching (paper §7)
// ---------------------------------------------------------------------------

/// The §7 extension: continuous batching per instance (no pads, no invalid
/// tokens), each schedule capped at `slice_len` generated tokens,
/// **precise** per-slice memory admission instead of ILS's conservative
/// cap, and coordinator-side offloading of new and rescheduled requests to
/// the instance with the most free projected KV memory.
pub struct SclsCbPolicy {
    workers: Vec<SlicedContinuousWorker>,
    looping: Vec<bool>,
    last_done: Vec<f64>,
    kv_budget: u64,
    max_kv_seen: u64,
}

impl SclsCbPolicy {
    pub fn new(cfg: &SimConfig, slice_len: u32) -> SclsCbPolicy {
        assert!(cfg.workers > 0);
        let kv_budget = (0.9 * cfg.engine.m_ava as f64) as u64;
        let workers: Vec<SlicedContinuousWorker> = (0..cfg.workers)
            .map(|w| {
                SlicedContinuousWorker::new(
                    cfg.engine
                        .latency(cfg.seed ^ (w as u64).wrapping_mul(0x5A5A)),
                    slice_len,
                    kv_budget,
                    cfg.engine.kv_delta,
                    cfg.max_gen_len,
                )
            })
            .collect();
        let n = workers.len();
        SclsCbPolicy {
            workers,
            looping: vec![false; n],
            last_done: vec![0.0; n],
            kv_budget,
            max_kv_seen: 0,
        }
    }

    /// Per-instance KV budget the precise admission enforces.
    pub fn kv_budget(&self) -> u64 {
        self.kv_budget
    }

    /// Largest *projected* KV observed on any instance after admission
    /// (no-OOM invariant: never exceeds [`Self::kv_budget`]).
    pub fn max_kv_observed(&self) -> u64 {
        self.max_kv_seen
    }

    /// Offload to the instance with the most free projected memory (ties:
    /// shortest local queue); kick its iteration loop if idle.
    fn assign(&mut self, r: Request, ctx: &mut SimCtx) {
        let w = (0..self.workers.len())
            .min_by(|&a, &b| {
                self.workers[a]
                    .kv_projected()
                    .cmp(&self.workers[b].kv_projected())
                    .then_with(|| {
                        self.workers[a]
                            .waiting
                            .len()
                            .cmp(&self.workers[b].waiting.len())
                    })
            })
            .unwrap();
        self.workers[w].waiting.push_back(r);
        if !self.looping[w] {
            if let Some(d) = self.workers[w].begin_iteration() {
                self.looping[w] = true;
                self.max_kv_seen = self.max_kv_seen.max(self.workers[w].kv_projected());
                ctx.complete_at(ctx.now + d, w);
            }
        }
    }
}

impl SchedulingPolicy for SclsCbPolicy {
    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx) {
        self.assign(req, ctx);
    }

    fn on_worker_done(&mut self, wi: usize, ctx: &mut SimCtx) {
        let exits = self.workers[wi].finish_iteration(ctx.now);
        for r in exits.done {
            self.last_done[wi] = ctx.now;
            ctx.record_completion(&r);
        }
        // §7: slice-capped requests are rescheduled to the least
        // memory-loaded instance (their KV was just released).
        for r in exits.rescheduled {
            self.assign(r, ctx);
        }
        if let Some(d) = self.workers[wi].begin_iteration() {
            self.max_kv_seen = self.max_kv_seen.max(self.workers[wi].kv_projected());
            ctx.complete_at(ctx.now + d, wi);
        } else {
            self.looping[wi] = false;
        }
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.last_done.clone();
    }
}
