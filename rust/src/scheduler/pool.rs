//! The request pool (paper Fig. 7): newly arrived requests and uncompleted
//! rescheduled requests wait here between schedule ticks.

use crate::core::Request;

#[derive(Debug, Default)]
pub struct RequestPool {
    requests: Vec<Request>,
}

impl RequestPool {
    pub fn new() -> RequestPool {
        RequestPool {
            requests: Vec::new(),
        }
    }

    pub fn push(&mut self, r: Request) {
        self.requests.push(r);
    }

    /// Drain everything (SCLS "periodically fetches all requests", §4.1).
    pub fn fetch_all(&mut self) -> Vec<Request> {
        std::mem::take(&mut self.requests)
    }

    /// Drain at most `n`, in arrival order of insertion (FCFS baselines).
    pub fn fetch_up_to(&mut self, n: usize) -> Vec<Request> {
        if n >= self.requests.len() {
            return self.fetch_all();
        }
        let rest = self.requests.split_off(n);
        std::mem::replace(&mut self.requests, rest)
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, 0.0, 10, 10)
    }

    #[test]
    fn fetch_all_drains() {
        let mut p = RequestPool::new();
        p.push(req(1));
        p.push(req(2));
        let all = p.fetch_all();
        assert_eq!(all.len(), 2);
        assert!(p.is_empty());
    }

    #[test]
    fn fetch_up_to_preserves_order() {
        let mut p = RequestPool::new();
        for i in 0..5 {
            p.push(req(i));
        }
        let first = p.fetch_up_to(2);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(p.len(), 3);
        let rest = p.fetch_up_to(10);
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3, 4]);
    }
}
