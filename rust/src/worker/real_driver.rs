//! Wall-clock driver for the real PJRT cluster.
//!
//! Shares the *same scheduling brain* as the DES — the
//! [`SlicedCoordinator`] (pool, DP batcher, offloader, load ledger,
//! interval controller) that `sim::policies::SlicedPolicy` drives in
//! virtual time — but replays arrivals on the wall clock with OS threads:
//! each worker thread owns a `RealEngine` (its own PJRT client + compiled
//! executables) with its input channel acting as the paper's worker local
//! queue (Fig. 7: receiving thread + processing thread). The offline
//! registry has no tokio, so this uses std threads + mpsc — same topology,
//! blocking handoff. Like the DES loop, it streams batch and completion
//! records to a [`MetricsSink`] while the run is in flight
//! ([`run_real_streaming`]).

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::batcher::fcfs_batches;
use crate::core::{Batch, Request};
use crate::engine::real::{RealEngine, RealSliceResult};
use crate::estimator::fit::{fit_bilinear, Obs};
use crate::estimator::memory::{MemoryEstimator, MemoryRule};
use crate::estimator::serving_time::{ServeEstimate, SliceTimeEstimator};
use crate::metrics::{BatchRecord, MetricsSink, NullSink, RunMetrics};
use crate::runtime::ModelRuntime;
use crate::scheduler::coordinator::SlicedCoordinator;
use crate::scheduler::spec::{BatchingSpec, SchedulerSpec};

/// Real-cluster parameters.
#[derive(Debug, Clone)]
pub struct RealClusterConfig {
    pub artifacts_dir: PathBuf,
    pub workers: usize,
    pub slice_len: u32,
    /// Maximal generation length (must fit the bucket budget:
    /// max_input + max_gen ≤ largest L bucket).
    pub max_gen_len: u32,
    /// Skip the per-bucket profiling pass and use a crude constant
    /// estimator (useful for tests).
    pub skip_profiling: bool,
    /// Pre-compile every bucket on every worker before the arrival clock
    /// starts (production behaviour: no request pays first-use compile
    /// latency). Off for tests — compilation then happens lazily.
    pub warmup: bool,
}

/// Profile the real engine over its buckets and fit a whole-slice bilinear
/// surface (the real-mode analogue of §4.2's profiling).
pub fn profile_real(rt: &mut ModelRuntime, slice_len: u32, reps: u32) -> Result<SliceTimeEstimator> {
    let buckets: Vec<_> = rt
        .manifest
        .buckets
        .iter()
        .filter(|b| b.s == slice_len)
        .cloned()
        .collect();
    anyhow::ensure!(!buckets.is_empty(), "no buckets for slice {slice_len}");
    let mut obs = Vec::new();
    for b in &buckets {
        let (n, l) = (b.n as usize, b.l as usize);
        // Synthetic full-length rows exercise the worst case of the bucket.
        let mut tokens = vec![0i32; n * l];
        for (i, t) in tokens.iter_mut().enumerate() {
            *t = 3 + (i % 200) as i32;
        }
        let lengths = vec![l as i32; n];
        let active = vec![1i32; n];
        let offs = vec![0i32; n];
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let r = rt.execute_slice(b, &tokens, &lengths, &active, &offs)?;
            best = best.min(r.wall);
        }
        obs.push(Obs {
            n: b.n as f64,
            x: b.l as f64,
            latency: best,
        });
    }
    let surface =
        fit_bilinear(&obs).ok_or_else(|| anyhow!("profile fit failed ({} obs)", obs.len()))?;
    Ok(SliceTimeEstimator { surface })
}

/// Bucket-capacity memory rule: the real engine can serve at most the
/// largest exported N bucket, and nothing beyond the largest L bucket.
pub fn bucket_memory_rule(rt: &ModelRuntime, slice_len: u32) -> MemoryEstimator {
    let max_l = rt
        .manifest
        .buckets
        .iter()
        .filter(|b| b.s == slice_len)
        .map(|b| b.l)
        .max()
        .unwrap_or(0);
    let max_n = rt.manifest.max_batch_for(16.min(max_l), slice_len).unwrap_or(1);
    // Table keyed on L = L_i + S: beyond the largest bucket -> infeasible.
    MemoryEstimator {
        rule: MemoryRule::Table(vec![(max_l + slice_len, 0), (0, max_n)]),
    }
}

enum WorkerMsg {
    /// Engine loaded (and warmed up when configured); ready to serve.
    Ready,
    Done {
        worker: usize,
        batch: Batch,
        result: RealSliceResult,
    },
    Failed {
        worker: usize,
        error: String,
    },
}

/// Run a request stream against the real cluster (no streaming sink).
pub fn run_real(
    incoming: Vec<Request>,
    spec: &SchedulerSpec,
    cfg: &RealClusterConfig,
) -> Result<RunMetrics> {
    run_real_streaming(incoming, spec, cfg, &mut NullSink)
}

/// Run a request stream (arrival-stamped, tokens attached) against the real
/// cluster under the given scheduler spec. Arrivals are replayed on the
/// wall clock; the function returns once every request completes. Batch
/// starts and completions stream to `sink` as they happen (a batch's
/// `actual_serve_time` is 0.0 at start time and patched into `RunMetrics`
/// at completion).
pub fn run_real_streaming(
    mut incoming: Vec<Request>,
    spec: &SchedulerSpec,
    cfg: &RealClusterConfig,
    sink: &mut dyn MetricsSink,
) -> Result<RunMetrics> {
    assert!(cfg.workers > 0);
    incoming.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    for r in &incoming {
        anyhow::ensure!(
            !r.tokens.is_empty(),
            "real mode requires requests with concrete tokens (Request::with_tokens)"
        );
    }

    // ---- estimator + memory rule (profiled once, §4.2) -----------------
    let mut prof_rt = ModelRuntime::new(&cfg.artifacts_dir)?;
    let est: Box<dyn ServeEstimate + Send> = if cfg.skip_profiling {
        struct Crude;
        impl ServeEstimate for Crude {
            fn serve_est(&self, n: u32, l_i: u32, s: u32) -> f64 {
                1e-4 * (n as f64) * (l_i as f64 + s as f64)
            }
        }
        Box::new(Crude)
    } else {
        Box::new(profile_real(&mut prof_rt, cfg.slice_len, 1)?)
    };
    let mem = bucket_memory_rule(&prof_rt, cfg.slice_len);
    drop(prof_rt);

    // ---- worker threads --------------------------------------------------
    let (done_tx, done_rx) = mpsc::channel::<WorkerMsg>();
    let mut batch_txs = Vec::with_capacity(cfg.workers);
    let mut handles = Vec::with_capacity(cfg.workers);
    for w in 0..cfg.workers {
        let (tx, rx) = mpsc::channel::<Batch>();
        batch_txs.push(tx);
        let done = done_tx.clone();
        let dir = cfg.artifacts_dir.clone();
        let (s, mg, warm) = (cfg.slice_len, cfg.max_gen_len, cfg.warmup);
        handles.push(thread::spawn(move || {
            // Optionally compile every bucket up front so no request pays
            // first-use compilation latency (production behaviour).
            let mut engine = match RealEngine::new(&dir, s, mg).and_then(|mut e| {
                if warm {
                    e.warmup()?;
                }
                Ok(e)
            }) {
                Ok(e) => e,
                Err(e) => {
                    let _ = done.send(WorkerMsg::Failed {
                        worker: w,
                        error: format!("init: {e}"),
                    });
                    return;
                }
            };
            let _ = done.send(WorkerMsg::Ready);
            // The input channel is the local queue; recv blocks when idle.
            while let Ok(batch) = rx.recv() {
                match engine.serve_slice(&batch) {
                    Ok(result) => {
                        let _ = done.send(WorkerMsg::Done {
                            worker: w,
                            batch,
                            result,
                        });
                    }
                    Err(e) => {
                        let _ = done.send(WorkerMsg::Failed {
                            worker: w,
                            error: format!("serve: {e}"),
                        });
                        return;
                    }
                }
            }
        }));
    }
    drop(done_tx);

    // Wait for every worker to load (and warm up) before the arrival clock
    // starts — requests must not be charged for deployment startup.
    let mut ready = 0usize;
    while ready < cfg.workers {
        match done_rx.recv() {
            Ok(WorkerMsg::Ready) => ready += 1,
            Ok(WorkerMsg::Failed { worker, error }) => {
                return Err(anyhow!("worker {worker} failed: {error}"));
            }
            Ok(_) => unreachable!("work before ready"),
            Err(_) => return Err(anyhow!("workers exited during startup")),
        }
    }

    // ---- coordinator loop -------------------------------------------------
    // The decision core (pool → DP batcher → offloader → ledger → interval)
    // is the shared `SlicedCoordinator`; this loop only owns the wall
    // clock, the channels, and the metrics.
    let start = Instant::now();
    let now = || start.elapsed().as_secs_f64();

    let mut coord = SlicedCoordinator::new(spec, cfg.workers);
    coord.reserve_pool(incoming.len());
    let mut metrics = RunMetrics::with_capacity(incoming.len());
    let mut worker_last_done = vec![0.0f64; cfg.workers];
    // Worker-locus FCFS state:
    let mut worker_req_q: Vec<Vec<Request>> = vec![Vec::new(); cfg.workers];
    let mut worker_busy = vec![false; cfg.workers];

    let mut next_tick = 0.0f64;
    let mut next_arrival_idx = 0usize;
    let mut outstanding = incoming.len();

    // Ledger charging happens in the coordinator (schedule_tick for DP
    // batches, `charge` for worker-locus ones); dispatch only logs + sends.
    let dispatch = |w: usize,
                    mut batch: Batch,
                    metrics: &mut RunMetrics,
                    sink: &mut dyn MetricsSink,
                    batch_txs: &[mpsc::Sender<Batch>],
                    t: f64|
     -> Result<()> {
        let li = batch.input_len();
        for r in &mut batch.requests {
            r.slices += 1;
            r.pad_tokens += (li - r.input_len) as u64;
        }
        let rec = BatchRecord {
            start: t,
            worker: w,
            size: batch.size() as u32,
            input_len: li,
            pad_tokens: batch.pad_tokens(),
            est_serve_time: batch.est_serve_time,
            actual_serve_time: 0.0, // patched at completion
            early_return: false,
        };
        sink.on_batch(t, &rec);
        metrics.batches.push(rec);
        batch_txs[w]
            .send(batch)
            .map_err(|_| anyhow!("worker {w} channel closed"))
    };

    // For worker-locus FCFS: start a batch on `w` if idle and queue nonempty.
    macro_rules! try_start_worker {
        ($w:expr) => {{
            let w = $w;
            if !worker_busy[w] && !worker_req_q[w].is_empty() {
                if let BatchingSpec::WorkerFcfs { batch_size } = spec.batching {
                    let take = (batch_size as usize).min(worker_req_q[w].len());
                    let reqs: Vec<Request> = worker_req_q[w].drain(..take).collect();
                    let mut bs = fcfs_batches(reqs, batch_size, est.as_ref(), spec.slice_len);
                    let b = bs.pop().unwrap();
                    worker_busy[w] = true;
                    coord.charge(w, b.est_serve_time);
                    dispatch(w, b, &mut metrics, &mut *sink, &batch_txs, now())?;
                }
            }
        }};
    }

    while outstanding > 0 {
        let t = now();

        // 1. Inject due arrivals.
        while next_arrival_idx < incoming.len() && incoming[next_arrival_idx].arrival <= t {
            let r = incoming[next_arrival_idx].clone();
            next_arrival_idx += 1;
            if let Some((w, r)) = coord.admit(r) {
                worker_req_q[w].push(r);
                try_start_worker!(w);
            }
        }

        // 2. Schedule tick (coordinator batching).
        if coord.has_ticks() && t >= next_tick {
            let drained = coord.schedule_tick(est.as_ref(), &mem);
            if drained > 0 {
                metrics.peak_pool = metrics.peak_pool.max(drained);
                sink.on_pool_depth(t, drained);
                let mut assign = coord.take_assignments();
                for (w, b) in assign.drain(..) {
                    dispatch(w, b, &mut metrics, &mut *sink, &batch_txs, t)?;
                }
                coord.recycle_assignments(assign);
            }
            next_tick = t
                + coord
                    .next_interval()
                    .expect("ticks only exist with an interval")
                    .max(0.005);
        }

        // 3. Wait for the next deadline or a completion.
        let mut deadline = f64::INFINITY;
        if next_arrival_idx < incoming.len() {
            deadline = deadline.min(incoming[next_arrival_idx].arrival);
        }
        if coord.has_ticks() {
            deadline = deadline.min(next_tick);
        }
        let timeout = if deadline.is_finite() {
            Duration::from_secs_f64((deadline - now()).max(0.0).min(0.25))
        } else {
            Duration::from_millis(250)
        };

        match done_rx.recv_timeout(timeout) {
            Ok(WorkerMsg::Ready) => unreachable!("ready after startup"),
            Ok(WorkerMsg::Done {
                worker,
                batch,
                result,
            }) => {
                let t = now();
                coord.batch_done(worker, batch.est_serve_time);
                worker_last_done[worker] = t;
                worker_busy[worker] = false;
                // Patch the batch record with measured duration.
                if let Some(rec) = metrics
                    .batches
                    .iter_mut()
                    .rev()
                    .find(|r| r.worker == worker && r.actual_serve_time == 0.0)
                {
                    rec.actual_serve_time = result.outcome.duration;
                    rec.early_return = result.outcome.early_return;
                }
                for ((mut r, o), toks) in batch
                    .requests
                    .into_iter()
                    .zip(result.outcome.per_request)
                    .zip(result.new_tokens)
                {
                    r.generated += o.new_tokens;
                    r.invalid_tokens += o.invalid_tokens as u64;
                    r.tokens.extend_from_slice(&toks);
                    r.input_len = r.tokens.len() as u32;
                    if o.finished {
                        r.finished_at = Some(t);
                        outstanding -= 1;
                        metrics.record_completion(&r, t);
                        if let Some(c) = metrics.completed.last() {
                            sink.on_completion(t, c);
                        }
                    } else if let Some((w, r)) = coord.admit(r) {
                        worker_req_q[w].push(r);
                        try_start_worker!(w);
                    }
                }
                try_start_worker!(worker);
            }
            Ok(WorkerMsg::Failed { worker, error }) => {
                return Err(anyhow!("worker {worker} failed: {error}"));
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err(anyhow!("all workers exited with {outstanding} outstanding"));
            }
        }
    }

    drop(batch_txs);
    for h in handles {
        let _ = h.join();
    }
    metrics.worker_completion = worker_last_done;
    sink.on_run_end(&metrics);
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::presets::{EngineKind, EnginePreset};
    use crate::scheduler::spec::IntervalSpec;
    use std::path::Path;

    fn art_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        art_dir().join("manifest.json").exists()
    }

    fn requests(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| {
                let len = 3 + (i * 7) % 40;
                let toks: Vec<i32> = (0..len).map(|k| 3 + ((i * 31 + k) % 400) as i32).collect();
                Request::with_tokens(i as u64, 0.02 * i as f64, toks)
            })
            .collect()
    }

    fn cfg(workers: usize) -> RealClusterConfig {
        RealClusterConfig {
            artifacts_dir: art_dir(),
            workers,
            slice_len: 16,
            max_gen_len: 64,
            skip_profiling: true,
            warmup: false,
        }
    }

    #[test]
    fn real_scls_end_to_end_completes() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let preset = EnginePreset::paper(EngineKind::Hf);
        let mut spec = SchedulerSpec::scls(&preset, 16);
        // Tight tick so the test is fast.
        spec.interval = IntervalSpec::Adaptive {
            lambda: 0.5,
            gamma: 0.05,
        };
        let mut tally = crate::metrics::Tally::default();
        let m = run_real_streaming(requests(6), &spec, &cfg(2), &mut tally).unwrap();
        assert_eq!(m.completed.len(), 6);
        assert!(m.completed.iter().all(|c| c.generated >= 1 && c.generated <= 64));
        assert!(!m.batches.is_empty());
        assert!(m.batches.iter().all(|b| b.actual_serve_time > 0.0));
        // The sink saw the same stream the metrics logged.
        assert_eq!(tally.completions as usize, m.completed.len());
        assert_eq!(tally.batches as usize, m.batches.len());
    }

    #[test]
    fn real_sls_end_to_end_completes() {
        if !have_artifacts() {
            return;
        }
        let preset = EnginePreset::paper(EngineKind::Hf);
        let mut spec = SchedulerSpec::sls(&preset, 64);
        spec.slice_len = 64; // iteration limit = max gen: but artifacts only
                             // have S=16, so SLS-on-real uses 4 chained slices
        spec.slice_len = 16;
        spec.batching = BatchingSpec::WorkerFcfs { batch_size: 4 };
        let m = run_real(requests(5), &spec, &cfg(2)).unwrap();
        assert_eq!(m.completed.len(), 5);
    }
}
