//! Streaming log-bucketed histogram with a guaranteed relative quantile
//! error — the O(1)-per-sample replacement for stored-sample percentiles.
//!
//! [`StreamingHist`] is a DDSketch-style sketch over non-negative values:
//! bucket `i` covers `(γ^(i-1), γ^i]` with `γ = (1+α)/(1-α)`, so any two
//! values in one bucket differ by at most a factor of `γ` and the bucket's
//! representative value `2·γ^i/(γ+1)` is within relative error **α** of
//! every member. Quantiles are answered by nearest-rank walk over the
//! bucket counts, giving the documented guarantee:
//!
//! > for any q, `|quantile(q) - exact_nearest_rank_quantile(q)| ≤
//! > α · exact_nearest_rank_quantile(q)` (up to float rounding at bucket
//! > boundaries), where the exact quantile is `sorted[rank-1]` with
//! > `rank = clamp(ceil(q·n), 1, n)`.
//!
//! Memory is O(number of occupied buckets) — for the default `α = 0.01`
//! that is ~70 buckets per decade of dynamic range, *independent of the
//! sample count*, which is what lets the DES keep latency/TTFT/TPOT
//! distributions on 100M-request traces without retaining per-sample
//! vectors. Sketches over the same `α` merge losslessly (bucket-wise count
//! addition), so per-shard sketches can be combined after a parallel run.
//!
//! Values `v ≤ 0` (and every non-finite value except `+∞`, which is
//! rejected too) land in a dedicated zero bucket reported as exactly
//! `0.0` — the domain here is durations, where negatives only arise from
//! clock clamping. An empty histogram answers `0.0` for every quantile,
//! matching the legacy stored-sample behavior on empty sample sets.

use crate::util::json::Json;

/// Default relative-error bound α (1%).
pub const DEFAULT_ALPHA: f64 = 0.01;

/// Mergeable log-bucketed quantile sketch (see module docs).
#[derive(Debug, Clone)]
pub struct StreamingHist {
    alpha: f64,
    gamma: f64,
    ln_gamma: f64,
    /// Samples with `v ≤ 0` (reported as exactly 0.0).
    zero_count: u64,
    /// Total samples folded in, including the zero bucket.
    count: u64,
    /// Exact extrema (tracked outside the buckets).
    min: f64,
    max: f64,
    /// Sum of all samples (exact mean numerator, accumulated in add order).
    sum: f64,
    /// Bucket index of `counts[0]`; buckets are a contiguous window.
    offset: i32,
    counts: Vec<u64>,
}

impl Default for StreamingHist {
    fn default() -> Self {
        StreamingHist::new()
    }
}

impl StreamingHist {
    /// Sketch with the default α = 1% relative-error bound.
    pub fn new() -> StreamingHist {
        StreamingHist::with_alpha(DEFAULT_ALPHA)
    }

    /// Sketch with a caller-chosen relative-error bound `alpha ∈ (0, 1)`.
    pub fn with_alpha(alpha: f64) -> StreamingHist {
        assert!(alpha > 0.0 && alpha < 1.0, "alpha must be in (0, 1)");
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        StreamingHist {
            alpha,
            gamma,
            ln_gamma: gamma.ln(),
            zero_count: 0,
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
            offset: 0,
            counts: Vec::new(),
        }
    }

    /// The documented relative quantile-error bound.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Bucket index for a strictly positive value.
    fn index_of(&self, v: f64) -> i32 {
        (v.ln() / self.ln_gamma).ceil() as i32
    }

    /// Representative value of bucket `i`: the point whose relative error
    /// to every member of `(γ^(i-1), γ^i]` is ≤ α.
    fn value_of(&self, i: i32) -> f64 {
        2.0 * (self.gamma.powi(i)) / (self.gamma + 1.0)
    }

    /// Fold one sample in. NaN is skipped; `v ≤ 0` lands in the zero
    /// bucket.
    pub fn add(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if v <= 0.0 {
            self.zero_count += 1;
            return;
        }
        let i = self.index_of(v);
        self.bump(i, 1);
    }

    fn bump(&mut self, i: i32, by: u64) {
        if self.counts.is_empty() {
            self.offset = i;
            self.counts.push(by);
            return;
        }
        if i < self.offset {
            let grow = (self.offset - i) as usize;
            self.counts.splice(0..0, vec![0; grow]);
            self.offset = i;
        } else if (i - self.offset) as usize >= self.counts.len() {
            let need = (i - self.offset) as usize + 1;
            self.counts.resize(need, 0);
        }
        self.counts[(i - self.offset) as usize] += by;
    }

    /// Merge another sketch of the *same* α in (lossless: bucket-wise count
    /// addition). Panics when the error bounds differ — merging sketches
    /// with different bucket bases has no exact meaning.
    pub fn merge(&mut self, other: &StreamingHist) {
        assert!(
            self.alpha == other.alpha,
            "cannot merge sketches with different alpha ({} vs {})",
            self.alpha,
            other.alpha
        );
        self.count += other.count;
        self.zero_count += other.zero_count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (k, &c) in other.counts.iter().enumerate() {
            if c > 0 {
                self.bump(other.offset + k as i32, c);
            }
        }
    }

    /// Total samples folded in.
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact minimum sample (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Exact mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Nearest-rank quantile with relative error ≤ α (see module docs).
    /// `q` is clamped to `[0, 1]`; an empty sketch answers exactly 0.0.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = if q.is_nan() { 1.0 } else { q.clamp(0.0, 1.0) };
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        if rank <= self.zero_count {
            return 0.0;
        }
        let mut seen = self.zero_count;
        for (k, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return self.value_of(self.offset + k as i32);
            }
        }
        // Unreachable when counts are consistent; fall back to the exact
        // max rather than panicking inside metrics code.
        self.max()
    }

    /// Percentile convenience: `p` in `[0, 100]`.
    pub fn percentile(&self, p: f64) -> f64 {
        self.quantile(p / 100.0)
    }

    /// Deterministic distribution summary for result JSON: exact count,
    /// min, max, and mean, plus sketched p50/p90/p99. An empty sketch
    /// serializes as all zeros (byte-stable on runs that never add).
    pub fn summary_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("count", self.count)
            .set("min", self.min())
            .set("max", self.max())
            .set("mean", self.mean())
            .set("p50", self.quantile(0.50))
            .set("p90", self.quantile(0.90))
            .set("p99", self.quantile(0.99));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exact nearest-rank quantile the sketch is measured against.
    fn exact_nearest_rank(sorted: &[f64], q: f64) -> f64 {
        let n = sorted.len() as u64;
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        sorted[(rank - 1) as usize]
    }

    #[test]
    fn empty_hist_is_all_zeros() {
        let h = StreamingHist::new();
        assert_eq!(h.count(), 0);
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.mean(), 0.0);
        let j = h.summary_json();
        assert_eq!(j.get("count").unwrap().as_i64(), Some(0));
        assert_eq!(j.get("p99").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn single_value_quantiles_hit_the_bound() {
        let mut h = StreamingHist::new();
        h.add(3.7);
        assert_eq!(h.count(), 1);
        for q in [0.0, 0.5, 0.99, 1.0] {
            let got = h.quantile(q);
            assert!(
                (got - 3.7).abs() <= 3.7 * h.alpha() + 1e-12,
                "q={q}: {got} vs 3.7"
            );
        }
        assert_eq!(h.min(), 3.7);
        assert_eq!(h.max(), 3.7);
        assert_eq!(h.mean(), 3.7);
    }

    #[test]
    fn quantiles_match_exact_within_alpha_on_wide_range() {
        // Values spanning 6 decades; deterministic LCG draws.
        let mut h = StreamingHist::new();
        let mut vals = Vec::new();
        let mut x = 0x2545F4914F6CDD1Du64;
        for _ in 0..5000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let u = (x >> 11) as f64 / (1u64 << 53) as f64;
            let v = 1e-3 * (13.8 * u).exp(); // ~1e-3 .. ~1e3
            vals.push(v);
            h.add(v);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999, 1.0] {
            let exact = exact_nearest_rank(&vals, q);
            let got = h.quantile(q);
            assert!(
                (got - exact).abs() <= exact * (h.alpha() + 1e-9) + 1e-12,
                "q={q}: sketch {got} vs exact {exact}"
            );
        }
    }

    #[test]
    fn zero_and_negative_values_land_in_the_zero_bucket() {
        let mut h = StreamingHist::new();
        h.add(0.0);
        h.add(-1.5);
        h.add(2.0);
        h.add(f64::NAN); // skipped
        assert_eq!(h.count(), 3);
        assert_eq!(h.quantile(0.01), 0.0, "rank 1 is a zero-bucket sample");
        let p99 = h.quantile(0.99);
        assert!((p99 - 2.0).abs() <= 2.0 * h.alpha() + 1e-12);
        assert_eq!(h.min(), -1.5, "extrema stay exact");
    }

    #[test]
    fn merge_is_lossless_bucket_addition() {
        let mut a = StreamingHist::new();
        let mut b = StreamingHist::new();
        let mut whole = StreamingHist::new();
        for i in 1..=100 {
            let v = i as f64 * 0.13;
            whole.add(v);
            if i % 2 == 0 {
                a.add(v);
            } else {
                b.add(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(
                a.quantile(q).to_bits(),
                whole.quantile(q).to_bits(),
                "merged sketch must answer bit-identically at q={q}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "different alpha")]
    fn merge_rejects_mismatched_alpha() {
        let mut a = StreamingHist::with_alpha(0.01);
        let b = StreamingHist::with_alpha(0.02);
        a.merge(&b);
    }

    #[test]
    fn determinism_add_order_independent_quantiles() {
        // Bucket counts are order-independent; only `sum` accumulates in
        // add order, and these values sum exactly either way.
        let mut fwd = StreamingHist::new();
        let mut rev = StreamingHist::new();
        let vals: Vec<f64> = (1..=64).map(|i| i as f64).collect();
        for &v in &vals {
            fwd.add(v);
        }
        for &v in vals.iter().rev() {
            rev.add(v);
        }
        for q in [0.25, 0.5, 0.99] {
            assert_eq!(fwd.quantile(q).to_bits(), rev.quantile(q).to_bits());
        }
    }
}
