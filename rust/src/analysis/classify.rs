//! Module classification for the lint rules.
//!
//! Paths are crate-relative (`src/sim/driver.rs`, `src/main.rs`) with `/`
//! separators. Classification is purely positional: the first directory
//! under `src/` names the module, top-level files classify as their stem
//! (`src/main.rs` → `main`). Two module sets drive the rules:
//!
//! * **Deterministic modules** — the simulator's measurement core. Every
//!   byte of their output must be a pure function of (trace, config,
//!   seed): no hash-order iteration, no wall clock, no ad-hoc float
//!   comparators. This is what the frozen differential suites
//!   (`props_policy_differential`, `props_dp_differential`, ...) rely on.
//! * **Real-time allowlist** — the modules whose *job* is wall-clock time
//!   (profiling, bench harness, log timestamps, the real PJRT driver).
//!   Only these may touch `Instant`/`SystemTime`.

/// Modules whose behaviour must be bit-deterministic (hash-order and
/// float-cmp rules apply).
pub const DETERMINISTIC_MODULES: [&str; 10] = [
    "core",
    "sim",
    "scheduler",
    "batcher",
    "estimator",
    "engine",
    "offloader",
    "predictor",
    "slo",
    "workload",
];

/// Modules (or `module/file` submodules) allowed to read the wall clock.
pub const WALL_CLOCK_ALLOWLIST: [&str; 6] = [
    "telemetry/profile",
    "bench",
    "util/logging",
    "runtime",
    "worker/real_driver",
    "main",
];

/// Top-level module of a crate-relative path (`src/sim/driver.rs` → `sim`,
/// `src/main.rs` → `main`). Non-`src/` paths have no module.
pub fn module_of(rel: &str) -> Option<&str> {
    let mut parts = rel.split('/');
    if parts.next() != Some("src") {
        return None;
    }
    let first = parts.next()?;
    match parts.next() {
        Some(_) => Some(first),
        None => Some(first.strip_suffix(".rs").unwrap_or(first)),
    }
}

/// `module/file-stem` of a nested path (`src/util/logging.rs` →
/// `util/logging`); `None` for top-level files.
pub fn submodule_of(rel: &str) -> Option<String> {
    let parts: Vec<&str> = rel.split('/').collect();
    if parts.first() != Some(&"src") || parts.len() < 3 {
        return None;
    }
    let stem = parts[2].strip_suffix(".rs").unwrap_or(parts[2]);
    Some(format!("{}/{stem}", parts[1]))
}

/// True when the deterministic-module rules (hash-order, float-cmp) apply.
pub fn is_deterministic(rel: &str) -> bool {
    module_of(rel).is_some_and(|m| DETERMINISTIC_MODULES.contains(&m))
}

/// True when the file may read the wall clock.
pub fn wall_clock_allowed(rel: &str) -> bool {
    if module_of(rel).is_some_and(|m| WALL_CLOCK_ALLOWLIST.contains(&m)) {
        return true;
    }
    submodule_of(rel).is_some_and(|s| WALL_CLOCK_ALLOWLIST.contains(&s.as_str()))
}

/// Module-path variant of [`wall_clock_allowed`], for the import-graph
/// rule: does a `crate::seg1[::seg2]` path land in the real-time
/// allowlist? Matches `seg1` as a whole module (`bench`, `runtime`) or
/// `seg1/seg2` as an allowlisted submodule (`telemetry/profile`).
pub fn wall_clock_module(seg1: &str, seg2: Option<&str>) -> bool {
    if WALL_CLOCK_ALLOWLIST.contains(&seg1) {
        return true;
    }
    match seg2 {
        Some(s2) => {
            let sub = format!("{seg1}/{s2}");
            WALL_CLOCK_ALLOWLIST.contains(&sub.as_str())
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification() {
        assert_eq!(module_of("src/sim/driver.rs"), Some("sim"));
        assert_eq!(module_of("src/main.rs"), Some("main"));
        assert_eq!(module_of("src/lib.rs"), Some("lib"));
        assert_eq!(module_of("tests/props_lint.rs"), None);
        assert_eq!(submodule_of("src/util/logging.rs"), Some("util/logging".into()));
        assert_eq!(submodule_of("src/main.rs"), None);
    }

    #[test]
    fn deterministic_set() {
        assert!(is_deterministic("src/sim/driver.rs"));
        assert!(is_deterministic("src/batcher/dp.rs"));
        assert!(is_deterministic("src/predictor/mod.rs"));
        assert!(!is_deterministic("src/telemetry/hist.rs"));
        assert!(!is_deterministic("src/util/stats.rs"));
        assert!(!is_deterministic("src/metrics/sink.rs"));
        assert!(!is_deterministic("src/main.rs"));
        assert!(!is_deterministic("src/analysis/rules.rs"));
    }

    #[test]
    fn wall_clock_module_paths() {
        assert!(wall_clock_module("bench", None));
        assert!(wall_clock_module("runtime", Some("client")));
        assert!(wall_clock_module("telemetry", Some("profile")));
        assert!(wall_clock_module("util", Some("logging")));
        assert!(wall_clock_module("worker", Some("real_driver")));
        assert!(!wall_clock_module("telemetry", None));
        assert!(!wall_clock_module("telemetry", Some("hist")));
        assert!(!wall_clock_module("util", Some("stats")));
        assert!(!wall_clock_module("sim", Some("driver")));
    }

    #[test]
    fn wall_clock_allowlist() {
        assert!(wall_clock_allowed("src/telemetry/profile.rs"));
        assert!(wall_clock_allowed("src/bench/harness.rs"));
        assert!(wall_clock_allowed("src/util/logging.rs"));
        assert!(wall_clock_allowed("src/runtime/client.rs"));
        assert!(wall_clock_allowed("src/worker/real_driver.rs"));
        assert!(wall_clock_allowed("src/main.rs"));
        assert!(!wall_clock_allowed("src/telemetry/timeline.rs"));
        assert!(!wall_clock_allowed("src/worker/mod.rs"));
        assert!(!wall_clock_allowed("src/sim/driver.rs"));
        assert!(!wall_clock_allowed("src/util/stats.rs"));
    }
}
