//! Slice-level scheduling **on top of continuous batching** — the paper's
//! §7 extension ("Integration with continuous batching"), which the
//! authors describe as work in progress on vLLM. This module implements
//! that design in the DES:
//!
//! * per-iteration joins/exits as in ILS (no padding, no invalid tokens);
//! * each *schedule* is capped at S generated tokens — a request that hits
//!   the cap exits the instance, releases its KV memory, and goes back to
//!   the coordinator pool to be **rescheduled to the instance with the
//!   most free memory** (the §7 long-request fix);
//! * admission is *precise* instead of conservative: a request is admitted
//!   iff the KV it can grow to within this slice — (cached + S)·Δ — fits
//!   alongside the slice-projected KV of everything already running. No
//!   fixed parallel-request cap (§7: "serve as many requests in parallel
//!   as possible without causing OOM errors").
//!
//! The rescheduling cost is faithful: re-admission pays a fresh prefill
//! over input + everything generated so far (the KV cache does not move
//! between instances), exactly like static-batching SCLS's reschedule.

use std::collections::VecDeque;

use crate::core::Request;

use super::latency::EngineLatency;

/// A request in the running set.
#[derive(Debug)]
struct SlicedRunning {
    req: Request,
    /// Cached length (input + all generated tokens).
    cached: u32,
    /// Tokens still to generate (EOS oracle or the max-gen cap).
    remaining: u32,
    /// Tokens generated within the current schedule (slice).
    gen_this_slice: u32,
}

/// What `finish_iteration` hands back to the coordinator.
#[derive(Debug, Default)]
pub struct SliceExits {
    /// Finished: EOS (oracle) or the maximal generation length.
    pub done: Vec<Request>,
    /// Hit the slice cap; must be rescheduled (pool → some instance).
    pub rescheduled: Vec<Request>,
}

/// One slice-capped continuous-batching LLM instance.
pub struct SlicedContinuousWorker {
    pub waiting: VecDeque<Request>,
    running: Vec<SlicedRunning>,
    pub engine: EngineLatency,
    /// Slice length S: per-schedule generated-token cap.
    pub slice_len: u32,
    /// KV budget in bytes and per-token KV size.
    pub kv_budget: u64,
    pub kv_delta: u64,
    pub max_gen_len: u32,
}

impl SlicedContinuousWorker {
    pub fn new(
        engine: EngineLatency,
        slice_len: u32,
        kv_budget: u64,
        kv_delta: u64,
        max_gen_len: u32,
    ) -> SlicedContinuousWorker {
        SlicedContinuousWorker {
            waiting: VecDeque::new(),
            running: Vec::new(),
            engine,
            slice_len: slice_len.max(1),
            kv_budget,
            kv_delta,
            max_gen_len,
        }
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Precise per-slice KV projection (§4.3 logic applied per schedule):
    /// every running request may grow to `cached + (S − generated_in_slice)`
    /// tokens before it exits this instance.
    pub fn kv_projected(&self) -> u64 {
        self.running
            .iter()
            .map(|r| {
                let growth = self
                    .slice_len
                    .saturating_sub(r.gen_this_slice)
                    .min(r.remaining);
                (r.cached as u64 + growth as u64) * self.kv_delta
            })
            .sum()
    }

    /// Begin the next iteration: admit whatever provably fits, then return
    /// the duration of one decode iteration over the running set (plus the
    /// prefill cost of requests admitted at this boundary; rescheduled
    /// requests re-prefill over input + generated). `None` = idle.
    pub fn begin_iteration(&mut self) -> Option<f64> {
        let mut admit_prefill = 0.0;
        while let Some(front) = self.waiting.front() {
            // Worst-case KV this candidate reaches within the slice.
            let cand_need =
                (front.input_len as u64 + self.slice_len as u64) * self.kv_delta;
            if self.kv_projected() + cand_need > self.kv_budget {
                break;
            }
            let mut req = self.waiting.pop_front().unwrap();
            req.slices += 1;
            admit_prefill += self.engine.prefill_mean(1, req.input_len);
            let remaining = self
                .max_gen_len
                .saturating_sub(req.generated)
                .min(req.remaining_to_eos())
                .max(1);
            self.running.push(SlicedRunning {
                cached: req.input_len,
                remaining,
                gen_this_slice: 0,
                req,
            });
        }
        if self.running.is_empty() {
            return None;
        }
        let n = self.running.len() as u32;
        let mean_l =
            (self.running.iter().map(|r| r.cached as u64).sum::<u64>() / n as u64) as u32;
        Some(admit_prefill + self.engine.decode_iter_mean(mean_l, n))
    }

    /// Crash-path surrender: hand back everything this instance holds —
    /// the running set (the caller re-prefills over input + generated, so
    /// at most the interrupted slice's tokens since the last boundary are
    /// recomputed) and the untouched waiting queue. The KV accounting
    /// resets with the running set.
    pub fn abandon(&mut self) -> (Vec<Request>, Vec<Request>) {
        (
            self.running.drain(..).map(|r| r.req).collect(),
            self.waiting.drain(..).collect(),
        )
    }

    /// Complete the iteration: every running request gains one token;
    /// finished requests exit as `done`, slice-capped ones as
    /// `rescheduled` (with `input_len` advanced so the next prefill covers
    /// the full context).
    pub fn finish_iteration(&mut self, now: f64) -> SliceExits {
        for r in &mut self.running {
            r.cached += 1;
            r.remaining -= 1;
            r.gen_this_slice += 1;
            // First-token stamp for TTFT accounting: this boundary delivers
            // the request's first generated token. (Rescheduled requests
            // resume with `generated > 0` and keep their original stamp.)
            if r.req.generated == 0 && r.req.first_token_at.is_none() {
                r.req.first_token_at = Some(now);
            }
            r.req.generated += 1;
        }
        let mut out = SliceExits::default();
        let mut k = 0;
        while k < self.running.len() {
            if self.running[k].remaining == 0 {
                let mut fin = self.running.swap_remove(k);
                fin.req.finished_at = Some(now);
                out.done.push(fin.req);
            } else if self.running[k].gen_this_slice >= self.slice_len {
                let mut res = self.running.swap_remove(k);
                // Next schedule re-prefills over everything so far (§7:
                // the KV cache is dropped on exit).
                res.req.input_len = res.cached;
                out.rescheduled.push(res.req);
            } else {
                k += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker(slice: u32) -> SlicedContinuousWorker {
        let mut lat = EngineLatency::ds(1);
        lat.jitter = 0.0;
        SlicedContinuousWorker::new(lat, slice, 48 << 30, 800 * 1024, 1024)
    }

    fn req(id: u64, input: u32, gen: u32) -> Request {
        Request::new(id, 0.0, input, gen)
    }

    #[test]
    fn no_fixed_parallel_cap() {
        // 64 requests all fit the precise-memory admission at once.
        let mut w = worker(128);
        for i in 0..64 {
            w.waiting.push_back(req(i, 100, 10));
        }
        w.begin_iteration().unwrap();
        assert_eq!(w.running_len(), 64);
    }

    #[test]
    fn precise_admission_blocks_on_projected_kv() {
        let mut w = worker(128);
        // Budget: exactly one request's worst case (100 + 128 tokens).
        w.kv_budget = 228 * w.kv_delta;
        w.waiting.push_back(req(0, 100, 500));
        w.waiting.push_back(req(1, 100, 500));
        w.begin_iteration().unwrap();
        assert_eq!(w.running_len(), 1);
        // ... but a short-remaining request projects less and still fits
        // after the first one's slice budget shrinks by generation.
        for t in 0..64 {
            w.finish_iteration(t as f64);
            w.begin_iteration().unwrap();
        }
        // First request generated 64, projects cached+64 more: still 228.
        assert_eq!(w.running_len(), 1, "projection must stay at worst case");
    }

    #[test]
    fn slice_cap_evicts_and_marks_reschedule() {
        let mut w = worker(8);
        w.waiting.push_back(req(0, 10, 20)); // needs 20 > slice 8
        w.begin_iteration().unwrap();
        let mut resched = None;
        for t in 0..8 {
            let out = w.finish_iteration(t as f64);
            assert!(out.done.is_empty());
            if !out.rescheduled.is_empty() {
                resched = Some(out.rescheduled.into_iter().next().unwrap());
                break;
            }
            w.begin_iteration().unwrap();
        }
        let r = resched.expect("slice cap never fired");
        assert_eq!(r.generated, 8);
        assert_eq!(r.input_len, 18, "next prefill covers input+generated");
        assert_eq!(r.slices, 1);
        assert_eq!(w.running_len(), 0, "KV released at slice exit");
    }

    #[test]
    fn finishes_inside_slice_without_reschedule() {
        let mut w = worker(128);
        w.waiting.push_back(req(0, 10, 3));
        w.begin_iteration().unwrap();
        w.finish_iteration(1.0);
        w.begin_iteration().unwrap();
        w.finish_iteration(2.0);
        w.begin_iteration().unwrap();
        let out = w.finish_iteration(3.0);
        assert_eq!(out.done.len(), 1);
        assert_eq!(out.done[0].generated, 3);
        assert!(out.rescheduled.is_empty());
    }

    #[test]
    fn ttft_stamped_at_first_decode_iteration_and_survives_reschedule() {
        let mut w = worker(4);
        w.waiting.push_back(req(0, 10, 6)); // needs 6 > slice 4: reschedules
        let mut now = 0.0;
        let mut carried = None;
        let done = loop {
            let d = w.begin_iteration().unwrap();
            now += d;
            let out = w.finish_iteration(now);
            if !out.done.is_empty() {
                break out.done;
            }
            for r in out.rescheduled {
                carried = r.first_token_at;
                w.waiting.push_back(r); // re-admit on the same instance
            }
        };
        let r = &done[0];
        let first = r.first_token_at.expect("first token stamped");
        assert_eq!(Some(first), carried, "reschedule keeps the stamp");
        assert!(
            first < r.finished_at.unwrap(),
            "TTFT must be strictly earlier than finish"
        );
    }

    #[test]
    fn abandon_surrenders_running_and_waiting_and_resets_kv() {
        let mut w = worker(8);
        w.kv_budget = (10 + 8) * w.kv_delta; // exactly one request fits
        w.waiting.push_back(req(0, 10, 20));
        w.waiting.push_back(req(1, 10, 20));
        w.begin_iteration().unwrap();
        w.finish_iteration(1.0);
        let (running, waiting) = w.abandon();
        assert_eq!(running.len(), 1);
        assert_eq!(running[0].id, 0);
        assert_eq!(running[0].generated, 1, "boundary state survives");
        assert_eq!(waiting.len(), 1);
        assert_eq!(waiting[0].id, 1);
        assert_eq!(w.running_len(), 0);
        assert_eq!(w.kv_projected(), 0);
        assert!(w.begin_iteration().is_none(), "instance is empty");
    }

    #[test]
    fn kv_projection_counts_slice_growth() {
        let mut w = worker(16);
        w.waiting.push_back(req(0, 100, 1000));
        w.begin_iteration().unwrap();
        assert_eq!(w.kv_projected(), (100 + 16) * w.kv_delta);
        w.finish_iteration(1.0);
        // cached grew to 101, slice growth left 15 → same worst case.
        assert_eq!(w.kv_projected(), (101 + 15) * w.kv_delta);
    }
}
