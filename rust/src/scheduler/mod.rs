//! Scheduling: the open [`policy::SchedulingPolicy`] API, the shared
//! sliced-family coordinator core, and the declarative `SchedulerSpec`
//! axes describing SCLS (§4), the SLS baseline (§5.1), and the SO/PM/AB/LB
//! ablation ladder (§5.4).
//!
//! A `SchedulerSpec` is pure configuration over four orthogonal axes; it
//! *constructs* a policy object (`spec.policy(&sim_cfg)`) that the single
//! generic DES loop (`sim::driver::run_policy`) interprets. ILS and
//! SCLS-CB (continuous batching, §5.1/§7) are policies of their own in
//! `sim::policies`. The real-mode driver (`worker::real_driver`) shares
//! the same coordinator brain ([`coordinator::SlicedCoordinator`]).

pub mod coordinator;
pub mod fleet;
pub mod interval;
pub mod policy;
pub mod pool;
pub mod spec;

pub use coordinator::SlicedCoordinator;
pub use fleet::{WorkerHealth, WorkerLedger};
pub use interval::IntervalController;
pub use policy::{
    build_policy, canonical_policy_name, parse_policy_name, SchedulingPolicy, SimCtx, WorkerLoss,
    BUILTIN_POLICIES,
};
pub use pool::RequestPool;
pub use spec::{BatchingSpec, IntervalSpec, OffloadSpec, SchedulerSpec};
