//! Memory-usage estimator (paper §4.3, Eq. 5–9 + Algorithm 2).
//!
//! KV-cache memory for a static batch:
//!
//!   M_kv(N, L_i, L_o) = (L_i + L_o) · N · Δ                  (5)
//!   M_ava = M_cap − M_model − M_engine                        (6)
//!
//! Feasibility under slice length S:
//!
//!   M_kv(N, L_i, S) ≤ ζ · M_ava                               (7)/(9)
//!
//! HF-like engines take the analytic rule with a fragmentation coefficient
//! ζ < 1 (paper: ζ = 0.9). DS-like engines have opaque memory management,
//! so the paper falls back to a profiled rule table (Algorithm 2) keyed on
//! total token count L = L_i + S; we implement both verbatim and a
//! generalization that accepts any profiled (threshold → max batch) table.

/// Engine-specific OOM-feasibility rule.
#[derive(Debug, Clone)]
pub enum MemoryRule {
    /// Analytic Eq. (9): M_kv ≤ ζ·M_ava.
    Analytic {
        /// Per-token KV bytes (Δ in Eq. 5).
        delta: u64,
        /// Available bytes for KV cache (Eq. 6).
        m_ava: u64,
        /// Fragmentation coefficient ζ ∈ (0, 1].
        zeta: f64,
    },
    /// Profiled rule table (Algorithm 2 generalized): thresholds on total
    /// token count L = L_i + S, descending, each with the max batch size.
    /// The last entry's threshold must be 0 (catch-all).
    Table(Vec<(u32, u32)>),
}

/// The estimator the batcher queries at every DP step.
#[derive(Debug, Clone)]
pub struct MemoryEstimator {
    pub rule: MemoryRule,
}

impl MemoryEstimator {
    /// Paper's HF configuration (Eq. 9 with ζ = 0.9).
    pub fn analytic(delta: u64, m_ava: u64, zeta: f64) -> MemoryEstimator {
        assert!(zeta > 0.0 && zeta <= 1.0);
        MemoryEstimator {
            rule: MemoryRule::Analytic { delta, m_ava, zeta },
        }
    }

    /// Paper's Algorithm 2 verbatim (DS under the experimental settings:
    /// L ≤ 2048): L > 1024 → N ≤ 12; L > 512 → N ≤ 22; else N ≤ 28.
    pub fn ds_rules() -> MemoryEstimator {
        MemoryEstimator {
            rule: MemoryRule::Table(vec![(1024, 12), (512, 22), (0, 28)]),
        }
    }

    /// Eq. (5): KV bytes for a batch (analytic rule only; 0 for tables).
    pub fn m_kv(&self, n: u32, l_i: u32, l_o: u32) -> u64 {
        match &self.rule {
            MemoryRule::Analytic { delta, .. } => {
                (l_i as u64 + l_o as u64) * n as u64 * delta
            }
            MemoryRule::Table(_) => 0,
        }
    }

    /// Would serving (N, L_i) for S iterations OOM? (Eq. 7/9 or Alg. 2.)
    pub fn would_oom(&self, n: u32, l_i: u32, s: u32) -> bool {
        match &self.rule {
            MemoryRule::Analytic { delta, m_ava, zeta } => {
                let need = (l_i as u64 + s as u64) * n as u64 * delta;
                (need as f64) > zeta * *m_ava as f64
            }
            MemoryRule::Table(table) => {
                let l = l_i + s;
                for &(thresh, max_n) in table {
                    if l > thresh {
                        return n > max_n;
                    }
                }
                // unreachable when the table ends with (0, _) and l >= 1,
                // but be conservative for l == 0:
                n > table.last().map(|&(_, m)| m).unwrap_or(0)
            }
        }
    }

    /// Eq. (8): largest feasible batch size for (L_i, S).
    pub fn max_batch(&self, l_i: u32, s: u32) -> u32 {
        match &self.rule {
            MemoryRule::Analytic { delta, m_ava, zeta } => {
                let per_req = (l_i as u64 + s as u64) * delta;
                if per_req == 0 {
                    return u32::MAX;
                }
                ((zeta * *m_ava as f64) / per_req as f64).floor() as u32
            }
            MemoryRule::Table(table) => {
                let l = l_i + s;
                for &(thresh, max_n) in table {
                    if l > thresh {
                        return max_n;
                    }
                }
                table.last().map(|&(_, m)| m).unwrap_or(0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MIB: u64 = 1 << 20;
    const GIB: u64 = 1 << 30;

    /// LLaMA2-13B-ish: Δ = 800 KiB/token, 48 GiB available for KV.
    fn hf() -> MemoryEstimator {
        MemoryEstimator::analytic(800 * 1024, 48 * GIB, 0.9)
    }

    #[test]
    fn eq5_m_kv() {
        let e = MemoryEstimator::analytic(100, 1_000_000, 1.0);
        assert_eq!(e.m_kv(4, 10, 6), 16 * 4 * 100);
    }

    #[test]
    fn analytic_feasibility_boundary() {
        // budget = 0.9 * 48 GiB; per request = (1024+128)*800KiB
        let e = hf();
        let n_max = e.max_batch(1024, 128);
        assert!(!e.would_oom(n_max, 1024, 128));
        assert!(e.would_oom(n_max + 1, 1024, 128));
    }

    #[test]
    fn eq8_shrinks_with_slice_length() {
        // The paper's key claim: larger S ⇒ smaller N_max; small S ⇒ big
        // batches. Setting S to the full max-generation limit degenerates
        // SCLS into SLS.
        let e = hf();
        assert!(e.max_batch(256, 64) > e.max_batch(256, 128));
        assert!(e.max_batch(256, 128) > e.max_batch(256, 1024));
    }

    #[test]
    fn ds_rule_table_verbatim() {
        // Algorithm 2: L>1024 -> N>12 OOMs; L>512 -> N>22; else N>28.
        let e = MemoryEstimator::ds_rules();
        // L = 1025
        assert!(!e.would_oom(12, 1000, 25));
        assert!(e.would_oom(13, 1000, 25));
        // L = 1024 falls to the >512 branch
        assert!(!e.would_oom(22, 896, 128));
        assert!(e.would_oom(23, 896, 128));
        // L = 512 falls to the else branch
        assert!(!e.would_oom(28, 384, 128));
        assert!(e.would_oom(29, 384, 128));
    }

    #[test]
    fn ds_max_batch_matches_would_oom() {
        let e = MemoryEstimator::ds_rules();
        for &(li, s) in &[(1000u32, 128u32), (500, 128), (100, 128), (10, 16)] {
            let m = e.max_batch(li, s);
            assert!(!e.would_oom(m, li, s));
            assert!(e.would_oom(m + 1, li, s));
        }
    }

    #[test]
    fn zeta_tightens_budget() {
        let loose = MemoryEstimator::analytic(MIB, GIB, 1.0);
        let tight = MemoryEstimator::analytic(MIB, GIB, 0.5);
        assert!(loose.max_batch(100, 28) >= tight.max_batch(100, 28));
    }

    #[test]
    fn single_request_always_fits_in_sane_config() {
        let e = hf();
        assert!(!e.would_oom(1, 1024, 1024));
    }
}
