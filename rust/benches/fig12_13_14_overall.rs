//! Figs. 12/13/14 — overall performance vs arrival rate: throughput,
//! average and tail response times, plus the dive-in counters (invalid
//! tokens, batch size, pad tokens, slice distribution, early-return ratio)
//! for the five (engine, scheduler) cells. Prints the reproduced sweep,
//! then times the heaviest cell.

use scls::bench::figures::{fig12_13_14, run_cell, FigureConfig};
use scls::bench::harness::{bench, report_header};
use scls::engine::presets::EngineKind;

fn main() {
    // Shapes stabilize well below the paper's full 10-minute traces.
    let fc = FigureConfig::quick(0.1);
    fig12_13_14(&fc, &[12.0, 16.0, 20.0, 24.0, 28.0]).print();

    println!("{}", report_header());
    let small = FigureConfig::quick(0.05);
    for (kind, which) in [
        (EngineKind::Hf, "SCLS"),
        (EngineKind::Ds, "SCLS"),
        (EngineKind::Ds, "SLS"),
    ] {
        let r = bench(
            &format!("cell {}-{which} @ rate 28 (30 s trace)", kind.name()),
            || run_cell(&small, kind, which, 28.0, small.slice_len),
        );
        println!("{}", r.report());
    }
}
