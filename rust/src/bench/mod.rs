//! Benchmark infrastructure: a criterion-style micro harness (criterion is
//! not in the offline registry) and the figure-regeneration drivers that
//! back `cargo bench`, `scls-repro figures`, and EXPERIMENTS.md.

pub mod figures;
pub mod harness;

pub use harness::{bench, bench_with_budget, BenchResult};
