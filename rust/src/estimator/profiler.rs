//! Grid profiler (paper §4.2): measures prefill latency and per-iteration
//! decode latency over a (batch size × length) grid, producing the
//! observations `fit` turns into Eq. (3)/(4) coefficients.
//!
//! The profiler is generic over a measurement source so it works against
//! both the DES latency model (figure regeneration, where the paper's
//! A100 numbers are simulated) and the real PJRT engine (where timings are
//! wall-clock). Measurement sources expose the two primitive latencies;
//! composite serving times are checked by `validate_serving_time`.

use super::fit::{fit_bilinear, fit_rmse, Obs};
use super::serving_time::{LinearLatency, ServingTimeEstimator};

/// Anything that can be timed for one prefill / one decode iteration.
pub trait LatencySource {
    /// Measured latency of a prefill over (batch n, input length l_i).
    fn measure_prefill(&mut self, n: u32, l_i: u32) -> f64;
    /// Measured latency of one decode iteration at cached length l, batch n.
    fn measure_decode_iter(&mut self, l: u32, n: u32) -> f64;
}

/// The profiling grid. Defaults mirror the paper's Fig. 8/9 axes.
#[derive(Debug, Clone)]
pub struct ProfileGrid {
    pub batch_sizes: Vec<u32>,
    pub input_lens: Vec<u32>,
    pub cached_lens: Vec<u32>,
}

impl Default for ProfileGrid {
    fn default() -> Self {
        ProfileGrid {
            batch_sizes: vec![1, 2, 4, 8, 12, 16],
            input_lens: vec![16, 32, 64, 128, 256, 512, 1024],
            cached_lens: vec![64, 128, 256, 512, 1024, 1536, 2048],
        }
    }
}

/// Raw profile data plus the fitted estimator.
#[derive(Debug, Clone)]
pub struct ProfileResult {
    pub prefill_obs: Vec<Obs>,
    pub decode_obs: Vec<Obs>,
    pub estimator: ServingTimeEstimator,
    /// Fig. 10a's metric: per-phase fit RMSE (seconds).
    pub prefill_rmse: f64,
    pub decode_rmse: f64,
}

/// Run the grid and fit both surfaces.
pub fn profile_and_fit(src: &mut dyn LatencySource, grid: &ProfileGrid) -> ProfileResult {
    let mut prefill_obs = Vec::new();
    for &n in &grid.batch_sizes {
        for &l in &grid.input_lens {
            prefill_obs.push(Obs {
                n: n as f64,
                x: l as f64,
                latency: src.measure_prefill(n, l),
            });
        }
    }
    let mut decode_obs = Vec::new();
    for &n in &grid.batch_sizes {
        for &l in &grid.cached_lens {
            decode_obs.push(Obs {
                n: n as f64,
                x: l as f64,
                latency: src.measure_decode_iter(l, n),
            });
        }
    }
    let prefill = fit_bilinear(&prefill_obs).unwrap_or(LinearLatency {
        c1: 0.0,
        c2: 0.0,
        c3: 0.0,
        c4: 0.0,
    });
    let decode = fit_bilinear(&decode_obs).unwrap_or(LinearLatency {
        c1: 0.0,
        c2: 0.0,
        c3: 0.0,
        c4: 0.0,
    });
    let estimator = ServingTimeEstimator { prefill, decode };
    ProfileResult {
        prefill_rmse: fit_rmse(&prefill, &prefill_obs),
        decode_rmse: fit_rmse(&decode, &decode_obs),
        prefill_obs,
        decode_obs,
        estimator,
    }
}

/// Fig. 10b's experiment: estimate whole serving times for `iters`
/// iterations across a holdout grid and report the RMSE against the
/// measured total (prefill + summed decode iterations).
pub fn validate_serving_time(
    src: &mut dyn LatencySource,
    est: &ServingTimeEstimator,
    batch_sizes: &[u32],
    input_lens: &[u32],
    iters: u32,
) -> f64 {
    let mut pred = Vec::new();
    let mut actual = Vec::new();
    for &n in batch_sizes {
        for &li in input_lens {
            pred.push(est.serve(n, li, iters));
            let mut total = src.measure_prefill(n, li);
            for l in (li + 1)..=(li + iters) {
                total += src.measure_decode_iter(l, n);
            }
            actual.push(total);
        }
    }
    crate::util::stats::rmse(&pred, &actual)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Synthetic source: bilinear truth + multiplicative noise.
    struct Synth {
        rng: Rng,
        noise: f64,
    }

    impl LatencySource for Synth {
        fn measure_prefill(&mut self, n: u32, l: u32) -> f64 {
            let t = 1.5e-4 * (n as f64) * (l as f64) + 2e-3 * n as f64 + 1e-4 * l as f64 + 0.01;
            t * (1.0 + self.noise * self.rng.normal())
        }

        fn measure_decode_iter(&mut self, l: u32, n: u32) -> f64 {
            let t = 5e-7 * (n as f64) * (l as f64) + 7e-4 * n as f64 + 2.5e-6 * l as f64 + 0.02;
            t * (1.0 + self.noise * self.rng.normal())
        }
    }

    #[test]
    fn profile_fit_recovers_noiseless() {
        let mut src = Synth {
            rng: Rng::new(1),
            noise: 0.0,
        };
        let res = profile_and_fit(&mut src, &ProfileGrid::default());
        assert!(res.prefill_rmse < 1e-9, "{}", res.prefill_rmse);
        assert!(res.decode_rmse < 1e-9, "{}", res.decode_rmse);
    }

    #[test]
    fn profile_fit_small_rmse_with_noise() {
        // Mirrors the paper's finding: per-iteration error negligible,
        // 128-iteration error small but accumulated.
        let mut src = Synth {
            rng: Rng::new(2),
            noise: 0.03,
        };
        let res = profile_and_fit(&mut src, &ProfileGrid::default());
        assert!(res.prefill_rmse < 0.05, "{}", res.prefill_rmse);
        assert!(res.decode_rmse < 0.01, "{}", res.decode_rmse);

        let mut holdout = Synth {
            rng: Rng::new(3),
            noise: 0.03,
        };
        let e128 = validate_serving_time(
            &mut holdout,
            &res.estimator,
            &[1, 4, 8],
            &[32, 128, 512],
            128,
        );
        // accumulated error stays bounded (paper: 0.4 s DS / 2.3 s HF)
        assert!(e128 < 1.0, "128-iter RMSE {e128}");
        assert!(e128 > res.decode_rmse, "accumulation should grow error");
    }
}
