//! Criterion-style micro-bench harness: warmup, calibrated iteration
//! counts, and robust summary statistics, driven by `cargo bench` targets
//! with `harness = false`.

use std::time::{Duration, Instant};

use crate::util::stats;

/// Summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  ({} samples)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            self.samples
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Column header matching `BenchResult::report`.
pub fn report_header() -> String {
    format!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "p50", "p95"
    )
}

/// Run `f` under the harness: ~0.5 s warmup, then sample batches sized so
/// each batch takes ≳1 ms, for ~2 s of measurement (tunable via
/// SCLS_BENCH_SECS). Prevents the optimizer from discarding work via
/// `std::hint::black_box` at the call sites.
///
/// The environment variable is read only here, at the public entry point;
/// everything below takes the budget as a parameter so tests never mutate
/// process-global state (mutating env vars races under the parallel test
/// runner).
pub fn bench<R>(name: &str, f: impl FnMut() -> R) -> BenchResult {
    let budget = std::env::var("SCLS_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(2.0);
    bench_with_budget(name, budget, f)
}

/// [`bench`] with an explicit measurement budget in seconds.
pub fn bench_with_budget<R>(name: &str, budget: f64, mut f: impl FnMut() -> R) -> BenchResult {
    // Warmup + batch-size calibration.
    let warm_until = Instant::now() + Duration::from_secs_f64(budget.min(0.5));
    let mut one = Duration::ZERO;
    let mut warm_iters = 0u64;
    while Instant::now() < warm_until || warm_iters == 0 {
        let t0 = Instant::now();
        std::hint::black_box(f());
        one += t0.elapsed();
        warm_iters += 1;
    }
    let per_call = one.as_secs_f64() / warm_iters as f64;
    let batch = ((1e-3 / per_call.max(1e-9)).ceil() as u64).clamp(1, 1_000_000);

    // Measurement.
    let mut samples_ns = Vec::new();
    let deadline = Instant::now() + Duration::from_secs_f64(budget);
    while Instant::now() < deadline || samples_ns.len() < 5 {
        let t0 = Instant::now();
        for _ in 0..batch {
            std::hint::black_box(f());
        }
        samples_ns.push(t0.elapsed().as_nanos() as f64 / batch as f64);
        if samples_ns.len() >= 200 {
            break;
        }
    }

    BenchResult {
        name: name.to_string(),
        samples: samples_ns.len(),
        mean_ns: stats::mean(&samples_ns),
        p50_ns: stats::percentile(&samples_ns, 50.0),
        p95_ns: stats::percentile(&samples_ns, 95.0),
        std_ns: stats::std_dev(&samples_ns),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        // Budget threaded as a parameter — no process-global env mutation,
        // which raced with other tests under the parallel runner.
        let r = bench_with_budget("noop-ish", 0.05, || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.samples >= 5);
        assert!(r.p95_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn tiny_budget_still_yields_minimum_samples() {
        let r = bench_with_budget("tiny", 0.001, || std::hint::black_box(1u64) + 1);
        assert!(r.samples >= 5);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
