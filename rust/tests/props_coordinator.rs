//! Property-based tests of the coordinator invariants (via `testprop`,
//! the in-tree property framework): the DP batcher (Alg. 1), the max-min
//! offloader (Eq. 11), the memory rules (Eq. 5–9 / Alg. 2), the
//! serving-time estimator (Eq. 1–4), and the interval controller (Eq. 12).

use scls::batcher::{dp_batch, fcfs_batches, DpBatcherConfig};
use scls::core::{Batch, Request};
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::estimator::serving_time::ServeEstimate;
use scls::offloader::{LoadLedger, MaxMinOffloader};
use scls::scheduler::IntervalController;
use scls::sim::driver::fitted_estimator;
use scls::testprop::{check, Gen};
use scls::{prop_assert, prop_assert_eq};

fn gen_requests(g: &mut Gen, max_n: usize) -> Vec<Request> {
    g.vec(1, max_n, |g| {
        Request::new(g.u64(), 0.0, g.u32(1, 1024), g.u32(1, 1024))
    })
    .into_iter()
    .enumerate()
    .map(|(i, mut r)| {
        r.id = i as u64; // unique ids
        r
    })
    .collect()
}

fn preset_for(g: &mut Gen) -> EnginePreset {
    if g.bool() {
        EnginePreset::paper(EngineKind::Hf)
    } else {
        EnginePreset::paper(EngineKind::Ds)
    }
}

#[test]
fn dp_batch_partitions_without_loss_or_duplication() {
    check("dp-partition", 200, |g| {
        let preset = preset_for(g);
        let est = fitted_estimator(&preset, 3);
        let mem = preset.memory_estimator();
        let slice_len = *g.pick(&[32u32, 64, 128, 256]);
        let reqs = gen_requests(g, 80);
        let n = reqs.len();
        let mut ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        ids.sort_unstable();

        let batches = dp_batch(
            reqs,
            &est,
            &mem,
            &DpBatcherConfig {
                slice_len,
                max_batch_size: if g.bool() { Some(g.u32(1, 16)) } else { None },
                pred_corrected: false,
            },
        );
        let mut got: Vec<u64> = batches.iter().flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        got.sort_unstable();
        prop_assert_eq!(got.len(), n, "request count changed");
        prop_assert_eq!(got, ids, "request set changed");
        Ok(())
    });
}

#[test]
fn dp_batches_are_contiguous_in_sorted_order_and_feasible() {
    check("dp-feasible", 200, |g| {
        let preset = preset_for(g);
        let est = fitted_estimator(&preset, 4);
        let mem = preset.memory_estimator();
        let slice_len = *g.pick(&[64u32, 128]);
        let cap = if g.bool() { Some(g.u32(1, 20)) } else { None };
        let reqs = gen_requests(g, 60);
        let batches = dp_batch(
            reqs,
            &est,
            &mem,
            &DpBatcherConfig {
                slice_len,
                max_batch_size: cap,
                pred_corrected: false,
            },
        );
        let mut last_max = 0u32;
        for b in &batches {
            let bmax = b.input_len();
            let bmin = b.requests.iter().map(|r| r.input_len).min().unwrap();
            // Contiguity in the sorted order: this batch's min ≥ previous
            // batch's max.
            prop_assert!(bmin >= last_max, "batches interleave: {bmin} < {last_max}");
            last_max = bmax;
            // Feasibility: memory rule and optional cap.
            let n = b.size() as u32;
            prop_assert!(
                n == 1 || !mem.would_oom(n, bmax, slice_len),
                "infeasible batch N={n} L={bmax} S={slice_len}"
            );
            if let Some(c) = cap {
                prop_assert!(n <= c.max(1), "cap {c} violated by N={n}");
            }
            // est_serve_time was filled with the batch's own estimate.
            let want = est.serve_est(n, bmax, slice_len);
            prop_assert!(
                (b.est_serve_time - want).abs() < 1e-9,
                "stale est_serve_time"
            );
        }
        Ok(())
    });
}

#[test]
fn dp_total_time_never_worse_than_fcfs_or_singletons() {
    check("dp-optimal-vs-baselines", 120, |g| {
        let preset = preset_for(g);
        let est = fitted_estimator(&preset, 5);
        let mem = preset.memory_estimator();
        let slice_len = 128;
        let reqs = gen_requests(g, 40);

        let total = |bs: &[Batch]| -> f64 { bs.iter().map(|b| b.est_serve_time).sum() };

        let dp = dp_batch(
            reqs.clone(),
            &est,
            &mem,
            &DpBatcherConfig {
                slice_len,
                max_batch_size: None,
                pred_corrected: false,
            },
        );
        // Baseline 1: every request its own batch.
        let singletons: f64 = reqs
            .iter()
            .map(|r| est.serve_est(1, r.input_len, slice_len))
            .sum();
        // Baseline 2: FCFS fixed-size batching (the SLS batcher).
        let fcfs = fcfs_batches(reqs.clone(), preset.sls_batch_size, &est, slice_len);

        prop_assert!(
            total(&dp) <= singletons + 1e-9,
            "DP {} worse than singletons {}",
            total(&dp),
            singletons
        );
        prop_assert!(
            total(&dp) <= total(&fcfs) + 1e-9,
            "DP {} worse than FCFS {}",
            total(&dp),
            total(&fcfs)
        );
        Ok(())
    });
}

#[test]
fn dp_respects_algorithm2_feasibility_exactly() {
    // The DS table rule: N ≤ 28 (L ≤ 512), N ≤ 22 (≤1024), N ≤ 12 (else).
    check("dp-alg2", 150, |g| {
        let preset = EnginePreset::paper(EngineKind::Ds);
        let est = fitted_estimator(&preset, 6);
        let mem = preset.memory_estimator();
        let s = 128;
        let reqs = gen_requests(g, 100);
        for b in dp_batch(
            reqs,
            &est,
            &mem,
            &DpBatcherConfig {
                slice_len: s,
                max_batch_size: None,
                pred_corrected: false,
            },
        ) {
            let l = b.input_len() + s;
            let n = b.size() as u32;
            let cap = if l > 1024 {
                12
            } else if l > 512 {
                22
            } else {
                28
            };
            prop_assert!(n <= cap.max(1), "Alg2: N={n} for L={l}");
        }
        Ok(())
    });
}

#[test]
fn maxmin_is_lpt_list_scheduling() {
    check("maxmin-lpt", 200, |g| {
        let workers = g.usize(1, 12);
        let batches: Vec<Batch> = g.vec(1, 40, |g| {
            let mut b = Batch::new(vec![Request::new(g.u64(), 0.0, 10, 10)]);
            b.est_serve_time = g.f64(0.01, 30.0);
            b
        });
        let times: Vec<f64> = batches.iter().map(|b| b.est_serve_time).collect();
        let total: f64 = times.iter().sum();
        let tmax = times.iter().cloned().fold(0.0, f64::max);

        let mut ledger = LoadLedger::new(workers);
        let out = MaxMinOffloader.offload(batches, &mut ledger);

        // Ledger bookkeeping: per-worker sums match the assignment.
        let mut sums = vec![0.0f64; workers];
        for (w, b) in &out {
            sums[*w] += b.est_serve_time;
        }
        for w in 0..workers {
            prop_assert!((sums[w] - ledger.load(w)).abs() < 1e-9, "ledger drift");
        }
        // LPT guarantee: makespan ≤ 4/3·OPT, with OPT ≥ max(total/m, t_max).
        let opt_lb = (total / workers as f64).max(tmax);
        prop_assert!(
            ledger.max() <= 4.0 / 3.0 * opt_lb + 1e-9,
            "makespan {} > 4/3 × {}",
            ledger.max(),
            opt_lb
        );
        // Longest-first order.
        for pair in out.windows(2) {
            prop_assert!(
                pair[0].1.est_serve_time >= pair[1].1.est_serve_time - 1e-12,
                "not longest-first"
            );
        }
        Ok(())
    });
}

#[test]
fn memory_rules_monotone_in_batch_and_length() {
    check("mem-monotone", 200, |g| {
        let preset = preset_for(g);
        let mem = preset.memory_estimator();
        let n = g.u32(1, 64);
        let l = g.u32(1, 1024);
        let s = *g.pick(&[32u32, 128, 512]);
        if mem.would_oom(n, l, s) {
            // Monotone: more requests / longer inputs can only stay OOM.
            prop_assert!(mem.would_oom(n + 1, l, s), "N-monotonicity");
            prop_assert!(mem.would_oom(n, l + 64, s), "L-monotonicity");
        }
        if !mem.would_oom(n, l, s) && n > 1 {
            prop_assert!(!mem.would_oom(n - 1, l, s), "N-anti-monotonicity");
        }
        Ok(())
    });
}

#[test]
fn estimator_closed_form_matches_iteration_sum() {
    check("estimator-closed-form", 150, |g| {
        let preset = preset_for(g);
        let est = fitted_estimator(&preset, 8);
        let n = g.u32(1, 32);
        let li = g.u32(1, 1024);
        let lo = g.u32(1, 512);
        let closed = est.decode(n, li, lo);
        let mut acc = 0.0;
        for l in (li + 1)..=(li + lo) {
            acc += est.decode_iter(l, n);
        }
        prop_assert!(
            (closed - acc).abs() <= 1e-6 * acc.max(1.0),
            "closed {closed} vs sum {acc} (n={n} li={li} lo={lo})"
        );
        // Monotonicity in every argument.
        prop_assert!(est.serve(n + 1, li, lo) >= est.serve(n, li, lo), "N mono");
        prop_assert!(est.serve(n, li + 1, lo) >= est.serve(n, li, lo), "L mono");
        prop_assert!(est.serve(n, li, lo + 1) >= est.serve(n, li, lo), "S mono");
        Ok(())
    });
}

#[test]
fn interval_controller_bounds() {
    check("interval-eq12", 200, |g| {
        let lambda = g.f64(0.1, 0.9);
        let gamma = g.f64(0.5, 6.0);
        let ctrl = IntervalController::Adaptive { lambda, gamma };
        let workers = g.usize(1, 8);
        let mut ledger = LoadLedger::new(workers);
        for w in 0..workers {
            ledger.add(w, g.f64(0.0, 100.0));
        }
        let t = ctrl.next_interval(&ledger);
        // Eq. (12): T = max(λ·min_w load, Γ).
        let want = (lambda * ledger.min()).max(gamma);
        prop_assert!((t - want).abs() < 1e-12, "T={t} want {want}");
        prop_assert!(t >= gamma, "below Γ");
        Ok(())
    });
}

#[test]
fn fcfs_batches_preserve_arrival_order_and_size() {
    check("fcfs-order", 150, |g| {
        let preset = preset_for(g);
        let est = fitted_estimator(&preset, 9);
        let bs = g.u32(1, 16);
        let reqs: Vec<Request> = (0..g.usize(1, 50))
            .map(|i| Request::new(i as u64, i as f64, g.u32(1, 1024), 10))
            .collect();
        let n = reqs.len();
        let batches = fcfs_batches(reqs, bs, &est, 128);
        // Sizes: all full except possibly the last.
        for (i, b) in batches.iter().enumerate() {
            if i + 1 < batches.len() {
                prop_assert_eq!(b.size(), bs as usize, "non-final batch not full");
            }
            prop_assert!(b.size() <= bs as usize, "over-size");
        }
        // Order: ids strictly increasing across the concatenation.
        let ids: Vec<u64> = batches.iter().flat_map(|b| b.requests.iter().map(|r| r.id)).collect();
        prop_assert_eq!(ids.len(), n, "loss");
        prop_assert!(ids.windows(2).all(|w| w[0] < w[1]), "order broken");
        Ok(())
    });
}
