//! Minimal offline-vendored subset of the `log` logging facade.
//!
//! API-compatible (for this repo's usage) with the real `log` crate:
//! `Level`, `LevelFilter`, `Metadata`, `Record`, the `Log` trait,
//! `set_logger` / `set_max_level`, and the `error!`..`trace!` macros.
//! The backend lives in `scls::util::logging`.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single log message.
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Verbosity ceiling installed via [`set_max_level`].
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        // Honour width/alignment flags (e.g. `{:5}`): delegate to str.
        fmt::Display::fmt(s, f)
    }
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of an in-flight log message.
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log message: metadata plus pre-formatted arguments.
#[derive(Clone, Copy, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Install the global logger. Idempotent failure: returns `Err` if one is
/// already set.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global verbosity ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Trace, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_orders_against_filter() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Trace > LevelFilter::Off);
    }

    #[test]
    fn max_level_roundtrips() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }

    #[test]
    fn display_pads() {
        assert_eq!(format!("{:5}", Level::Warn), "WARN ");
        assert_eq!(format!("{}", Level::Error), "ERROR");
    }
}
