//! Property suite for the elastic fault-tolerant fleet (join / drain /
//! crash with slice-boundary migration and stale-work reclaim).
//!
//! Two families of guarantees:
//!
//! 1. **Fault-free identity** — running any policy through the faulted
//!    loop with [`FaultPlan::none`] is *byte-identical* (on the
//!    `RunMetrics::to_json` event log) to the unfaulted loop, and — for
//!    the policies with frozen pre-trait drivers — to `sim::reference`.
//!    The elastic-fleet machinery must be invisible until a plan says
//!    otherwise.
//!
//! 2. **No lost work** — under randomized traces and randomized fault
//!    plans that keep worker 0 untouched (so at least one worker is
//!    always alive), every request completes exactly once with its full
//!    generation length: a crash loses at most the in-flight slice, never
//!    a request. Counter identities ride along: `reclaimed_requests ≥
//!    lost_slices`, and crash-free plans keep every crash counter at 0.

use std::collections::HashMap;

use scls::engine::presets::{EngineKind, EnginePreset};
use scls::sim::driver::{SimConfig, Simulation};
use scls::sim::reference::{run_ils_reference, run_scls_cb_reference, run_sliced_reference};
use scls::sim::FaultPlan;
use scls::scheduler::spec::SchedulerSpec;
use scls::testprop::{check, Gen};
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};
use scls::{prop_assert, prop_assert_eq};

fn trace(kind: WorkloadKind, rate: f64, duration: f64, seed: u64) -> Trace {
    Trace::generate(&TraceConfig {
        kind,
        rate,
        duration,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed,
    })
}

fn cfg(workers: usize, kind: EngineKind, seed: u64) -> SimConfig {
    SimConfig::new(workers, EnginePreset::paper(kind), 1024, seed)
}

/// The byte-level fingerprint two runs must share to count as identical.
fn fingerprint(m: &scls::metrics::RunMetrics) -> String {
    m.to_json().to_string_pretty()
}

/// Policies with fault hooks wired (the other registry names keep the
/// default no-op hooks and are covered by the identity tests only).
const ELASTIC: [&str; 3] = ["scls", "ils", "p-scls"];

/// Every completed request appears exactly once with its full generation
/// length (target capped by the run's max-gen limit).
fn assert_complete(
    m: &scls::metrics::RunMetrics,
    t: &Trace,
    label: &str,
) -> scls::testprop::PropResult {
    prop_assert_eq!(
        m.completed.len(),
        t.len(),
        "{label}: {} of {} requests completed",
        m.completed.len(),
        t.len()
    );
    let mut seen: HashMap<u64, u32> = HashMap::new();
    for c in &m.completed {
        prop_assert!(
            seen.insert(c.id, c.generated).is_none(),
            "{label}: request {} completed twice",
            c.id
        );
    }
    for r in &t.requests {
        let want = r.target_gen_len.min(1024).max(1);
        let got = seen.get(&r.id).copied();
        prop_assert_eq!(
            got,
            Some(want),
            "{label}: request {} generated {:?}, wanted {}",
            r.id,
            got,
            want
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// 1. Fault-free identity
// ---------------------------------------------------------------------------

#[test]
fn none_plan_is_byte_identical_for_every_policy() {
    let names = [
        "sls", "so", "pm", "ab", "lb", "scls", "ils", "scls-cb", "p-scls", "p-cb", "d-scls",
        "p-srpt", "sw-slo",
    ];
    for kind in [EngineKind::Hf, EngineKind::Ds] {
        let t = trace(WorkloadKind::CodeFuse, 5.0, 30.0, 601);
        let c = cfg(4, kind, 601);
        let sim = Simulation::new(c);
        for name in names {
            let plain = sim.run_named(&t, name, 128).unwrap();
            let faulted = sim.run_named_faulted(&t, name, 128, &FaultPlan::none()).unwrap();
            assert_eq!(
                fingerprint(&plain),
                fingerprint(&faulted),
                "{name} on {} diverged under the empty fault plan",
                kind.name()
            );
        }
    }
}

#[test]
fn none_plan_matches_frozen_references() {
    let preset = EnginePreset::paper(EngineKind::Ds);
    let t = trace(WorkloadKind::CodeFuse, 6.0, 35.0, 602);
    let c = cfg(4, EngineKind::Ds, 602);
    let sim = Simulation::new(c.clone());
    let none = FaultPlan::none();
    assert_eq!(
        fingerprint(&run_sliced_reference(&t, &SchedulerSpec::scls(&preset, 128), &c)),
        fingerprint(&sim.run_named_faulted(&t, "scls", 128, &none).unwrap()),
        "SCLS faulted-loop diverged from the pre-trait driver"
    );
    assert_eq!(
        fingerprint(&run_ils_reference(&t, &c)),
        fingerprint(&sim.run_named_faulted(&t, "ils", 128, &none).unwrap()),
        "ILS faulted-loop diverged from the pre-trait driver"
    );
    assert_eq!(
        fingerprint(&run_scls_cb_reference(&t, &c, 128)),
        fingerprint(&sim.run_named_faulted(&t, "scls-cb", 128, &none).unwrap()),
        "SCLS-CB faulted-loop diverged from the pre-trait driver"
    );
}

// ---------------------------------------------------------------------------
// 2. No lost work under randomized fault plans
// ---------------------------------------------------------------------------

/// A random plan over `workers` initial workers that never touches worker
/// 0, so the accepting fleet is never empty. Returns the plan and how many
/// crash events it contains.
fn random_plan(g: &mut Gen, workers: usize, horizon: f64) -> (FaultPlan, usize) {
    let mut plan = FaultPlan::none();
    let mut crashes = 0;
    for _ in 0..g.usize(1, 4) {
        let at = g.f64(1.0, horizon);
        match g.usize(0, 2) {
            0 => {
                plan = plan.crash(g.usize(1, workers - 1), at);
                crashes += 1;
            }
            1 => plan = plan.drain(g.usize(1, workers - 1), at),
            _ => plan = plan.join(g.u32(1, 2), at),
        }
    }
    (plan, crashes)
}

#[test]
fn randomized_faults_lose_no_requests() {
    check("fault-no-lost-work", 10, |g: &mut Gen| {
        let kind = if g.bool() { EngineKind::Hf } else { EngineKind::Ds };
        let workload = if g.bool() {
            WorkloadKind::CodeFuse
        } else {
            WorkloadKind::ShareGpt
        };
        let rate = *g.pick(&[3.0, 6.0]);
        let workers = *g.pick(&[2usize, 3, 5]);
        let seed = g.u64();
        let t = trace(workload, rate, 25.0, seed);
        let (plan, crashes) = random_plan(g, workers, 40.0);
        let sim = Simulation::new(cfg(workers, kind, seed));
        for name in ELASTIC {
            let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
            let label = format!("{name} ({workers}w seed {seed} plan {plan:?})");
            assert_complete(&m, &t, &label)?;
            prop_assert!(
                m.reclaimed_requests >= m.lost_slices,
                "{label}: reclaimed {} < lost slices {}",
                m.reclaimed_requests,
                m.lost_slices
            );
            prop_assert!(
                m.worker_crashes as usize <= crashes,
                "{label}: {} crashes recorded, {} scheduled",
                m.worker_crashes,
                crashes
            );
            if crashes == 0 {
                prop_assert_eq!(m.worker_crashes, 0, "{label}: phantom crash");
                prop_assert_eq!(m.lost_slices, 0, "{label}: lost slices without a crash");
            }
        }
        Ok(())
    });
}

#[test]
fn drain_only_plans_migrate_without_loss() {
    // Stagger a drain of every worker but 0, with replacements joining
    // later: graceful handoff must never count a crash or lose a slice.
    for workers in [2usize, 4] {
        let t = trace(WorkloadKind::CodeFuse, 5.0, 30.0, 611);
        let mut plan = FaultPlan::none();
        for w in 1..workers {
            plan = plan.drain(w, 5.0 * w as f64);
        }
        plan = plan.join(workers as u32 - 1, 20.0);
        let sim = Simulation::new(cfg(workers, EngineKind::Ds, 611));
        for name in ELASTIC {
            let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
            assert_eq!(m.completed.len(), t.len(), "{name} lost requests on drain");
            assert_eq!(m.worker_crashes, 0, "{name} counted a crash on drain");
            assert_eq!(m.lost_slices, 0, "{name} lost a slice on drain");
        }
    }
}

#[test]
fn rolling_restart_completes_everything() {
    let workers = 4usize;
    let t = trace(WorkloadKind::CodeFuse, 5.0, 30.0, 612);
    let plan = FaultPlan::rolling(workers, 6.0);
    let sim = Simulation::new(cfg(workers, EngineKind::Ds, 612));
    for name in ELASTIC {
        let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
        assert_eq!(m.completed.len(), t.len(), "{name} lost requests in rolling restart");
        assert_eq!(m.worker_crashes, 0, "{name}: rolling restarts are graceful");
        assert_eq!(m.lost_slices, 0, "{name}: rolling restarts lose nothing");
    }
}

#[test]
fn crash_reclaims_and_recompletes() {
    // A mid-run crash of a loaded worker: survivors resume at the last
    // slice boundary and everything still completes exactly once.
    let workers = 3usize;
    let t = trace(WorkloadKind::CodeFuse, 8.0, 25.0, 613);
    let plan = FaultPlan::none().crash(1, 6.0).crash(2, 12.0).join(2, 15.0);
    let sim = Simulation::new(cfg(workers, EngineKind::Ds, 613));
    for name in ELASTIC {
        let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
        assert_eq!(m.completed.len(), t.len(), "{name} lost requests on crash");
        assert_eq!(m.worker_crashes, 2, "{name} miscounted crashes");
        assert!(
            m.reclaimed_requests >= m.lost_slices,
            "{name}: reclaimed {} < lost slices {}",
            m.reclaimed_requests,
            m.lost_slices
        );
    }
}

#[test]
fn join_only_plans_touch_no_fault_counters() {
    let t = trace(WorkloadKind::CodeFuse, 6.0, 25.0, 614);
    let plan = FaultPlan::none().join(2, 8.0);
    let sim = Simulation::new(cfg(2, EngineKind::Ds, 614));
    for name in ELASTIC {
        let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
        assert_eq!(m.completed.len(), t.len(), "{name} lost requests on join");
        assert_eq!(m.worker_crashes, 0);
        assert_eq!(m.reclaimed_requests, 0);
        assert_eq!(m.lost_slices, 0);
        assert_eq!(m.migrations, 0);
    }
}
