//! Minimal offline-vendored subset of the `anyhow` error library.
//!
//! Covers what this repo uses: [`Error`] (message-chain based), [`Result`],
//! the `anyhow!` / `bail!` / `ensure!` macros, the [`Context`] extension
//! trait on `Result` and `Option`, and `?`-conversion from any
//! `std::error::Error`. Display follows anyhow's convention: `{}` prints
//! the outermost message, `{:#}` prints the full `a: b: c` chain.

use std::error::Error as StdError;
use std::fmt;

/// A dynamically typed error with a chain of context messages.
/// `chain[0]` is the outermost (most recently attached) message; the last
/// entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg(message: impl fmt::Display) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Attach an outer context message (used by the [`Context`] trait).
    pub fn wrap(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The `a: b: c` cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, so this blanket conversion stays coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to `Result` / `Option` failures.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.wrap("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            let r: std::result::Result<(), std::io::Error> = Err(io_err());
            r?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "step 3");

        let o: Option<u32> = None;
        let e = o.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        assert_eq!(format!("{}", f(50).unwrap_err()), "x too big: 50");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }

    #[test]
    fn bare_ensure_form() {
        fn f(ok: bool) -> Result<()> {
            ensure!(ok);
            Ok(())
        }
        assert!(f(true).is_ok());
        assert!(format!("{}", f(false).unwrap_err()).contains("condition failed"));
    }
}
