"""L1 — Pallas attention kernels for static-batching LLM serving (SCLS).

Two kernels cover the serving hot spot the paper's cost model (Eq. 1/2)
splits into:

* ``prefill_attention`` — full causal attention over a *left-padded* static
  batch (paper §2.4): each request row occupies positions ``[L - len, L)``;
  everything before that is pad and must never be attended to.
* ``decode_attention`` — one-token attention against a KV cache of capacity
  ``C``; only positions ``[start, cur)`` of the cache are valid keys.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the CUDA
threadblock sweep of the paper's engines becomes a Pallas grid over
``(batch, head)``; the combined causal+pad mask is built *inside* the kernel
from ``broadcasted_iota`` against a per-row scalar start index, so no
``(N, L, L)`` mask tensor is ever materialized in HBM. All contractions use
``preferred_element_type=float32`` so they land on the MXU.

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness is pinned against ``ref.py`` by pytest/hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # large negative for masked logits (f32-safe, avoids nan)


# ---------------------------------------------------------------------------
# Prefill kernel
# ---------------------------------------------------------------------------

def _prefill_kernel(start_ref, q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One (batch row, head) tile: masked softmax(q @ k^T) @ v.

    Block shapes: q/k/v/o are (L, dh) in VMEM; ``start_ref`` holds the row's
    first valid position (L - true_len) as an int32 scalar block of shape (1,).
    """
    q = q_ref[...].astype(jnp.float32) * scale   # (L, dh)
    k = k_ref[...].astype(jnp.float32)           # (L, dh)
    v = v_ref[...].astype(jnp.float32)           # (L, dh)
    start = start_ref[0]

    # (L, L) attention scores on the MXU.
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

    l = q.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)  # query position i
    cols = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)  # key position j
    # causal: j <= i ; pad: j >= start. Queries in the pad region produce
    # garbage rows, which downstream layers ignore (their residual output is
    # never read — only positions >= start contribute to logits).
    mask = (cols <= rows) & (cols >= start)
    s = jnp.where(mask, s, NEG_INF)

    # Numerically-stable softmax along keys.
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)

    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def prefill_attention(q, k, v, lengths, *, interpret: bool = True):
    """Masked causal attention over a left-padded static batch.

    Args:
      q, k, v: ``(N, H, L, dh)`` float32.
      lengths: ``(N,)`` int32 — true (unpadded) length of each row.
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      ``(N, H, L, dh)`` float32 attention output.
    """
    n, h, l, dh = q.shape
    assert k.shape == (n, h, l, dh) and v.shape == (n, h, l, dh)
    starts = (l - lengths).astype(jnp.int32)  # first valid position per row

    kernel = functools.partial(_prefill_kernel, scale=1.0 / (dh ** 0.5))
    grid = (n, h)
    blk = pl.BlockSpec((None, None, l, dh), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),  # start scalar per row
            blk, blk, blk,
        ],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((n, h, l, dh), jnp.float32),
        interpret=interpret,
    )(starts, q, k, v)


# ---------------------------------------------------------------------------
# Decode kernel
# ---------------------------------------------------------------------------

def _decode_kernel(bounds_ref, q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One (batch row, head) tile: single query against the KV cache.

    Block shapes: q/o are (1, dh); k/v are (C, dh); ``bounds_ref`` is an int32
    block of shape (2,) holding ``[start, cur)`` — the valid cache window.
    """
    q = q_ref[...].astype(jnp.float32) * scale   # (1, dh)
    k = k_ref[...].astype(jnp.float32)           # (C, dh)
    v = v_ref[...].astype(jnp.float32)           # (C, dh)
    start = bounds_ref[0]
    cur = bounds_ref[1]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (1, C)
    c = k.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)
    mask = (cols >= start) & (cols < cur)
    s = jnp.where(mask, s, NEG_INF)

    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def decode_attention(q, k_cache, v_cache, starts, cur, *, interpret: bool = True):
    """One-token attention against a static-capacity KV cache.

    Args:
      q: ``(N, H, 1, dh)`` float32 — current token's query.
      k_cache, v_cache: ``(N, H, C, dh)`` float32 — cache, positions
        ``[starts[i], cur)`` valid for row ``i``.
      starts: ``(N,)`` int32 — first valid cache position per row
        (left-padding offset).
      cur: int32 scalar — one past the last valid cache position (same for
        every row under static batching: all rows advance in lockstep).

    Returns:
      ``(N, H, 1, dh)`` float32.
    """
    n, h, one, dh = q.shape
    assert one == 1
    c = k_cache.shape[2]
    assert k_cache.shape == (n, h, c, dh) and v_cache.shape == (n, h, c, dh)

    cur_vec = jnp.full((n,), cur, dtype=jnp.int32)
    bounds = jnp.stack([starts.astype(jnp.int32), cur_vec], axis=1)  # (N, 2)

    kernel = functools.partial(_decode_kernel, scale=1.0 / (dh ** 0.5))
    grid = (n, h)
    qblk = pl.BlockSpec((None, None, 1, dh), lambda i, j: (i, j, 0, 0))
    cblk = pl.BlockSpec((None, None, c, dh), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, 2), lambda i, j: (i, 0)),
            qblk, cblk, cblk,
        ],
        out_specs=qblk,
        out_shape=jax.ShapeDtypeStruct((n, h, 1, dh), jnp.float32),
        interpret=interpret,
    )(bounds, q, k_cache, v_cache)
