//! Differential suite for the `SchedulingPolicy` refactor: every ported
//! policy, run through the single generic DES loop
//! (`sim::driver::run_policy`), must produce a **byte-identical**
//! `RunMetrics` event log (`RunMetrics::to_json`) to the frozen pre-trait
//! drivers retained in `sim::reference` — on fixed seeds, across engines,
//! rates, slice lengths, and worker counts. Same pattern as the DP
//! batcher's `props_dp_differential.rs`.
//!
//! Also property-checks the §7/ILS admission invariant under the generic
//! loop: no instance's (projected) KV footprint ever exceeds its budget —
//! the no-OOM guarantee the paper's precise admission is for.

use scls::engine::presets::{EngineKind, EnginePreset};
use scls::metrics::NullSink;
use scls::scheduler::spec::SchedulerSpec;
use scls::sim::driver::{run_ils, run_policy, run_scls_cb, run_sliced, SimConfig, Simulation};
use scls::sim::policies::{IlsPolicy, SclsCbPolicy};
use scls::sim::reference::{run_ils_reference, run_scls_cb_reference, run_sliced_reference};
use scls::testprop::{check, Gen};
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};
use scls::{prop_assert, prop_assert_eq};

fn trace(kind: WorkloadKind, rate: f64, duration: f64, seed: u64) -> Trace {
    Trace::generate(&TraceConfig {
        kind,
        rate,
        duration,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed,
    })
}

fn cfg(workers: usize, kind: EngineKind, seed: u64) -> SimConfig {
    SimConfig::new(workers, EnginePreset::paper(kind), 1024, seed)
}

/// The byte-level fingerprint two runs must share to count as identical.
fn fingerprint(m: &scls::metrics::RunMetrics) -> String {
    m.to_json().to_string_pretty()
}

#[test]
fn sliced_ladder_matches_reference_byte_for_byte() {
    for kind in [EngineKind::Hf, EngineKind::Ds] {
        let preset = EnginePreset::paper(kind);
        for (rate, duration, seed) in [(4.0, 30.0, 301), (8.0, 45.0, 302)] {
            let t = trace(WorkloadKind::CodeFuse, rate, duration, seed);
            let c = cfg(4, kind, seed);
            for spec in SchedulerSpec::ablation_ladder(&preset, 128, 1024) {
                let reference = run_sliced_reference(&t, &spec, &c);
                let ported = run_sliced(&t, &spec, &c);
                assert_eq!(
                    fingerprint(&reference),
                    fingerprint(&ported),
                    "{} diverged from the pre-trait driver ({} rate {rate} seed {seed})",
                    spec.name,
                    kind.name(),
                );
            }
        }
    }
}

#[test]
fn sliced_slice_length_sweep_matches_reference() {
    let preset = EnginePreset::paper(EngineKind::Ds);
    let t = trace(WorkloadKind::CodeFuse, 6.0, 40.0, 303);
    let c = cfg(4, EngineKind::Ds, 303);
    for s_len in [32u32, 64, 256, 512] {
        let spec = SchedulerSpec::scls(&preset, s_len);
        assert_eq!(
            fingerprint(&run_sliced_reference(&t, &spec, &c)),
            fingerprint(&run_sliced(&t, &spec, &c)),
            "SCLS S={s_len} diverged"
        );
    }
}

#[test]
fn sliced_worker_counts_match_reference() {
    let preset = EnginePreset::paper(EngineKind::Ds);
    let t = trace(WorkloadKind::ShareGpt, 6.0, 40.0, 304);
    for workers in [1usize, 2, 8] {
        let c = cfg(workers, EngineKind::Ds, 304);
        let spec = SchedulerSpec::scls(&preset, 128);
        assert_eq!(
            fingerprint(&run_sliced_reference(&t, &spec, &c)),
            fingerprint(&run_sliced(&t, &spec, &c)),
            "SCLS on {workers} workers diverged"
        );
    }
}

#[test]
fn ils_matches_reference_byte_for_byte() {
    for (rate, duration, seed) in [(4.0, 30.0, 311), (10.0, 60.0, 312)] {
        let t = trace(WorkloadKind::CodeFuse, rate, duration, seed);
        let c = cfg(4, EngineKind::Ds, seed);
        assert_eq!(
            fingerprint(&run_ils_reference(&t, &c)),
            fingerprint(&run_ils(&t, &c)),
            "ILS diverged (rate {rate} seed {seed})"
        );
    }
}

#[test]
fn scls_cb_matches_reference_byte_for_byte() {
    for (rate, duration, seed, s_len) in [(4.0, 30.0, 321, 128u32), (10.0, 60.0, 322, 64)] {
        let t = trace(WorkloadKind::CodeFuse, rate, duration, seed);
        let c = cfg(4, EngineKind::Ds, seed);
        assert_eq!(
            fingerprint(&run_scls_cb_reference(&t, &c, s_len)),
            fingerprint(&run_scls_cb(&t, &c, s_len)),
            "SCLS-CB diverged (rate {rate} seed {seed} S={s_len})"
        );
    }
}

#[test]
fn registry_construction_matches_reference() {
    // The name-based path (CLI / figure cells) is the same policy objects.
    let t = trace(WorkloadKind::CodeFuse, 5.0, 30.0, 331);
    let c = cfg(4, EngineKind::Ds, 331);
    let sim = Simulation::new(c.clone());
    let preset = EnginePreset::paper(EngineKind::Ds);
    for (name, reference) in [
        ("sls", run_sliced_reference(&t, &SchedulerSpec::sls(&preset, 1024), &c)),
        ("scls", run_sliced_reference(&t, &SchedulerSpec::scls(&preset, 128), &c)),
        ("ils", run_ils_reference(&t, &c)),
        ("scls-cb", run_scls_cb_reference(&t, &c, 128)),
    ] {
        let ported = sim.run_named(&t, name, 128).unwrap();
        assert_eq!(
            fingerprint(&reference),
            fingerprint(&ported),
            "registry-built '{name}' diverged"
        );
    }
}

#[test]
fn randomized_sliced_differential() {
    // Randomized workload/cluster shapes, smaller but broader than the
    // fixed-seed cases above.
    check("policy-differential", 12, |g: &mut Gen| {
        let kind = if g.bool() { EngineKind::Hf } else { EngineKind::Ds };
        let preset = EnginePreset::paper(kind);
        let workload = if g.bool() {
            WorkloadKind::CodeFuse
        } else {
            WorkloadKind::ShareGpt
        };
        let rate = *g.pick(&[2.0, 5.0, 9.0]);
        let workers = *g.pick(&[1usize, 3, 5]);
        let s_len = *g.pick(&[64u32, 128, 256]);
        let seed = g.u64();
        let t = trace(workload, rate, 25.0, seed);
        let c = cfg(workers, kind, seed);
        let specs = [
            SchedulerSpec::scls(&preset, s_len),
            SchedulerSpec::sls(&preset, 1024),
            SchedulerSpec::load_balancing(&preset, s_len),
        ];
        for spec in &specs {
            prop_assert!(
                fingerprint(&run_sliced_reference(&t, spec, &c))
                    == fingerprint(&run_sliced(&t, spec, &c)),
                "{} diverged ({} {workers}w rate {rate} S={s_len} seed {seed})",
                spec.name,
                kind.name()
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// No-OOM admission property (ILS conservative cap, SCLS-CB precise)
// ---------------------------------------------------------------------------

#[test]
fn ils_admission_never_exceeds_kv_budget() {
    check("ils-no-oom", 20, |g: &mut Gen| {
        let rate = *g.pick(&[2.0, 6.0, 12.0]);
        let workers = *g.pick(&[1usize, 2, 4]);
        let seed = g.u64();
        let t = trace(WorkloadKind::CodeFuse, rate, 25.0, seed);
        let c = cfg(workers, EngineKind::Ds, seed);
        let mut policy = IlsPolicy::new(&c);
        let m = run_policy(&t, &mut policy, c.workers, &mut NullSink);
        prop_assert_eq!(m.completed.len(), t.len(), "requests lost");
        prop_assert!(
            policy.max_kv_observed() <= policy.kv_budget(),
            "ILS admitted past the KV budget: {} > {}",
            policy.max_kv_observed(),
            policy.kv_budget()
        );
        prop_assert!(policy.max_kv_observed() > 0, "invariant never exercised");
        Ok(())
    });
}

#[test]
fn scls_cb_admission_never_exceeds_kv_budget() {
    check("scls-cb-no-oom", 20, |g: &mut Gen| {
        let rate = *g.pick(&[2.0, 6.0, 12.0]);
        let workers = *g.pick(&[1usize, 2, 4]);
        let s_len = *g.pick(&[32u32, 128, 512]);
        let seed = g.u64();
        let t = trace(WorkloadKind::CodeFuse, rate, 25.0, seed);
        let c = cfg(workers, EngineKind::Ds, seed);
        let mut policy = SclsCbPolicy::new(&c, s_len);
        let m = run_policy(&t, &mut policy, c.workers, &mut NullSink);
        prop_assert_eq!(m.completed.len(), t.len(), "requests lost");
        prop_assert!(
            policy.max_kv_observed() <= policy.kv_budget(),
            "SCLS-CB projected KV past the budget: {} > {} (S={})",
            policy.max_kv_observed(),
            policy.kv_budget(),
            s_len
        );
        prop_assert!(policy.max_kv_observed() > 0, "invariant never exercised");
        Ok(())
    });
}
