"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness signal: pytest + hypothesis assert the Pallas
kernels (interpret mode) match these to float tolerance across shapes, batch
sizes, head counts, and padding patterns.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def prefill_attention_ref(q, k, v, lengths):
    """Reference masked causal attention over a left-padded batch.

    Same contract as ``attention.prefill_attention``.
    """
    n, h, l, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("nhid,nhjd->nhij", q.astype(jnp.float32), k.astype(jnp.float32)) * scale

    rows = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    causal = cols <= rows                                    # (L, L)
    starts = (l - lengths).astype(jnp.int32)                 # (N,)
    pad_ok = cols[None, :, :] >= starts[:, None, None]       # (N, L, L)
    mask = causal[None, None, :, :] & pad_ok[:, None, :, :]  # (N, 1, L, L)

    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nhij,nhjd->nhid", p, v.astype(jnp.float32))


def decode_attention_ref(q, k_cache, v_cache, starts, cur):
    """Reference one-token attention against a KV cache window.

    Same contract as ``attention.decode_attention``.
    """
    n, h, _, dh = q.shape
    c = k_cache.shape[2]
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum(
        "nhid,nhjd->nhij", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale  # (N, H, 1, C)

    cols = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)      # (1, C)
    valid = (cols >= starts[:, None]) & (cols < cur)           # (N, C)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nhij,nhjd->nhid", p, v_cache.astype(jnp.float32))
