//! Property tests for the bulk estimator kernels: `serve_est_many` must be
//! bit-identical to the scalar `serve_est` loop for BOTH estimator
//! surfaces (the two-surface Eq. 1–4 estimator and the whole-slice
//! real-engine surface), across randomized coefficients — including
//! clamp-activating negative fits — and every chunk-remainder lane width.
//! The DP planner's differential contracts read candidates out of
//! bulk-filled buffers, so this bit-identity is what keeps them sound.
//!
//! Also covers the skip-certificate contract of `serve_affine_slack`:
//! wherever `serve_affine` applies, every float `serve_est(n')` must sit
//! at or above the certified affine lower bound anchored at any n ≤ n'.

use scls::estimator::serving_time::{
    LinearLatency, ServeEstimate, ServingTimeEstimator, SliceTimeEstimator,
};
use scls::prop_assert;
use scls::testprop::{check, Gen};

/// Random coefficients around fitted magnitudes, ~25% negative so the
/// `max(0, ·)` clamps activate (and `serve_affine` returns `None` for
/// some lengths).
fn gen_surface(g: &mut Gen, scales: [f64; 4]) -> LinearLatency {
    let mut coeff = |scale: f64| {
        let x = g.f64(0.0, scale);
        if g.u32(0, 3) == 0 {
            -x
        } else {
            x
        }
    };
    LinearLatency {
        c1: coeff(scales[0]),
        c2: coeff(scales[1]),
        c3: coeff(scales[2]),
        c4: coeff(scales[3]),
    }
}

fn gen_two_surface(g: &mut Gen) -> ServingTimeEstimator {
    ServingTimeEstimator {
        prefill: gen_surface(g, [5e-4, 2e-3, 5e-4, 0.05]),
        decode: gen_surface(g, [2e-6, 1e-3, 5e-6, 0.05]),
    }
}

fn assert_bulk_matches_scalar(
    est: &dyn ServeEstimate,
    g: &mut Gen,
    ctx: &str,
) -> Result<(), scls::testprop::PropFail> {
    let l_i = g.u32(0, 1400);
    let s = *g.pick(&[0u32, 1, 16, 128, 512, 1024]);
    let n0 = g.u32(1, 64);
    // Lengths 0..=33 sweep every remainder width of the 8-lane chunks
    // (0..LANES) plus multi-chunk bodies; an occasional long run checks
    // deep into the chunked loop.
    let len = if g.u32(0, 9) == 0 {
        g.usize(64, 400)
    } else {
        g.usize(0, 33)
    };
    let mut out = vec![f64::NAN; len];
    est.serve_est_many(n0..n0 + len as u32, l_i, s, &mut out);
    for (k, &got) in out.iter().enumerate() {
        let n = n0 + k as u32;
        let want = est.serve_est(n, l_i, s);
        prop_assert!(
            got.to_bits() == want.to_bits(),
            "{ctx}: serve_est_many[{k}] (n={n}, l_i={l_i}, s={s}) = {got:?} vs scalar {want:?}"
        );
    }
    Ok(())
}

#[test]
fn bulk_kernel_bit_identical_two_surface() {
    check("bulk-kernel-two-surface", 300, |g| {
        let est = gen_two_surface(g);
        assert_bulk_matches_scalar(&est, g, "two-surface")
    });
}

#[test]
fn bulk_kernel_bit_identical_slice_surface() {
    check("bulk-kernel-slice-surface", 300, |g| {
        let est = SliceTimeEstimator {
            surface: gen_surface(g, [2e-5, 3e-4, 1e-5, 0.02]),
        };
        assert_bulk_matches_scalar(&est, g, "slice-surface")
    });
}

#[test]
fn bulk_kernel_default_impl_is_the_scalar_loop() {
    // A custom estimator that does NOT override the kernel must get the
    // scalar loop verbatim (this is what keeps opaque estimators inside
    // the planner's differential contract).
    struct Weird;
    impl ServeEstimate for Weird {
        fn serve_est(&self, n: u32, l_i: u32, s: u32) -> f64 {
            // Deliberately rounding-hostile: not affine, not monotone.
            ((n as f64).sqrt() * 1e3 + (l_i as f64) / 7.0) * (s as f64 + 0.1).ln_1p()
        }
    }
    check("bulk-kernel-default", 200, |g| {
        assert_bulk_matches_scalar(&Weird, g, "default-impl")
    });
}

#[test]
fn affine_slack_certifies_random_surfaces() {
    // Wherever the affine fast path applies, the certified slack must
    // cover the float gap between serve_est and the affine anchor — the
    // exact inequality the corrected planner's skip certificates assume:
    //   serve_est(n') ≥ (a·n + b) + (n' − n)·a − σ   for 1 ≤ n ≤ n' ≤ N.
    check("bulk-kernel-slack", 300, |g| {
        let est = gen_two_surface(g);
        let l_i = g.u32(0, 1400);
        let s = *g.pick(&[1u32, 16, 128, 512, 1024]);
        let Some((a, b)) = est.serve_affine(l_i, s) else {
            return Ok(()); // clamp may fire: no certificate claimed
        };
        let n_max = g.u32(2, 4096);
        let slack = est.serve_affine_slack(l_i, s, n_max);
        prop_assert!(
            slack.is_finite() && slack >= 0.0,
            "slack {slack} not finite/non-negative"
        );
        for _ in 0..16 {
            let hi = g.u32(1, n_max);
            let lo = g.u32(1, hi);
            let v = est.serve_est(hi, l_i, s);
            let bound = (a * lo as f64 + b) + (hi - lo) as f64 * a - slack;
            prop_assert!(
                v >= bound,
                "serve_est({hi},{l_i},{s})={v} below certified bound {bound} \
                 (anchor n={lo}, n_max={n_max}, slack={slack})"
            );
        }
        Ok(())
    });
}
