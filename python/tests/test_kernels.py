"""L1 correctness: Pallas kernels (interpret mode) vs pure-jnp oracle.

This is the CORE correctness signal for the compute layer: every shape,
padding pattern, and cache window the runtime can produce must match the
reference to float tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

RTOL = 2e-5
ATOL = 2e-5


def rand(key, shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def split(key, k):
    return jax.random.split(key, k)


# ---------------------------------------------------------------------------
# Prefill kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,h,l,dh", [
    (1, 1, 4, 4),
    (1, 4, 16, 8),
    (2, 2, 32, 16),
    (4, 4, 64, 32),
    (8, 4, 128, 32),
])
def test_prefill_matches_ref_full_lengths(n, h, l, dh):
    ks = split(jax.random.PRNGKey(n * 1000 + l), 3)
    q, k, v = (rand(kk, (n, h, l, dh)) for kk in ks)
    lengths = jnp.full((n,), l, jnp.int32)
    out = A.prefill_attention(q, k, v, lengths)
    ref = R.prefill_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("lengths", [
    [1, 1, 1],
    [16, 1, 9],
    [5, 12, 16],
    [3, 3, 3],
])
def test_prefill_matches_ref_padded(lengths):
    n, h, l, dh = len(lengths), 2, 16, 8
    ks = split(jax.random.PRNGKey(7), 3)
    q, k, v = (rand(kk, (n, h, l, dh)) for kk in ks)
    lens = jnp.asarray(lengths, jnp.int32)
    out = A.prefill_attention(q, k, v, lens)
    ref = R.prefill_attention_ref(q, k, v, lens)
    # Compare only the valid (non-pad) query positions: pad-region outputs
    # are unread garbage by contract.
    for i, ln in enumerate(lengths):
        s = l - ln
        np.testing.assert_allclose(out[i, :, s:, :], ref[i, :, s:, :],
                                   rtol=RTOL, atol=ATOL)


def test_prefill_causality():
    """Perturbing a future token must not change earlier outputs."""
    n, h, l, dh = 1, 2, 12, 8
    ks = split(jax.random.PRNGKey(3), 3)
    q, k, v = (rand(kk, (n, h, l, dh)) for kk in ks)
    lengths = jnp.full((n,), l, jnp.int32)
    base = A.prefill_attention(q, k, v, lengths)
    k2 = k.at[:, :, -1, :].add(100.0)
    v2 = v.at[:, :, -1, :].add(100.0)
    pert = A.prefill_attention(q, k2, v2, lengths)
    np.testing.assert_allclose(base[:, :, :-1, :], pert[:, :, :-1, :],
                               rtol=RTOL, atol=ATOL)
    assert not np.allclose(base[:, :, -1, :], pert[:, :, -1, :])


def test_prefill_pad_isolation():
    """Perturbing the pad region must not change valid outputs."""
    n, h, l, dh = 2, 2, 16, 8
    ks = split(jax.random.PRNGKey(11), 3)
    q, k, v = (rand(kk, (n, h, l, dh)) for kk in ks)
    lengths = jnp.asarray([6, 10], jnp.int32)
    base = A.prefill_attention(q, k, v, lengths)
    # Scribble over pad keys/values of row 0 (positions [0, l-6)).
    k2 = k.at[0, :, : l - 6, :].set(999.0)
    v2 = v.at[0, :, : l - 6, :].set(-999.0)
    pert = A.prefill_attention(q, k2, v2, lengths)
    np.testing.assert_allclose(base[0, :, l - 6:, :], pert[0, :, l - 6:, :],
                               rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 5),
    h=st.sampled_from([1, 2, 4]),
    l=st.sampled_from([4, 8, 16, 24]),
    dh=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
    data=st.data(),
)
def test_prefill_hypothesis_sweep(n, h, l, dh, seed, data):
    lengths = data.draw(
        st.lists(st.integers(1, l), min_size=n, max_size=n), label="lengths"
    )
    ks = split(jax.random.PRNGKey(seed), 3)
    q, k, v = (rand(kk, (n, h, l, dh)) for kk in ks)
    lens = jnp.asarray(lengths, jnp.int32)
    out = A.prefill_attention(q, k, v, lens)
    ref = R.prefill_attention_ref(q, k, v, lens)
    for i, ln in enumerate(lengths):
        s = l - ln
        np.testing.assert_allclose(out[i, :, s:, :], ref[i, :, s:, :],
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Decode kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,h,c,dh,cur", [
    (1, 1, 8, 4, 4),
    (2, 4, 24, 8, 20),
    (4, 2, 48, 16, 48),
    (8, 4, 144, 32, 100),
])
def test_decode_matches_ref(n, h, c, dh, cur):
    ks = split(jax.random.PRNGKey(c + cur), 3)
    q = rand(ks[0], (n, h, 1, dh))
    kc = rand(ks[1], (n, h, c, dh))
    vc = rand(ks[2], (n, h, c, dh))
    starts = jnp.zeros((n,), jnp.int32)
    out = A.decode_attention(q, kc, vc, starts, cur)
    ref = R.decode_attention_ref(q, kc, vc, starts, cur)
    np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)


def test_decode_window_isolation():
    """K/V outside [start, cur) must not influence the output."""
    n, h, c, dh = 2, 2, 16, 8
    ks = split(jax.random.PRNGKey(5), 3)
    q = rand(ks[0], (n, h, 1, dh))
    kc = rand(ks[1], (n, h, c, dh))
    vc = rand(ks[2], (n, h, c, dh))
    starts = jnp.asarray([3, 6], jnp.int32)
    cur = 12
    base = A.decode_attention(q, kc, vc, starts, cur)
    kc2 = kc.at[:, :, :3, :].set(1e3).at[:, :, 12:, :].set(-1e3)
    vc2 = vc.at[:, :, :3, :].set(1e3).at[:, :, 12:, :].set(-1e3)
    pert = A.decode_attention(q, kc2, vc2, starts, cur)
    np.testing.assert_allclose(base, pert, rtol=RTOL, atol=ATOL)


def test_decode_single_valid_position():
    """cur = start + 1 ⇒ output is exactly the one valid V row."""
    n, h, c, dh = 1, 1, 8, 4
    ks = split(jax.random.PRNGKey(9), 3)
    q = rand(ks[0], (n, h, 1, dh))
    kc = rand(ks[1], (n, h, c, dh))
    vc = rand(ks[2], (n, h, c, dh))
    starts = jnp.asarray([4], jnp.int32)
    out = A.decode_attention(q, kc, vc, starts, 5)
    np.testing.assert_allclose(out[0, 0, 0], vc[0, 0, 4], rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    c=st.sampled_from([8, 16, 32]),
    dh=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
    data=st.data(),
)
def test_decode_hypothesis_sweep(n, h, c, dh, seed, data):
    cur = data.draw(st.integers(2, c), label="cur")
    starts = data.draw(
        st.lists(st.integers(0, cur - 1), min_size=n, max_size=n),
        label="starts",
    )
    ks = split(jax.random.PRNGKey(seed), 3)
    q = rand(ks[0], (n, h, 1, dh))
    kc = rand(ks[1], (n, h, c, dh))
    vc = rand(ks[2], (n, h, c, dh))
    out = A.decode_attention(q, kc, vc, jnp.asarray(starts, jnp.int32), cur)
    ref = R.decode_attention_ref(q, kc, vc, jnp.asarray(starts, jnp.int32), cur)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_decode_equals_prefill_last_row():
    """Decode of token t against a cache built by prefill must equal the
    prefill attention output at position t (consistency across kernels)."""
    n, h, l, dh = 2, 2, 10, 8
    ks = split(jax.random.PRNGKey(21), 3)
    q, k, v = (rand(kk, (n, h, l, dh)) for kk in ks)
    lengths = jnp.full((n,), l, jnp.int32)
    full = A.prefill_attention(q, k, v, lengths)
    # Last position via the decode kernel:
    out = A.decode_attention(q[:, :, -1:, :], k, v, jnp.zeros((n,), jnp.int32), l)
    np.testing.assert_allclose(out, full[:, :, -1:, :], rtol=RTOL, atol=ATOL)
