//! Descriptive statistics + histograms for the metrics layer and the bench
//! harness (offline registry has no statistics crates).

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for len < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile (p in [0, 100]). Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Root-mean-square error between predictions and observations.
pub fn rmse(pred: &[f64], obs: &[f64]) -> f64 {
    assert_eq!(pred.len(), obs.len());
    if pred.is_empty() {
        return 0.0;
    }
    let se: f64 = pred
        .iter()
        .zip(obs)
        .map(|(p, o)| (p - o) * (p - o))
        .sum();
    (se / pred.len() as f64).sqrt()
}

/// Fixed-bin histogram over [lo, hi); out-of-range values (±∞ included)
/// clamp to the edge bins. NaN samples are skipped entirely — a NaN would
/// otherwise clamp to NaN, cast to bin 0, and still bump `count`,
/// silently skewing `pdf`/`cdf`.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; n_bins],
            count: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        let n = self.bins.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64).floor();
        let idx = idx.clamp(0.0, (n - 1) as f64) as usize;
        self.bins[idx] += 1;
        self.count += 1;
    }

    /// Probability density per bin (integrates to ~1 over the range).
    pub fn pdf(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        let n = self.count.max(1) as f64;
        self.bins.iter().map(|&c| c as f64 / n / w).collect()
    }

    /// Cumulative distribution at each bin's right edge.
    pub fn cdf(&self) -> Vec<f64> {
        let n = self.count.max(1) as f64;
        let mut acc = 0u64;
        self.bins
            .iter()
            .map(|&c| {
                acc += c;
                acc as f64 / n
            })
            .collect()
    }

    pub fn bin_centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        (0..self.bins.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }
}

/// Streaming accumulator: count/mean/min/max/std without storing samples.
#[derive(Debug, Clone, Default)]
pub struct Accum {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Accum {
    pub fn new() -> Self {
        Accum {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Welford online update.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn rmse_zero_for_exact() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn histogram_pdf_cdf() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        let pdf = h.pdf();
        assert!(pdf.iter().all(|&p| (p - 0.1).abs() < 1e-12));
        let cdf = h.cdf();
        assert!((cdf[9] - 1.0).abs() < 1e-12);
        assert!((cdf[4] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_clamps() {
        let mut h = Histogram::new(0.0, 1.0, 2);
        h.add(-5.0);
        h.add(5.0);
        assert_eq!(h.bins, vec![1, 1]);
        h.add(f64::NEG_INFINITY);
        h.add(f64::INFINITY);
        assert_eq!(h.bins, vec![2, 2]);
        assert_eq!(h.count, 4);
    }

    #[test]
    fn histogram_skips_nan() {
        let mut h = Histogram::new(0.0, 10.0, 4);
        h.add(1.0);
        h.add(f64::NAN);
        h.add(9.0);
        // NaN neither lands in a bin nor inflates the count, so the
        // pdf/cdf normalization stays truthful.
        assert_eq!(h.count, 2);
        assert_eq!(h.bins, vec![1, 0, 0, 1]);
        let cdf = h.cdf();
        assert!((cdf[3] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn accum_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.5];
        let mut a = Accum::new();
        for &x in &xs {
            a.add(x);
        }
        assert!((a.mean() - mean(&xs)).abs() < 1e-12);
        assert!((a.std() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 5.5);
    }
}
