//! Surface-coverage checks: the `sink-surface` rule.
//!
//! Two drift-prone surfaces are re-derived from source on every lint run:
//!
//! * **MetricsSink coverage** — every method of the `MetricsSink` trait
//!   must be forwarded by `Fanout` (or a fan-out silently drops events
//!   for some sinks) and counted by `Tally` (or the cheap counters stop
//!   reflecting the full event stream). Adding a hook to the trait and
//!   forgetting an impl is exactly the bug class this catches: default
//!   trait methods make it compile clean.
//! * **Policy registry ↔ README** — every name in `BUILTIN_POLICIES`
//!   must appear backtick-quoted in the repo README, so the documented
//!   policy catalog can't silently fall behind the registry.
//!
//! Checks are text-level (token stream from [`super::lexer`]), with
//! doctored-input entry points so tests can exercise the failure paths
//! without mutating the real sources.

use std::fs;
use std::path::Path;

use super::lexer::{self, Tok, TokKind};
use super::rules::RULE_SINK_SURFACE;
use super::Finding;

/// `src/metrics/sink.rs` relative to the crate root.
pub const SINK_PATH: &str = "src/metrics/sink.rs";
/// `src/scheduler/policy.rs` relative to the crate root.
pub const POLICY_PATH: &str = "src/scheduler/policy.rs";

/// The impls that must cover the full trait surface.
const REQUIRED_IMPLS: [&str; 2] = ["Fanout", "Tally"];

/// Method names (with the `fn` keyword's line) declared by
/// `trait MetricsSink` in `src`. Empty when the trait isn't found.
pub fn trait_methods(src: &str) -> Vec<(String, u32)> {
    let (toks, _) = lexer::lex(src);
    let Some(open) = toks
        .windows(2)
        .position(|w| ident_is(&w[0], "trait") && ident_is(&w[1], "MetricsSink"))
    else {
        return Vec::new();
    };
    fns_in_block(&toks, open + 2)
}

/// Method names implemented by `impl MetricsSink for <type_name>` in
/// `src`. `None` when no such impl exists.
pub fn impl_methods(src: &str, type_name: &str) -> Option<Vec<String>> {
    let (toks, _) = lexer::lex(src);
    let at = toks.windows(4).position(|w| {
        ident_is(&w[0], "impl")
            && ident_is(&w[1], "MetricsSink")
            && ident_is(&w[2], "for")
            && ident_is(&w[3], type_name)
    })?;
    Some(fns_in_block(&toks, at + 4).into_iter().map(|(name, _)| name).collect())
}

fn ident_is(t: &Tok, text: &str) -> bool {
    t.kind == TokKind::Ident && t.text == text
}

/// `fn` item names at depth 1 of the first brace block at or after
/// `from`. Depth filtering keeps closures and nested items inside method
/// bodies from registering as surface methods.
fn fns_in_block(toks: &[Tok], from: usize) -> Vec<(String, u32)> {
    let mut j = from;
    while j < toks.len() && !(toks[j].kind == TokKind::Punct && toks[j].text == "{") {
        j += 1;
    }
    let mut depth = 0i32;
    let mut out = Vec::new();
    while j < toks.len() {
        let t = &toks[j];
        if t.kind == TokKind::Punct {
            if t.text == "{" {
                depth += 1;
            } else if t.text == "}" {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
        } else if depth == 1 && ident_is(t, "fn") {
            if let Some(name) = toks.get(j + 1).filter(|n| n.kind == TokKind::Ident) {
                out.push((name.text.clone(), t.line));
            }
        }
        j += 1;
    }
    out
}

/// String literals of the `BUILTIN_POLICIES` const initializer (with
/// their lines): the tokens between the declaration's `=` and its `;`.
pub fn policy_names(src: &str) -> Vec<(String, u32)> {
    let (toks, _) = lexer::lex(src);
    let Some(decl) = toks
        .windows(2)
        .position(|w| ident_is(&w[0], "const") && ident_is(&w[1], "BUILTIN_POLICIES"))
    else {
        return Vec::new();
    };
    let mut j = decl + 2;
    while j < toks.len() && !(toks[j].kind == TokKind::Punct && toks[j].text == "=") {
        j += 1;
    }
    let mut out = Vec::new();
    for t in &toks[j..] {
        if t.kind == TokKind::Punct && t.text == ";" {
            break;
        }
        if t.kind == TokKind::Str {
            out.push((t.text.clone(), t.line));
        }
    }
    out
}

/// Check MetricsSink coverage from the trait file's text.
pub fn check_sink_text(sink_src: &str) -> Vec<Finding> {
    let methods = trait_methods(sink_src);
    let mut findings = Vec::new();
    if methods.is_empty() {
        findings.push(missing(SINK_PATH, 0, "trait MetricsSink not found".to_string()));
        return findings;
    }
    for impl_ty in REQUIRED_IMPLS {
        let Some(have) = impl_methods(sink_src, impl_ty) else {
            findings.push(missing(
                SINK_PATH,
                0,
                format!("impl MetricsSink for {impl_ty} not found"),
            ));
            continue;
        };
        for (name, line) in &methods {
            if !have.iter().any(|h| h == name) {
                findings.push(missing(
                    SINK_PATH,
                    *line,
                    format!(
                        "MetricsSink::{name} is not implemented by {impl_ty} — the default \
                         no-op hides dropped events; forward (Fanout) or count (Tally) it"
                    ),
                ));
            }
        }
    }
    findings
}

/// Check registry ↔ README coverage from the two files' texts. Policy
/// names must appear backtick-quoted in the README, the form the policy
/// catalog uses.
pub fn check_readme_text(policy_src: &str, readme: &str) -> Vec<Finding> {
    let names = policy_names(policy_src);
    let mut findings = Vec::new();
    if names.is_empty() {
        findings.push(missing(POLICY_PATH, 0, "BUILTIN_POLICIES const not found".to_string()));
        return findings;
    }
    for (name, line) in names {
        if !readme.contains(&format!("`{name}`")) {
            findings.push(missing(
                POLICY_PATH,
                line,
                format!("registry policy `{name}` is not documented in README.md"),
            ));
        }
    }
    findings
}

fn missing(file: &str, line: u32, message: String) -> Finding {
    Finding {
        file: file.to_string(),
        line,
        rule: RULE_SINK_SURFACE,
        message,
    }
}

/// Run both surface checks against the tree at `root` (the crate root).
/// The README lives beside the crate directory (repo root), with a
/// fallback to `root/README.md` for self-contained fixture trees.
pub fn check(root: &Path) -> Vec<Finding> {
    let mut findings = Vec::new();
    match fs::read_to_string(root.join(SINK_PATH)) {
        Ok(src) => findings.extend(check_sink_text(&src)),
        Err(_) => findings.push(missing(SINK_PATH, 0, "file missing".to_string())),
    }
    let policy = match fs::read_to_string(root.join(POLICY_PATH)) {
        Ok(src) => src,
        Err(_) => {
            findings.push(missing(POLICY_PATH, 0, "file missing".to_string()));
            return findings;
        }
    };
    let readme = root
        .parent()
        .and_then(|p| fs::read_to_string(p.join("README.md")).ok())
        .or_else(|| fs::read_to_string(root.join("README.md")).ok());
    match readme {
        Some(text) => findings.extend(check_readme_text(&policy, &text)),
        None => findings.push(missing(POLICY_PATH, 0, "README.md not found".to_string())),
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    const SINK: &str = "pub trait MetricsSink {\n\
                        \x20   fn on_a(&mut self) {}\n\
                        \x20   fn on_b(&mut self, x: u64) {}\n\
                        }\n\
                        impl MetricsSink for Fanout<'_> {\n\
                        \x20   fn on_a(&mut self) { let f = |q: u32| { q }; f(1); }\n\
                        \x20   fn on_b(&mut self, x: u64) {}\n\
                        }\n\
                        impl MetricsSink for Tally {\n\
                        \x20   fn on_a(&mut self) {}\n\
                        }\n";

    #[test]
    fn trait_and_impl_parsing() {
        let m = trait_methods(SINK);
        assert_eq!(m, vec![("on_a".to_string(), 2), ("on_b".to_string(), 3)]);
        assert_eq!(
            impl_methods(SINK, "Fanout"),
            Some(vec!["on_a".to_string(), "on_b".to_string()])
        );
        assert_eq!(impl_methods(SINK, "Tally"), Some(vec!["on_a".to_string()]));
        assert_eq!(impl_methods(SINK, "NullSink"), None);
    }

    #[test]
    fn missing_method_is_a_finding_at_trait_line() {
        let f = check_sink_text(SINK);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, RULE_SINK_SURFACE);
        assert_eq!(f[0].line, 3, "anchored at the trait's fn line");
        assert!(f[0].message.contains("on_b"));
        assert!(f[0].message.contains("Tally"));
    }

    #[test]
    fn closure_body_fns_do_not_count_as_methods() {
        // `f` inside on_a's body is at depth > 1 and must not register.
        assert!(!impl_methods(SINK, "Fanout").unwrap().contains(&"f".to_string()));
    }

    const POLICY: &str =
        "pub const BUILTIN_POLICIES: [&str; 2] = [\"SLS\", \"SCLS-CB\"];\nfn x() {}\n";

    #[test]
    fn policy_names_from_const_initializer() {
        assert_eq!(
            policy_names(POLICY),
            vec![("SLS".to_string(), 1), ("SCLS-CB".to_string(), 1)]
        );
    }

    #[test]
    fn readme_check_wants_backtick_quoted_names() {
        assert!(check_readme_text(POLICY, "docs: `SLS` and `SCLS-CB` here").is_empty());
        let f = check_readme_text(POLICY, "only `SLS` is documented");
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("SCLS-CB"));
        // Bare (unquoted) mention is not enough.
        let f = check_readme_text(POLICY, "`SLS` and SCLS-CB");
        assert_eq!(f.len(), 1);
    }
}
