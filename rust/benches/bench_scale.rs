//! Scale benchmark: drain a paper-shaped 1M-request trace on a 64-worker
//! SCLS cluster and record the coordinator's real cost (`cargo bench
//! --bench bench_scale`).
//!
//! This is the perf trajectory anchor for the coordinator hot paths: the
//! DP batcher, the schedule-tick loop, and the DES driver all run at
//! production pool sizes here (the adaptive interval stretches under
//! backlog, so late ticks batch hundreds of thousands of pooled requests
//! at once). Writes `BENCH_scale.json` with events/sec, wall time, and the
//! peak pool size so future PRs can regress against it.
//!
//! Knobs (env): SCLS_SCALE_REQUESTS [1000000], SCLS_SCALE_WORKERS [64],
//! SCLS_SCALE_RATE [2000], SCLS_SCALE_SLICE [128].

use std::time::Instant;

use scls::engine::presets::{EngineKind, EnginePreset};
use scls::scheduler::spec::SchedulerSpec;
use scls::sim::driver::{run_sliced, SimConfig};
use scls::util::json::Json;
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(default)
}

fn main() {
    let n_requests = env_u64("SCLS_SCALE_REQUESTS", 1_000_000) as usize;
    let workers = env_u64("SCLS_SCALE_WORKERS", 64) as usize;
    let rate = env_u64("SCLS_SCALE_RATE", 2000) as f64;
    let slice_len = env_u64("SCLS_SCALE_SLICE", 128) as u32;

    // Paper-shaped workload: CodeFuse length distributions, Poisson
    // arrivals. Generate slightly long, then truncate to the exact count so
    // the headline number is stable across RNG drift.
    let gen_start = Instant::now();
    let mut trace = Trace::generate(&TraceConfig {
        kind: WorkloadKind::CodeFuse,
        rate,
        duration: (n_requests as f64 / rate) * 1.05 + 1.0,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed: 42,
    });
    trace.requests.truncate(n_requests);
    let n = trace.len();
    println!(
        "bench_scale: {} requests generated in {:.2} s ({} workers, rate {rate}, S={slice_len})",
        n,
        gen_start.elapsed().as_secs_f64(),
        workers
    );

    let preset = EnginePreset::paper(EngineKind::Ds);
    let spec = SchedulerSpec::scls(&preset, slice_len);
    let sim = SimConfig::new(workers, preset.clone(), 1024, 42);

    let t0 = Instant::now();
    let m = run_sliced(&trace, &spec, &sim);
    let wall = t0.elapsed().as_secs_f64();

    assert_eq!(m.completed.len(), n, "scale drain lost requests");
    let events_per_sec = m.events as f64 / wall.max(1e-9);
    let s = m.summarize();

    println!("drained {} requests in {wall:.3} s wall", s.completed);
    println!("events            {}", m.events);
    println!("events/sec        {events_per_sec:.0}");
    println!("peak pool size    {}", m.peak_pool);
    println!("batches served    {}", m.batches.len());
    println!("virtual makespan  {:.1} s", m.makespan);
    println!("virtual thpt      {:.2} req/s", s.throughput);

    let mut j = Json::obj();
    j.set("requests", n as u64)
        .set("workers", workers as u64)
        .set("rate", rate)
        .set("slice_len", slice_len)
        .set("wall_seconds", wall)
        .set("events", m.events)
        .set("events_per_sec", events_per_sec)
        .set("peak_pool", m.peak_pool as u64)
        .set("batches", m.batches.len() as u64)
        .set("virtual_makespan", m.makespan)
        .set("virtual_throughput", s.throughput)
        .set("completed", s.completed as u64);
    let path = "BENCH_scale.json";
    std::fs::write(path, j.to_string_pretty()).expect("write BENCH_scale.json");
    println!("wrote {path}");
}
