//! Least-squares fitting of the Eq. (3)/(4) latency surfaces from profiled
//! data (the paper uses `scipy.curve_fit`; we solve the normal equations).

use crate::util::linalg::least_squares;
use crate::util::stats::rmse;

use super::serving_time::LinearLatency;

/// One profiled observation of a bilinear surface: (N, x) → latency.
#[derive(Debug, Clone, Copy)]
pub struct Obs {
    pub n: f64,
    pub x: f64,
    pub latency: f64,
}

/// Fit `c1·N·x + c2·N + c3·x + c4` to the observations.
pub fn fit_bilinear(obs: &[Obs]) -> Option<LinearLatency> {
    if obs.len() < 4 {
        return None;
    }
    let rows: Vec<Vec<f64>> = obs
        .iter()
        .map(|o| vec![o.n * o.x, o.n, o.x, 1.0])
        .collect();
    let y: Vec<f64> = obs.iter().map(|o| o.latency).collect();
    least_squares(&rows, &y).map(|b| LinearLatency::from_slice(&b))
}

/// RMSE of a fitted surface against observations (Fig. 10's metric).
pub fn fit_rmse(fit: &LinearLatency, obs: &[Obs]) -> f64 {
    let pred: Vec<f64> = obs.iter().map(|o| fit.eval(o.n, o.x)).collect();
    let actual: Vec<f64> = obs.iter().map(|o| o.latency).collect();
    rmse(&pred, &actual)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn surface(n: f64, x: f64) -> f64 {
        1.5e-4 * n * x + 2e-3 * n + 1e-4 * x + 0.011
    }

    #[test]
    fn recovers_exact_surface() {
        let mut obs = Vec::new();
        for n in [1.0, 2.0, 4.0, 8.0, 16.0] {
            for x in [16.0, 64.0, 256.0, 1024.0] {
                obs.push(Obs {
                    n,
                    x,
                    latency: surface(n, x),
                });
            }
        }
        let fit = fit_bilinear(&obs).unwrap();
        assert!((fit.c1 - 1.5e-4).abs() < 1e-10);
        assert!((fit.c2 - 2e-3).abs() < 1e-8);
        assert!((fit.c3 - 1e-4).abs() < 1e-8);
        assert!((fit.c4 - 0.011).abs() < 1e-8);
        assert!(fit_rmse(&fit, &obs) < 1e-9);
    }

    #[test]
    fn robust_to_noise() {
        let mut rng = Rng::new(99);
        let mut obs = Vec::new();
        for n in [1.0, 2.0, 4.0, 8.0, 12.0, 16.0] {
            for x in [16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0] {
                let base = surface(n, x);
                obs.push(Obs {
                    n,
                    x,
                    latency: base * (1.0 + 0.02 * rng.normal()),
                });
            }
        }
        let fit = fit_bilinear(&obs).unwrap();
        // relative error of the dominant coefficient stays small
        assert!((fit.c1 - 1.5e-4).abs() / 1.5e-4 < 0.1, "c1 = {}", fit.c1);
        // and the fit predicts the clean surface well
        let clean: Vec<Obs> = obs
            .iter()
            .map(|o| Obs {
                n: o.n,
                x: o.x,
                latency: surface(o.n, o.x),
            })
            .collect();
        assert!(fit_rmse(&fit, &clean) < 0.05);
    }

    #[test]
    fn too_few_points_none() {
        let obs = vec![
            Obs {
                n: 1.0,
                x: 1.0,
                latency: 1.0,
            };
            3
        ];
        assert!(fit_bilinear(&obs).is_none());
    }

    #[test]
    fn degenerate_design_falls_back() {
        // All observations at the same (n, x): rank-1 design. The ridge
        // fallback must still return something finite.
        let obs = vec![
            Obs {
                n: 2.0,
                x: 8.0,
                latency: 1.0,
            };
            8
        ];
        if let Some(fit) = fit_bilinear(&obs) {
            assert!(fit.eval(2.0, 8.0).is_finite());
        }
    }
}
