//! Minimal JSON reader/writer (the offline registry has no serde).
//!
//! Covers what this repo needs: parsing `artifacts/manifest.json`, and
//! writing experiment-result files under `results/`. Full JSON value model,
//! recursive-descent parser, pretty printer. Numbers are f64 (with an i64
//! accessor for integral values), matching JSON semantics.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("set on non-object");
        }
        self
    }

    // ---- accessors -----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["model", "vocab"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // ---- parsing --------------------------------------------------------
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- writing ---------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}
impl From<Vec<f64>> for Json {
    fn from(x: Vec<f64>) -> Json {
        Json::Arr(x.into_iter().map(Json::Num).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let mut end = self.pos;
                        while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                            end += 1;
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\n"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\n")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"model": {"vocab": 512, "kv_bytes_per_token": 2048},
                    "buckets": [{"n":1,"l":16,"s":16,"file":"g.hlo.txt"}]}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.at(&["model", "vocab"]).unwrap().as_i64(), Some(512));
        let b = &v.get("buckets").unwrap().as_arr().unwrap()[0];
        assert_eq!(b.get("file").unwrap().as_str(), Some("g.hlo.txt"));
    }

    #[test]
    fn writer_builds_objects() {
        let mut o = Json::obj();
        o.set("x", 1i64).set("y", "z").set("b", true);
        let s = o.to_string_compact();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("x").unwrap().as_i64(), Some(1));
        assert_eq!(back.get("y").unwrap().as_str(), Some("z"));
        assert_eq!(back.get("b").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":3}}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }
}
