//! Observability subsystem: streaming histograms, per-worker time-series,
//! exportable run timelines, and opt-in hot-path profiling.
//!
//! Everything here layers on the existing [`crate::metrics::MetricsSink`]
//! seam — telemetry *observes* the run's event stream, it never feeds back
//! into scheduling or into the deterministic `RunMetrics` event log, so a
//! run with every sink attached stays byte-identical to a `NullSink` run
//! (property-enforced by `tests/props_telemetry.rs`).
//!
//! * [`hist::StreamingHist`] — mergeable log-bucketed quantile sketch with
//!   a documented ≤ α relative error bound and O(1)-per-sample memory;
//!   backs `SloTracker`'s TTFT/TPOT percentiles and the distribution
//!   summaries in `RunMetrics::to_json`.
//! * [`timeseries::TimeSeriesSink`] — fixed-interval per-worker gauges
//!   (KV occupancy, queue depth, busy fraction, served-token share)
//!   folded into load-imbalance indices
//!   ([`timeseries::ImbalanceReport`]: Jain's fairness, max/mean, CV).
//! * [`timeline::TimelineSink`] — batches as per-worker spans and
//!   fleet/shed/reclaim events as instants, exportable as JSONL
//!   (`simulate --trace-out`) and Chrome `trace_event` JSON
//!   (`--chrome-trace`, Perfetto-loadable).
//! * [`profile`] — opt-in wall-clock section timers on the coordinator
//!   hot paths (`dp_plan`, offload, drain-sort); zero overhead when
//!   disabled, surfaced by `simulate --profile` and `micro_hotpaths`.

pub mod hist;
pub mod profile;
pub mod timeline;
pub mod timeseries;

pub use hist::StreamingHist;
pub use profile::{HotPathProfile, Stopwatch};
pub use timeline::TimelineSink;
pub use timeseries::{ImbalanceReport, TimeSeriesSink};
