//! Figs. 15/16 — the SO/PM/AB/LB ablation ladder at rate 20: each SCLS
//! design feature added one at a time on top of SLS. Prints the reproduced
//! ladder for both engines, then times one rung per axis change.

use scls::bench::figures::{fig15_16, run_cell, FigureConfig};
use scls::bench::harness::{bench, report_header};
use scls::engine::presets::EngineKind;

fn main() {
    let fc = FigureConfig::quick(0.1);
    fig15_16(&fc, EngineKind::Ds).print();
    fig15_16(&fc, EngineKind::Hf).print();

    println!("{}", report_header());
    let small = FigureConfig::quick(0.05);
    for which in ["SO", "PM", "AB", "LB", "SCLS"] {
        let r = bench(&format!("ablation rung DS-{which} (30 s trace)"), || {
            run_cell(&small, EngineKind::Ds, which, 20.0, small.slice_len)
        });
        println!("{}", r.report());
    }
}
