"""L2 correctness: slice generation (KV-cached, Pallas) vs the stateless
recompute oracle; static-batching semantics (padding, EOS, early return,
invalid tokens); shape contracts of the AOT entrypoint."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

CFG = M.ModelConfig()
PARAMS = M.init_params(CFG)


def make_batch(lengths, l, seed=0):
    """Left-padded token batch with the given true lengths."""
    rng = np.random.default_rng(seed)
    n = len(lengths)
    toks = np.zeros((n, l), np.int32)
    for i, ln in enumerate(lengths):
        toks[i, l - ln:] = rng.integers(3, CFG.vocab, ln)
    return toks


def run_cached(toks, lens, active, s, gen_offset=None):
    off = None if gen_offset is None else jnp.asarray(gen_offset, jnp.int32)
    gen, iters = M.prefill_and_generate(
        PARAMS, jnp.asarray(toks), jnp.asarray(lens, jnp.int32),
        jnp.asarray(active, jnp.int32), off, cfg=CFG, slice_len=s,
    )
    return np.asarray(gen), int(iters)


@pytest.mark.parametrize("lengths,l,s", [
    ([8], 8, 4),
    ([16, 5, 9], 16, 8),
    ([1, 2, 3, 4], 8, 8),
    ([32, 17], 32, 16),
])
def test_cached_matches_stateless_ref(lengths, l, s):
    toks = make_batch(lengths, l, seed=l + s)
    lens = np.asarray(lengths, np.int32)
    active = np.ones(len(lengths), np.int32)
    gen, iters = run_cached(toks, lens, active, s)
    ref, ref_iters = M.generate_ref(PARAMS, toks, lens, active, cfg=CFG, slice_len=s)
    assert iters == ref_iters
    np.testing.assert_array_equal(gen, ref)


def test_inactive_rows_do_not_perturb_active():
    """Filler rows (bucket padding) must not change active rows' outputs."""
    lengths = [12, 7]
    l, s = 16, 8
    toks = make_batch(lengths, l, seed=1)
    gen_a, _ = run_cached(toks, lengths, [1, 1], s)

    toks4 = np.zeros((4, l), np.int32)
    toks4[:2] = toks
    toks4[2:, -1] = 3  # filler rows: minimal length-1 content
    gen_b, _ = run_cached(toks4, [12, 7, 1, 1], [1, 1, 0, 0], s)
    np.testing.assert_array_equal(gen_a, gen_b[:2])


def test_generation_deterministic():
    toks = make_batch([10, 4], 16, seed=2)
    g1, i1 = run_cached(toks, [10, 4], [1, 1], 8)
    g2, i2 = run_cached(toks, [10, 4], [1, 1], 8)
    np.testing.assert_array_equal(g1, g2)
    assert i1 == i2


def test_slice_iteration_limit():
    """gen must have exactly slice_len columns and iters <= slice_len."""
    toks = make_batch([16], 16, seed=3)
    for s in (1, 2, 4, 8):
        gen, iters = run_cached(toks, [16], [1], s)
        assert gen.shape == (1, s)
        assert 1 <= iters <= s


def test_early_return_when_all_eos():
    """A batch whose rows all emit EOS quickly must early-return (iters < S)
    and pad the remaining columns — the paper's early-return case (§4.2)."""
    # eos_alpha guarantees EOS wins once the boost passes the max logit, so a
    # long slice must terminate early for ANY input.
    toks = make_batch([4], 16, seed=4)
    cfg_boost = M.ModelConfig(eos_alpha=8.0)  # aggressive: EOS by step ~2
    params = M.init_params(cfg_boost)
    gen, iters = M.prefill_and_generate(
        params, jnp.asarray(toks), jnp.asarray([4], jnp.int32),
        jnp.asarray([1], jnp.int32), None, cfg=cfg_boost, slice_len=12,
    )
    gen = np.asarray(gen)
    iters = int(iters)
    assert iters < 12
    assert (gen[0, iters:] == M.PAD_ID).all()
    assert M.EOS_ID in gen[0, :iters]


def test_invalid_tokens_after_eos():
    """With multiple rows, a row that hits EOS early keeps generating until
    the batch finishes — static-batching invalid tokens (§2.4)."""
    cfg = M.ModelConfig(eos_alpha=0.0)  # rows never EOS naturally...
    params = M.init_params(cfg)
    # ...except we can't force one row to EOS without the boost; instead use
    # the default config and scan many seeds for the pattern.
    found = False
    for seed in range(12):
        toks = make_batch([9, 9], 16, seed=100 + seed)
        gen, iters = M.prefill_and_generate(
            PARAMS, jnp.asarray(toks), jnp.asarray([9, 9], jnp.int32),
            jnp.asarray([1, 1], jnp.int32), None, cfg=CFG, slice_len=12,
        )
        gen, iters = np.asarray(gen), int(iters)
        for row in gen:
            eos_pos = np.where(row[:iters] == M.EOS_ID)[0]
            if len(eos_pos) and eos_pos[0] < iters - 1:
                # tokens exist after the first EOS => invalid tokens generated
                found = True
        if found:
            break
    assert found, "no row exhibited post-EOS generation in 12 seeds"


def test_prefix_consistency_across_slice_lengths():
    """The first min(S1,S2) tokens must agree between slice lengths, until an
    early return interferes (greedy decoding is prefix-stable)."""
    toks = make_batch([14], 16, seed=6)
    g4, i4 = run_cached(toks, [14], [1], 4)
    g8, i8 = run_cached(toks, [14], [1], 8)
    k = min(i4, i8, 4)
    np.testing.assert_array_equal(g4[0, :k], g8[0, :k])


def test_reschedule_prefill_recompute_consistency():
    """Serving 2 slices with re-prefill (the SCLS reschedule path: input +
    generated-so-far re-fed as a longer input) must equal serving one long
    slice, when no early return truncates the first slice."""
    l0, s = 8, 4
    toks = make_batch([l0], l0, seed=7)
    g1, i1 = run_cached(toks, [l0], [1], s)
    if i1 < s or M.EOS_ID in g1[0]:
        pytest.skip("first slice ended early for this seed")
    # Reschedule: new input = original + generated, left-padded into L=16,
    # with gen_offset carrying the EOS-boost progression across slices.
    new_len = l0 + s
    toks2 = np.zeros((1, 16), np.int32)
    toks2[0, 16 - new_len: 16 - s] = toks[0]
    toks2[0, 16 - s:] = g1[0]
    g2, _ = run_cached(toks2, [new_len], [1], s, gen_offset=[s])
    # One long slice of 2s tokens from the original input:
    toks_l = np.zeros((1, 16), np.int32)
    toks_l[0, 16 - l0:] = toks[0]
    g_long, i_long = run_cached(toks_l, [l0], [1], 2 * s)
    np.testing.assert_array_equal(g1[0], g_long[0, :s])
    k = min(4, i_long - s) if i_long > s else 0
    if k > 0:
        np.testing.assert_array_equal(g2[0, :k], g_long[0, s:s + k])


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 4),
    l=st.sampled_from([8, 16]),
    s=st.sampled_from([4, 8]),
    seed=st.integers(0, 1000),
    data=st.data(),
)
def test_hypothesis_cached_vs_ref(n, l, s, seed, data):
    lengths = data.draw(st.lists(st.integers(1, l), min_size=n, max_size=n))
    toks = make_batch(lengths, l, seed=seed)
    active = np.ones(n, np.int32)
    gen, iters = run_cached(toks, lengths, active, s)
    ref, ref_iters = M.generate_ref(
        PARAMS, toks, np.asarray(lengths, np.int32), active, cfg=CFG, slice_len=s
    )
    assert iters == ref_iters
    np.testing.assert_array_equal(gen, ref)


def test_kv_bytes_per_token():
    # 2 layers * 2 (K+V) * 128 dims * 4 bytes = 2048 B/token
    assert CFG.kv_bytes_per_token == 2048
