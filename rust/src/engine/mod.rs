//! Engines: the LLM-instance substrates the scheduler serves against.
//!
//! * `latency` — the calibrated A100/LLaMA2-13B latency surfaces (Eq. 3/4
//!   forms) for the HF- and DS-like engines.
//! * `presets` — per-engine bundles: latency + memory rule + the paper's
//!   experimental constants (fixed SLS batch size, Γ).
//! * `sim` — virtual-time static-batching engine driven by the latency
//!   model and the trace's generation-length oracle.
//! * `continuous` — iteration-level continuous-batching engine used by the
//!   ILS baseline (DeepSpeed-FastGen-like).
//! * `continuous_scls` — slice-capped continuous batching with precise
//!   per-slice memory admission: the paper's §7 extension (SCLS on a
//!   vLLM-style engine).
//! * `continuous_pred` — prediction-reserved continuous batching: KV
//!   admission against predicted demand with eviction-based mispredict
//!   recovery (the P-CB substrate).
//! * `real` — PJRT-backed execution of the AOT tiny-GPT artifacts.

pub mod continuous;
pub mod continuous_pred;
pub mod continuous_scls;
pub mod latency;
pub mod presets;
pub mod real;
pub mod sim;

pub use latency::EngineLatency;
pub use presets::{EngineKind, EnginePreset};
pub use sim::SimEngine;
