//! Properties of the generation-length-prediction subsystem and the
//! prediction-aware policies built on it (P-SCLS, P-CB):
//!
//! 1. **No-OOM under any error draw** — P-CB's projected KV never exceeds
//!    the budget, across randomized predictors, error magnitudes, cluster
//!    shapes, and deliberately tight budgets that force eviction-based
//!    recovery (≥ 200 randomized cases).
//! 2. **Oracle P-SCLS pass bound** — with perfect predictions every
//!    request completes in at most as many slice passes as baseline SCLS
//!    takes on the same fixed-seed trace.
//! 3. **Acceptance throughput shape** — P-CB with the oracle beats
//!    baseline SCLS-CB on the default CodeFuse configuration (rate 20,
//!    600 s, 4 workers), and heavy prediction noise does not come for
//!    free.
//! 4. **Online refit** — `OnlineBuckets` converges to the static
//!    `BucketClassifier` fit on a stationary workload, and refitting
//!    mid-run never breaks the P-CB no-OOM invariant.
//! 5. **Predicted DP correction** — with the oracle predictor and
//!    `pred_corrected_dp`, P-SCLS's serve estimates track actual serving
//!    strictly better than the full-budget estimates, without losing
//!    throughput on the acceptance cell.

use std::collections::HashMap;

use scls::engine::presets::{EngineKind, EnginePreset};
use scls::metrics::NullSink;
use scls::predictor::PredictorSpec;
use scls::scheduler::spec::SchedulerSpec;
use scls::sim::driver::{run_p_cb, run_p_scls, run_policy, run_scls_cb, run_sliced, SimConfig};
use scls::sim::policies::PredictiveCbPolicy;
use scls::testprop::{check, Gen};
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};
use scls::{prop_assert, prop_assert_eq};

fn trace(kind: WorkloadKind, rate: f64, duration: f64, seed: u64) -> Trace {
    Trace::generate(&TraceConfig {
        kind,
        rate,
        duration,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed,
    })
}

fn cfg(workers: usize, kind: EngineKind, seed: u64) -> SimConfig {
    SimConfig::new(workers, EnginePreset::paper(kind), 1024, seed)
}

// ---------------------------------------------------------------------------
// 1. No-OOM KV-budget invariant under arbitrary prediction error
// ---------------------------------------------------------------------------

#[test]
fn p_cb_never_exceeds_kv_budget_under_any_error_draw() {
    // ≥ 200 randomized draws (ISSUE acceptance): predictors of every
    // fidelity, tight budgets that make reservations collide, and error
    // magnitudes up to e^{2z}.
    check("p-cb-no-oom", 200, |g: &mut Gen| {
        let rate = *g.pick(&[2.0, 5.0, 10.0]);
        let workers = *g.pick(&[1usize, 2, 4]);
        let seed = g.u64();
        let predictor = match g.usize(0, 4) {
            0 => PredictorSpec::Oracle,
            1 => PredictorSpec::Noisy {
                sigma: *g.pick(&[0.1, 0.5, 1.0, 2.0]),
            },
            2 => PredictorSpec::Bucket {
                buckets: *g.pick(&[2u32, 4, 8]),
                accuracy: *g.pick(&[0.5, 0.85, 1.0]),
                workload: WorkloadKind::CodeFuse,
            },
            3 => PredictorSpec::Online {
                window: *g.pick(&[64usize, 256, 1024]),
                buckets: *g.pick(&[2u32, 4, 8]),
                accuracy: *g.pick(&[0.5, 0.85, 1.0]),
                workload: WorkloadKind::CodeFuse,
            },
            _ => PredictorSpec::Percentile {
                pct: *g.pick(&[50.0, 90.0, 99.0]),
                workload: WorkloadKind::CodeFuse,
            },
        };
        let mut c = cfg(workers, EngineKind::Ds, seed).with_predictor(predictor);
        // Tight budgets: a few thousand KV token-slots instead of ~56k, so
        // reservations collide and the recovery path actually runs. Every
        // budget still holds one worst-case request (input 1024 + cap
        // 1024 ≤ 0.9 · m_ava / Δ), so no request is unservable.
        let budget_tokens = *g.pick(&[4096u64, 6144, 16384]);
        c.engine.m_ava = budget_tokens * c.engine.kv_delta;
        let t = trace(WorkloadKind::CodeFuse, rate, 25.0, seed);
        let mut policy =
            PredictiveCbPolicy::new(&c, c.predictor.build(c.max_gen_len, c.seed));
        let m = run_policy(&t, &mut policy, c.workers, &mut NullSink);
        prop_assert_eq!(m.completed.len(), t.len(), "requests lost");
        prop_assert!(
            policy.max_kv_observed() <= policy.kv_budget(),
            "P-CB projected KV past the budget: {} > {} ({:?})",
            policy.max_kv_observed(),
            policy.kv_budget(),
            c.predictor
        );
        if !t.is_empty() {
            prop_assert!(policy.max_kv_observed() > 0, "invariant never exercised");
        }
        // Recovery accounting is consistent: every completion happened.
        prop_assert!(
            m.completed.iter().all(|r| r.generated >= 1),
            "empty generation recorded"
        );
        Ok(())
    });
}

#[test]
fn p_cb_tight_budget_exercises_recovery() {
    // A deliberately under-predicting predictor on a tight budget must
    // take the eviction path and still drain cleanly.
    let seed = 4242;
    let mut c = cfg(2, EngineKind::Ds, seed).with_predictor(PredictorSpec::Percentile {
        pct: 25.0,
        workload: WorkloadKind::CodeFuse,
    });
    c.engine.m_ava = 6144 * c.engine.kv_delta;
    let t = trace(WorkloadKind::CodeFuse, 6.0, 40.0, seed);
    let m = run_p_cb(&t, &c);
    assert_eq!(m.completed.len(), t.len());
    assert!(
        m.underpredicted > 0,
        "p25 predictions must under-predict the upper three quarters"
    );
    // Recovery means extra admissions: slices > 1 for evicted requests.
    assert!(m.completed.iter().any(|r| r.slices > 1));
}

// ---------------------------------------------------------------------------
// 2. Oracle P-SCLS: never more slice passes than baseline SCLS
// ---------------------------------------------------------------------------

#[test]
fn oracle_p_scls_takes_at_most_scls_passes() {
    for (rate, duration, seed) in [(4.0, 30.0, 901), (8.0, 45.0, 902), (12.0, 30.0, 903)] {
        let t = trace(WorkloadKind::CodeFuse, rate, duration, seed);
        let c = cfg(4, EngineKind::Ds, seed); // predictor defaults to Oracle
        let preset = EnginePreset::paper(EngineKind::Ds);
        let p = run_p_scls(&t, &c, 128);
        let s = run_sliced(&t, &SchedulerSpec::scls(&preset, 128), &c);
        assert_eq!(p.completed.len(), t.len(), "P-SCLS lost requests");
        assert_eq!(s.completed.len(), t.len(), "SCLS lost requests");
        let scls_passes: HashMap<u64, u32> =
            s.completed.iter().map(|r| (r.id, r.slices)).collect();
        for r in &p.completed {
            let baseline = scls_passes[&r.id];
            assert!(
                r.slices <= baseline,
                "req {} took {} P-SCLS passes vs {} SCLS passes (seed {seed})",
                r.id,
                r.slices,
                baseline
            );
        }
        // Oracle seeding lands every request at its exact rung: one pass.
        assert!(p.completed.iter().all(|r| r.slices == 1));
        assert_eq!(p.underpredicted, 0, "oracle must never requeue");
    }
}

#[test]
fn noisy_p_scls_recovers_underpredictions() {
    let seed = 905;
    let t = trace(WorkloadKind::CodeFuse, 6.0, 40.0, seed);
    let c = cfg(4, EngineKind::Ds, seed)
        .with_predictor(PredictorSpec::Noisy { sigma: 1.0 });
    let m = run_p_scls(&t, &c, 128);
    assert_eq!(m.completed.len(), t.len(), "recovery must complete everything");
    assert!(m.underpredicted > 0, "sigma 1.0 must under-predict some requests");
    assert!(
        m.completed.iter().all(|r| r.generated >= 1),
        "every request generated"
    );
}

// ---------------------------------------------------------------------------
// 3. Acceptance throughput shape (default CodeFuse configuration)
// ---------------------------------------------------------------------------

#[test]
fn oracle_p_cb_beats_scls_cb_on_default_codefuse_trace() {
    // ISSUE acceptance: rate 20, 600 s, 4 workers, default CodeFuse trace.
    let t = trace(WorkloadKind::CodeFuse, 20.0, 600.0, 42);
    let c = cfg(4, EngineKind::Ds, 42);
    let p = run_p_cb(&t, &c);
    let b = run_scls_cb(&t, &c, 128);
    assert_eq!(p.completed.len(), t.len());
    assert_eq!(b.completed.len(), t.len());
    let pt = p.summarize().throughput;
    let bt = b.summarize().throughput;
    assert!(
        pt > bt,
        "P-CB (oracle) {pt} must beat SCLS-CB {bt}: exact reservations avoid \
         every slice-exit re-prefill"
    );
    assert_eq!(p.underpredicted, 0);
    assert_eq!(p.overpredicted, 0);
    assert_eq!(p.wasted_kv_token_steps, 0);
}

// ---------------------------------------------------------------------------
// 4. Online refit: convergence + invariants under refitting
// ---------------------------------------------------------------------------

#[test]
fn online_buckets_converge_to_static_fit_on_stationary_workload() {
    use scls::core::Request;
    use scls::predictor::{BucketClassifier, LengthPredictor, OnlineBuckets};
    use scls::util::rng::Rng;

    let dist = WorkloadKind::CodeFuse.gen_dist(1024);
    let stat = BucketClassifier::fit_distribution(&dist, 8, 1.0, 7);
    let mut online = OnlineBuckets::cold(8, 1.0, 4096, 7, 1024);
    let mut rng = Rng::new(1234);
    for id in 0..20_000u64 {
        let len = dist.sample(&mut rng);
        online.observe(&Request::new(id, 0.0, 64, len), len);
    }
    assert!(online.refits() > 0);
    let se = stat.edges();
    let oe = online.edges();
    assert_eq!(
        oe.len(),
        se.len(),
        "same workload, same bucket count: {oe:?} vs {se:?}"
    );
    // Each refitted quantile edge must sit near the offline fit's (both
    // are finite-sample quantiles of the same distribution; the online
    // window is 4096 samples, so allow generous sampling slack).
    for (o, s) in oe.iter().zip(se) {
        let tol = (0.2 * *s as f64).max(16.0);
        assert!(
            (*o as f64 - *s as f64).abs() <= tol,
            "edge {o} vs static {s} beyond tolerance {tol} ({oe:?} vs {se:?})"
        );
    }
}

#[test]
fn online_refit_never_breaks_p_cb_no_oom() {
    // The dedicated online arm of the invariant: tight budgets, a cold
    // online predictor that refits throughout the run, eviction recovery
    // in play — projected KV must never pass the budget and every request
    // must drain.
    for (seed, window) in [(11u64, 64usize), (12, 256), (13, 1024)] {
        let mut c = cfg(2, EngineKind::Ds, seed).with_predictor(PredictorSpec::Online {
            window,
            buckets: 8,
            accuracy: 0.85,
            workload: WorkloadKind::CodeFuse,
        });
        c.engine.m_ava = 6144 * c.engine.kv_delta;
        let t = trace(WorkloadKind::CodeFuse, 8.0, 40.0, seed);
        let mut policy = PredictiveCbPolicy::new(&c, c.predictor.build(c.max_gen_len, c.seed));
        let m = run_policy(&t, &mut policy, c.workers, &mut NullSink);
        assert_eq!(m.completed.len(), t.len(), "requests lost (window {window})");
        assert!(
            policy.max_kv_observed() <= policy.kv_budget(),
            "online P-CB projected KV past the budget: {} > {}",
            policy.max_kv_observed(),
            policy.kv_budget()
        );
        assert!(
            m.predictor_refits > 0,
            "a {}-request run must refit a window-{window} predictor",
            t.len()
        );
    }
}

#[test]
fn p_scls_online_predictor_completes_and_refits() {
    let seed = 907;
    let t = trace(WorkloadKind::CodeFuse, 8.0, 60.0, seed);
    let c = cfg(4, EngineKind::Ds, seed).with_predictor(PredictorSpec::Online {
        window: 256,
        buckets: 8,
        accuracy: 0.85,
        workload: WorkloadKind::CodeFuse,
    });
    let m = run_p_scls(&t, &c, 128);
    assert_eq!(m.completed.len(), t.len(), "online P-SCLS lost requests");
    assert!(m.predictor_refits > 0, "completions must drive refits");
}

// ---------------------------------------------------------------------------
// 5. Predicted early-return correction in the DP batcher
// ---------------------------------------------------------------------------

#[test]
fn corrected_dp_tracks_actual_serving_and_keeps_throughput() {
    // ISSUE acceptance cell: rate 20, 600 s, 4 workers, oracle predictor.
    let t = trace(WorkloadKind::CodeFuse, 20.0, 600.0, 42);
    let base = cfg(4, EngineKind::Ds, 42); // predictor defaults to Oracle
    let corr = cfg(4, EngineKind::Ds, 42).with_pred_corrected_dp(true);
    let mu = run_p_scls(&t, &base, 128);
    let mc = run_p_scls(&t, &corr, 128);
    assert_eq!(mu.completed.len(), t.len());
    assert_eq!(mc.completed.len(), t.len());
    assert!(mc.corrected_batches > 0, "oracle predictions sit below rung caps");
    assert_eq!(base.predictor, corr.predictor, "only the correction differs");

    // The mechanism: with exact predictions the corrected estimate is the
    // estimator evaluated at the true early-return length, so the
    // systematic rung-rounding overestimate disappears and only latency
    // jitter remains. Mean |est − actual| must shrink.
    let mean_err = |m: &scls::metrics::RunMetrics| {
        m.batches
            .iter()
            .map(|b| (b.est_serve_time - b.actual_serve_time).abs())
            .sum::<f64>()
            / m.batches.len().max(1) as f64
    };
    let eu = mean_err(&mu);
    let ec = mean_err(&mc);
    assert!(
        ec < eu,
        "corrected estimates must track serving better: {ec} !< {eu}"
    );

    // And honest estimates must not cost throughput (the acceptance bar
    // is corrected ≥ uncorrected; allow a sliver of simulation noise).
    let tu = mu.summarize().throughput;
    let tc = mc.summarize().throughput;
    assert!(
        tc >= tu * 0.99,
        "corrected P-SCLS {tc} lost throughput vs uncorrected {tu}"
    );
}

#[test]
fn p_cb_noise_is_not_free() {
    // The figure sweep's monotone-degradation claim, spot-checked at its
    // endpoints: heavy prediction error can't beat the exact oracle by
    // more than simulation noise.
    let t = trace(WorkloadKind::CodeFuse, 20.0, 120.0, 77);
    let c0 = cfg(4, EngineKind::Ds, 77); // oracle
    let c1 = cfg(4, EngineKind::Ds, 77)
        .with_predictor(PredictorSpec::Noisy { sigma: 1.0 });
    let exact = run_p_cb(&t, &c0);
    let noisy = run_p_cb(&t, &c1);
    assert_eq!(noisy.completed.len(), t.len());
    assert!(noisy.underpredicted > 0, "sigma 1.0 must trigger recovery");
    let te = exact.summarize().throughput;
    let tn = noisy.summarize().throughput;
    assert!(
        tn <= te * 1.02,
        "noisy predictions ({tn}) must not beat the oracle ({te}) beyond noise"
    );
}
