//! Deterministic PRNG + sampling distributions.
//!
//! The offline registry has no `rand` crate, so this implements
//! xoshiro256** (Blackman/Vigna) plus the distributions the workload
//! generator and the DES jitter model need: uniform, normal (Box–Muller),
//! lognormal, exponential, and Poisson (Knuth / PTRS-lite).
//!
//! Everything in the repo that consumes randomness takes an explicit
//! `&mut Rng`, so every experiment is reproducible from a single seed.

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via splitmix64 so low-entropy seeds still give full state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent child stream (for per-worker/per-phase RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as u32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (caches the spare deviate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 (log of zero).
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given rate (mean 1/rate) — Poisson inter-arrivals.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Poisson-distributed count. Knuth for small lambda, normal
    /// approximation (continuity-corrected, clamped) for large.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal_ms(lambda, lambda.sqrt()).round();
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = (self.next_u64() % (i as u64 + 1)) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_u32_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range_u32(5, 9);
            assert!((5..=9).contains(&x));
        }
        assert_eq!(r.range_u32(3, 3), 3);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let rate = 20.0;
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.002, "mean {mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(17);
        for &lam in &[0.5, 3.0, 50.0] {
            let n = 50_000;
            let mean = (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < lam * 0.05 + 0.05, "lam {lam} mean {mean}");
        }
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(19);
        for _ in 0..1000 {
            assert!(r.lognormal(3.0, 1.0) > 0.0);
        }
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(23);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(31);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
