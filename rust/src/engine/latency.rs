//! Calibrated latency surfaces for the simulated A100/LLaMA2-13B engines.
//!
//! The DES ground truth uses the same bilinear forms the paper fits
//! (Eq. 3/4) — that is not circular: the paper *demonstrates* those forms
//! match the engines (Fig. 8/9 linearity, Fig. 10 negligible RMSE), so a
//! simulator with bilinear truth + noise reproduces the estimation problem
//! faithfully. Profiling noise (multiplicative lognormal jitter) is applied
//! per measurement, so fitted estimators carry realistic error that
//! accumulates over iterations exactly as Fig. 10b describes.
//!
//! Calibration anchors (see DESIGN.md §Calibration):
//! * DS prefill: T(1,64) ≈ 30 ms, T(8,1024) ≈ 1.35 s (Fig. 8 magnitudes).
//! * DS decode:  τ(64,1) ≈ 20 ms, τ(2048,12) ≈ 45 ms (Fig. 9 magnitudes).
//! * HF ≈ 2.6× DS ("DS leverages customized CUDA kernels ... latency bases
//!   much smaller", §4.2).

use crate::estimator::profiler::LatencySource;
use crate::estimator::serving_time::LinearLatency;
use crate::util::rng::Rng;

/// Ground-truth latency model of one engine on one GPU.
#[derive(Debug, Clone)]
pub struct EngineLatency {
    pub prefill: LinearLatency,
    pub decode: LinearLatency,
    /// Multiplicative noise sigma (lognormal), e.g. 0.03 = ±3%.
    pub jitter: f64,
    rng: Rng,
}

impl EngineLatency {
    pub fn new(prefill: LinearLatency, decode: LinearLatency, jitter: f64, seed: u64) -> Self {
        EngineLatency {
            prefill,
            decode,
            jitter,
            rng: Rng::new(seed),
        }
    }

    /// Deepspeed-inference-like (fast CUDA kernels).
    pub fn ds(seed: u64) -> EngineLatency {
        EngineLatency::new(
            LinearLatency {
                c1: 1.458e-4 / 1.0,
                c2: 6.7e-4,
                c3: 1.354e-4,
                c4: 0.0113,
            },
            LinearLatency {
                c1: 5.04e-7,
                c2: 6.95e-4,
                c3: 2.52e-6,
                c4: 0.0191,
            },
            0.03,
            seed,
        )
    }

    /// Huggingface-transformers-like (pure PyTorch, ~2.6× slower bases).
    pub fn hf(seed: u64) -> EngineLatency {
        let ds = EngineLatency::ds(seed);
        let scale = |l: LinearLatency| LinearLatency {
            c1: l.c1 * 2.6,
            c2: l.c2 * 2.6,
            c3: l.c3 * 2.6,
            c4: l.c4 * 2.6,
        };
        EngineLatency::new(scale(ds.prefill), scale(ds.decode), 0.05, seed)
    }

    fn jittered(&mut self, base: f64) -> f64 {
        if self.jitter == 0.0 { // scls-lint: allow(float-cmp): exact zero = no-jitter sentinel
            return base;
        }
        base * self.rng.lognormal(0.0, self.jitter)
    }

    /// Noise-free prefill latency.
    pub fn prefill_mean(&self, n: u32, l_i: u32) -> f64 {
        self.prefill.eval(n as f64, l_i as f64).max(0.0)
    }

    /// Noise-free per-iteration decode latency.
    pub fn decode_iter_mean(&self, l: u32, n: u32) -> f64 {
        self.decode.eval(n as f64, l as f64).max(0.0)
    }

    /// Noise-free total decode time for `iters` iterations after `l_i`
    /// cached tokens (closed-form arithmetic series).
    pub fn decode_total_mean(&self, n: u32, l_i: u32, iters: u32) -> f64 {
        if iters == 0 {
            return 0.0;
        }
        let (nf, li, lo) = (n as f64, l_i as f64, iters as f64);
        let sum_l = lo * (2.0 * li + lo + 1.0) / 2.0;
        ((self.decode.c1 * nf + self.decode.c3) * sum_l
            + (self.decode.c2 * nf + self.decode.c4) * lo)
            .max(0.0)
    }

    /// Jittered total serving time for one static-batching slice.
    pub fn serve_sample(&mut self, n: u32, l_i: u32, iters: u32) -> f64 {
        let base = self.prefill_mean(n, l_i) + self.decode_total_mean(n, l_i, iters);
        self.jittered(base)
    }
}

impl LatencySource for EngineLatency {
    fn measure_prefill(&mut self, n: u32, l_i: u32) -> f64 {
        let base = self.prefill_mean(n, l_i);
        self.jittered(base)
    }

    fn measure_decode_iter(&mut self, l: u32, n: u32) -> f64 {
        let base = self.decode_iter_mean(l, n);
        self.jittered(base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ds_anchors_plausible() {
        let m = EngineLatency::ds(0);
        let t = m.prefill_mean(8, 1024);
        assert!((1.0..1.8).contains(&t), "prefill(8,1024) = {t}");
        let t1 = m.prefill_mean(1, 64);
        assert!((0.01..0.06).contains(&t1), "prefill(1,64) = {t1}");
        let d = m.decode_iter_mean(2048, 12);
        assert!((0.03..0.06).contains(&d), "decode(2048,12) = {d}");
        let d1 = m.decode_iter_mean(64, 1);
        assert!((0.015..0.03).contains(&d1), "decode(64,1) = {d1}");
    }

    #[test]
    fn hf_slower_than_ds() {
        let hf = EngineLatency::hf(0);
        let ds = EngineLatency::ds(0);
        assert!(hf.prefill_mean(8, 512) > 2.0 * ds.prefill_mean(8, 512));
        assert!(hf.decode_iter_mean(512, 8) > 2.0 * ds.decode_iter_mean(512, 8));
    }

    #[test]
    fn closed_form_matches_loop() {
        let m = EngineLatency::ds(0);
        let total = m.decode_total_mean(8, 200, 128);
        let mut acc = 0.0;
        for l in 201..=328 {
            acc += m.decode_iter_mean(l, 8);
        }
        assert!((total - acc).abs() < 1e-9 * acc);
    }

    #[test]
    fn jitter_centered_on_mean() {
        let mut m = EngineLatency::ds(7);
        let base = m.prefill_mean(4, 256) + m.decode_total_mean(4, 256, 64);
        let n = 4000;
        let mean: f64 = (0..n).map(|_| m.serve_sample(4, 256, 64)).sum::<f64>() / n as f64;
        assert!((mean / base - 1.0).abs() < 0.01, "ratio {}", mean / base);
    }

    #[test]
    fn zero_jitter_deterministic() {
        let mut m = EngineLatency::new(
            EngineLatency::ds(0).prefill,
            EngineLatency::ds(0).decode,
            0.0,
            0,
        );
        let a = m.serve_sample(4, 128, 32);
        let b = m.serve_sample(4, 128, 32);
        assert_eq!(a, b);
    }
}
