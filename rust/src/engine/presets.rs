//! Per-engine experiment presets mirroring the paper's §5.1 settings.

use crate::estimator::memory::MemoryEstimator;

use super::latency::EngineLatency;

/// Which inference engine a worker runs (paper: HF v4.35.0, DS v0.13.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// huggingface-transformers: flexible batch sizes, analytic memory rule
    /// with fragmentation coefficient ζ (Eq. 9).
    Hf,
    /// deepspeed-inference: fast kernels, inflexible memory management →
    /// profiled rule table (Algorithm 2).
    Ds,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.to_ascii_lowercase().as_str() {
            "hf" | "huggingface" => Some(EngineKind::Hf),
            "ds" | "deepspeed" => Some(EngineKind::Ds),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Hf => "HF",
            EngineKind::Ds => "DS",
        }
    }
}

/// Everything the schedulers need to know about an engine deployment.
#[derive(Debug, Clone)]
pub struct EnginePreset {
    pub kind: EngineKind,
    /// Fixed batch size SLS uses to avoid OOM (paper: HF 16, DS 12).
    pub sls_batch_size: u32,
    /// Minimal schedule interval Γ (paper: HF 6 s, DS 3 s).
    pub gamma: f64,
    /// Adaptive-interval factor λ (paper: 0.5).
    pub lambda: f64,
    /// Per-token KV bytes Δ (Eq. 5). LLaMA2-13B fp16: 2 (K+V) × 40 layers
    /// × 5120 dim × 2 B = 800 KiB/token.
    pub kv_delta: u64,
    /// KV-cache budget M_ava (Eq. 6): 80 GB − 26 GB weights − engine state.
    pub m_ava: u64,
    /// ILS *effective* parallel-decode cap (DS/FastGen only).
    ///
    /// The paper attributes FastGen's low throughput to "a conservative
    /// memory management mechanism that limits the number of
    /// parallel-processing requests" (§3.1) but does not report the
    /// configuration; its measured numbers imply FastGen's throughput was
    /// only slightly above fixed-batch-12 SLS. This constant is therefore
    /// calibrated so the reproduced SCLS/ILS throughput ratio falls inside
    /// the paper's reported +61.6%..+171.0% band across rates 12–28
    /// (see EXPERIMENTS.md §Fig12); with the Eq. (4) latency surface that
    /// lands at an effective parallelism of 3.
    pub ils_max_parallel: u32,
}

const GIB: u64 = 1 << 30;

impl EnginePreset {
    pub fn paper(kind: EngineKind) -> EnginePreset {
        match kind {
            EngineKind::Hf => EnginePreset {
                kind,
                sls_batch_size: 16,
                gamma: 6.0,
                lambda: 0.5,
                kv_delta: 800 * 1024,
                m_ava: 48 * GIB,
                ils_max_parallel: 0, // paper only runs ILS on DS
            },
            EngineKind::Ds => EnginePreset {
                kind,
                sls_batch_size: 12,
                gamma: 3.0,
                lambda: 0.5,
                kv_delta: 800 * 1024,
                m_ava: 48 * GIB,
                ils_max_parallel: 3,
            },
        }
    }

    /// The engine's OOM-feasibility rule (paper §4.3).
    pub fn memory_estimator(&self) -> MemoryEstimator {
        match self.kind {
            EngineKind::Hf => MemoryEstimator::analytic(self.kv_delta, self.m_ava, 0.9),
            EngineKind::Ds => MemoryEstimator::ds_rules(),
        }
    }

    /// Ground-truth latency model for one worker (`seed` decorrelates
    /// per-worker jitter streams).
    pub fn latency(&self, seed: u64) -> EngineLatency {
        match self.kind {
            EngineKind::Hf => EngineLatency::hf(seed),
            EngineKind::Ds => EngineLatency::ds(seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants() {
        let hf = EnginePreset::paper(EngineKind::Hf);
        assert_eq!(hf.sls_batch_size, 16);
        assert_eq!(hf.gamma, 6.0);
        let ds = EnginePreset::paper(EngineKind::Ds);
        assert_eq!(ds.sls_batch_size, 12);
        assert_eq!(ds.gamma, 3.0);
    }

    #[test]
    fn sls_fixed_batch_is_oom_safe_at_max_lengths() {
        // The paper chose 16/12 to avoid OOM at L_i = L_o = 1024.
        for kind in [EngineKind::Hf, EngineKind::Ds] {
            let p = EnginePreset::paper(kind);
            let mem = p.memory_estimator();
            assert!(
                !mem.would_oom(p.sls_batch_size, 1024, 1024),
                "{kind:?} SLS batch size OOMs"
            );
        }
    }

    #[test]
    fn parse_kind() {
        assert_eq!(EngineKind::parse("hf"), Some(EngineKind::Hf));
        assert_eq!(EngineKind::parse("DS"), Some(EngineKind::Ds));
        assert_eq!(EngineKind::parse("vllm"), None);
    }
}
