//! Per-worker time-series gauges and load-imbalance indices.
//!
//! The paper's load-balance claim is about *distributions over time* —
//! per-worker KV occupancy, queue depth, busy fraction — not just the
//! end-of-run completion-time spread. [`TimeSeriesSink`] is a
//! [`MetricsSink`] that bins the run's per-worker observations into
//! fixed-interval gauges (memory O(workers · duration/dt), independent of
//! request count) and folds them into an [`ImbalanceReport`]:
//!
//! * **Jain's fairness index** `(Σx)² / (n·Σx²)` — 1.0 is perfectly
//!   balanced, `1/n` is one worker doing everything;
//! * **max/mean** — how far the hottest worker runs above the average;
//! * **CV** (coefficient of variation, σ/μ) — the spread the paper's
//!   CT-std metric approximates, but over *served work* rather than final
//!   completion times.
//!
//! Observations arrive on two hooks: `on_worker_sample` (per serving
//! iteration: decoded tokens, resident KV, queue depth — emitted by every
//! built-in policy through `SimCtx::record_served`) and `on_batch` (busy
//! spans: in the DES the serve duration is known at batch start). The sink
//! never touches `RunMetrics`, so attaching it cannot move a run's
//! deterministic fingerprint.

use crate::metrics::{BatchRecord, MetricsSink};
use crate::util::json::Json;

/// Default gauge sampling interval (seconds of virtual time per bin).
pub const DEFAULT_INTERVAL: f64 = 1.0;

/// One worker's binned gauges plus run totals.
#[derive(Debug, Clone, Default)]
pub struct WorkerSeries {
    /// Per-bin maximum resident KV tokens observed.
    pub kv: Vec<u64>,
    /// Per-bin maximum queue depth observed.
    pub queue: Vec<u64>,
    /// Per-bin busy seconds (serve-span overlap with the bin).
    pub busy: Vec<f64>,
    /// Total decoded tokens served by this worker.
    pub served_tokens: u64,
    /// Total busy seconds (Σ batch serve durations).
    pub busy_time: f64,
    /// Batches this worker served.
    pub batches: u64,
}

/// Load-imbalance indices over a per-worker load vector.
#[derive(Debug, Clone, PartialEq)]
pub struct ImbalanceReport {
    /// Jain's fairness index in `[1/n, 1]`; 1.0 = perfectly balanced.
    pub jains: f64,
    /// Hottest worker's load over the mean (≥ 1.0; 1.0 = balanced).
    pub max_over_mean: f64,
    /// Coefficient of variation σ/μ (0.0 = balanced).
    pub cv: f64,
    /// The per-worker loads the indices were computed from.
    pub per_worker: Vec<f64>,
}

impl ImbalanceReport {
    /// Compute the indices from a per-worker load vector. Workers that
    /// served nothing count as zeros (they are imbalance, not absence).
    pub fn from_loads(loads: &[f64]) -> ImbalanceReport {
        ImbalanceReport {
            jains: jains_fairness(loads),
            max_over_mean: max_over_mean(loads),
            cv: coeff_of_variation(loads),
            per_worker: loads.to_vec(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("jains", self.jains)
            .set("max_over_mean", self.max_over_mean)
            .set("cv", self.cv)
            .set("per_worker", self.per_worker.clone());
        o
    }
}

/// Jain's fairness index `(Σx)²/(n·Σx²)`; 1.0 for empty/all-zero input
/// (nothing served is vacuously balanced).
pub fn jains_fairness(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if n == 0.0 || sq == 0.0 {
        1.0
    } else {
        (sum * sum) / (n * sq)
    }
}

/// Max load over mean load; 1.0 for empty/all-zero input.
pub fn max_over_mean(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    let max = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    if n == 0.0 || sum <= 0.0 {
        1.0
    } else {
        max / (sum / n)
    }
}

/// Coefficient of variation σ/μ (population σ); 0.0 for empty/all-zero.
pub fn coeff_of_variation(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let mean = xs.iter().sum::<f64>() / n;
    if mean <= 0.0 {
        return 0.0;
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    var.sqrt() / mean
}

/// Streaming per-worker time-series collector (see module docs).
#[derive(Debug, Clone)]
pub struct TimeSeriesSink {
    dt: f64,
    workers: Vec<WorkerSeries>,
}

impl Default for TimeSeriesSink {
    fn default() -> Self {
        TimeSeriesSink::new(DEFAULT_INTERVAL)
    }
}

impl TimeSeriesSink {
    /// Collector with a `dt`-second sampling interval.
    pub fn new(dt: f64) -> TimeSeriesSink {
        assert!(dt.is_finite() && dt > 0.0, "interval must be positive");
        TimeSeriesSink {
            dt,
            workers: Vec::new(),
        }
    }

    pub fn interval(&self) -> f64 {
        self.dt
    }

    /// Per-worker series, indexed by worker id (empty entries for workers
    /// that never appeared).
    pub fn workers(&self) -> &[WorkerSeries] {
        &self.workers
    }

    fn worker_mut(&mut self, w: usize) -> &mut WorkerSeries {
        if w >= self.workers.len() {
            self.workers.resize_with(w + 1, WorkerSeries::default);
        }
        &mut self.workers[w]
    }

    fn bin(&self, now: f64) -> usize {
        ((now / self.dt).floor().max(0.0)) as usize
    }

    /// Imbalance indices over total served tokens per worker.
    pub fn served_imbalance(&self) -> ImbalanceReport {
        let loads: Vec<f64> = self.workers.iter().map(|w| w.served_tokens as f64).collect();
        ImbalanceReport::from_loads(&loads)
    }

    /// Imbalance indices over total busy time per worker.
    pub fn busy_imbalance(&self) -> ImbalanceReport {
        let loads: Vec<f64> = self.workers.iter().map(|w| w.busy_time).collect();
        ImbalanceReport::from_loads(&loads)
    }

    /// Per-worker busy *fraction* over `[0, horizon]` (clamped to 1.0 per
    /// worker when spans overlap the horizon edge).
    pub fn busy_fractions(&self, horizon: f64) -> Vec<f64> {
        if !(horizon.is_finite() && horizon > 0.0) {
            return vec![0.0; self.workers.len()];
        }
        self.workers
            .iter()
            .map(|w| (w.busy_time / horizon).min(1.0))
            .collect()
    }

    /// Full per-worker series + indices as JSON (the `figobs` payload).
    pub fn to_json(&self, horizon: f64) -> Json {
        let mut o = Json::obj();
        o.set("interval", self.dt)
            .set("served_imbalance", self.served_imbalance().to_json())
            .set("busy_imbalance", self.busy_imbalance().to_json())
            .set("busy_fractions", self.busy_fractions(horizon));
        let workers: Vec<Json> = self
            .workers
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let mut j = Json::obj();
                j.set("worker", i)
                    .set("served_tokens", w.served_tokens)
                    .set("busy_time", w.busy_time)
                    .set("batches", w.batches)
                    .set("kv_max", Json::Arr(w.kv.iter().map(|&x| Json::from(x)).collect()))
                    .set(
                        "queue_max",
                        Json::Arr(w.queue.iter().map(|&x| Json::from(x)).collect()),
                    )
                    .set("busy", w.busy.clone());
                j
            })
            .collect();
        o.set("workers", Json::Arr(workers));
        o
    }
}

impl MetricsSink for TimeSeriesSink {
    fn on_batch(&mut self, now: f64, rec: &BatchRecord) {
        let dt = self.dt;
        let bin0 = self.bin(now);
        let dur = rec.actual_serve_time.max(0.0);
        let w = self.worker_mut(rec.worker);
        w.batches += 1;
        w.busy_time += dur;
        // Spread the serve span over the bins it overlaps.
        let end = now + dur;
        let bin1 = ((end / dt).floor().max(0.0)) as usize;
        if w.busy.len() <= bin1 {
            w.busy.resize(bin1 + 1, 0.0);
        }
        for (k, slot) in w.busy.iter_mut().enumerate().take(bin1 + 1).skip(bin0) {
            let lo = (k as f64 * dt).max(now);
            let hi = ((k + 1) as f64 * dt).min(end);
            if hi > lo {
                *slot += hi - lo;
            }
        }
    }

    fn on_worker_sample(
        &mut self,
        now: f64,
        worker: usize,
        new_tokens: u64,
        kv_in_use: u64,
        queue_depth: usize,
    ) {
        let bin = self.bin(now);
        let w = self.worker_mut(worker);
        w.served_tokens += new_tokens;
        if w.kv.len() <= bin {
            w.kv.resize(bin + 1, 0);
        }
        w.kv[bin] = w.kv[bin].max(kv_in_use);
        if w.queue.len() <= bin {
            w.queue.resize(bin + 1, 0);
        }
        w.queue[bin] = w.queue[bin].max(queue_depth as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_on_degenerate_inputs() {
        assert_eq!(jains_fairness(&[]), 1.0);
        assert_eq!(jains_fairness(&[0.0, 0.0]), 1.0);
        assert_eq!(max_over_mean(&[]), 1.0);
        assert_eq!(coeff_of_variation(&[]), 0.0);
    }

    #[test]
    fn indices_on_balanced_and_skewed_loads() {
        let balanced = [10.0, 10.0, 10.0, 10.0];
        assert!((jains_fairness(&balanced) - 1.0).abs() < 1e-12);
        assert!((max_over_mean(&balanced) - 1.0).abs() < 1e-12);
        assert!(coeff_of_variation(&balanced).abs() < 1e-12);

        let one_hot = [40.0, 0.0, 0.0, 0.0];
        assert!((jains_fairness(&one_hot) - 0.25).abs() < 1e-12, "1/n");
        assert!((max_over_mean(&one_hot) - 4.0).abs() < 1e-12);
        assert!(coeff_of_variation(&one_hot) > 1.0);

        // More balanced always scores higher on Jain's.
        let mild = [12.0, 11.0, 9.0, 8.0];
        assert!(jains_fairness(&mild) > jains_fairness(&one_hot));
    }

    #[test]
    fn sink_bins_samples_and_busy_spans() {
        let mut ts = TimeSeriesSink::new(1.0);
        ts.on_worker_sample(0.4, 0, 64, 512, 3);
        ts.on_worker_sample(0.9, 0, 32, 800, 1);
        ts.on_worker_sample(2.5, 1, 128, 300, 0);
        // A 1.5 s serve span starting at 0.75 overlaps bins 0, 1, 2.
        ts.on_batch(
            0.75,
            &BatchRecord {
                start: 0.75,
                worker: 0,
                size: 4,
                input_len: 64,
                pad_tokens: 0,
                est_serve_time: 1.4,
                actual_serve_time: 1.5,
                early_return: false,
            },
        );
        let w0 = &ts.workers()[0];
        assert_eq!(w0.served_tokens, 96);
        assert_eq!(w0.kv[0], 800, "bin keeps the max gauge");
        assert_eq!(w0.queue[0], 3);
        assert_eq!(w0.batches, 1);
        assert!((w0.busy_time - 1.5).abs() < 1e-12);
        assert!((w0.busy[0] - 0.25).abs() < 1e-12);
        assert!((w0.busy[1] - 1.0).abs() < 1e-12);
        assert!((w0.busy[2] - 0.25).abs() < 1e-12);
        let w1 = &ts.workers()[1];
        assert_eq!(w1.served_tokens, 128);
        assert_eq!(w1.kv[2], 300);

        let rep = ts.served_imbalance();
        assert_eq!(rep.per_worker, vec![96.0, 128.0]);
        assert!(rep.jains > 0.9 && rep.jains <= 1.0);
        let busy = ts.busy_fractions(3.0);
        assert!((busy[0] - 0.5).abs() < 1e-12);
        assert_eq!(busy[1], 0.0);
    }
}
