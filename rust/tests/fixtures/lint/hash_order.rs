// Lint fixture (never compiled): hash-order positives and suppressions.
// Scanned by tests/props_lint.rs under virtual paths — as a deterministic
// module ("src/sim/fixture.rs") every unsuppressed mention must fire; as
// a non-deterministic module ("src/telemetry/fixture.rs") none may.
use std::collections::HashMap; // line 5: finding
use std::collections::HashSet; // line 6: finding

fn positives() {
    let m: HashMap<u32, u32> = HashMap::new(); // line 9: two findings
    let s = HashSet::from([1u32]); // line 10: finding
    drop((m, s));
}

fn suppressed() {
    let m: HashMap<u32, u32> = HashMap::new(); // scls-lint: allow(hash-order): keyed only, never iterated
    drop(m);
}

fn never_fire() {
    // HashMap in a comment is not a finding.
    let s = "HashMap in a string is not a finding";
    let h = MyHashMapLike::default(); // distinct identifier: no finding
    drop((s, h));
}
