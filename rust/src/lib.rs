//! # SCLS — Slice-Level Scheduling for LLM Serving
//!
//! A production-shaped reproduction of *"Slice-Level Scheduling for High
//! Throughput and Load Balanced LLM Serving"* as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the scheduling system: serving-time estimator
//!   (Eq. 1–4), memory estimator (Eq. 5–9 / Alg. 2), DP adaptive batcher
//!   (Alg. 1), max-min offloader, adaptive schedule interval (Eq. 12), plus
//!   the SLS/ILS baselines and the SO/PM/AB/LB ablations.
//! * **L2/L1 (python/compile, build-time only)** — a tiny-GPT decoder with
//!   Pallas attention kernels, AOT-lowered to HLO text per (N, L, S)
//!   bucket; `runtime` loads and executes them via PJRT. Python never runs
//!   on the request path.
//!
//! Scheduling is unified behind one open API: every scheduler — the
//! paper's eight, the prediction-aware P-SCLS/P-CB pair, plus yours — is a
//! [`scheduler::SchedulingPolicy`] run by
//! the single generic DES loop ([`sim::driver::run_policy`]), and the
//! real PJRT cluster shares the same coordinator brain
//! ([`scheduler::SlicedCoordinator`]). Start at [`sim::Simulation`]
//! (virtual-time, paper-scale experiments) or
//! [`worker::real_driver::run_real`] (wall-clock serving of the real
//! model); attach [`metrics::MetricsSink`]s to stream a run's event
//! stream live. `examples/quickstart.rs` is the five-minute tour;
//! `examples/custom_policy.rs` shows a user-defined scheduler in ~20
//! lines.
//!
//! Determinism is load-bearing here (frozen differential suites compare
//! runs byte-for-byte), so the crate ships its own static-analysis pass:
//! [`analysis`], exposed as `scls-repro lint`.

#![forbid(unsafe_code)]

pub mod analysis;
pub mod batcher;
pub mod bench;
pub mod config;
pub mod core;
pub mod engine;
pub mod estimator;
pub mod metrics;
pub mod offloader;
pub mod predictor;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod slo;
pub mod telemetry;
pub mod testprop;
pub mod util;
pub mod worker;
pub mod workload;
