//! Load-balancing demo: max-min offloading vs round-robin (§4.5, Fig. 17).
//!
//! Both schedulers see the same batches; only the offload policy differs.
//! Round-robin ignores the serving-time estimates, so workers that keep
//! drawing long batches fall behind and the per-instance completion times
//! spread out. Max-min (Eq. 11) sends the longest-serving batch to the
//! least-loaded worker, keeping the completion times tight. The paper's
//! point (§3.2) is that the imbalance *accumulates over time*, so this
//! demo runs the full 10-minute trace at saturation — at short durations
//! the two policies are statistically indistinguishable.
//!
//! Run with: `cargo run --release --example load_balance_demo`

use scls::engine::presets::{EngineKind, EnginePreset};
use scls::scheduler::spec::SchedulerSpec;
use scls::sim::driver::{run_sliced, SimConfig};
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};

fn main() {
    let preset = EnginePreset::paper(EngineKind::Ds);
    println!("load_balance_demo: AB (round-robin) vs LB (max-min), 8 DS workers\n");
    println!(
        "{:<10} {:>9} {:>10} {:>12} {:>14}",
        "workload", "policy", "thpt", "avg RT (s)", "CT std (s)"
    );

    for (wl_name, kind) in [
        ("codefuse", WorkloadKind::CodeFuse),
        ("sharegpt", WorkloadKind::ShareGpt),
    ] {
        let trace = Trace::generate(&TraceConfig {
            kind,
            rate: 24.0,
            duration: 600.0,
            max_input_len: 1024,
            max_gen_len: 1024,
            seed: 11,
        });
        let sim = SimConfig::new(8, preset.clone(), 1024, 11);

        // AB and LB differ in exactly one axis: the offload policy.
        let rr = run_sliced(&trace, &SchedulerSpec::adaptive_batching(&preset, 128), &sim)
            .summarize();
        let mm = run_sliced(&trace, &SchedulerSpec::load_balancing(&preset, 128), &sim)
            .summarize();

        for (policy, s) in [("RR", &rr), ("max-min", &mm)] {
            println!(
                "{:<10} {:>9} {:>10.2} {:>12.1} {:>14.2}",
                wl_name, policy, s.throughput, s.avg_response_time, s.ct_std
            );
        }
        println!(
            "{:<10} max-min cuts CT-STD by {:.0}%\n",
            "",
            100.0 * (1.0 - mm.ct_std / rr.ct_std.max(1e-9))
        );
    }

    // Worker-level view on one run: per-instance completion times.
    let trace = Trace::generate(&TraceConfig {
        kind: WorkloadKind::CodeFuse,
        rate: 24.0,
        duration: 600.0,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed: 12,
    });
    let sim = SimConfig::new(8, preset.clone(), 1024, 12);
    let rr = run_sliced(&trace, &SchedulerSpec::adaptive_batching(&preset, 128), &sim);
    let mm = run_sliced(&trace, &SchedulerSpec::load_balancing(&preset, 128), &sim);
    println!("per-worker completion times (s):");
    println!(
        "  round-robin: {:?}",
        rr.worker_completion
            .iter()
            .map(|t| t.round() as i64)
            .collect::<Vec<_>>()
    );
    println!(
        "  max-min:     {:?}",
        mm.worker_completion
            .iter()
            .map(|t| t.round() as i64)
            .collect::<Vec<_>>()
    );
}
